//! Capacity planner: the recommender + cost models as a downstream user
//! would drive them (paper §4.2.1 "configuration recommender" + §3.1
//! Cost) — for each registered model and target SLO/rate, print the top-3
//! configurations with latency, throughput and cloud cost.
//!
//! Run with: `cargo run --release --example capacity_planner`

use inferbench::analysis::recommend;
use inferbench::hardware::{energy, find, roofline, Parallelism};
use inferbench::models::catalog::{self, Task};
use inferbench::util::render;

fn parallelism(task: Task) -> Parallelism {
    match task {
        Task::IC | Task::OD | Task::GAN => Parallelism::cnn(28),
        Task::NLP => Parallelism::sequence(128),
        Task::TC => Parallelism::sequence(64),
    }
}

fn main() {
    // Planning scenarios: (model, latency SLO ms, expected rate rps).
    let scenarios = [
        ("resnet50", 50.0, 200.0),
        ("mobilenet_v1", 20.0, 500.0),
        ("bert_large", 100.0, 60.0),
        ("textlstm", 30.0, 300.0),
    ];

    for (model_name, slo_ms, rate) in scenarios {
        let model = catalog::find(model_name).unwrap();
        let par = parallelism(model.task);
        let rec = recommend(model, par, slo_ms / 1e3, rate, 3);
        println!(
            "\n=== {model_name} — SLO {slo_ms} ms, {rate:.0} rps ({} configs considered) ===",
            rec.considered
        );
        if rec.top.is_empty() {
            println!("  no configuration meets this SLO at this rate — scale out or relax");
            continue;
        }
        let rows: Vec<Vec<String>> = rec
            .top
            .iter()
            .map(|c| {
                let est = roofline::estimate(c.platform, &model.profile, par, c.batch, model.request_bytes);
                let e = energy::energy(c.platform, &est, c.batch);
                vec![
                    c.platform.id.to_string(),
                    c.software.id.to_string(),
                    c.batch.to_string(),
                    render::fmt_duration(c.latency_s),
                    format!("{:.0}", c.throughput_rps),
                    c.cost_per_1k_usd.map(|v| format!("${v:.4}")).unwrap_or("-".into()),
                    format!("{:.2} J", e.joules_per_request),
                    format!("{:.2} mg", e.co2_g_per_request * 1e3),
                ]
            })
            .collect();
        print!(
            "{}",
            render::table(
                &["Platform", "Software", "Batch", "Latency", "Max RPS", "$/1k", "Energy/req", "CO2/req"],
                &rows
            )
        );
    }

    // Sanity panel: what the SLO check protects against — batch-128 V100.
    let rn = catalog::find("resnet50").unwrap();
    let v100 = find("G1").unwrap();
    let big = roofline::estimate(v100, &rn.profile, Parallelism::cnn(28), 128, rn.request_bytes);
    println!(
        "\n(For contrast: resnet50 batch-128 on V100 = {} per batch — great throughput, dead SLO.)",
        render::fmt_duration(big.total_s)
    );
}
