//! End-to-end validation driver (DESIGN.md §6, EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real workload: the Pallas kernels
//! (L1) lowered inside the JAX models (L2) are AOT-compiled to HLO text,
//! loaded by the rust PJRT runtime, and served by the live engine (L3) —
//! request generator -> dynamic batcher -> real XLA execution on CPU —
//! under Poisson load, reporting latency percentiles and throughput.
//!
//! Requires artifacts: `make artifacts` first. Run:
//!   `cargo run --release --example e2e_serving`

use inferbench::serving::live::{run_load, LiveConfig, LiveServer};
use inferbench::serving::Policy;
use inferbench::util::render;

fn serve_one(stem: &str, rate: f64, duration: f64, max_batch: usize) -> anyhow::Result<Vec<String>> {
    eprintln!("== {stem}: loading artifacts (XLA compile + param upload)...");
    let server = LiveServer::start(LiveConfig {
        artifact_dir: "artifacts".into(),
        model_stem: stem.into(),
        policy: Policy::Dynamic { max_size: max_batch, max_wait_s: 0.004 },
        seed: 0,
    })?;
    let coldstart: f64 = server.info.variants.iter().map(|(_, t)| t).sum();
    eprintln!(
        "   cold start (compile all variants): {}",
        render::fmt_duration(coldstart)
    );
    // Warm the executor, then measure under load.
    let _ = run_load(&server, rate.min(10.0), 1.0, 1)?;
    let mut report = run_load(&server, rate, duration, 42)?;
    let row = vec![
        stem.to_string(),
        format!("{rate:.0}"),
        report.completed.to_string(),
        format!("{:.1}", report.throughput_rps()),
        render::fmt_duration(report.e2e.percentile(50.0)),
        render::fmt_duration(report.e2e.percentile(95.0)),
        render::fmt_duration(report.e2e.percentile(99.0)),
        format!("{:.2}", report.batch_sizes.mean()),
        render::fmt_duration(coldstart),
    ];
    server.shutdown()?;
    Ok(row)
}

fn main() -> anyhow::Result<()> {
    println!("InferBench e2e: live CPU serving of AOT-compiled Pallas/JAX models\n");
    let mut rows = Vec::new();
    // (model stem, offered rate rps, duration s, max dynamic batch)
    // Rates chosen near each model's measured single-core capacity so the
    // dynamic batcher actually forms batches.
    for (stem, rate, dur, mb) in [
        ("mlp_d8_w512", 60.0, 15.0, 8),
        ("resnet_mini", 8.0, 15.0, 4),
        ("bert_mini", 8.0, 15.0, 4),
        ("cnn_d4_c32", 12.0, 15.0, 4),
        ("lstm_mini", 15.0, 15.0, 8),
    ] {
        match serve_one(stem, rate, dur, mb) {
            Ok(row) => rows.push(row),
            Err(e) => {
                eprintln!("   {stem} FAILED: {e:#}");
                rows.push(vec![stem.into(), "-".into(), "FAILED".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into()]);
            }
        }
    }
    println!("\nE2E serving results (real XLA execution, Poisson open-loop load):");
    print!(
        "{}",
        render::table(
            &["Model", "Rate", "Done", "RPS", "p50", "p95", "p99", "Mean batch", "Coldstart"],
            &rows
        )
    );
    println!("\nRecord these rows in EXPERIMENTS.md §E2E.");
    Ok(())
}
