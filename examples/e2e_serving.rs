//! End-to-end validation driver (DESIGN.md §6, EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real workload: the Pallas kernels
//! (L1) lowered inside the JAX models (L2) are AOT-compiled to HLO text,
//! loaded by the rust PJRT runtime, and served by the live engine (L3) —
//! request generator -> dynamic batcher -> real XLA execution on CPU —
//! under Poisson load, reporting latency percentiles and throughput.
//!
//! Requires artifacts: `make artifacts` first. Run:
//!   `cargo run --release --example e2e_serving`

use inferbench::coordinator::job::service_model_for;
use inferbench::metrics::ScaleEventKind;
use inferbench::pipeline::{Processors, RequestPath, LAN};
use inferbench::serving::autoscale::{AutoscaleConfig, ScalePolicy};
use inferbench::serving::cluster::{run as run_cluster, ClusterConfig, ReplicaConfig};
use inferbench::serving::live::{run_load, LiveConfig, LiveServer};
use inferbench::serving::{backends, Policy, RouterPolicy, Software};
use inferbench::util::render;
use inferbench::metrics::MetricsMode;
use inferbench::workload::{Pattern, Workload};

fn serve_one(stem: &str, rate: f64, duration: f64, max_batch: usize) -> anyhow::Result<Vec<String>> {
    eprintln!("== {stem}: loading artifacts (XLA compile + param upload)...");
    let server = LiveServer::start(LiveConfig {
        artifact_dir: "artifacts".into(),
        model_stem: stem.into(),
        policy: Policy::Dynamic { max_size: max_batch, max_wait_s: 0.004 },
        seed: 0,
    })?;
    let coldstart: f64 = server.info.variants.iter().map(|(_, t)| t).sum();
    eprintln!(
        "   cold start (compile all variants): {}",
        render::fmt_duration(coldstart)
    );
    // Warm the executor, then measure under load.
    let _ = run_load(&server, rate.min(10.0), 1.0, 1)?;
    let report = run_load(&server, rate, duration, 42)?;
    let row = vec![
        stem.to_string(),
        format!("{rate:.0}"),
        report.completed.to_string(),
        format!("{:.1}", report.throughput_rps()),
        render::fmt_duration(report.e2e.percentile(50.0)),
        render::fmt_duration(report.e2e.percentile(95.0)),
        render::fmt_duration(report.e2e.percentile(99.0)),
        format!("{:.2}", report.batch_sizes.mean()),
        render::fmt_duration(coldstart),
    ];
    server.shutdown()?;
    Ok(row)
}

fn main() -> anyhow::Result<()> {
    println!("InferBench e2e: live CPU serving of AOT-compiled Pallas/JAX models\n");
    let mut rows = Vec::new();
    // (model stem, offered rate rps, duration s, max dynamic batch)
    // Rates chosen near each model's measured single-core capacity so the
    // dynamic batcher actually forms batches.
    for (stem, rate, dur, mb) in [
        ("mlp_d8_w512", 60.0, 15.0, 8),
        ("resnet_mini", 8.0, 15.0, 4),
        ("bert_mini", 8.0, 15.0, 4),
        ("cnn_d4_c32", 12.0, 15.0, 4),
        ("lstm_mini", 15.0, 15.0, 8),
    ] {
        match serve_one(stem, rate, dur, mb) {
            Ok(row) => rows.push(row),
            Err(e) => {
                eprintln!("   {stem} FAILED: {e:#}");
                rows.push(vec![stem.into(), "-".into(), "FAILED".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into()]);
            }
        }
    }
    println!("\nE2E serving results (real XLA execution, Poisson open-loop load):");
    print!(
        "{}",
        render::table(
            &["Model", "Rate", "Done", "RPS", "p50", "p95", "p99", "Mean batch", "Coldstart"],
            &rows
        )
    );
    println!("\nRecord these rows in EXPERIMENTS.md §E2E.");

    cluster_scaleout_section()?;
    autoscale_spike_section()?;
    multimodel_sharing_section()?;
    tracing_section()?;
    Ok(())
}

/// Simulated cluster tier on top of the same serving stack: scale the
/// ResNet50-on-V100 pipeline from 1 to 4 replicas under each router
/// policy. Runs without artifacts (it uses the analytic service model),
/// so this section always produces numbers even when the live rows above
/// failed for lack of `make artifacts`.
fn cluster_scaleout_section() -> anyhow::Result<()> {
    println!("\nCluster scale-out (simulated, ResNet50 on G1, TFS, 120 rps per replica):\n");
    let duration = 30.0;
    let mut rows = Vec::new();
    for n in [1usize, 2, 4] {
        for router in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastOutstanding,
            RouterPolicy::PowerOfTwoChoices { seed: 99 },
        ] {
            let rn = inferbench::models::catalog::find("resnet50").unwrap();
            let cfg = ClusterConfig {
                workload: Workload::Stream {
                    pattern: Pattern::Poisson { rate: 120.0 * n as f64 },
                    seed: 1234,
                },
                duration_s: duration,
                replicas: (0..n)
                    .map(|_| -> anyhow::Result<ReplicaConfig> {
                        Ok(ReplicaConfig {
                            software: &backends::TFS,
                            service: service_model_for("resnet50", "G1")?,
                            policy: Policy::Dynamic { max_size: 8, max_wait_s: 0.005 },
                            max_queue: 8192,
                        })
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
                router,
                autoscale: None,
                cold_start: None,
                path: RequestPath {
                    processors: Processors::image(),
                    network: LAN,
                    payload_bytes: rn.request_bytes,
                },
                metrics: MetricsMode::Exact,
                admission: None,
                faults: None,
                retry: None,
                seed: 99,
            };
            let r = run_cluster(&cfg);
            let c = &r.collector;
            rows.push(vec![
                n.to_string(),
                router.label().to_string(),
                format!("{:.0}", c.throughput_rps()),
                format!("{:.1}", c.e2e.percentile(50.0) * 1e3),
                format!("{:.1}", c.e2e.percentile(99.0) * 1e3),
                format!("{:.2}", r.mean_batch()),
            ]);
        }
    }
    print!(
        "{}",
        render::table(&["Replicas", "Router", "rps", "p50 ms", "p99 ms", "mean batch"], &rows)
    );
    println!("\n(run `cargo bench --bench fig16_scaleout` for the full scale-out figure)");
    Ok(())
}

/// Autoscaling under spike load (simulated; runs without artifacts): a 6x
/// burst hits a 2-replica fleet; scale-up pays each software's cold start
/// before new capacity is routable, and the post-burst drain-on-remove
/// retires replicas only after they finish their backlog. TrIS vs TFS
/// isolates the cold-start profile: same measured device time, ~9.4 s vs
/// ~2.2 s to bring a 100 MB model up.
fn autoscale_spike_section() -> anyhow::Result<()> {
    println!("\nAutoscale under spike (simulated, 150 rps base / 900 rps burst, 2 -> max 8 replicas):\n");
    let weight_bytes: u64 = 100_000_000;
    let replica = |software: &'static Software| ReplicaConfig {
        software,
        service: inferbench::serving::ServiceModel::Measured {
            per_batch: vec![(1, 0.005)],
            utilization: 0.6,
        },
        policy: Policy::Single,
        max_queue: 200_000,
    };
    let mut rows = Vec::new();
    for software in [&backends::TFS, &backends::TRIS] {
        let cfg = ClusterConfig {
            workload: Workload::Stream {
                pattern: Pattern::Spike {
                    base_rate: 150.0,
                    burst_rate: 900.0,
                    start_s: 20.0,
                    duration_s: 12.0,
                },
                seed: 2024,
            },
            duration_s: 60.0,
            replicas: vec![replica(software), replica(software)],
            router: RouterPolicy::LeastOutstanding,
            autoscale: Some(AutoscaleConfig {
                policy: ScalePolicy::QueueDepth {
                    up_per_replica: 6.0,
                    down_per_replica: 0.5,
                    cooldown_s: 1.0,
                },
                min_replicas: 2,
                max_replicas: 8,
                template: replica(software),
                weight_bytes,
                eval_interval_s: 0.5,
            }),
            cold_start: None,
            path: RequestPath::local(Processors::none()),
            metrics: MetricsMode::Exact,
            admission: None,
            faults: None,
            retry: None,
            seed: 2024,
        };
        let r = run_cluster(&cfg);
        assert_eq!(r.collector.completed + r.dropped, r.issued, "conservation across scale events");
        let burst = r.collector.e2e_in_window(20.0, 32.0);
        rows.push(vec![
            software.id.to_string(),
            format!("{:.1}", software.coldstart_s(weight_bytes)),
            format!("{}", r.scale.max_active()),
            format!(
                "{}/{}",
                r.scale.count(ScaleEventKind::AddRequested),
                r.scale.count(ScaleEventKind::Retired)
            ),
            format!("{:.0}", burst.percentile(99.0) * 1e3),
            r.dropped.to_string(),
        ]);
    }
    print!(
        "{}",
        render::table(
            &["Software", "Coldstart s", "Max replicas", "Adds/retires", "burst p99 ms", "Dropped"],
            &rows
        )
    );
    println!("\n(run `cargo bench --bench fig17_autoscale` for the full autoscale figure)");
    Ok(())
}

/// Sharing versus Dedicate (simulated; runs without artifacts): the same
/// two models served colocated on one MPS-shared replica versus dedicated
/// on two. Light load shows the consolidation win (half the replicas for
/// ~the MPS overhead); overcommitted load shows the cost — the shared
/// tail melts while the dedicated pair stays stable.
fn multimodel_sharing_section() -> anyhow::Result<()> {
    use inferbench::serving::multimodel::{
        self, ContentionModel, ModelSpec, MultiModelConfig, MultiReplicaConfig,
    };
    println!("\nSharing vs dedicate (simulated, 2 models x 5 ms service on TrIS):\n");
    let model = |name: &str, rate: f64| ModelSpec {
        name: name.into(),
        service: inferbench::serving::ServiceModel::Measured {
            per_batch: vec![(1, 0.005)],
            utilization: 0.6,
        },
        policy: Policy::Single,
        weight_bytes: 200_000_000,
        max_queue: 400_000,
        pattern: inferbench::workload::Pattern::Poisson { rate },
    };
    let replica = |hosted: Vec<usize>| MultiReplicaConfig {
        software: &backends::TRIS,
        mem_bytes: 16_000_000_000,
        hosted,
    };
    let mut rows = Vec::new();
    for (regime, rate) in [("light", 40.0), ("overcommitted", 120.0)] {
        for (mode, fleet) in [
            ("shared", vec![replica(vec![0, 1])]),
            ("dedicated", vec![replica(vec![0]), replica(vec![1])]),
        ] {
            let cfg = MultiModelConfig {
                models: vec![model("a", rate), model("b", rate)],
                replicas: fleet,
                router: RouterPolicy::LeastOutstanding,
                duration_s: 20.0,
                placement_ops: vec![],
                contention: ContentionModel::default(),
                path: RequestPath::local(Processors::none()),
                metrics: MetricsMode::Exact,
                admission: None,
                faults: None,
                retry: None,
                seed: 77,
            };
            let r = multimodel::run(&cfg);
            for m in &r.models {
                assert!(m.conserved(), "stream {} ledger broken", m.name);
            }
            // Cost axis of §3.3: devices x cheapest G1 list price for the
            // run window.
            let hourly = inferbench::hardware::cloud::cheapest_hourly_usd("G1")
                .expect("G1 offered in the price table");
            let cost = hourly / 3600.0 * cfg.duration_s * r.replica_count() as f64;
            rows.push(vec![
                regime.to_string(),
                format!("{rate:.0}"),
                mode.to_string(),
                r.replica_count().to_string(),
                format!("{:.1}", r.collector.e2e.percentile(50.0) * 1e3),
                format!("{:.1}", r.collector.e2e.percentile(99.0) * 1e3),
                r.dropped.to_string(),
                format!("{cost:.4}"),
            ]);
        }
    }
    print!(
        "{}",
        render::table(
            &["Regime", "Rate/model", "Mode", "Replicas", "p50 ms", "p99 ms", "Dropped", "Cost $"],
            &rows
        )
    );
    println!("\n(run `cargo bench --bench fig_sharing` for the full sharing figure)");
    Ok(())
}

/// Tracing (simulated; runs without artifacts): rerun a burst scenario
/// with full request tracing on — which is bit-invisible to the
/// simulation — export the span tree + gauge timelines as Perfetto JSON
/// (loadable at ui.perfetto.dev), and print the 5 slowest sampled
/// requests with their per-stage breakdown.
fn tracing_section() -> anyhow::Result<()> {
    use inferbench::obs::{Span, TraceConfig, TraceSink};
    use inferbench::serving::ServiceModel;
    println!("\nTracing a burst (simulated, 150 rps base / 900 rps burst, full sampling):\n");
    let replica = || ReplicaConfig {
        software: &backends::TFS,
        service: ServiceModel::Measured { per_batch: vec![(1, 0.005)], utilization: 0.6 },
        policy: Policy::Dynamic { max_size: 8, max_wait_s: 0.004 },
        max_queue: 200_000,
    };
    let cfg = ClusterConfig {
        workload: Workload::Stream {
            pattern: Pattern::Spike {
                base_rate: 150.0,
                burst_rate: 900.0,
                start_s: 6.0,
                duration_s: 4.0,
            },
            seed: 314,
        },
        duration_s: 16.0,
        replicas: vec![replica(), replica()],
        router: RouterPolicy::LeastOutstanding,
        autoscale: None,
        cold_start: None,
        path: RequestPath::local(Processors::image()),
        metrics: MetricsMode::Exact,
        admission: None,
        faults: None,
        retry: None,
        seed: 314,
    };
    let plain = run_cluster(&cfg);
    let traced = inferbench::serving::cluster::run_traced(&cfg, &TraceConfig::full());
    assert_eq!(
        plain.collector.fingerprint(),
        traced.collector.fingerprint(),
        "tracing must be bit-invisible"
    );
    let trace = traced.trace.expect("full tracing produces a trace");

    let out_path = "e2e_burst.trace.json";
    TraceSink::write_perfetto(out_path, &trace)
        .map_err(|e| anyhow::anyhow!("writing {out_path}: {e}"))?;
    println!(
        "exported {} spans + {} gauge series to {out_path} (open at ui.perfetto.dev)",
        trace.spans.len(),
        trace.gauges.len()
    );

    // The 5 slowest requests, with where the time went stage by stage.
    let mut roots: Vec<&Span> =
        trace.spans.iter().filter(|s| s.parent.is_none() && s.name == "request").collect();
    roots.sort_by(|a, b| {
        let (da, db) = (a.end_s - a.start_s, b.end_s - b.start_s);
        db.partial_cmp(&da).unwrap().then(a.id.cmp(&b.id))
    });
    let mut rows = Vec::new();
    for root in roots.iter().take(5) {
        let stages: Vec<String> = trace
            .spans
            .iter()
            .filter(|s| s.parent == Some(root.id) && s.end_s > s.start_s)
            .map(|s| format!("{} {:.2}ms", s.name, (s.end_s - s.start_s) * 1e3))
            .collect();
        let attr = |key: &str| {
            root.attrs.iter().find(|(k, _)| k == key).map_or("?".to_string(), |(_, v)| v.render())
        };
        rows.push(vec![
            attr("id"),
            format!("{:.3}", root.start_s),
            format!("{:.1}", (root.end_s - root.start_s) * 1e3),
            attr("outcome"),
            stages.join(" -> "),
        ]);
    }
    print!(
        "{}",
        render::table(&["Request", "Arrived s", "e2e ms", "Outcome", "Stage breakdown"], &rows)
    );
    println!(
        "\n(add `trace:` to a coordinator job YAML, or `--trace-out` to fig17_autoscale, \
         for the same export elsewhere)"
    );
    Ok(())
}
