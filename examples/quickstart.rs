//! Quickstart: submit a small benchmark campaign to an in-process
//! InferBench cluster and read the results back — the "configuration
//! file with a few lines of code" workflow from the paper's abstract.
//!
//! Run with: `cargo run --release --example quickstart`

use inferbench::coordinator::{JobSpec, Leader, LeaderConfig, SchedulerPolicy};
use inferbench::perfdb::Query;
use inferbench::util::render;

fn main() -> anyhow::Result<()> {
    // 1. A benchmark submission is a few lines of YAML.
    let submission = r#"
name: resnet50-on-v100
task: serving_sim
model: resnet50
platform: G1
software: tfs
workload:
  rate: 60.0        # Poisson arrivals, requests/second
  duration_s: 30
batching:
  max_size: 8
  max_wait_ms: 5
"#;

    // 2. Start a leader with four follower workers (threads standing in
    //    for the paper's follower servers) and the two-tier scheduler.
    let leader = Leader::start(LeaderConfig {
        workers: 4,
        policy: SchedulerPolicy::qa_sjf(),
        time_scale: 1.0,
        threads_per_worker: 1,
        seed: 7,
    });

    // 3. Submit the job plus a comparison grid over serving software.
    let mut n = 0;
    leader.submit(JobSpec::parse_yaml(submission)?)?;
    n += 1;
    for software in ["tris", "onnx", "torchscript"] {
        let spec = submission
            .replace("software: tfs", &format!("software: {software}"))
            .replace("name: resnet50-on-v100", &format!("name: resnet50-{software}"));
        leader.submit(JobSpec::parse_yaml(&spec)?)?;
        n += 1;
    }

    // 4. Wait and report.
    let done = leader.wait_for(n, std::time::Duration::from_secs(120))?;
    println!("completed {} benchmark jobs:", done.len());
    for c in &done {
        println!(
            "  {} on worker {}: waited {} ran {}",
            c.name,
            c.worker,
            render::fmt_duration(c.waited_s),
            render::fmt_duration(c.ran_s)
        );
    }

    // 5. Query the PerfDB: which serving software wins on tail latency?
    let db = leader.perfdb.lock().unwrap();
    let rows: Vec<Vec<String>> = db
        .leaderboard(&Query::default().task("serving_sim"), "p99_ms")
        .iter()
        .map(|r| {
            vec![
                r.software.clone(),
                format!("{:.1}", r.metric("p50_ms").unwrap()),
                format!("{:.1}", r.metric("p99_ms").unwrap()),
                format!("{:.1}", r.metric("throughput_rps").unwrap()),
                format!("{:.2}", r.metric("mean_batch").unwrap()),
            ]
        })
        .collect();
    println!("\nresnet50 @ 60 rps on V100 — serving software leaderboard (by p99):");
    print!(
        "{}",
        render::table(&["Software", "p50 ms", "p99 ms", "Throughput", "Mean batch"], &rows)
    );
    drop(db);
    leader.shutdown();
    Ok(())
}
