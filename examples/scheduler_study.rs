//! The paper's §5.5 case study, live: run the same benchmark-job stream
//! through three scheduler configurations on a real threaded cluster
//! (time-scaled sleeps standing in for benchmark jobs) and through the
//! DES, and report the average-JCT improvement (paper: QA+SJF = 1.43x
//! over RR+FCFS).
//!
//! Run with: `cargo run --release --example scheduler_study`

use inferbench::coordinator::scheduler::{simulate_online, synthetic_jobs, SchedulerPolicy};
use inferbench::coordinator::{JobSpec, Leader, LeaderConfig};
use inferbench::util::render;

fn main() -> anyhow::Result<()> {
    let policies =
        [SchedulerPolicy::rr_fcfs(), SchedulerPolicy::rr_sjf(), SchedulerPolicy::qa_sjf()];

    // ---- DES at paper scale: 200 jobs, 4 workers --------------------------
    println!("DES: 200 synthetic benchmark jobs (lognormal durations), 4 workers\n");
    let jobs = synthetic_jobs(200, 20.0, 42);
    let mut rows = Vec::new();
    let mut base_jct = 0.0;
    for p in policies {
        let out = simulate_online(&jobs, 4, p);
        if p == SchedulerPolicy::rr_fcfs() {
            base_jct = out.mean_jct_s();
        }
        rows.push((p.label().to_string(), out.mean_jct_s()));
    }
    let items: Vec<(String, f64)> = rows.clone();
    print!("{}", render::bar_chart("Average JCT (seconds, lower is better)", &items, 40));
    for (label, jct) in &rows {
        println!("  {label}: {:.1}s  ({:.2}x vs RR+FCFS)", jct, base_jct / jct);
    }

    // ---- Live threaded cluster, time-scaled --------------------------------
    println!("\nLive cluster: 24 jobs on 3 workers (sleeps at 100x time scale)\n");
    let mut live_rows = Vec::new();
    for p in policies {
        let leader = Leader::start(LeaderConfig {
            workers: 3,
            policy: p,
            time_scale: 100.0,
            threads_per_worker: 1,
            seed: 0,
        });
        // Same job stream for every policy: a burst of mixed-length jobs.
        let mut rng = inferbench::util::rng::Pcg64::seeded(9);
        for i in 0..24 {
            let secs = rng.lognormal(60f64.ln(), 1.1).clamp(5.0, 1800.0);
            leader.submit(JobSpec::parse_yaml(&format!(
                "name: j{i}\ntask: sleep\nseconds: {secs:.1}\n"
            ))?)?;
        }
        let done = leader.wait_for(24, std::time::Duration::from_secs(120))?;
        // Report in *scaled* time so numbers compare with the DES.
        let mean_jct = done.iter().map(|c| c.jct_s()).sum::<f64>() / done.len() as f64 * 100.0;
        live_rows.push((p.label().to_string(), mean_jct));
        leader.shutdown();
    }
    print!("{}", render::bar_chart("Live mean JCT (scaled seconds)", &live_rows, 40));
    let base = live_rows[0].1;
    for (label, jct) in &live_rows {
        println!("  {label}: {:.0}s  ({:.2}x vs RR+FCFS)", jct, base / jct);
    }
    println!("\nPaper Fig 15: QA+SJF reduces average JCT by 1.43x (~30%) vs RR+FCFS.");
    Ok(())
}
