"""Analytic FLOPs / parameter / memory-traffic model for every family.

These formulas are the single source of truth for the compute profile of
the canonical models (paper §4.2.2). They are embedded into
``artifacts/manifest.json`` by aot.py, and the rust side
(``rust/src/models/analytic.rs``) mirrors them exactly — a pytest and a
cargo test each assert the two implementations agree on the same configs.

Conventions (all per *one* sample, f32):
  * a matmul (K x N) costs ``2*K*N`` FLOPs;
  * elementwise/bias/activation terms are included where they are not
    negligible (LSTM gates, softmax);
  * ``weight_bytes`` is read once per *batch*; ``act_bytes`` is the
    activation read+write traffic per sample. Arithmetic intensity at
    batch b is therefore ``flops*b / (weight_bytes + act_bytes*b)`` —
    which is what makes batch sweep move models from memory- to
    compute-bound on the Roofline (paper Fig 10b).
"""

from __future__ import annotations


def mlp_profile(depth: int, width: int, in_dim: int = 256, classes: int = 16) -> dict:
    flops = 2 * in_dim * width + depth * 2 * width * width + 2 * width * classes
    params = (
        in_dim * width + width
        + depth * (width * width + width)
        + width * classes + classes
    )
    # activations: input + hidden after each layer + logits, read+write.
    act_elems = in_dim + (depth + 1) * width + classes
    return {
        "flops": flops,
        "params": params,
        "weight_bytes": params * 4,
        "act_bytes": 2 * act_elems * 4,
    }


def cnn_profile(depth: int, channels: int, hw: int = 32, cin: int = 3, classes: int = 16) -> dict:
    px = hw * hw
    flops = (
        2 * 9 * cin * channels * px               # stem conv
        + depth * 2 * 9 * channels * channels * px  # residual blocks
        + 2 * channels * classes                   # head
    )
    params = (
        9 * cin * channels + channels
        + depth * (9 * channels * channels + channels)
        + channels * classes + classes
    )
    act_elems = px * cin + (depth + 1) * px * channels + channels + classes
    return {
        "flops": flops,
        "params": params,
        "weight_bytes": params * 4,
        "act_bytes": 2 * act_elems * 4,
    }


def rnn_profile(depth: int, hidden: int, seq: int = 16, in_dim: int = 64, classes: int = 16) -> dict:
    gates = 2 * (hidden * 4 * hidden) * 2  # x@Wx + h@Wh per step
    flops = (
        2 * in_dim * hidden * seq      # input projection per step
        + depth * seq * gates          # LSTM cells
        + depth * seq * 10 * hidden    # gate nonlinearities + state update
        + 2 * hidden * classes         # head
    )
    params = (
        in_dim * hidden + hidden
        + depth * (hidden * 4 * hidden * 2 + 4 * hidden)
        + hidden * classes + classes
    )
    act_elems = seq * in_dim + (depth + 1) * seq * hidden + classes
    return {
        "flops": flops,
        "params": params,
        "weight_bytes": params * 4,
        "act_bytes": 2 * act_elems * 4,
    }


def transformer_profile(depth: int, d_model: int, heads: int, seq: int = 64, classes: int = 16) -> dict:
    d = d_model
    per_layer = (
        8 * seq * d * d        # q,k,v,o projections
        + 4 * seq * seq * d    # QK^T and PV contractions
        + 5 * seq * seq        # softmax (exp, sum, div, max, sub)
        + 16 * seq * d * d     # FFN (d -> 4d -> d)
    )
    flops = depth * per_layer + 2 * d * classes
    params = depth * (4 * d * d + d * 4 * d + 4 * d + 4 * d * d + d + 4 * d) + d * classes + classes
    act_elems = seq * d * (4 * depth + 1) + depth * heads * seq * seq + classes
    return {
        "flops": flops,
        "params": params,
        "weight_bytes": params * 4,
        "act_bytes": 2 * act_elems * 4,
    }


def profile_for(family: str, hp: dict) -> dict:
    """Dispatch on family name; hp holds the hyper-parameters."""
    if family == "mlp":
        return mlp_profile(hp["depth"], hp["width"], hp.get("in_dim", 256), hp.get("classes", 16))
    if family == "cnn":
        return cnn_profile(hp["depth"], hp["channels"], hp.get("hw", 32), hp.get("cin", 3), hp.get("classes", 16))
    if family == "rnn":
        return rnn_profile(hp["depth"], hp["hidden"], hp.get("seq", 16), hp.get("in_dim", 64), hp.get("classes", 16))
    if family == "transformer":
        return transformer_profile(hp["depth"], hp["d_model"], hp["heads"], hp.get("seq", 64), hp.get("classes", 16))
    raise ValueError(f"unknown family {family!r}")
