"""AOT lowering: JAX models -> artifacts/*.hlo.txt + manifest.json.

This is the only place python runs — once, at build time (`make
artifacts`). Interchange is HLO **text**, not ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

The manifest records, for every artifact: the family + hyper-parameters,
the exact ordered input specs (params then x) the rust runtime must feed,
the output shape, and the analytic compute profile (FLOPs / params /
weight & activation bytes) that drives the hardware roofline models.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import analytic, model

DTYPES = {"f32": jnp.float32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(family: str, hp: dict) -> tuple[str, dict]:
    """Lower one (family, hyper-params) config; returns (hlo_text, manifest entry)."""
    fn, param_specs, x_spec = model.build(family, hp)
    specs = [jax.ShapeDtypeStruct(s.shape, DTYPES[s.dtype]) for s in param_specs]
    x = jax.ShapeDtypeStruct(x_spec.shape, DTYPES[x_spec.dtype])
    lowered = jax.jit(fn).lower(tuple(specs), x)
    hlo = to_hlo_text(lowered)

    profile = analytic.profile_for(family, hp)
    classes = hp.get("classes", 16)
    entry = {
        "family": family,
        "hyperparams": hp,
        "inputs": [
            {"name": s.name, "shape": list(s.shape), "dtype": s.dtype}
            for s in (*param_specs, x_spec)
        ],
        "output": {"shape": [hp["batch"], classes], "dtype": "f32"},
        "flops_per_sample": profile["flops"],
        "params": profile["params"],
        "weight_bytes": profile["weight_bytes"],
        "act_bytes_per_sample": profile["act_bytes"],
    }
    return hlo, entry


def variant_name(family: str, hp: dict) -> str:
    keys = [k for k in ("depth", "width", "channels", "hidden", "d_model", "heads", "seq") if k in hp]
    parts = [family] + [f"{k[0]}{hp[k]}" for k in keys] + [f"b{hp['batch']}"]
    return "_".join(parts)


def default_variants() -> list[tuple[str, str, dict]]:
    """(artifact name, family, hyper-params) for the default `make artifacts` set.

    Kept modest (compile time): the serving benches execute the real-world
    stand-ins on CPU at a few batch sizes; GPU-platform curves come from
    the calibrated roofline model, which needs only the manifest profiles.
    """
    out = []
    for name, (family, hp0) in model.REAL_WORLD.items():
        for batch in (1, 4, 8):
            hp = dict(hp0, batch=batch)
            out.append((f"{name}_b{batch}", family, hp))
    # One canonical per family for runtime integration tests + Fig 9 anchors.
    canon = [
        ("mlp", {"depth": 8, "width": 512}),
        ("cnn", {"depth": 4, "channels": 32, "hw": 16}),
        ("rnn", {"depth": 2, "hidden": 128, "seq": 16}),
        ("transformer", {"depth": 2, "d_model": 128, "heads": 4, "seq": 64}),
    ]
    for family, hp0 in canon:
        for batch in (1, 8):
            hp = dict(hp0, batch=batch)
            out.append((variant_name(family, hp), family, hp))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    for name, family, hp in default_variants():
        if args.only and args.only not in name:
            continue
        hlo, entry = lower_variant(family, hp)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        entry["hlo_file"] = f"{name}.hlo.txt"
        manifest[name] = entry
        print(f"  lowered {name}: {len(hlo)} chars, {entry['params']} params")

    manifest_path = os.path.join(args.out, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {manifest_path} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
