"""Layer-1 Pallas kernels for the InferBench canonical model families.

One kernel per canonical block from the paper (§4.2.2 Canonical Model
Generator): FC -> matmul_block, Transformer -> attention, RNN -> lstm_cell,
CNN residual block -> conv_block. All lowered with interpret=True so the
HLO runs on the CPU PJRT client that the rust runtime drives.
"""

from .attention import attention
from .conv_block import conv_block, conv_in, im2col
from .lstm_cell import lstm_cell
from .matmul_block import linear

__all__ = ["attention", "conv_block", "conv_in", "im2col", "linear", "lstm_cell"]
