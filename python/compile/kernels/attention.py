"""Layer-1 Pallas kernel: fused multi-head self-attention (Transformer block).

The paper's Transformer canonical family stacks attention blocks; this
kernel fuses QK^T, the numerically-stable softmax, and the PV contraction
for one (batch, head) pair per grid step, so the S x S score matrix lives
only in VMEM and never round-trips to HBM — the TPU re-thinking of what a
CUDA flash-attention kernel does with shared-memory tiles per threadblock.

Sequence lengths in the canonical families are small enough (<= 512) that a
whole head fits in VMEM; `common.block_bytes` asserts that at trace time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import VMEM_BUDGET, block_bytes


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool):
    # Block is (1, 1, S, Dh): one head of one batch element.
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        seq = q.shape[0]
        row = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 1)
        s = jnp.where(col <= row, s, -1e30)
    # Numerically stable softmax over the key axis.
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def attention(q, k, v, *, causal: bool = False, interpret: bool = True):
    """Fused softmax(q k^T / sqrt(d)) v per head.

    Args:
      q, k, v: ``(B, H, S, Dh)`` f32.
      causal: apply a causal mask (decoder-style families).
      interpret: must stay True for CPU-PJRT execution.

    Returns:
      ``(B, H, S, Dh)`` f32 attention output.
    """
    b, h, s, dh = q.shape
    assert k.shape == (b, h, s, dh) and v.shape == (b, h, s, dh)
    assert (
        block_bytes((s, dh), (s, dh), (s, dh), (s, s), (s, dh)) < VMEM_BUDGET
    ), "attention head does not fit in VMEM; shrink seq or head dim"
    scale = 1.0 / float(dh) ** 0.5

    kernel = functools.partial(_attention_kernel, scale=scale, causal=causal)
    spec = pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)


def vmem_footprint(s: int, dh: int) -> dict:
    """Static VMEM/MXU profile per grid step — used by EXPERIMENTS.md §Perf."""
    return {
        "block": (s, dh),
        "vmem_bytes": block_bytes((s, dh), (s, dh), (s, dh), (s, s), (s, dh)),
        # Two contractions: (S,Dh)x(Dh,S) and (S,S)x(S,Dh).
        "mxu_utilization": min(s, 128) * min(dh, 128) / (128.0 * 128.0),
    }
