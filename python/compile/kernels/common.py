"""Shared helpers for the Pallas kernels (Layer 1).

All kernels in this package are written for the TPU programming model —
blocks tiled for VMEM, inner products shaped for the 128x128 MXU — but are
lowered with ``interpret=True`` so the resulting HLO runs on any PJRT
backend (including the rust CPU client on the request path). See
DESIGN.md §3 (Hardware adaptation).
"""

from __future__ import annotations

import jax.numpy as jnp

# The MXU systolic array is 128x128; VMEM is ~16 MiB per core. Tiles are
# chosen as the largest power-of-two divisor of the dimension capped at
# the MXU edge, which keeps every kernel correct for the small model
# shapes used in tests while remaining MXU-aligned for production shapes.
MXU_EDGE = 128
# VMEM budget (bytes) we allow a single kernel invocation to use; the
# kernels assert their per-step block footprint stays under this.
VMEM_BUDGET = 16 * 1024 * 1024


def tile(dim: int, cap: int = MXU_EDGE) -> int:
    """Largest power-of-two divisor of ``dim`` that is <= ``cap``.

    Falls back to ``dim`` itself when ``dim`` has no power-of-two factor
    <= cap (e.g. odd dims), which keeps the kernel correct at the cost of
    a single large block.
    """
    if dim <= cap:
        return dim
    t = cap
    while t > 1:
        if dim % t == 0:
            return t
        t //= 2
    return dim


def block_bytes(*shapes: tuple[int, ...], dtype_bytes: int = 4) -> int:
    """Total bytes of the given block shapes (f32 by default)."""
    total = 0
    for s in shapes:
        n = 1
        for d in s:
            n *= d
        total += n * dtype_bytes
    return total


def apply_activation(x, activation: str | None):
    """Epilogue activations fused into the kernels."""
    if activation is None or activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "gelu":
        # tanh-approximation GELU: cheap on the VPU, matches jax.nn.gelu
        # (approximate=True) which ref.py uses as the oracle.
        c = 0.7978845608028654  # sqrt(2/pi)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    if activation == "tanh":
        return jnp.tanh(x)
    if activation == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-x))
    raise ValueError(f"unknown activation: {activation!r}")


VALID_ACTIVATIONS = ("none", "relu", "gelu", "tanh", "sigmoid")
