"""Layer-1 Pallas kernel path: residual conv block (the CNN canonical block).

The paper's CNN family stacks residual blocks. On TPU a 3x3 conv is
executed as an im2col matmul on the MXU (that is literally what XLA:TPU
does); we make that explicit: patch extraction is a build-time jnp
reshape (`conv_general_dilated_patches`), and the hot compute — the
(B*H*W, 9C) x (9C, C) contraction with the bias + ReLU + skip-connection
epilogue — is the fused Pallas matmul kernel from `matmul_block`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .matmul_block import linear


def im2col(x):
    """Extract 3x3 SAME patches: ``(B, H, W, C)`` -> ``(B*H*W, 9*C)``.

    Channel-major patch layout (C chunks of 9 spatial taps) to match
    ``conv_general_dilated_patches``'s depthwise ordering; ref.py and the
    weight layout in `conv_weights` use the same convention.
    """
    b, h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(3, 3),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return patches.reshape(b * h * w, 9 * c)


@functools.partial(jax.jit, static_argnames=("interpret",))
def conv_block(x, w, b, *, interpret: bool = True):
    """Residual block: ``relu(conv3x3(x) + b) + x`` fused via the matmul kernel.

    Args:
      x: ``(B, H, W, C)`` f32 feature map.
      w: ``(9*C, C)`` conv weights in im2col layout.
      b: ``(C,)`` bias.
      interpret: must stay True for CPU-PJRT execution.

    Returns:
      ``(B, H, W, C)`` f32.
    """
    bsz, h, ww, c = x.shape
    assert w.shape == (9 * c, c), f"bad conv weight shape {w.shape} for C={c}"
    cols = im2col(x)
    flat_residual = x.reshape(bsz * h * ww, c)
    out = linear(
        cols, w, b, residual=flat_residual, activation="relu", interpret=interpret
    )
    return out.reshape(bsz, h, ww, c)


@functools.partial(jax.jit, static_argnames=("interpret",))
def conv_in(x, w, b, *, interpret: bool = True):
    """Stem conv: ``relu(conv3x3(x) + b)`` mapping C_in -> C_out channels.

    Args:
      x: ``(B, H, W, C_in)``; w: ``(9*C_in, C_out)``; b: ``(C_out,)``.
    """
    bsz, h, ww, cin = x.shape
    cout = w.shape[1]
    assert w.shape[0] == 9 * cin
    cols = im2col(x)
    out = linear(cols, w, b, activation="relu", interpret=interpret)
    return out.reshape(bsz, h, ww, cout)
