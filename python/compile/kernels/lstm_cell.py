"""Layer-1 Pallas kernel: fused LSTM cell (the RNN canonical block).

One grid step processes a batch tile: both gate matmuls (x Wx and h Wh),
the bias add, all four gate nonlinearities, and the cell/hidden state
updates are fused into a single VMEM-resident kernel. On CUDA this is the
classic "fused LSTM cell" persistent kernel; on TPU the gate matmuls map
to the MXU and the elementwise tail to the VPU without leaving VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import VMEM_BUDGET, block_bytes, tile


def _sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h2_ref, c2_ref, *, hidden: int):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    # (bm, 4H) gate pre-activations: two MXU contractions + bias.
    gates = (
        jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    i = _sigmoid(gates[:, 0 * hidden : 1 * hidden])
    f = _sigmoid(gates[:, 1 * hidden : 2 * hidden] + 1.0)  # forget-gate bias init
    g = jnp.tanh(gates[:, 2 * hidden : 3 * hidden])
    o = _sigmoid(gates[:, 3 * hidden : 4 * hidden])
    c2 = f * c + i * g
    h2_ref[...] = o * jnp.tanh(c2)
    c2_ref[...] = c2


@functools.partial(jax.jit, static_argnames=("interpret",))
def lstm_cell(x, h, c, wx, wh, b, *, interpret: bool = True):
    """One fused LSTM step.

    Args:
      x: ``(B, D)`` input at this timestep.
      h: ``(B, H)`` previous hidden state.
      c: ``(B, H)`` previous cell state.
      wx: ``(D, 4H)`` input->gates weights (gate order: i, f, g, o).
      wh: ``(H, 4H)`` hidden->gates weights.
      b: ``(4H,)`` gate bias.
      interpret: must stay True for CPU-PJRT execution.

    Returns:
      ``(h', c')`` each ``(B, H)``.
    """
    bsz, d = x.shape
    hidden = h.shape[1]
    assert h.shape == (bsz, hidden) and c.shape == (bsz, hidden)
    assert wx.shape == (d, 4 * hidden) and wh.shape == (hidden, 4 * hidden)
    assert b.shape == (4 * hidden,)

    bm = tile(bsz)
    assert (
        block_bytes((bm, d), (bm, hidden), (bm, hidden), (d, 4 * hidden), (hidden, 4 * hidden), (bm, 4 * hidden))
        < VMEM_BUDGET
    ), "LSTM cell block exceeds VMEM budget; shrink hidden size"

    kernel = functools.partial(_lstm_kernel, hidden=hidden)
    b2 = b.reshape(1, 4 * hidden)
    out_shape = (
        jax.ShapeDtypeStruct((bsz, hidden), x.dtype),
        jax.ShapeDtypeStruct((bsz, hidden), x.dtype),
    )
    state_spec = pl.BlockSpec((bm, hidden), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(bsz // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            state_spec,
            state_spec,
            pl.BlockSpec((d, 4 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden, 4 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((1, 4 * hidden), lambda i: (0, 0)),
        ],
        out_specs=(state_spec, state_spec),
        out_shape=out_shape,
        interpret=interpret,
    )(x, h, c, wx, wh, b2)


def vmem_footprint(bsz: int, d: int, hidden: int) -> dict:
    """Static VMEM/MXU profile per grid step — used by EXPERIMENTS.md §Perf."""
    bm = tile(bsz)
    return {
        "block": (bm, d, hidden),
        "vmem_bytes": block_bytes(
            (bm, d), (bm, hidden), (bm, hidden), (d, 4 * hidden), (hidden, 4 * hidden), (bm, 4 * hidden)
        ),
        "mxu_utilization": min(bm, 128) * min(4 * hidden, 128) / (128.0 * 128.0),
    }
