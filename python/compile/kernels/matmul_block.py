"""Layer-1 Pallas kernel: tiled matmul with fused epilogue (the FC block).

This is the compute hot-spot of the paper's FC/MLP canonical family and the
projection matmuls of every other family. The CUDA analogue would stage
tiles through shared memory per threadblock; here the HBM->VMEM schedule is
expressed with a 3-D grid over (M/bm, N/bn, K/bk) and BlockSpec index maps,
accumulating partial products into the output block (revisited across the
k-steps of the grid) and applying the epilogue — bias + activation +
optional residual — on the final k-step, so the block never round-trips to
HBM between accumulation and epilogue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import VMEM_BUDGET, apply_activation, block_bytes, tile


def _matmul_kernel(x_ref, w_ref, b_ref, r_ref, o_ref, *, nk: int, activation, has_bias, has_residual):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j], epilogue at k=nk-1."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU contraction in f32 accumulation.
    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        if has_bias:
            acc = acc + b_ref[...]
        acc = apply_activation(acc, activation)
        if has_residual:
            acc = acc + r_ref[...]
        o_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("activation", "bm", "bn", "bk", "interpret")
)
def linear(
    x,
    w,
    b=None,
    residual=None,
    *,
    activation: str | None = None,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool = True,
):
    """``act(x @ w + b) + residual`` as a single fused Pallas kernel.

    Args:
      x: ``(M, K)`` f32 input activations.
      w: ``(K, N)`` f32 weights.
      b: optional ``(N,)`` bias, fused into the epilogue.
      residual: optional ``(M, N)`` tensor added after the activation
        (the skip connection of the paper's residual CNN block).
      activation: one of ``common.VALID_ACTIVATIONS``.
      bm/bn/bk: tile overrides; default MXU-aligned power-of-two tiles.
      interpret: must stay True for CPU-PJRT execution (see DESIGN.md §3).

    Returns:
      ``(M, N)`` f32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    # Default tiles: as large as VMEM comfortably allows (fewer grid steps
    # means fewer HBM<->VMEM round-trips on TPU and, under interpret=True,
    # fewer XLA while-loop iterations on the CPU serving path — the §Perf
    # L1 fix that took resnet_mini from ~336ms to tens of ms per b1
    # inference). Still multiples of the 128 MXU edge whenever the dims
    # have pow2 factors; the VMEM assert below is the safety net.
    bm = bm or tile(m, 1024)
    bn = bn or tile(n, 512)
    bk = bk or tile(k, 1024)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    assert (
        block_bytes((bm, bk), (bk, bn), (bm, bn), (bm, bn)) < VMEM_BUDGET
    ), "block footprint exceeds VMEM budget"

    has_bias = b is not None
    has_residual = residual is not None
    # Pallas wants every ref present; feed zero-size dummies when absent so
    # the kernel signature stays fixed.
    b2 = (b if has_bias else jnp.zeros((n,), x.dtype)).reshape(1, n)
    r2 = residual if has_residual else jnp.zeros((1, 1), x.dtype)

    grid = (m // bm, n // bn, nk)
    kernel = functools.partial(
        _matmul_kernel,
        nk=nk,
        activation=activation,
        has_bias=has_bias,
        has_residual=has_residual,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            (
                pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
                if has_residual
                else pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0))
            ),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w, b2, r2)


def vmem_footprint(m: int, n: int, k: int) -> dict:
    """Static VMEM/MXU profile of one grid step — used by EXPERIMENTS.md §Perf."""
    bm, bn, bk = tile(m), tile(n), tile(k)
    return {
        "block": (bm, bn, bk),
        "vmem_bytes": block_bytes((bm, bk), (bk, bn), (bm, bn), (bm, bn)),
        "mxu_tiles": ((bm + 127) // 128) * ((bn + 127) // 128) * ((bk + 127) // 128),
        # Fraction of the 128x128 systolic array covered by the block edges.
        "mxu_utilization": min(bm, 128) * min(bn, 128) / (128.0 * 128.0),
    }
