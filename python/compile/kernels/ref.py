"""Pure-jnp oracles for every Layer-1 Pallas kernel.

These are the CORE correctness signal: pytest (python/tests/) sweeps
shapes/dtypes with hypothesis and asserts the Pallas kernels match these
references to tight tolerances. Keep them boring and obviously correct —
no pallas, no tiling, no fusion.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_activation_ref(x, activation):
    if activation is None or activation == "none":
        return x
    if activation == "relu":
        return jax.nn.relu(x)
    if activation == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if activation == "tanh":
        return jnp.tanh(x)
    if activation == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(activation)


def linear_ref(x, w, b=None, residual=None, *, activation=None):
    """Oracle for matmul_block.linear."""
    out = x @ w
    if b is not None:
        out = out + b
    out = apply_activation_ref(out, activation)
    if residual is not None:
        out = out + residual
    return out


def attention_ref(q, k, v, *, causal=False):
    """Oracle for attention.attention. q/k/v: (B, H, S, Dh)."""
    dh = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        seq = q.shape[2]
        mask = jnp.tril(jnp.ones((seq, seq), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def lstm_cell_ref(x, h, c, wx, wh, b):
    """Oracle for lstm_cell.lstm_cell (gate order i, f, g, o; +1 forget bias)."""
    hidden = h.shape[1]
    gates = x @ wx + h @ wh + b
    i = jax.nn.sigmoid(gates[:, 0 * hidden : 1 * hidden])
    f = jax.nn.sigmoid(gates[:, 1 * hidden : 2 * hidden] + 1.0)
    g = jnp.tanh(gates[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden : 4 * hidden])
    c2 = f * c + i * g
    return o * jnp.tanh(c2), c2


def conv_block_ref(x, w, b):
    """Oracle for conv_block.conv_block: relu(conv3x3(x)+b) + x, SAME padding.

    Weights arrive in im2col layout (9*C, C) with channel-major patch
    ordering (matching conv_general_dilated_patches); convert back to HWIO
    for the reference convolution.
    """
    c = x.shape[-1]
    whwio = w.reshape(c, 3, 3, c).transpose(1, 2, 0, 3)  # (3,3,Cin,Cout)
    out = jax.lax.conv_general_dilated(
        x, whwio, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return jax.nn.relu(out + b) + x


def conv_in_ref(x, w, b):
    """Oracle for conv_block.conv_in: relu(conv3x3(x)+b), C_in -> C_out."""
    cin = x.shape[-1]
    cout = w.shape[1]
    whwio = w.reshape(cin, 3, 3, cout).transpose(1, 2, 0, 3)
    out = jax.lax.conv_general_dilated(
        x, whwio, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return jax.nn.relu(out + b)
