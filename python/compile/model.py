"""Layer-2: the canonical model generator (paper §4.2.2), in JAX.

Four families built by stacking the paper's four blocks — FC, residual
CNN, LSTM, Transformer-attention — each parameterized by depth / width /
batch, plus small "real-world" stand-ins (resnet_mini, bert_mini,
mobilenet_mini). Every block's hot compute is a Layer-1 Pallas kernel, so
the kernels lower into the same HLO module that the rust runtime executes.

Parameters are *runtime inputs* (not baked constants): per-layer weights
are stacked along a leading ``depth`` axis and the layer loop is a
``lax.scan``, which keeps the lowered HLO small and depth-independent.
``param_specs`` gives the exact input order the rust side must feed.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import attention, conv_block, conv_in, linear, lstm_cell


class ParamSpec(NamedTuple):
    name: str
    shape: tuple
    dtype: str = "f32"


# ---------------------------------------------------------------------------
# MLP family (FC blocks)
# ---------------------------------------------------------------------------

def mlp_param_specs(depth, width, in_dim=256, classes=16):
    return [
        ParamSpec("w_in", (in_dim, width)),
        ParamSpec("b_in", (width,)),
        ParamSpec("ws", (depth, width, width)),
        ParamSpec("bs", (depth, width)),
        ParamSpec("w_out", (width, classes)),
        ParamSpec("b_out", (classes,)),
    ]


def mlp_apply(params, x):
    """x: (B, in_dim) -> logits (B, classes)."""
    w_in, b_in, ws, bs, w_out, b_out = params
    h = linear(x, w_in, b_in, activation="relu")

    def block(h, wb):
        w, b = wb
        return linear(h, w, b, activation="relu"), None

    h, _ = jax.lax.scan(block, h, (ws, bs))
    return linear(h, w_out, b_out)


# ---------------------------------------------------------------------------
# CNN family (residual blocks)
# ---------------------------------------------------------------------------

def cnn_param_specs(depth, channels, hw=32, cin=3, classes=16):
    return [
        ParamSpec("w_stem", (9 * cin, channels)),
        ParamSpec("b_stem", (channels,)),
        ParamSpec("ws", (depth, 9 * channels, channels)),
        ParamSpec("bs", (depth, channels)),
        ParamSpec("w_head", (channels, classes)),
        ParamSpec("b_head", (classes,)),
    ]


def cnn_apply(params, x):
    """x: (B, H, W, cin) -> logits (B, classes)."""
    w_stem, b_stem, ws, bs, w_head, b_head = params
    h = conv_in(x, w_stem, b_stem)

    def block(h, wb):
        w, b = wb
        return conv_block(h, w, b), None

    h, _ = jax.lax.scan(block, h, (ws, bs))
    pooled = jnp.mean(h, axis=(1, 2))  # global average pool
    return linear(pooled, w_head, b_head)


# ---------------------------------------------------------------------------
# RNN family (LSTM blocks)
# ---------------------------------------------------------------------------

def rnn_param_specs(depth, hidden, seq=16, in_dim=64, classes=16):
    del seq  # static shape of x, not of params
    return [
        ParamSpec("w_in", (in_dim, hidden)),
        ParamSpec("b_in", (hidden,)),
        ParamSpec("wx", (depth, hidden, 4 * hidden)),
        ParamSpec("wh", (depth, hidden, 4 * hidden)),
        ParamSpec("b", (depth, 4 * hidden)),
        ParamSpec("w_head", (hidden, classes)),
        ParamSpec("b_head", (classes,)),
    ]


def rnn_apply(params, x):
    """x: (B, S, in_dim) -> logits (B, classes)."""
    w_in, b_in, wx, wh, b, w_head, b_head = params
    bsz, seq, in_dim = x.shape
    hidden = w_in.shape[1]
    h = linear(x.reshape(bsz * seq, in_dim), w_in, b_in, activation="relu")
    seq_h = h.reshape(bsz, seq, hidden)

    def layer(seq_h, layer_params):
        lwx, lwh, lb = layer_params
        h0 = jnp.zeros((bsz, hidden), x.dtype)
        c0 = jnp.zeros((bsz, hidden), x.dtype)

        def step(carry, xt):
            h, c = carry
            h2, c2 = lstm_cell(xt, h, c, lwx, lwh, lb)
            return (h2, c2), h2

        (_, _), ys = jax.lax.scan(step, (h0, c0), seq_h.transpose(1, 0, 2))
        return ys.transpose(1, 0, 2), None

    seq_h, _ = jax.lax.scan(layer, seq_h, (wx, wh, b))
    return linear(seq_h[:, -1, :], w_head, b_head)


# ---------------------------------------------------------------------------
# Transformer family (attention blocks)
# ---------------------------------------------------------------------------

def transformer_param_specs(depth, d_model, heads, seq=64, classes=16):
    del heads, seq
    d = d_model
    return [
        ParamSpec("wq", (depth, d, d)),
        ParamSpec("wk", (depth, d, d)),
        ParamSpec("wv", (depth, d, d)),
        ParamSpec("wo", (depth, d, d)),
        ParamSpec("w1", (depth, d, 4 * d)),
        ParamSpec("b1", (depth, 4 * d)),
        ParamSpec("w2", (depth, 4 * d, d)),
        ParamSpec("b2", (depth, d)),
        ParamSpec("ln1_g", (depth, d)),
        ParamSpec("ln1_b", (depth, d)),
        ParamSpec("ln2_g", (depth, d)),
        ParamSpec("ln2_b", (depth, d)),
        ParamSpec("w_head", (d, classes)),
        ParamSpec("b_head", (classes,)),
    ]


def _layer_norm(x, g, b, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * g + b


def transformer_apply(params, x, *, heads):
    """x: (B, S, d_model) pre-embedded tokens -> logits (B, classes)."""
    (wq, wk, wv, wo, w1, b1, w2, b2, ln1_g, ln1_b, ln2_g, ln2_b, w_head, b_head) = params
    bsz, seq, d = x.shape
    dh = d // heads

    def split_heads(t):  # (B*S, D) -> (B, H, S, Dh)
        return t.reshape(bsz, seq, heads, dh).transpose(0, 2, 1, 3)

    def block(h, lp):
        lwq, lwk, lwv, lwo, lw1, lb1, lw2, lb2, g1, bb1, g2, bb2 = lp
        hn = _layer_norm(h, g1, bb1)
        flat = hn.reshape(bsz * seq, d)
        q = split_heads(linear(flat, lwq))
        k = split_heads(linear(flat, lwk))
        v = split_heads(linear(flat, lwv))
        att = attention(q, k, v)
        att = att.transpose(0, 2, 1, 3).reshape(bsz * seq, d)
        h = h + linear(att, lwo).reshape(bsz, seq, d)
        hn = _layer_norm(h, g2, bb2)
        ff = linear(hn.reshape(bsz * seq, d), lw1, lb1, activation="gelu")
        h = h + linear(ff, lw2, lb2).reshape(bsz, seq, d)
        return h, None

    h, _ = jax.lax.scan(
        block, x, (wq, wk, wv, wo, w1, b1, w2, b2, ln1_g, ln1_b, ln2_g, ln2_b)
    )
    pooled = jnp.mean(h, axis=1)
    return linear(pooled, w_head, b_head)


# ---------------------------------------------------------------------------
# Family registry + real-world stand-ins
# ---------------------------------------------------------------------------

def build(family: str, hp: dict):
    """Return (apply_fn(params, x), param_specs, input_spec) for a config.

    ``apply_fn`` returns a 1-tuple ``(logits,)`` so the lowered HLO has the
    tuple root the rust loader expects (``to_tuple1``).
    """
    classes = hp.get("classes", 16)
    batch = hp["batch"]
    if family == "mlp":
        in_dim = hp.get("in_dim", 256)
        specs = mlp_param_specs(hp["depth"], hp["width"], in_dim, classes)
        fn = lambda params, x: (mlp_apply(params, x),)
        x_spec = ParamSpec("x", (batch, in_dim))
    elif family == "cnn":
        hw, cin = hp.get("hw", 32), hp.get("cin", 3)
        specs = cnn_param_specs(hp["depth"], hp["channels"], hw, cin, classes)
        fn = lambda params, x: (cnn_apply(params, x),)
        x_spec = ParamSpec("x", (batch, hw, hw, cin))
    elif family == "rnn":
        seq, in_dim = hp.get("seq", 16), hp.get("in_dim", 64)
        specs = rnn_param_specs(hp["depth"], hp["hidden"], seq, in_dim, classes)
        fn = lambda params, x: (rnn_apply(params, x),)
        x_spec = ParamSpec("x", (batch, seq, in_dim))
    elif family == "transformer":
        seq, heads = hp.get("seq", 64), hp["heads"]
        specs = transformer_param_specs(hp["depth"], hp["d_model"], heads, seq, classes)
        apply = functools.partial(transformer_apply, heads=heads)
        fn = lambda params, x: (apply(params, x),)
        x_spec = ParamSpec("x", (batch, seq, hp["d_model"]))
    else:
        raise ValueError(f"unknown family {family!r}")
    return fn, specs, x_spec


# Small "real-world" stand-ins for the paper's registered models (§5.1).
# Keys are the names the rust catalog and EXPERIMENTS.md refer to.
REAL_WORLD = {
    "resnet_mini": ("cnn", {"depth": 8, "channels": 64, "hw": 32}),
    "mobilenet_mini": ("cnn", {"depth": 4, "channels": 32, "hw": 32}),
    "bert_mini": ("transformer", {"depth": 4, "d_model": 256, "heads": 4, "seq": 128}),
    "lstm_mini": ("rnn", {"depth": 2, "hidden": 256, "seq": 32}),
}


def init_params(specs, seed=0):
    """Deterministic param values for tests (the rust side generates its own)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for spec in specs:
        key, sub = jax.random.split(key)
        fan_in = spec.shape[0] if len(spec.shape) == 1 else spec.shape[-2]
        scale = 1.0 / max(1.0, float(fan_in)) ** 0.5
        out.append(jax.random.normal(sub, spec.shape, jnp.float32) * scale)
    return tuple(out)
