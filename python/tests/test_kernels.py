"""Kernel-vs-ref correctness: the CORE signal (pallas interpret vs pure jnp).

hypothesis sweeps shapes (and the activation/causal configuration space);
assert_allclose against ref.py at tight f32 tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, conv_block, conv_in, linear, lstm_cell
from compile.kernels import ref
from compile.kernels.common import VALID_ACTIVATIONS, tile

RTOL = 2e-5
ATOL = 2e-5


def rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# matmul_block.linear
# ---------------------------------------------------------------------------

class TestLinear:
    @pytest.mark.parametrize("activation", VALID_ACTIVATIONS)
    def test_activations(self, activation):
        k = keys(4)
        x, w = rand(k[0], (16, 96)), rand(k[1], (96, 48), 0.1)
        b, r = rand(k[2], (48,)), rand(k[3], (16, 48))
        got = linear(x, w, b, r, activation=activation)
        want = ref.linear_ref(x, w, b, r, activation=activation)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_no_bias_no_residual(self):
        k = keys(2)
        x, w = rand(k[0], (8, 32)), rand(k[1], (32, 24), 0.1)
        np.testing.assert_allclose(linear(x, w), ref.linear_ref(x, w), rtol=RTOL, atol=ATOL)

    def test_bias_only(self):
        k = keys(3)
        x, w, b = rand(k[0], (8, 32)), rand(k[1], (32, 24), 0.1), rand(k[2], (24,))
        np.testing.assert_allclose(linear(x, w, b), ref.linear_ref(x, w, b), rtol=RTOL, atol=ATOL)

    def test_multi_k_step_accumulation(self):
        # K > 128 forces multiple k grid steps through the accumulator path.
        k = keys(2)
        x, w = rand(k[0], (4, 512)), rand(k[1], (512, 32), 0.05)
        np.testing.assert_allclose(
            linear(x, w, bk=128), ref.linear_ref(x, w), rtol=5e-5, atol=5e-5
        )

    def test_large_mxu_aligned(self):
        k = keys(2)
        x, w = rand(k[0], (256, 256)), rand(k[1], (256, 256), 0.05)
        np.testing.assert_allclose(linear(x, w), ref.linear_ref(x, w), rtol=1e-4, atol=1e-4)

    def test_batch_one(self):
        # Serving hot case: single-row matmul.
        k = keys(3)
        x, w, b = rand(k[0], (1, 64)), rand(k[1], (64, 64), 0.1), rand(k[2], (64,))
        np.testing.assert_allclose(
            linear(x, w, b, activation="relu"),
            ref.linear_ref(x, w, b, activation="relu"),
            rtol=RTOL, atol=ATOL,
        )

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([1, 2, 3, 5, 8, 16, 31, 64]),
        kdim=st.sampled_from([8, 16, 32, 96, 256]),
        n=st.sampled_from([8, 24, 48, 128]),
        act=st.sampled_from(VALID_ACTIVATIONS),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, m, kdim, n, act, seed):
        k = keys(3, seed)
        x, w, b = rand(k[0], (m, kdim)), rand(k[1], (kdim, n), 0.1), rand(k[2], (n,))
        got = linear(x, w, b, activation=act)
        want = ref.linear_ref(x, w, b, activation=act)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_rejects_bad_contraction(self):
        k = keys(2)
        with pytest.raises(AssertionError):
            linear(rand(k[0], (4, 8)), rand(k[1], (16, 4)))

    def test_tile_helper(self):
        assert tile(256) == 128
        assert tile(100) == 100  # fits under the cap -> whole dim
        assert tile(160) == 32  # largest pow2 divisor <= 128
        assert tile(64) == 64
        assert tile(7) == 7  # odd dims fall back to the full dim
        assert tile(258) == 2
        assert tile(255) == 255  # no pow2 factor -> single large block


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

class TestAttention:
    @pytest.mark.parametrize("causal", [False, True])
    def test_basic(self, causal):
        k = keys(3)
        q, kk, v = (rand(k[i], (2, 4, 16, 32)) for i in range(3))
        got = attention(q, kk, v, causal=causal)
        want = ref.attention_ref(q, kk, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_single_head_single_batch(self):
        k = keys(3)
        q, kk, v = (rand(k[i], (1, 1, 8, 16)) for i in range(3))
        np.testing.assert_allclose(
            attention(q, kk, v), ref.attention_ref(q, kk, v), rtol=RTOL, atol=ATOL
        )

    def test_softmax_stability_large_logits(self):
        # Large-magnitude q/k would overflow a naive softmax.
        k = keys(3)
        q, kk, v = (rand(k[i], (1, 2, 8, 16), 30.0) for i in range(3))
        got = attention(q, kk, v)
        want = ref.attention_ref(q, kk, v)
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_causal_first_position_sees_only_itself(self):
        k = keys(3)
        q, kk, v = (rand(k[i], (1, 1, 8, 4)) for i in range(3))
        out = attention(q, kk, v, causal=True)
        # Row 0 attends only to key 0 -> output equals v[0].
        np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], rtol=RTOL, atol=ATOL)

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.sampled_from([1, 2, 4]),
        s=st.sampled_from([4, 8, 32, 64]),
        dh=st.sampled_from([8, 16, 64]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, b, h, s, dh, causal, seed):
        k = keys(3, seed)
        q, kk, v = (rand(k[i], (b, h, s, dh)) for i in range(3))
        got = attention(q, kk, v, causal=causal)
        want = ref.attention_ref(q, kk, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# lstm_cell
# ---------------------------------------------------------------------------

class TestLstmCell:
    def test_basic(self):
        k = keys(6)
        x, h, c = rand(k[0], (4, 32)), rand(k[1], (4, 64)), rand(k[2], (4, 64))
        wx, wh = rand(k[3], (32, 256), 0.1), rand(k[4], (64, 256), 0.1)
        b = rand(k[5], (256,), 0.1)
        (h2, c2) = lstm_cell(x, h, c, wx, wh, b)
        h2r, c2r = ref.lstm_cell_ref(x, h, c, wx, wh, b)
        np.testing.assert_allclose(h2, h2r, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(c2, c2r, rtol=RTOL, atol=ATOL)

    def test_zero_state(self):
        k = keys(3)
        bsz, d, hid = 2, 16, 32
        x = rand(k[0], (bsz, d))
        h = jnp.zeros((bsz, hid))
        c = jnp.zeros((bsz, hid))
        wx, wh = rand(k[1], (d, 4 * hid), 0.1), rand(k[2], (hid, 4 * hid), 0.1)
        b = jnp.zeros((4 * hid,))
        h2, c2 = lstm_cell(x, h, c, wx, wh, b)
        h2r, c2r = ref.lstm_cell_ref(x, h, c, wx, wh, b)
        np.testing.assert_allclose(h2, h2r, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(c2, c2r, rtol=RTOL, atol=ATOL)

    def test_state_bounded(self):
        # tanh-bounded hidden state stays in [-1, 1].
        k = keys(6)
        x = rand(k[0], (4, 16), 10.0)
        h, c = rand(k[1], (4, 32), 10.0), rand(k[2], (4, 32), 10.0)
        wx, wh = rand(k[3], (16, 128)), rand(k[4], (32, 128))
        b = rand(k[5], (128,))
        h2, _ = lstm_cell(x, h, c, wx, wh, b)
        assert np.all(np.abs(np.asarray(h2)) <= 1.0 + 1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        bsz=st.sampled_from([1, 2, 4, 8, 17]),
        d=st.sampled_from([8, 32, 64]),
        hid=st.sampled_from([16, 32, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, bsz, d, hid, seed):
        k = keys(6, seed)
        x, h, c = rand(k[0], (bsz, d)), rand(k[1], (bsz, hid)), rand(k[2], (bsz, hid))
        wx, wh = rand(k[3], (d, 4 * hid), 0.1), rand(k[4], (hid, 4 * hid), 0.1)
        b = rand(k[5], (4 * hid,), 0.1)
        h2, c2 = lstm_cell(x, h, c, wx, wh, b)
        h2r, c2r = ref.lstm_cell_ref(x, h, c, wx, wh, b)
        np.testing.assert_allclose(h2, h2r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(c2, c2r, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# conv_block
# ---------------------------------------------------------------------------

class TestConvBlock:
    def test_residual_block(self):
        k = keys(3)
        x = rand(k[0], (2, 8, 8, 16))
        w, b = rand(k[1], (144, 16), 0.1), rand(k[2], (16,))
        np.testing.assert_allclose(
            conv_block(x, w, b), ref.conv_block_ref(x, w, b), rtol=RTOL, atol=ATOL
        )

    def test_stem(self):
        k = keys(3)
        x = rand(k[0], (2, 8, 8, 3))
        w, b = rand(k[1], (27, 16), 0.1), rand(k[2], (16,))
        np.testing.assert_allclose(
            conv_in(x, w, b), ref.conv_in_ref(x, w, b), rtol=RTOL, atol=ATOL
        )

    def test_identity_weights_residual_passthrough(self):
        # Zero conv weights + zero bias -> relu(0) + x == x.
        x = rand(keys(1)[0], (1, 4, 4, 8))
        w = jnp.zeros((72, 8))
        b = jnp.zeros((8,))
        np.testing.assert_allclose(conv_block(x, w, b), x, rtol=RTOL, atol=ATOL)

    @settings(max_examples=10, deadline=None)
    @given(
        bsz=st.integers(1, 3),
        hw=st.sampled_from([4, 8, 16]),
        c=st.sampled_from([4, 8, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, bsz, hw, c, seed):
        k = keys(3, seed)
        x = rand(k[0], (bsz, hw, hw, c))
        w, b = rand(k[1], (9 * c, c), 0.1), rand(k[2], (c,))
        np.testing.assert_allclose(
            conv_block(x, w, b), ref.conv_block_ref(x, w, b), rtol=1e-4, atol=1e-4
        )
