"""Manifest consistency: what aot.py wrote matches the live analytic
formulas and the actual lowered HLO files (requires `make artifacts`)."""

import json
import os

import pytest

from compile import analytic, aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def load():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_covers_default_variants():
    manifest = load()
    expected = {name for name, _, _ in aot.default_variants()}
    assert set(manifest) == expected


def test_profiles_match_analytic():
    for name, entry in load().items():
        prof = analytic.profile_for(entry["family"], entry["hyperparams"])
        assert entry["flops_per_sample"] == prof["flops"], name
        assert entry["params"] == prof["params"], name
        assert entry["weight_bytes"] == prof["weight_bytes"], name
        assert entry["act_bytes_per_sample"] == prof["act_bytes"], name


def test_hlo_files_exist_and_nontrivial():
    for name, entry in load().items():
        path = os.path.join(ART, entry["hlo_file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 10_000, name


def test_input_specs_match_model_builder():
    for name, entry in load().items():
        _, specs, x_spec = model.build(entry["family"], entry["hyperparams"])
        want = [(s.name, list(s.shape)) for s in (*specs, x_spec)]
        got = [(i["name"], i["shape"]) for i in entry["inputs"]]
        assert got == want, name


def test_param_count_matches_input_shapes():
    import numpy as np
    for name, entry in load().items():
        total = sum(
            int(np.prod(i["shape"])) for i in entry["inputs"][:-1]
        )
        assert total == entry["params"], name
