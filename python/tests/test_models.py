"""Layer-2 model tests: shapes, determinism, numerics, spec consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import analytic, model

FAMILY_CONFIGS = [
    ("mlp", {"depth": 2, "width": 64, "batch": 3}),
    ("cnn", {"depth": 2, "channels": 8, "hw": 8, "batch": 3}),
    ("rnn", {"depth": 2, "hidden": 32, "seq": 4, "batch": 3}),
    ("transformer", {"depth": 2, "d_model": 32, "heads": 2, "seq": 8, "batch": 3}),
]


@pytest.mark.parametrize("family,hp", FAMILY_CONFIGS)
class TestFamilies:
    def test_output_shape(self, family, hp):
        fn, specs, xs = model.build(family, hp)
        params = model.init_params(specs)
        x = jax.random.normal(jax.random.PRNGKey(0), xs.shape)
        (out,) = fn(params, x)
        assert out.shape == (hp["batch"], hp.get("classes", 16))

    def test_finite_outputs(self, family, hp):
        fn, specs, xs = model.build(family, hp)
        params = model.init_params(specs)
        x = jax.random.normal(jax.random.PRNGKey(1), xs.shape) * 3.0
        (out,) = fn(params, x)
        assert np.isfinite(np.asarray(out)).all()

    def test_deterministic(self, family, hp):
        fn, specs, xs = model.build(family, hp)
        params = model.init_params(specs)
        x = jax.random.normal(jax.random.PRNGKey(2), xs.shape)
        (a,) = fn(params, x)
        (b,) = fn(params, x)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_batch_independence(self, family, hp):
        # Row i of a batched run must equal a single-sample run of row i —
        # the invariant dynamic batching relies on (paper §5.3).
        fn, specs, xs = model.build(family, hp)
        params = model.init_params(specs)
        x = jax.random.normal(jax.random.PRNGKey(3), xs.shape)
        (batched,) = fn(params, x)
        hp1 = dict(hp, batch=1)
        fn1, _, _ = model.build(family, hp1)
        (single,) = fn1(params, x[:1])
        np.testing.assert_allclose(
            np.asarray(batched)[0], np.asarray(single)[0], rtol=3e-5, atol=3e-5
        )

    def test_param_specs_match_init(self, family, hp):
        _, specs, _ = model.build(family, hp)
        params = model.init_params(specs)
        assert len(params) == len(specs)
        for p, s in zip(params, specs):
            assert p.shape == s.shape, s.name

    def test_analytic_params_match_actual(self, family, hp):
        # The manifest's analytic param count equals the true tensor count.
        _, specs, _ = model.build(family, hp)
        actual = sum(int(np.prod(s.shape)) for s in specs)
        prof = analytic.profile_for(family, hp)
        assert prof["params"] == actual


class TestRealWorldCatalog:
    def test_all_entries_build(self):
        for name, (family, hp0) in model.REAL_WORLD.items():
            hp = dict(hp0, batch=1)
            fn, specs, xs = model.build(family, hp)
            assert len(specs) > 0, name

    def test_resnet_mini_heavier_than_mobilenet_mini(self):
        # Preserves the paper's Fig 10a relationship.
        rn = model.REAL_WORLD["resnet_mini"]
        mb = model.REAL_WORLD["mobilenet_mini"]
        prn = analytic.profile_for(rn[0], dict(rn[1], batch=1))
        pmb = analytic.profile_for(mb[0], dict(mb[1], batch=1))
        assert prn["flops"] > 4 * pmb["flops"]

    def test_arithmetic_intensity_grows_with_batch(self):
        # The Roofline driver (Fig 10b): batch raises intensity.
        prof = analytic.mlp_profile(8, 512)
        def intensity(b):
            return prof["flops"] * b / (prof["weight_bytes"] + prof["act_bytes"] * b)
        assert intensity(32) > intensity(8) > intensity(1)


class TestAnalytic:
    def test_mlp_flops_formula(self):
        p = analytic.mlp_profile(depth=4, width=128, in_dim=256, classes=16)
        assert p["flops"] == 2 * 256 * 128 + 4 * 2 * 128 * 128 + 2 * 128 * 16

    def test_deeper_costs_more(self):
        for fam, base in [
            ("mlp", {"width": 128}),
            ("cnn", {"channels": 16}),
            ("rnn", {"hidden": 64}),
            ("transformer", {"d_model": 64, "heads": 2}),
        ]:
            shallow = analytic.profile_for(fam, dict(base, depth=2))
            deep = analytic.profile_for(fam, dict(base, depth=8))
            assert deep["flops"] > shallow["flops"]
            assert deep["params"] > shallow["params"]

    def test_unknown_family_raises(self):
        with pytest.raises(ValueError):
            analytic.profile_for("gan", {"depth": 1})
