//! Fig 10: Roofline analysis on V100.
//!
//!  (a) real-world CNN models: MobileNets memory-bound, heavy models
//!      compute-bound
//!  (b) generated MLP models: batch raises intensity (-> compute-bound);
//!      more layers/neurons at small batch stay memory-bound

use inferbench::analysis::roofline_point;
use inferbench::hardware::{find, Parallelism};
use inferbench::models::{analytic, catalog};
use inferbench::util::render;

fn main() {
    let v100 = find("G1").unwrap();
    let ridge = v100.ridge_point();
    println!(
        "=== Fig 10: Roofline on V100 (peak {:.1} TFLOPS, BW {:.0} GB/s, ridge {ridge:.1} FLOP/B) ===",
        v100.peak_fp32_tflops, v100.mem_bw_gbs
    );

    println!("\n--- (a) real-world models, batch 16 ---\n");
    let mut rows = Vec::new();
    for m in catalog::CATALOG {
        let par = match m.task {
            catalog::Task::NLP => Parallelism::sequence(128),
            catalog::Task::TC => Parallelism::sequence(64),
            _ => Parallelism::cnn(28),
        };
        let p = roofline_point(m.name, v100, &m.profile, par, 16);
        rows.push(vec![
            m.name.to_string(),
            format!("{:.1}", p.intensity),
            render::fmt_si(p.achieved_flops) + "FLOP/s",
            render::fmt_si(p.roof_flops) + "FLOP/s",
            format!("{:.0}%", p.attainment() * 100.0),
            if p.memory_bound { "memory".into() } else { "compute".into() },
        ]);
    }
    print!(
        "{}",
        render::table(&["Model", "Intensity FLOP/B", "Achieved", "Roof", "Attainment", "Bound"], &rows)
    );

    println!("\n--- (b) generated MLP models ---\n");
    let mut rows = Vec::new();
    let mut chart = Vec::new();
    for (depth, width) in [(4u64, 512u64), (4, 2048), (16, 512), (16, 2048)] {
        for batch in [1usize, 8, 64] {
            let prof = analytic::mlp(depth, width, 256, 16);
            let p = roofline_point(
                &format!("mlp d{depth} w{width} b{batch}"),
                v100,
                &prof,
                Parallelism::mlp(),
                batch,
            );
            rows.push(vec![
                p.label.clone(),
                format!("{:.2}", p.intensity),
                render::fmt_si(p.achieved_flops),
                format!("{:.0}%", p.attainment() * 100.0),
                if p.memory_bound { "memory".into() } else { "compute".into() },
            ]);
            chart.push((p.label.clone(), p.intensity));
        }
    }
    print!(
        "{}",
        render::table(&["Config", "Intensity FLOP/B", "Achieved FLOP/s", "Attainment", "Bound"], &rows)
    );
    print!("{}", render::bar_chart("\nArithmetic intensity (ridge = compute-bound threshold)", &chart, 40));
    println!(
        "\nPaper shape check: (a) MobileNet left of ridge ({ridge:.1}), ResNet/GAN/BERT right; \
         (b) batch moves MLPs right (ops/s rises with intensity); width/depth alone do not."
    );
}
