//! Fig 11: tail latency of serving systems under varied workloads
//! (TFS + ResNet50 on V100 unless stated; Poisson arrivals; CDFs).
//!
//!  (a) CDF across fixed batch sizes at 100 rps
//!  (b) p99 vs arrival rate
//!  (c) spike load: base 50 rps with a 5x burst
//!  (d) CDF across the four serving platforms at 100 rps
//!
//! Every section is a grid of independent simulations, so each runs its
//! cells through the parallel sweep pool (`sweep::map_indexed`); results
//! come back in cell order and are identical at any core count.

use inferbench::coordinator::job::service_model_for;
use inferbench::models::catalog;
use inferbench::pipeline::{Processors, RequestPath, LAN};
use inferbench::serving::{backends, run, Policy, SimConfig};
use inferbench::sweep;
use inferbench::util::render;
use inferbench::workload::{Pattern, Workload};

const DURATION: f64 = 120.0;

fn base_config(rate: f64) -> SimConfig {
    let rn = catalog::find("resnet50").unwrap();
    SimConfig {
        workload: Workload::Stream { pattern: Pattern::Poisson { rate }, seed: 1234 },
        duration_s: DURATION,
        policy: Policy::Dynamic { max_size: 8, max_wait_s: 0.005 },
        software: &backends::TFS,
        service: service_model_for("resnet50", "G1").unwrap(),
        path: RequestPath { processors: Processors::image(), network: LAN, payload_bytes: rn.request_bytes },
        max_queue: 8192,
        seed: 99,
    }
}

fn main() {
    let threads = sweep::default_threads();
    println!("=== Fig 11a: tail latency CDF vs batch size (TFS, ResNet50, 100 rps) ===\n");
    let batch_cfgs: Vec<(usize, SimConfig)> = [1usize, 4, 8, 16]
        .iter()
        .map(|&batch| {
            let mut cfg = base_config(100.0);
            cfg.policy = Policy::Fixed { size: batch, timeout_s: 0.05 };
            (batch, cfg)
        })
        .collect();
    let results = sweep::map_indexed(&batch_cfgs, threads, |_, (_, cfg)| run(cfg));
    let mut series = Vec::new();
    let mut rows = Vec::new();
    for ((batch, _), r) in batch_cfgs.iter().zip(results) {
        let mut c = r.collector;
        rows.push(vec![
            format!("batch {batch}"),
            format!("{:.1}", c.e2e.percentile(50.0) * 1e3),
            format!("{:.1}", c.e2e.percentile(95.0) * 1e3),
            format!("{:.1}", c.e2e.percentile(99.0) * 1e3),
        ]);
        series.push((format!("b{batch}"), c.e2e.cdf(60)));
    }
    print!("{}", render::table(&["Policy", "p50 ms", "p95 ms", "p99 ms"], &rows));
    print!("{}", render::cdf_plot("\nlatency CDF (x: seconds)", &series, 60, 12));

    println!("\n=== Fig 11b: p99 vs arrival rate (TFS, batch 1; capacity ~170 rps) ===\n");
    let rate_cfgs: Vec<(f64, SimConfig)> = [25.0, 50.0, 100.0, 140.0, 160.0, 175.0]
        .iter()
        .map(|&rate| {
            let mut cfg = base_config(rate);
            cfg.policy = Policy::Single; // paper serves b=1; queueing sets the tail
            (rate, cfg)
        })
        .collect();
    let results = sweep::map_indexed(&rate_cfgs, threads, |_, (_, cfg)| run(cfg));
    let items: Vec<(String, f64)> = rate_cfgs
        .iter()
        .zip(&results)
        .map(|((rate, _), r)| {
            (format!("{rate:>3.0} rps"), r.collector.e2e.percentile(99.0) * 1e3)
        })
        .collect();
    print!("{}", render::bar_chart("p99 latency (ms) vs arrival rate", &items, 40));
    println!("(tail blows up approaching capacity — the paper's 11b shape)");

    println!("\n=== Fig 11c: spike load (base 50 rps, burst 300 rps for 20s, batch 1) ===\n");
    let mut spike_cfg = base_config(50.0);
    spike_cfg.policy = Policy::Single;
    spike_cfg.workload = Workload::Stream {
        pattern: Pattern::Spike {
            base_rate: 50.0,
            burst_rate: 300.0,
            start_s: 40.0,
            duration_s: 20.0,
        },
        seed: 77,
    };
    let mut steady_cfg = base_config(50.0);
    steady_cfg.policy = Policy::Single;
    let pair = [spike_cfg, steady_cfg];
    let results = sweep::map_indexed(&pair, threads, |_, cfg| run(cfg));
    let (r, steady_r) = (&results[0], &results[1]);
    let c = &r.collector;
    println!(
        "completed {} dropped {}; p50 {:.1} ms p99 {:.1} ms max {:.1} ms",
        c.completed,
        r.dropped,
        c.e2e.percentile(50.0) * 1e3,
        c.e2e.percentile(99.0) * 1e3,
        c.e2e.max() * 1e3,
    );
    let steady = steady_r.collector.e2e.percentile(99.0);
    println!(
        "steady-state p99 at 50 rps: {:.1} ms -> spike inflates p99 by {:.1}x (paper: TFS cannot absorb spikes)",
        steady * 1e3,
        c.e2e.percentile(99.0) / steady
    );

    println!("\n=== Fig 11d: four serving platforms (ResNet50, V100, 100 rps) ===\n");
    let sw_cfgs: Vec<SimConfig> = backends::ALL
        .iter()
        .map(|&sw| {
            let mut cfg = base_config(100.0);
            cfg.software = sw;
            cfg
        })
        .collect();
    let results = sweep::map_indexed(&sw_cfgs, threads, |_, cfg| run(cfg));
    let mut series = Vec::new();
    let mut rows = Vec::new();
    for (&sw, r) in backends::ALL.iter().zip(results) {
        let mut c = r.collector;
        rows.push(vec![
            sw.name.to_string(),
            format!("{:.1}", c.e2e.percentile(50.0) * 1e3),
            format!("{:.1}", c.e2e.percentile(95.0) * 1e3),
            format!("{:.1}", c.e2e.percentile(99.0) * 1e3),
            format!("{:.1}", c.throughput_rps()),
        ]);
        series.push((sw.id.to_string(), c.e2e.cdf(60)));
    }
    print!("{}", render::table(&["Software", "p50 ms", "p95 ms", "p99 ms", "rps"], &rows));
    print!("{}", render::cdf_plot("\nlatency CDF by software (x: seconds)", &series, 60, 12));
    println!("\nPaper shape check: larger batch -> longer tail; rate -> tail blow-up near capacity; TrIS best, then ONNX-RT, TFS, TorchScript.");
}
