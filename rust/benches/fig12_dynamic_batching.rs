//! Fig 12: dynamic-batching throughput, TFS vs TrIS, vs client
//! concurrency (closed-loop clients, ResNet50 on V100).
//!
//! Paper reading: TrIS exploits the feature and scales throughput
//! steadily; TFS's naive scheduler can perform *worse than no batching*
//! at small concurrency.
//!
//! The concurrency × (software, batching) grid — 28 independent
//! closed-loop simulations — runs on the parallel sweep pool
//! (`sweep::map_indexed`); the shape checks reuse the grid cells instead
//! of re-running them.

use inferbench::coordinator::job::service_model_for;
use inferbench::models::catalog;
use inferbench::pipeline::{Processors, RequestPath, LAN};
use inferbench::serving::{backends, run, Policy, SimConfig, Software};
use inferbench::sweep;
use inferbench::util::render;

const DURATION: f64 = 60.0;
const CONCURRENCIES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// One grid cell: a closed-loop run at some concurrency, with dynamic
/// batching on or off.
struct Cell {
    concurrency: usize,
    software: &'static Software,
    dynamic: bool,
}

fn throughput(software: &'static Software, concurrency: usize, dynamic: bool) -> (f64, f64) {
    let rn = catalog::find("resnet50").unwrap();
    let config = SimConfig {
        workload: inferbench::workload::Workload::ClosedLoop { clients: concurrency },
        duration_s: DURATION,
        policy: if dynamic {
            Policy::Dynamic { max_size: 32, max_wait_s: 0.002 }
        } else {
            Policy::Single
        },
        software,
        service: service_model_for("resnet50", "G1").unwrap(),
        path: RequestPath { processors: Processors::image(), network: LAN, payload_bytes: rn.request_bytes },
        max_queue: 8192,
        seed: 31,
    };
    let r = run(&config);
    (r.throughput_rps(), r.mean_batch())
}

fn main() {
    let threads = sweep::default_threads();
    println!(
        "=== Fig 12: dynamic batching throughput vs concurrency (ResNet50, V100; \
         sweep on {threads} threads) ===\n"
    );
    // Row-major grid: per concurrency, the four (software, batching)
    // variants in column order.
    let mut cells = Vec::new();
    for &concurrency in &CONCURRENCIES {
        for (software, dynamic) in [
            (&backends::TFS, false),
            (&backends::TFS, true),
            (&backends::TRIS, false),
            (&backends::TRIS, true),
        ] {
            cells.push(Cell { concurrency, software, dynamic });
        }
    }
    let results = sweep::map_indexed(&cells, threads, |_, cell| {
        throughput(cell.software, cell.concurrency, cell.dynamic)
    });
    let at = |concurrency: usize, software_id: &str, dynamic: bool| -> (f64, f64) {
        let idx = cells
            .iter()
            .position(|c| {
                c.concurrency == concurrency && c.software.id == software_id && c.dynamic == dynamic
            })
            .expect("cell in grid");
        results[idx]
    };

    let mut rows = Vec::new();
    for &concurrency in &CONCURRENCIES {
        let (tfs_off, _) = at(concurrency, "tfs", false);
        let (tfs_dyn, tfs_b) = at(concurrency, "tfs", true);
        let (tris_off, _) = at(concurrency, "tris", false);
        let (tris_dyn, tris_b) = at(concurrency, "tris", true);
        rows.push(vec![
            concurrency.to_string(),
            format!("{tfs_off:.0}"),
            format!("{tfs_dyn:.0} (b={tfs_b:.1})"),
            format!("{tris_off:.0}"),
            format!("{tris_dyn:.0} (b={tris_b:.1})"),
        ]);
    }
    print!(
        "{}",
        render::table(
            &["Concurrency", "TFS no-batch", "TFS dynamic", "TrIS no-batch", "TrIS dynamic"],
            &rows
        )
    );
    let (tfs_dyn_small, _) = at(2, "tfs", true);
    let (tfs_off_small, _) = at(2, "tfs", false);
    let (tris_dyn_big, _) = at(64, "tris", true);
    let (tris_off_big, _) = at(64, "tris", false);
    println!(
        "\nPaper shape checks: TFS dynamic < TFS no-batch at concurrency 2: {} ({:.0} vs {:.0} rps); \
         TrIS dynamic >> no-batch at concurrency 64: {} ({:.0} vs {:.0} rps).",
        tfs_dyn_small < tfs_off_small,
        tfs_dyn_small,
        tfs_off_small,
        tris_dyn_big > 1.5 * tris_off_big,
        tris_dyn_big,
        tris_off_big,
    );
}
