//! Fig 13: GPU utilization timelines under real service workloads.
//!
//! The paper's two services: BERT at 30 req/s batch 1, and ResNet50 at
//! 160 req/s batch 1, each on a V100 behind TFS and TrIS. Reading: GPU
//! utilization is dynamic with the workload and *under-utilized* at low
//! arrival rates even for a heavy model — the headroom that motivates
//! sharing (MPS) work.

use inferbench::coordinator::job::service_model_for;
use inferbench::models::catalog;
use inferbench::pipeline::{Processors, RequestPath, LAN};
use inferbench::serving::{backends, run, Policy, SimConfig, Software};
use inferbench::util::render;

const DURATION: f64 = 60.0;

fn timeline(model: &str, rate: f64, software: &'static Software) -> (Vec<f64>, f64) {
    let m = catalog::find(model).unwrap();
    let config = SimConfig {
        workload: inferbench::workload::Workload::Stream {
            pattern: inferbench::workload::Pattern::Poisson { rate },
            seed: 5150,
        },
        duration_s: DURATION,
        policy: Policy::Single, // paper: batch size 1
        software,
        service: service_model_for(model, "G1").unwrap(),
        path: RequestPath { processors: Processors::image(), network: LAN, payload_bytes: m.request_bytes },
        max_queue: 8192,
        seed: 21,
    };
    let r = run(&config);
    // DCGM-style utilization: busy fraction, not FLOPs efficiency.
    (r.busy_timeline.series(), r.busy_timeline.mean())
}

fn sparkline(series: &[f64]) -> String {
    let glyphs = [' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    series
        .iter()
        .take(120)
        .map(|u| glyphs[((u * (glyphs.len() - 1) as f64).round() as usize).min(glyphs.len() - 1)])
        .collect()
}

fn main() {
    println!("=== Fig 13: GPU utilization under service workloads (V100, batch 1) ===\n");
    let mut rows = Vec::new();
    for (model, rate) in [("bert_large", 30.0), ("resnet50", 160.0)] {
        for sw in [&backends::TFS, &backends::TRIS] {
            let (series, mean) = timeline(model, rate, sw);
            println!("{model} @ {rate:.0} rps on {}: mean util {:.0}%", sw.id, mean * 100.0);
            println!("  [{}]", sparkline(&series));
            rows.push(vec![
                model.to_string(),
                format!("{rate:.0}"),
                sw.id.to_string(),
                format!("{:.1}%", mean * 100.0),
                format!("{:.1}%", series.iter().cloned().fold(0.0, f64::max) * 100.0),
            ]);
        }
    }
    print!("{}", render::table(&["Model", "Rate", "Software", "Mean util", "Peak util"], &rows));
    println!(
        "\nPaper shape check: utilization fluctuates with the Poisson workload and stays well \
         below 100% at these rates (BERT@30 light; ResNet50@160 heavier) — room for GPU sharing."
    );

    // Ablation: the sharing manager (§4.2.1) acting on exactly this
    // headroom — colocate the two services above via MPS and report the
    // Sharing-vs-Dedicated trade-off (§3.3).
    use inferbench::hardware::sharing::{consolidation, share, SharedService};
    use inferbench::hardware::{find, Parallelism};
    let v100 = find("G1").unwrap();
    let services = [
        SharedService {
            name: "bert@30rps".into(),
            profile: catalog::find("bert_large").unwrap().profile,
            parallelism: Parallelism::sequence(128),
            batch: 1,
            rate_rps: 30.0,
        },
        SharedService {
            name: "resnet@160rps".into(),
            profile: catalog::find("resnet50").unwrap().profile,
            parallelism: Parallelism::cnn(28),
            batch: 1,
            rate_rps: 160.0,
        },
    ];
    let report = share(v100, &services);
    let (needed, saved) = consolidation(&report);
    println!("\n--- sharing ablation (MPS, §3.3 Sharing vs Dedicated) ---\n");
    for o in &report.outcomes {
        println!(
            "  {:<16} exclusive {:>8} -> shared {:>8}  (demand {:.0}%)",
            o.name,
            render::fmt_duration(o.exclusive_s),
            render::fmt_duration(o.shared_s),
            o.demand * 100.0
        );
    }
    println!(
        "  total demand {:.0}% of one V100 -> {} GPU(s) under sharing, {} saved vs dedicated; slowdown {:.2}x",
        report.total_demand * 100.0,
        needed,
        saved,
        report.slowdown
    );
}
