//! Fig 14: inference-pipeline decomposition (ResNet50 + TFS).
//!
//!  (a) per-stage latency vs batch size (LAN): transmission comparable to
//!      inference at small batch; inference dominates at large batch
//!  (b) end-to-end latency by network technology: LAN < WiFi < 4G LTE
//!  (c) cold-start latency of models under TFS vs TrIS (anchored by the
//!      real measured XLA compile time of the mini artifacts when present)

use inferbench::coordinator::job::service_model_for;
use inferbench::metrics::STAGES;
use inferbench::models::catalog;
use inferbench::pipeline::{Network, Processors, RequestPath, LAN, LTE_4G, WIFI};
use inferbench::runtime::Engine;
use inferbench::serving::{backends, run, Policy, SimConfig};
use inferbench::util::render;
use inferbench::workload::{Pattern, Workload};

const DURATION: f64 = 60.0;

fn sim(batch: usize, network: Network) -> SimConfig {
    let rn = catalog::find("resnet50").unwrap();
    SimConfig {
        workload: Workload::Stream { pattern: Pattern::Poisson { rate: 60.0 }, seed: 2020 },
        duration_s: DURATION,
        policy: if batch == 1 {
            Policy::Single
        } else {
            Policy::Fixed { size: batch, timeout_s: 0.05 }
        },
        software: &backends::TFS,
        service: service_model_for("resnet50", "G1").unwrap(),
        path: RequestPath { processors: Processors::image(), network, payload_bytes: rn.request_bytes },
        max_queue: 8192,
        seed: 4,
    }
}

fn main() {
    println!("=== Fig 14a: latency per stage vs batch (LAN, ResNet50+TFS) ===\n");
    let mut rows = Vec::new();
    for batch in [1usize, 4, 8, 16] {
        let r = run(&sim(batch, LAN));
        let means = r.collector.stage_means();
        let mut row = vec![format!("b{batch}")];
        for s in STAGES {
            row.push(format!("{:.2}", means[&s] * 1e3));
        }
        let total: f64 = STAGES.iter().map(|s| means[s]).sum();
        row.push(format!("{:.2}", total * 1e3));
        rows.push(row);
    }
    print!(
        "{}",
        render::table(
            &["Batch", "pre ms", "transmit ms", "batch-wait ms", "infer ms", "post ms", "total ms"],
            &rows
        )
    );
    println!("\nCheck: at b1 transmission ~ inference; at b16 inference+wait dominate.");

    println!("\n=== Fig 14b: end-to-end latency by network technology (b1) ===\n");
    let mut items = Vec::new();
    for net in [LAN, WIFI, LTE_4G] {
        let r = run(&sim(1, net));
        let c = r.collector;
        items.push((net.name.to_string(), c.e2e.percentile(50.0) * 1e3));
    }
    print!("{}", render::bar_chart("median e2e latency (ms) by network", &items, 40));
    println!("Check: 4G LTE slowest — cloud DL from mobile pays heavy transmission cost.");

    println!("\n=== Fig 14c: cold-start latency, models x software ===\n");
    // Software model component (load + init) plus, when artifacts exist,
    // the real measured XLA compile time of the matching mini model.
    let engine = Engine::cpu("artifacts").ok();
    let mut rows = Vec::new();
    for m in ["mobilenet_v1", "resnet50", "bert_large"] {
        let model = catalog::find(m).unwrap();
        let measured = engine.as_ref().and_then(|e| {
            let stem = model.artifact_stem?;
            e.load(&format!("{stem}_b1"), 0).ok().map(|l| l.compile_time.as_secs_f64())
        });
        let mut row = vec![m.to_string()];
        for sw in [&backends::TFS, &backends::TRIS] {
            let t = sw.coldstart_s(model.profile.weight_bytes) + measured.unwrap_or(0.0);
            row.push(format!("{:.1}s", t));
        }
        row.push(
            measured.map(|t| format!("{:.2}s", t)).unwrap_or_else(|| "-".into()),
        );
        rows.push(row);
    }
    print!(
        "{}",
        render::table(&["Model", "TFS coldstart", "TrIS coldstart", "measured XLA compile (mini)"], &rows)
    );
    println!("\nCheck: TrIS slowest to start (>10s even for a small IC model); cold start grows with model size.");
}
