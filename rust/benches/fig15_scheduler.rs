//! Fig 15: the scheduler case study — average benchmark-job completion
//! time under RR+FCFS (baseline 1), RR+SJF (baseline 2), and the paper's
//! QA+SJF two-tier scheduler.
//!
//! Paper headline: QA+SJF reduces average JCT by 1.43x (~30%) vs RR+FCFS.
//! The bench sweeps workload seeds and reports the distribution of the
//! improvement factor, plus a sensitivity sweep over worker count and
//! load, and Algorithm-1 batch mode.

use inferbench::coordinator::scheduler::{
    schedule_batch, simulate_online, synthetic_jobs, SchedulerPolicy,
};
use inferbench::util::render;
use inferbench::util::stats::Summary;

fn main() {
    let policies =
        [SchedulerPolicy::rr_fcfs(), SchedulerPolicy::rr_sjf(), SchedulerPolicy::qa_sjf()];

    println!("=== Fig 15: scheduler comparison (online DES, 200 jobs, 4 workers) ===\n");
    // Distribution of improvement across 40 workload seeds.
    let mut speedup_rr_sjf = Summary::new();
    let mut speedup_qa_sjf = Summary::new();
    let mut mean_jct = [Summary::new(), Summary::new(), Summary::new()];
    for seed in 0..40u64 {
        let jobs = synthetic_jobs(200, 20.0, seed);
        let jcts: Vec<f64> =
            policies.iter().map(|p| simulate_online(&jobs, 4, *p).mean_jct_s()).collect();
        for (i, j) in jcts.iter().enumerate() {
            mean_jct[i].record(*j);
        }
        speedup_rr_sjf.record(jcts[0] / jcts[1]);
        speedup_qa_sjf.record(jcts[0] / jcts[2]);
    }
    let items: Vec<(String, f64)> = policies
        .iter()
        .zip(&mut mean_jct)
        .map(|(p, s)| (p.label().to_string(), s.mean()))
        .collect();
    print!("{}", render::bar_chart("average JCT (s) over 40 workloads", &items, 40));
    println!(
        "\nimprovement vs RR+FCFS: RR+SJF {:.2}x (p5 {:.2} p95 {:.2}) | QA+SJF {:.2}x (p5 {:.2} p95 {:.2})",
        speedup_rr_sjf.mean(),
        speedup_rr_sjf.percentile(5.0),
        speedup_rr_sjf.percentile(95.0),
        speedup_qa_sjf.mean(),
        speedup_qa_sjf.percentile(5.0),
        speedup_qa_sjf.percentile(95.0),
    );
    println!("paper: QA+SJF = 1.43x (30% reduction)");

    println!("\n--- sensitivity: workers x load (QA+SJF speedup vs RR+FCFS) ---\n");
    let mut rows = Vec::new();
    for workers in [2usize, 4, 8] {
        let mut row = vec![format!("{workers} workers")];
        for gap in [10.0, 20.0, 40.0] {
            let mut s = Summary::new();
            for seed in 0..10u64 {
                let jobs = synthetic_jobs(150, gap, 100 + seed);
                let base = simulate_online(&jobs, workers, SchedulerPolicy::rr_fcfs()).mean_jct_s();
                let ours = simulate_online(&jobs, workers, SchedulerPolicy::qa_sjf()).mean_jct_s();
                s.record(base / ours);
            }
            row.push(format!("{:.2}x", s.mean()));
        }
        rows.push(row);
    }
    print!(
        "{}",
        render::table(
            &["", "heavy load (gap 10s)", "medium (gap 20s)", "light (gap 40s)"],
            &rows
        )
    );

    println!("\n--- Algorithm 1 batch mode (all jobs at t=0, 100 jobs, 4 workers) ---\n");
    let mut rows = Vec::new();
    let jobs: Vec<_> = synthetic_jobs(100, 0.0001, 7)
        .into_iter()
        .map(|mut j| {
            j.submit_s = 0.0;
            j
        })
        .collect();
    let base = schedule_batch(&jobs, 4, SchedulerPolicy::rr_fcfs()).mean_jct_s();
    for p in policies {
        let out = schedule_batch(&jobs, 4, p);
        rows.push(vec![
            p.label().to_string(),
            format!("{:.1}s", out.mean_jct_s()),
            format!("{:.2}x", base / out.mean_jct_s()),
            format!("{:.1}s", out.makespan_s()),
        ]);
    }
    print!("{}", render::table(&["Policy", "Mean JCT", "vs RR+FCFS", "Makespan"], &rows));
}
