//! Fig 16 (beyond the paper): cluster scale-out — throughput and tail
//! latency vs replica count × router policy, on the N-replica serving
//! engine. Two readings:
//!
//!  (a) homogeneous scale-out: offered load grows with N (170 rps per
//!      replica against ~238 rps single-replica capacity); throughput
//!      scales near-linearly while the router policy sets the tail.
//!  (b) heterogeneous 4-replica cluster (2 fast + 2 slow): round-robin
//!      overloads the slow pair and its p99 diverges; least-outstanding
//!      (and mostly power-of-two) keep the cluster stable, and the
//!      latency-aware EWMA router shifts load off the slow pair
//!      entirely from its response-time signal. This is the
//!      replica-scaling trade-off highlighted by "Scalable AI Inference"
//!      serving surveys: the router, not the hardware, sets the tail.
//!
//! Both grids execute on the parallel sweep engine (`inferbench::sweep`):
//! cells run across all cores and come back in plan order, bit-identical
//! to a serial sweep, so the tables below don't depend on core count.

use inferbench::metrics::MetricsMode;
use inferbench::pipeline::{Processors, RequestPath};
use inferbench::serving::cluster::{ClusterConfig, ReplicaConfig};
use inferbench::serving::{backends, Policy, RouterPolicy, ServiceModel};
use inferbench::sweep::{self, SweepPlan};
use inferbench::util::render;
use inferbench::workload::{Pattern, Workload};

const DURATION: f64 = 40.0;
const SEED: u64 = 4242;

fn replica(per_req_ms: f64) -> ReplicaConfig {
    ReplicaConfig {
        software: &backends::TRIS,
        service: ServiceModel::Measured {
            per_batch: vec![(1, per_req_ms / 1e3), (8, per_req_ms * 2.2 / 1e3)],
            utilization: 0.6,
        },
        policy: Policy::Single,
        max_queue: 100_000,
    }
}

fn routers() -> [RouterPolicy; 4] {
    [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastOutstanding,
        RouterPolicy::PowerOfTwoChoices { seed: SEED },
        RouterPolicy::LatencyEwma { alpha: 0.3, stale_s: 0.1 },
    ]
}

fn cluster(replicas: Vec<ReplicaConfig>, rate: f64, router: RouterPolicy) -> ClusterConfig {
    ClusterConfig {
        workload: Workload::Stream { pattern: Pattern::Poisson { rate }, seed: SEED },
        duration_s: DURATION,
        replicas,
        router,
        autoscale: None,
        cold_start: None,
        path: RequestPath::local(Processors::none()),
        metrics: MetricsMode::Exact,
        admission: None,
        faults: None,
        retry: None,
        seed: SEED,
    }
}

fn main() {
    let threads = sweep::default_threads();
    println!(
        "=== Fig 16a: homogeneous scale-out (4.2 ms replicas, 170 rps offered per replica; \
         sweep on {threads} threads) ===\n"
    );
    let grid: Vec<(usize, RouterPolicy)> = [1usize, 2, 4, 8]
        .iter()
        .flat_map(|&n| routers().into_iter().map(move |r| (n, r)))
        .collect();
    let mut plan = SweepPlan::new(SEED);
    for &(n, router) in &grid {
        // Cells pin their own seeds (the committed table predates the
        // sweep engine); the derived cell seed is unused here.
        plan.push(format!("{n}x{}", router.label()), move |_seed| {
            cluster((0..n).map(|_| replica(5.0)).collect(), 170.0 * n as f64, router)
        });
    }
    let outcome = plan.run(threads);
    let mut rows = Vec::new();
    for (&(n, router), cell) in grid.iter().zip(&outcome.cells) {
        let r = &cell.result;
        // Busy fraction over the offered-load window only (the
        // timeline's horizon extends past DURATION for drain).
        let buckets = (DURATION / 0.5) as usize;
        let util: f64 = r
            .replicas
            .iter()
            .map(|m| {
                let s = m.busy_timeline.series();
                let w = &s[..buckets.min(s.len())];
                w.iter().sum::<f64>() / w.len().max(1) as f64
            })
            .sum::<f64>()
            / n as f64;
        let c = &r.collector;
        rows.push(vec![
            n.to_string(),
            router.label().to_string(),
            format!("{:.0}", c.throughput_rps()),
            format!("{:.1}", c.e2e.percentile(50.0) * 1e3),
            format!("{:.1}", c.e2e.percentile(99.0) * 1e3),
            format!("{:.0}%", util * 100.0),
        ]);
    }
    print!(
        "{}",
        render::table(&["Replicas", "Router", "rps", "p50 ms", "p99 ms", "mean util"], &rows)
    );
    println!("(throughput tracks replica count; least-outstanding/p2c/ewma trim the queueing tail)");

    println!("\n=== Fig 16b: heterogeneous 4-replica cluster (2x 4 ms + 2x 16 ms), 380 rps ===\n");
    let mut plan = SweepPlan::new(SEED);
    for router in routers() {
        plan.push(router.label(), move |_seed| {
            cluster(
                vec![replica(4.0), replica(4.0), replica(16.0), replica(16.0)],
                380.0,
                router,
            )
        });
    }
    let outcome = plan.run(threads);
    let mut rows = Vec::new();
    let mut p99_by_router = Vec::new();
    for (router, cell) in routers().into_iter().zip(&outcome.cells) {
        let r = &cell.result;
        let per: Vec<String> =
            r.replicas.iter().map(|m| m.collector.completed.to_string()).collect();
        let c = &r.collector;
        let p99 = c.e2e.percentile(99.0);
        p99_by_router.push((router.label(), p99));
        rows.push(vec![
            router.label().to_string(),
            format!("{:.0}", c.throughput_rps()),
            format!("{:.1}", c.e2e.percentile(50.0) * 1e3),
            format!("{:.1}", p99 * 1e3),
            per.join("/"),
        ]);
    }
    print!(
        "{}",
        render::table(&["Router", "rps", "p50 ms", "p99 ms", "completed per replica"], &rows)
    );

    let p99_of = |label: &str| {
        p99_by_router.iter().find(|(l, _)| *l == label).map(|(_, v)| *v).unwrap()
    };
    let (rr, lo) = (p99_of("round-robin"), p99_of("least-outstanding"));
    let ewma = p99_of("latency-ewma");
    println!(
        "\nround-robin p99 {:.1} ms vs least-outstanding p99 {:.1} ms ({:.1}x); \
         latency-ewma p99 {:.1} ms",
        rr * 1e3,
        lo * 1e3,
        rr / lo,
        ewma * 1e3
    );
    assert!(
        lo <= rr,
        "least-outstanding p99 ({lo}s) must not exceed round-robin p99 ({rr}s) on heterogeneous replicas"
    );
    println!("PASS: least-outstanding p99 <= round-robin p99 on heterogeneous replicas");
}
