//! Fig 17 (beyond the paper): autoscaling under spike load — p99 during
//! the burst vs during recovery, for scale policies × cold-start profiles.
//!
//! The paper measures cold starts of ">10 s even for a small IC model"
//! (Fig 14c, worst on TrIS); "Scalable AI Inference" shows replica
//! scale-up lag dominating tail latency under bursts. This figure puts the
//! two together on the elastic cluster tier: a Fig 11c spike (6x the base
//! rate) hits a 2-replica fleet; the autoscaler adds replicas that must
//! pay their software's cold start before taking traffic, then
//! drains-on-remove back down after the burst. Readings:
//!
//!  (a) burst-window p99 is strictly worse for the slow-cold-start
//!      backend (tris, ~9.4 s for this model) than the fast one (tfs,
//!      ~2.2 s) under the same scale policy — capacity arrives too late,
//!      even though TrIS serves each request *faster* once warm;
//!  (b) drain-on-remove preserves `issued == completed + dropped` exactly
//!      across every scale event — no request is lost at retirement.
//!
//! The policy × software grid runs on the parallel sweep engine
//! (`inferbench::sweep`); cells come back in plan order, bit-identical to
//! a serial sweep, and the replica-count timeline is read straight from
//! the grid cell instead of a fifth run.
//!
//! Pass `--trace-out <path>` to run the grid with full tracing (which is
//! bit-invisible — every assertion above still holds) and export the
//! queue-depth/TrIS cell's request spans + gauge timelines as Perfetto
//! JSON, loadable at ui.perfetto.dev. CI greps the `trace-export:` line.

use inferbench::metrics::{MetricsMode, ScaleEventKind};
use inferbench::obs::{TraceConfig, TraceSink};
use inferbench::pipeline::{Processors, RequestPath};
use inferbench::serving::autoscale::{AutoscaleConfig, ScalePolicy};
use inferbench::serving::cluster::{ClusterConfig, ReplicaConfig};
use inferbench::serving::{backends, Policy, RouterPolicy, ServiceModel, Software};
use inferbench::sweep::{self, SweepPlan};
use inferbench::util::cli::Args;
use inferbench::util::render;
use inferbench::workload::{Pattern, Workload};

const DURATION: f64 = 60.0;
const BASE_RATE: f64 = 150.0;
const BURST_RATE: f64 = 900.0;
const BURST_START: f64 = 20.0;
const BURST_LEN: f64 = 12.0;
const SEED: u64 = 1717;
/// ~100 MB of weights — a small IC model (the paper's Fig 14c case).
const WEIGHT_BYTES: u64 = 100_000_000;
const INITIAL_REPLICAS: usize = 2;

fn replica(software: &'static Software) -> ReplicaConfig {
    ReplicaConfig {
        software,
        // 5 ms measured device time (~200 rps capacity before software
        // factors); identical across backends so cold start + overheads
        // are the only difference.
        service: ServiceModel::Measured { per_batch: vec![(1, 0.005)], utilization: 0.6 },
        policy: Policy::Single,
        max_queue: 200_000,
    }
}

fn policies() -> [(&'static str, ScalePolicy); 2] {
    [
        (
            "queue-depth",
            ScalePolicy::QueueDepth { up_per_replica: 6.0, down_per_replica: 0.5, cooldown_s: 1.0 },
        ),
        ("utilization", ScalePolicy::Utilization { up: 0.85, down: 0.25, cooldown_s: 1.0 }),
    ]
}

fn config_for(software: &'static Software, policy: ScalePolicy) -> ClusterConfig {
    ClusterConfig {
        workload: Workload::Stream {
            pattern: Pattern::Spike {
                base_rate: BASE_RATE,
                burst_rate: BURST_RATE,
                start_s: BURST_START,
                duration_s: BURST_LEN,
            },
            seed: SEED,
        },
        duration_s: DURATION,
        replicas: (0..INITIAL_REPLICAS).map(|_| replica(software)).collect(),
        router: RouterPolicy::LeastOutstanding,
        autoscale: Some(AutoscaleConfig {
            policy,
            min_replicas: INITIAL_REPLICAS,
            max_replicas: 8,
            template: replica(software),
            weight_bytes: WEIGHT_BYTES,
            eval_interval_s: 0.5,
        }),
        cold_start: None,
        path: RequestPath::local(Processors::none()),
        metrics: MetricsMode::Exact,
        admission: None,
        faults: None,
        retry: None,
        seed: SEED,
    }
}

fn main() {
    let args = Args::from_env(&[]);
    let trace_out = args.trace_out();
    let threads = sweep::default_threads();
    println!(
        "=== Fig 17: autoscale under spike load ({BASE_RATE} rps base, {BURST_RATE} rps burst \
         [{BURST_START}, {}) s, 2 -> max 8 replicas; sweep on {threads} threads) ===\n",
        BURST_START + BURST_LEN
    );
    let mut grid = Vec::new();
    for (plabel, policy) in policies() {
        for software in [&backends::TFS, &backends::TRIS] {
            grid.push((plabel, policy, software));
        }
    }
    let mut plan = SweepPlan::new(SEED);
    for &(plabel, policy, software) in &grid {
        plan.push(format!("{plabel}/{}", software.id), move |_seed| config_for(software, policy));
    }
    // Tracing is a pure observer: with `--trace-out` every cell runs
    // fully traced and every assertion below still holds bit-for-bit.
    if trace_out.is_some() {
        plan.set_trace(TraceConfig::full());
    }
    let outcome = plan.run(threads);

    let mut rows = Vec::new();
    // (policy label, software id) -> burst-window p99 seconds
    let mut burst_p99 = Vec::new();
    for (&(plabel, _, software), cell) in grid.iter().zip(&outcome.cells) {
        let r = &cell.result;
        // (b) conservation across every scale event, exactly.
        assert_eq!(
            r.collector.completed + r.dropped,
            r.issued,
            "{plabel}/{}: drain-on-remove lost requests",
            software.id
        );
        let adds = r.scale.count(ScaleEventKind::AddRequested);
        let retires = r.scale.count(ScaleEventKind::Retired);
        assert!(adds >= 1, "{plabel}/{}: burst must trigger scale-up", software.id);
        assert!(
            retires >= 1,
            "{plabel}/{}: post-burst lull must trigger drain-on-remove",
            software.id
        );
        let steady = r.collector.e2e_in_window(0.0, BURST_START);
        let in_burst = r.collector.e2e_in_window(BURST_START, BURST_START + BURST_LEN);
        let recovery =
            r.collector.e2e_in_window(BURST_START + BURST_LEN, BURST_START + BURST_LEN + 12.0);
        burst_p99.push(((plabel, software.id), in_burst.percentile(99.0)));
        rows.push(vec![
            plabel.to_string(),
            software.id.to_string(),
            format!("{:.1}", software.coldstart_s(WEIGHT_BYTES)),
            format!("{}", r.scale.max_active()),
            format!("{adds}/{retires}"),
            format!("{:.1}", steady.percentile(99.0) * 1e3),
            format!("{:.0}", in_burst.percentile(99.0) * 1e3),
            format!("{:.1}", recovery.percentile(99.0) * 1e3),
            r.dropped.to_string(),
        ]);
    }
    print!(
        "{}",
        render::table(
            &[
                "Policy",
                "Software",
                "Coldstart s",
                "Max repl",
                "Adds/retires",
                "p99 steady ms",
                "p99 burst ms",
                "p99 recovery ms",
                "Dropped",
            ],
            &rows
        )
    );

    // One replica-count timeline for the figure's narrative, read from
    // the grid cell that already ran (queue-depth policy on TrIS).
    let tris_qd = grid
        .iter()
        .zip(&outcome.cells)
        .find(|(axis, _)| axis.0 == "queue-depth" && axis.2.id == "tris")
        .map(|(_, cell)| &cell.result)
        .expect("queue-depth/tris cell present");
    let series: Vec<String> =
        tris_qd.scale.active_series().iter().map(|(t, n)| format!("{t:.1}s:{n}")).collect();
    println!("\nTrIS/queue-depth active-replica timeline: {}", series.join(" -> "));

    // Trace export: the queue-depth/TrIS cell (the figure's narrative
    // cell) as a ui.perfetto.dev-loadable JSON file.
    if let Some(path) = trace_out {
        let trace = tris_qd.trace.as_ref().expect("traced sweep cell carries its trace");
        let bounded = trace.gauges.iter().all(|g| g.samples.len() <= 4096);
        TraceSink::write_perfetto(path, trace).expect("trace export written");
        println!(
            "trace-export: spans={} gauge_series={} truncated={} gauge_bounded={} file={path}",
            trace.spans.len(),
            trace.gauges.len(),
            trace.truncated,
            if bounded { "ok" } else { "OVERFLOW" }
        );
        assert!(bounded, "gauge ring exceeded its configured cap");
        assert!(!trace.spans.is_empty(), "traced cell produced no request spans");
    }

    // (a) same policy, slower cold start -> strictly worse burst p99.
    let p99_of = |plabel: &str, sw: &str| {
        burst_p99
            .iter()
            .find(|((p, s), _)| *p == plabel && *s == sw)
            .map(|(_, v)| *v)
            .expect("run present")
    };
    for (plabel, _) in policies() {
        let (tfs, tris) = (p99_of(plabel, "tfs"), p99_of(plabel, "tris"));
        println!(
            "{plabel}: burst p99 tfs {:.0} ms vs tris {:.0} ms ({:.2}x)",
            tfs * 1e3,
            tris * 1e3,
            tris / tfs
        );
        assert!(
            tris > tfs,
            "{plabel}: tris burst p99 ({tris}s) must exceed tfs ({tfs}s): \
             its ~9.4 s cold start delays relief capacity"
        );
    }
    println!("\nPASS: cold-start-bound scale-up lag sets the burst tail; conservation exact");
}
