//! Fig 7: latency & throughput vs batch size across hardware platforms.
//!
//!  (a) BERT-Large latency vs batch on C1/G1..G4 (CPU fixed at batch 1)
//!  (b) ResNet50 likewise
//!  (c) GPU/CPU speedup under SLO for OD / GAN / TC / IC on V100
//!
//! GPU curves come from the calibrated roofline model; the C1 column is
//! the modeled full-scale CPU latency, with the *real measured* latency of
//! the mini stand-in printed alongside for transparency (DESIGN.md §2).
//!
//! The batch × platform and model grids run through the parallel sweep
//! pool (`sweep::map_indexed`): each row is an independent cell, results
//! come back in row order, so the tables are identical at any core count.

use inferbench::analysis::speedup::{modeled_cpu_latency, speedup_under_slo};
use inferbench::hardware::{estimate, find, Parallelism};
use inferbench::models::catalog::{self, Task};
use inferbench::runtime::Engine;
use inferbench::sweep;
use inferbench::util::render;

const BATCHES: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];

fn parallelism(task: Task) -> Parallelism {
    match task {
        Task::IC | Task::OD | Task::GAN => Parallelism::cnn(28),
        Task::NLP => Parallelism::sequence(128),
        Task::TC => Parallelism::sequence(64),
    }
}

/// Real measured latency of the mini stand-in on this machine's CPU via
/// the actual PJRT path. Reported for transparency alongside the modeled
/// full-scale C1 number — NOT scaled up (interpret-mode kernels make the
/// mini run a poor proxy for a tuned full-scale CPU stack; DESIGN.md §2).
fn measured_mini_latency(engine: &Option<Engine>, model: &catalog::CatalogModel) -> Option<f64> {
    let engine = engine.as_ref()?;
    let stem = model.artifact_stem?;
    let loaded = engine.load(&format!("{stem}_b1"), 0).ok()?;
    loaded.warmup_and_measure(2, 5).ok()
}

fn latency_table(model: &catalog::CatalogModel, measured_mini: Option<f64>, threads: usize) {
    let par = parallelism(model.task);
    let cpu = find("C1").unwrap();
    let cpu_s = modeled_cpu_latency(cpu, &model.profile, par);
    println!(
        "\n--- {} ---  (C1 batch-1: {} modeled{})",
        model.name,
        render::fmt_duration(cpu_s),
        measured_mini
            .map(|t| format!("; mini stand-in measured {} on this host", render::fmt_duration(t)))
            .unwrap_or_default()
    );
    // One cell per batch row (each covers the four GPU platforms).
    let rows = sweep::map_indexed(&BATCHES, threads, |_, &b| {
        let mut row = vec![b.to_string()];
        for gid in ["G1", "G2", "G3", "G4"] {
            let g = find(gid).unwrap();
            let est = estimate(g, &model.profile, par, b, model.request_bytes);
            row.push(format!(
                "{} / {:.0}",
                render::fmt_duration(est.total_s),
                b as f64 / est.total_s
            ));
        }
        if b == 1 {
            row.push(format!("{} / {:.1}", render::fmt_duration(cpu_s), 1.0 / cpu_s));
        } else {
            row.push("-".into());
        }
        row
    });
    print!(
        "{}",
        render::table(
            &["Batch", "G1 V100 (lat/rps)", "G2 2080Ti", "G3 T4", "G4 P4", "C1 CPU"],
            &rows
        )
    );
}

fn main() {
    let threads = sweep::default_threads();
    let engine = Engine::cpu("artifacts").ok();
    if engine.is_none() {
        eprintln!("(artifacts not found: CPU anchors fall back to the model — run `make artifacts`)");
    }

    println!("=== Fig 7a/b: latency & throughput vs batch size ===");
    for name in ["bert_large", "resnet50"] {
        let m = catalog::find(name).unwrap();
        let measured = measured_mini_latency(&engine, m);
        latency_table(m, measured, threads);
    }

    println!("\n=== Fig 7c: GPU/CPU speedup under SLO (V100) ===\n");
    let v100 = find("G1").unwrap();
    let cpu = find("C1").unwrap();
    let models = catalog::speedup_study_models();
    // One cell per study model.
    let cells = sweep::map_indexed(&models, threads, |_, m| {
        let par = parallelism(m.task);
        let cpu_s = modeled_cpu_latency(cpu, &m.profile, par);
        speedup_under_slo(m.name, v100, &m.profile, par, m.request_bytes, cpu_s, &BATCHES)
    });
    let mut items = Vec::new();
    let mut rows = Vec::new();
    for (m, row) in models.iter().zip(&cells) {
        items.push((format!("{} ({})", m.task.label(), m.name), row.speedup));
        rows.push(vec![
            m.task.label().to_string(),
            m.name.to_string(),
            render::fmt_duration(row.slo_s),
            row.best_batch.to_string(),
            render::fmt_duration(row.gpu_latency_s),
            format!("{:.1}x", row.speedup),
        ]);
    }
    print!(
        "{}",
        render::table(&["Task", "Model", "SLO (=CPU lat)", "Best batch", "GPU lat", "Speedup"], &rows)
    );
    print!("{}", render::bar_chart("\nSpeedup over CPU under SLO", &items, 40));
    println!("\nPaper shape check: wide speedup range (paper: 3.6x-47.4x); latency flat for small batches then grows; larger batch -> higher throughput.");
}
