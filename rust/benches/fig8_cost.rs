//! Fig 8: the three cost comparisons across GPUs.
//!
//!  (a) energy consumption + CO2 emission per request vs batch (ResNet50,
//!      batch-processing)
//!  (b) cloud cost per request vs batch across providers/instances
//!      ([C1,C2] providers, [I1,I2,I3] instances, anonymized as the paper)

use inferbench::hardware::{cloud, energy, estimate, find, Parallelism};
use inferbench::models::catalog;
use inferbench::util::render;

const BATCHES: [usize; 6] = [1, 2, 4, 8, 16, 32];

fn main() {
    let rn = catalog::find("resnet50").unwrap();
    let par = Parallelism::cnn(28);

    println!("=== Fig 8a: energy & CO2 per request, ResNet50 ===\n");
    let mut rows = Vec::new();
    for &b in &BATCHES {
        let mut row = vec![b.to_string()];
        for gid in ["G1", "G2", "G3", "G4"] {
            let g = find(gid).unwrap();
            let est = estimate(g, &rn.profile, par, b, rn.request_bytes);
            let e = energy::energy(g, &est, b);
            row.push(format!("{:.2} J / {:.2} mg", e.joules_per_request, e.co2_g_per_request * 1e3));
        }
        rows.push(row);
    }
    print!(
        "{}",
        render::table(&["Batch", "G1 V100 (J/req, CO2/req)", "G2 2080Ti", "G3 T4", "G4 P4"], &rows)
    );
    // Headline observations as assertions-by-print.
    let j = |gid: &str, b: usize| {
        let g = find(gid).unwrap();
        energy::energy(g, &estimate(g, &rn.profile, par, b, rn.request_bytes), b).joules_per_request
    };
    println!(
        "\nChecks: batch-1 costs most energy/request on V100: {} ; V100 draws more than T4 at b8: {}",
        j("G1", 1) > j("G1", 8),
        j("G1", 8) > j("G3", 8),
    );

    println!("\n=== Fig 8b: cloud cost per 1k requests, ResNet50 ===\n");
    let mut rows = Vec::new();
    for &b in &BATCHES {
        let mut row = vec![b.to_string()];
        for inst in cloud::INSTANCES {
            let g = find(inst.platform_id).unwrap();
            let est = estimate(g, &rn.profile, par, b, rn.request_bytes);
            let c = cloud::cost_per_request_usd(inst, &est, b);
            row.push(format!("${:.4}", c * 1e3));
        }
        rows.push(row);
    }
    let headers: Vec<String> = std::iter::once("Batch".to_string())
        .chain(cloud::INSTANCES.iter().map(|i| format!("{}/{} ({})", i.provider, i.instance, i.platform_id)))
        .collect();
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    print!("{}", render::table(&headers_ref, &rows));
    println!(
        "\nChecks (paper's three observations): 1) same device (I1/V100) differs across providers; \
         2) T4 (I3) cheaper than P4 (I2) despite more compute; 3) cost/request falls with batch."
    );
}
