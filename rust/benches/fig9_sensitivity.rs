//! Fig 9: GPU-utilization heat maps vs model hyper-parameters, using
//! *generated* canonical models (the paper's §4.2.2 generator) on V100.
//!
//!  (a) CNN family: utilization vs (batch size x depth)
//!  (b) Transformer family: utilization vs (batch size x depth)
//!
//! The paper's reading: CNN utilization grows with both batch and depth;
//! Transformer utilization is driven more by depth.
//!
//! Each heat map is a depth × batch grid evaluated through the parallel
//! sweep pool (`sweep::map_indexed`); cells come back in grid order, so
//! the maps are identical at any core count.

use inferbench::hardware::{estimate, find, Parallelism};
use inferbench::models::analytic;
use inferbench::sweep;
use inferbench::util::render;

const BATCHES: [usize; 5] = [1, 4, 8, 16, 32];
const DEPTHS: [u64; 5] = [2, 4, 8, 12, 16];

/// Evaluate `util(depth, batch)` over the whole grid in parallel and
/// render it; values come back in (depth-major) grid order.
fn heat(
    title: &str,
    threads: usize,
    util: impl Fn(u64, usize) -> f64 + Sync, // (depth, batch) -> utilization %
) {
    let pairs: Vec<(u64, usize)> = DEPTHS
        .iter()
        .flat_map(|&d| BATCHES.iter().map(move |&b| (d, b)))
        .collect();
    let flat = sweep::map_indexed(&pairs, threads, |_, &(d, b)| util(d, b) * 100.0);
    let rows: Vec<String> = DEPTHS.iter().map(|d| format!("depth {d}")).collect();
    let cols: Vec<String> = BATCHES.iter().map(|b| format!("b{b}")).collect();
    let values: Vec<Vec<f64>> = flat.chunks(BATCHES.len()).map(|c| c.to_vec()).collect();
    print!("{}", render::heat_map(title, &rows, &cols, &values));
}

fn main() {
    let v100 = find("G1").unwrap();
    let threads = sweep::default_threads();

    println!("=== Fig 9a: CNN generated models — GPU utilization %% (V100) ===\n");
    heat("utilization(depth, batch), CNN c64 hw32", threads, |d, b| {
        let p = analytic::cnn(d, 64, 32, 3, 16);
        estimate(v100, &p, Parallelism::cnn(32), b, 0).utilization
    });

    println!("\n=== Fig 9b: Transformer generated models — GPU utilization %% (V100) ===\n");
    heat("utilization(depth, batch), Transformer d256 h4 s64", threads, |d, b| {
        let p = analytic::transformer(d, 256, 4, 64, 16);
        estimate(v100, &p, Parallelism::sequence(64), b, 0).utilization
    });

    // Quantify the paper's sensitivity claim: compare the utilization gain
    // from depth vs from batch for each family.
    let gain = |f: &dyn Fn(u64, usize) -> f64| {
        let depth_gain = f(16, 4) / f(2, 4);
        let batch_gain = f(4, 32) / f(4, 1);
        (depth_gain, batch_gain)
    };
    let cnn_fn = |d: u64, b: usize| {
        estimate(v100, &analytic::cnn(d, 64, 32, 3, 16), Parallelism::cnn(32), b, 0).utilization
    };
    let tr_fn = |d: u64, b: usize| {
        estimate(v100, &analytic::transformer(d, 256, 4, 64, 16), Parallelism::sequence(64), b, 0)
            .utilization
    };
    let (cd, cb) = gain(&cnn_fn);
    let (td, tb) = gain(&tr_fn);
    println!("\nSensitivity: CNN depth-gain {cd:.2}x batch-gain {cb:.2}x | Transformer depth-gain {td:.2}x batch-gain {tb:.2}x");
    println!(
        "Paper shape check: utilization grows with BOTH batch and depth for both families \
         (Fig 9 direction). Deviation noted in EXPERIMENTS.md: the paper reads transformer \
         depth as dominating batch; in our occupancy model both scale work linearly, so the \
         relative sensitivities come out comparable."
    );
}
