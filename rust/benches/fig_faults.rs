//! Tail latency, goodput and availability under replica crashes (the
//! fault-injection figure): a 3-replica fleet at ~70% load, crashed at
//! increasing rates from a seeded MTTF/MTTR profile, served either
//! fail-and-drop (requests stranded on a crashed replica die as
//! `replica-failed`) or with the ingress retry policy (stranded requests
//! re-issued with exponential backoff under a per-request deadline, onto
//! replicas the health-aware routers still consider routable). Readings:
//!
//!  (a) retry + health-aware routing strictly beats fail-and-drop on
//!      goodput at every crash rate and under both routers (asserted);
//!  (b) the conservation ledger survives faults exactly: per cell,
//!      `issued == completed + Σ dropped-by-reason` (asserted);
//!  (c) availability degrades with the crash rate — the fleet's measured
//!      `1 - downtime/(replicas × horizon)` tracks the configured
//!      MTTF/(MTTF+MTTR) — while the *retry* axis never changes it
//!      (faults are injected identically on both sides of each pair,
//!      from the same plan seed; asserted bitwise).
//!
//! The policy pairs are comparable by construction: within one
//! (crash rate, router) pair both cells share a workload seed and a
//! fault-plan seed, so the retry column differs only in what happens to
//! stranded requests. The grid runs through `sweep::map_indexed`; the
//! smoke run asserts serial-vs-threaded bit-identity on top.
//!
//! Run: `cargo bench --bench fig_faults [-- --smoke]`

use inferbench::metrics::{DropReason, MetricsMode};
use inferbench::pipeline::{Processors, RequestPath};
use inferbench::serving::cluster::{self, ClusterConfig, ClusterResult, ReplicaConfig};
use inferbench::serving::{
    backends, FaultPlan, FaultProfile, Policy, RetryPolicy, RouterPolicy, ServiceModel,
};
use inferbench::sweep;
use inferbench::util::render;
use inferbench::workload::{Pattern, Workload};

const SEED: u64 = 5505;
/// Measured per-request device time; with TrIS factors this yields
/// ~238 rps of capacity per replica (same service model as fig_qos).
const PER_REQ_S: f64 = 0.005;
const REPLICAS: usize = 3;
/// Offered load as a fraction of fleet capacity: enough headroom that a
/// surviving 2-replica fleet can absorb a crashed replica's retries.
const LOAD: f64 = 0.70;
/// Mean time to recovery: crashed replicas come back (through a cold
/// start) after ~1.5 s of downtime on average.
const MTTR_S: f64 = 1.5;

fn effective_service_s() -> f64 {
    PER_REQ_S * backends::TRIS.runtime_factor + backends::TRIS.batch_overhead_s
}

fn offered_rps() -> f64 {
    LOAD * REPLICAS as f64 / effective_service_s()
}

/// One grid cell: crash rate x router x whether stranded requests retry.
#[derive(Clone, Copy)]
struct Cell {
    mttf_s: f64,
    router: RouterPolicy,
    router_name: &'static str,
    retry: bool,
    /// Workload + fault seeds, shared by both policies of a pair so the
    /// retry column is the only difference within it.
    pair_seed: u64,
}

fn config_for(cell: &Cell, duration_s: f64) -> ClusterConfig {
    let replica = ReplicaConfig {
        software: &backends::TRIS,
        service: ServiceModel::Measured { per_batch: vec![(1, PER_REQ_S)], utilization: 0.6 },
        policy: Policy::Single,
        max_queue: 400_000,
    };
    let plan = FaultPlan::random(
        FaultProfile { mttf_s: cell.mttf_s, mttr_s: MTTR_S, degrade: None },
        cell.pair_seed,
    );
    ClusterConfig {
        workload: Workload::Stream {
            pattern: Pattern::Poisson { rate: offered_rps() },
            seed: cell.pair_seed,
        },
        duration_s,
        replicas: (0..REPLICAS).map(|_| replica.clone()).collect(),
        router: cell.router,
        autoscale: None,
        cold_start: None,
        path: RequestPath::local(Processors::none()),
        metrics: MetricsMode::Exact,
        admission: None,
        faults: Some(plan),
        retry: cell.retry.then(|| RetryPolicy::new(6, 10.0, 0.05)),
        seed: cell.pair_seed,
    }
}

fn goodput(r: &ClusterResult) -> f64 {
    r.collector.completed as f64 / r.issued.max(1) as f64
}

fn availability(r: &ClusterResult, duration_s: f64) -> f64 {
    1.0 - r.downtime_s / (REPLICAS as f64 * duration_s)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads = if smoke { 2 } else { sweep::default_threads() };
    let duration_s = if smoke { 20.0 } else { 40.0 };
    let mttfs: &[f64] = if smoke { &[10.0] } else { &[20.0, 10.0, 5.0] };
    let routers: [(RouterPolicy, &'static str); 2] = [
        (RouterPolicy::LeastOutstanding, "least-outstanding"),
        (RouterPolicy::RoundRobin, "round-robin"),
    ];

    // Pair-major grid: (mttf, router) pairs, each expanded into its
    // fail-and-drop and retry cells. The pair seed depends on the pair
    // position only, never on the policy column.
    let mut cells = Vec::new();
    for (mi, &mttf_s) in mttfs.iter().enumerate() {
        for (ri, &(router, router_name)) in routers.iter().enumerate() {
            let pair_seed = sweep::cell_seed(SEED, (mi * routers.len() + ri) as u64);
            for retry in [false, true] {
                cells.push(Cell { mttf_s, router, router_name, retry, pair_seed });
            }
        }
    }

    println!(
        "=== Crash rate x retry policy x router ({REPLICAS} replicas at {:.0}% load, \
         {:.0} rps offered, mttr {MTTR_S} s, {duration_s} s horizon, grid on {threads} \
         threads) ===\n",
        LOAD * 1e2,
        offered_rps(),
    );

    let run_grid = |threads: usize| -> Vec<ClusterResult> {
        sweep::map_indexed(&cells, threads, |_, cell| cluster::run(&config_for(cell, duration_s)))
    };
    let results = run_grid(threads);
    if smoke {
        // Crash-heavy bit-identity, serial vs threaded: fault injection
        // must not perturb the sweep engine's determinism.
        let serial = run_grid(1);
        for ((a, b), cell) in results.iter().zip(&serial).zip(&cells) {
            assert_eq!(
                a.collector.fingerprint(),
                b.collector.fingerprint(),
                "mttf {} {} retry={}: parallel grid must be bit-identical",
                cell.mttf_s,
                cell.router_name,
                cell.retry
            );
            assert_eq!(a.events, b.events);
        }
    }

    let mut rows = Vec::new();
    for (cell, r) in cells.iter().zip(&results) {
        // (b) Conservation holds exactly under faults, drop reasons
        // included.
        assert_eq!(
            r.collector.completed + r.dropped,
            r.issued,
            "mttf {} {} retry={}: conservation violated",
            cell.mttf_s,
            cell.router_name,
            cell.retry
        );
        assert!(r.collector.drops_conserved());
        rows.push(vec![
            format!("{:.0}", cell.mttf_s),
            cell.router_name.to_string(),
            if cell.retry { "retry" } else { "drop" }.to_string(),
            r.issued.to_string(),
            format!("{:.4}", goodput(r)),
            format!("{:.1}", r.collector.e2e.percentile(99.0) * 1e3),
            format!("{:.4}", availability(r, duration_s)),
            r.collector.dropped_by(DropReason::ReplicaFailed).to_string(),
            r.collector.dropped_by(DropReason::TimedOut).to_string(),
        ]);
    }
    print!(
        "{}",
        render::table(
            &["MTTF s", "Router", "Policy", "Issued", "Goodput", "p99 ms", "Avail", "Failed",
              "TimedOut"],
            &rows
        )
    );

    println!();
    for pair in cells.chunks(2).zip(results.chunks(2)).map(|(c, r)| (&c[0], &r[0], &c[1], &r[1])) {
        let (drop_cell, drop_r, retry_cell, retry_r) = pair;
        assert!(!drop_cell.retry && retry_cell.retry, "pair layout");
        // Faults are injected from the pair seed: the retry axis must not
        // move a single crash, so measured downtime matches bitwise.
        assert_eq!(
            drop_r.downtime_s.to_bits(),
            retry_r.downtime_s.to_bits(),
            "mttf {} {}: retry policy must not change the fault schedule",
            drop_cell.mttf_s,
            drop_cell.router_name
        );
        let (g_drop, g_retry) = (goodput(drop_r), goodput(retry_r));
        let p99_delta_ms = (retry_r.collector.e2e.percentile(99.0)
            - drop_r.collector.e2e.percentile(99.0))
            * 1e3;
        println!(
            "mttf {:>4.0} s, {:<17}: goodput {:.4} -> {:.4} (+{:.4}), availability {:.4}, \
             p99 {:+.1} ms, replica-failed drops {} -> {}",
            drop_cell.mttf_s,
            drop_cell.router_name,
            g_drop,
            g_retry,
            g_retry - g_drop,
            availability(drop_r, duration_s),
            p99_delta_ms,
            drop_r.collector.dropped_by(DropReason::ReplicaFailed),
            retry_r.collector.dropped_by(DropReason::ReplicaFailed),
        );
        // Crashes actually landed (a quiet plan would make the figure
        // vacuous) and the drop side lost requests to them.
        assert!(drop_r.downtime_s > 0.0, "no downtime at mttf {}", drop_cell.mttf_s);
        assert!(
            drop_r.collector.dropped_by(DropReason::ReplicaFailed) > 0,
            "mttf {} {}: crashes must strand requests on the drop side",
            drop_cell.mttf_s,
            drop_cell.router_name
        );
        // (a) Retry + health-aware routing strictly beats fail-and-drop
        // on goodput, at every crash rate, under both routers.
        assert!(
            g_retry > g_drop,
            "mttf {} {}: retry goodput {g_retry} must strictly beat drop {g_drop}",
            drop_cell.mttf_s,
            drop_cell.router_name
        );
    }
    // (c) Availability falls as crashes come faster. Each pair draws its
    // own fault seed, so adjacent MTTF points can flip by seed luck; the
    // endpoints of the axis (4x apart in crash rate) must still order.
    if mttfs.len() > 1 {
        for ri in 0..routers.len() {
            let at = |mi: usize| availability(&results[(mi * routers.len() + ri) * 2], duration_s);
            let (slowest, fastest) = (at(0), at(mttfs.len() - 1));
            assert!(
                fastest < slowest,
                "{}: availability at mttf {} ({fastest:.4}) should be below mttf {} \
                 ({slowest:.4})",
                routers[ri].1,
                mttfs[mttfs.len() - 1],
                mttfs[0]
            );
        }
    }
    println!(
        "\nPASS: retry strictly beat fail-and-drop on goodput at every crash rate and router, \
         conservation exact under faults, fault schedule independent of the retry policy"
    );
}
