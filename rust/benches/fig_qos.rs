//! Per-tenant QoS under overload (the ingress-tier figure): three
//! priority classes — gold / silver / bronze — offered 2–5x the fleet's
//! capacity through the shared admission tier, on the N-replica cluster
//! DES.
//!
//! The admission tier sheds lowest-class-first: each class has an
//! in-system depth (`shed_depth`), so as the backlog grows it crosses the
//! bronze threshold first, then silver, and only then gold. Readings:
//!
//!  (a) the gold SLO survives every overload: bronze (and, deeper in,
//!      silver) absorb the excess, so gold's p99 stays bounded by its
//!      queue-depth budget while total offered load quintuples
//!      (asserted);
//!  (b) shedding is strictly lowest-class-first: bronze shed fraction
//!      exceeds silver's, silver's is at least gold's, and gold never
//!      sheds (asserted, per overload);
//!  (c) the per-class ledgers are exact: the classes partition every
//!      issued request, and within each class
//!      `issued == completed + Σ dropped-by-reason` (asserted).
//!
//! The overload axis runs through `sweep::map_indexed` (seeds pinned to
//! plan position via `sweep::cell_seed`), so the figure parallelizes like
//! every other grid bench and is bit-identical at any thread count — the
//! smoke run asserts that too.
//!
//! Run: `cargo bench --bench fig_qos [-- --smoke]`

use inferbench::metrics::MetricsMode;
use inferbench::pipeline::{Processors, RequestPath};
use inferbench::serving::cluster::{self, ClusterConfig, ClusterResult, ReplicaConfig};
use inferbench::serving::{backends, AdmissionConfig, Policy, RouterPolicy, ServiceModel, TenantSpec};
use inferbench::sweep;
use inferbench::util::render;
use inferbench::workload::{Pattern, StreamSpec, Workload};

const SEED: u64 = 4404;
/// Measured per-request device time; with TrIS factors this yields
/// ~238 rps of capacity per replica (same service model as fig_sharing).
const PER_REQ_S: f64 = 0.005;
const REPLICAS: usize = 2;
/// Offered-load shares per class: gold stays under fleet capacity even at
/// the 5x point, so the SLO question is purely about isolation.
const SHARES: [f64; 3] = [0.15, 0.25, 0.60];
const CLASS_NAMES: [&str; 3] = ["gold", "silver", "bronze"];
/// In-system depth per class: the backlog crosses bronze's threshold
/// first, then silver's; gold's budget bounds its worst-case sojourn.
const SHED_DEPTH: [usize; 3] = [160, 80, 40];
/// Gold p99 SLO: its depth budget over the fleet service rate, with
/// headroom for batching/transport. ~160/476 s ≈ 340 ms would be the
/// absolute worst case; in practice the backlog parks near silver's
/// threshold, so 250 ms holds with margin.
const GOLD_P99_SLO_S: f64 = 0.250;

/// Effective per-request service time under TrIS (runtime factor +
/// per-batch overhead) — the capacity unit of the overload axis.
fn effective_service_s() -> f64 {
    PER_REQ_S * backends::TRIS.runtime_factor + backends::TRIS.batch_overhead_s
}

fn fleet_capacity_rps() -> f64 {
    REPLICAS as f64 / effective_service_s()
}

fn config_for(overload: f64, duration_s: f64, seed: u64) -> ClusterConfig {
    let offered = overload * fleet_capacity_rps();
    let streams: Vec<StreamSpec> = CLASS_NAMES
        .iter()
        .zip(SHARES)
        .enumerate()
        .map(|(c, (&name, share))| {
            StreamSpec::new(name, Pattern::Poisson { rate: offered * share })
                .with_qos(c as u8, 1.0)
        })
        .collect();
    let admission = AdmissionConfig {
        tenants: CLASS_NAMES
            .iter()
            .enumerate()
            .map(|(c, &name)| TenantSpec::new(name).with_class(c as u8))
            .collect(),
        shed_depth: SHED_DEPTH.to_vec(),
    };
    let replica = ReplicaConfig {
        software: &backends::TRIS,
        service: ServiceModel::Measured { per_batch: vec![(1, PER_REQ_S)], utilization: 0.6 },
        policy: Policy::Single,
        max_queue: 400_000,
    };
    ClusterConfig {
        workload: Workload::Streams { streams, seed },
        duration_s,
        replicas: (0..REPLICAS).map(|_| replica.clone()).collect(),
        router: RouterPolicy::LeastOutstanding,
        autoscale: None,
        cold_start: None,
        path: RequestPath::local(Processors::none()),
        metrics: MetricsMode::Exact,
        admission: Some(admission),
        faults: None,
        retry: None,
        seed,
    }
}

fn assert_class_ledgers(r: &ClusterResult, overload: f64) {
    assert_eq!(r.classes.len(), 3, "{overload}x: one ledger per class");
    let issued: u64 = r.classes.iter().map(|c| c.issued).sum();
    assert_eq!(issued, r.issued, "{overload}x: classes must partition issued requests");
    for cm in &r.classes {
        assert!(
            cm.conserved(),
            "{overload}x class {}: {} issued != {} completed + {} dropped (reasons sum {})",
            cm.class,
            cm.issued,
            cm.collector.completed,
            cm.collector.dropped,
            cm.collector.drop_breakdown().iter().map(|&(_, n)| n).sum::<u64>()
        );
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads = if smoke { 2 } else { sweep::default_threads() };
    let duration_s = if smoke { 10.0 } else { 25.0 };
    let overloads: &[f64] = if smoke { &[2.0, 5.0] } else { &[2.0, 3.0, 4.0, 5.0] };
    let capacity = fleet_capacity_rps();
    println!(
        "=== Per-class QoS vs offered overload ({REPLICAS} replicas, {capacity:.0} rps capacity, \
         {duration_s} s horizon, shed depths {SHED_DEPTH:?}, grid on {threads} threads) ===\n",
    );

    let run_grid = |threads: usize| -> Vec<ClusterResult> {
        sweep::map_indexed(overloads, threads, |i, &overload| {
            cluster::run(&config_for(overload, duration_s, sweep::cell_seed(SEED, i as u64)))
        })
    };
    let results = run_grid(threads);
    if smoke {
        // Bit-identity of the QoS grid, serial vs threaded: admission is
        // RNG-free, so the ingress tier must not perturb determinism.
        let serial = run_grid(1);
        for ((a, b), &overload) in results.iter().zip(&serial).zip(overloads) {
            assert_eq!(
                a.collector.fingerprint(),
                b.collector.fingerprint(),
                "{overload}x: parallel grid must be bit-identical"
            );
            assert_eq!(a.events, b.events);
        }
    }

    let mut rows = Vec::new();
    for (&overload, r) in overloads.iter().zip(&results) {
        assert_class_ledgers(r, overload);
        for cm in &r.classes {
            rows.push(vec![
                format!("{overload:.1}x"),
                CLASS_NAMES[cm.class as usize].to_string(),
                cm.issued.to_string(),
                format!("{:.3}", cm.goodput()),
                format!("{:.3}", cm.shed_fraction()),
                if cm.collector.completed > 0 {
                    format!("{:.1}", cm.collector.e2e.percentile(99.0) * 1e3)
                } else {
                    "-".to_string()
                },
                cm.collector.dropped.to_string(),
            ]);
        }
    }
    print!(
        "{}",
        render::table(
            &["Overload", "Class", "Issued", "Goodput", "Shed", "p99 ms", "Dropped"],
            &rows
        )
    );

    println!();
    for (&overload, r) in overloads.iter().zip(&results) {
        let shed: Vec<f64> = r.classes.iter().map(|c| c.shed_fraction()).collect();
        let gold = &r.classes[0];
        let gold_p99 = gold.collector.e2e.percentile(99.0);
        println!(
            "{overload:.1}x capacity: gold p99 {:.1} ms (SLO {:.0} ms), goodput {:.3}; \
             shed gold {:.3} / silver {:.3} / bronze {:.3}",
            gold_p99 * 1e3,
            GOLD_P99_SLO_S * 1e3,
            gold.goodput(),
            shed[0],
            shed[1],
            shed[2],
        );
        // (a) The gold SLO holds at every overload point.
        assert!(
            gold_p99 <= GOLD_P99_SLO_S,
            "{overload}x: gold p99 {gold_p99}s blows the {GOLD_P99_SLO_S}s SLO"
        );
        assert!(gold.goodput() > 0.99, "{overload}x: gold goodput {}", gold.goodput());
        // (b) Shedding is strictly lowest-class-first.
        assert_eq!(shed[0], 0.0, "{overload}x: gold must never shed");
        assert!(shed[2] > 0.0, "{overload}x: bronze absorbs the overload");
        assert!(
            shed[2] >= shed[1] && shed[1] >= shed[0],
            "{overload}x: shed fractions must be monotone in class: {shed:?}"
        );
        assert!(shed[2] > shed[0], "{overload}x: bronze must shed strictly more than gold");
    }
    // Deeper overload reaches strictly higher classes: at the top of the
    // axis silver sheds too, while gold still does not.
    let top = results.last().expect("non-empty overload axis");
    assert!(
        top.classes[1].shed_fraction() > 0.0,
        "at {}x the backlog must cross silver's threshold",
        overloads.last().unwrap()
    );
    println!(
        "\nPASS: gold p99 SLO held at every overload, shedding strictly lowest-class-first, \
         per-class conservation exact"
    );
}
