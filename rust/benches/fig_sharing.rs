//! Sharing versus Dedicate (paper §3.3; §4.2.1 sharing manager) on the
//! multi-model DES: the same K models served colocated on one MPS-shared
//! replica versus dedicated on K exclusive replicas, across colocation
//! degree × per-model rate.
//!
//! The static analytic model (`hardware::sharing::share`) predicts the
//! trade-off from offered rates; this figure produces it event-driven
//! from `serving::multimodel`, where the contention multiplier reacts to
//! *observed* per-model busy fractions. Readings:
//!
//!  (a) light colocation is nearly free: below `MPS_EFFICIENCY` total
//!      demand, sharing costs ~the per-dispatch MPS overhead while using
//!      1/K of the replicas — the consolidation win;
//!  (b) overcommit melts the shared tail: when `total_demand >
//!      mps_efficiency`, the colocated p99 is strictly worse than the
//!      same models dedicated (asserted), while the shared fleet stays
//!      strictly smaller and cheaper per wall-clock hour (asserted);
//!  (c) conservation is exact per model stream, shared or dedicated.
//!
//! The grid runs through `sweep::map_indexed` (one cell per
//! mode × degree × rate, seeds pinned to plan position via
//! `sweep::cell_seed`), so the figure parallelizes like every other grid
//! bench and is bit-identical at any thread count — the smoke run
//! asserts that too.
//!
//! Run: `cargo bench --bench fig_sharing [-- --smoke]`

use inferbench::hardware::cloud;
use inferbench::metrics::MetricsMode;
use inferbench::pipeline::{Processors, RequestPath};
use inferbench::serving::multimodel::{
    self, ContentionModel, ModelSpec, MultiModelConfig, MultiModelResult, MultiReplicaConfig,
};
use inferbench::serving::{backends, Policy, RouterPolicy, ServiceModel};
use inferbench::sweep;
use inferbench::util::render;
use inferbench::workload::Pattern;

const DURATION: f64 = 25.0;
const SEED: u64 = 3303;
/// Measured per-request device time: 5 ms => ~238 rps capacity per model
/// lane under TrIS factors.
const PER_REQ_S: f64 = 0.005;

/// Effective per-request service time under TrIS (runtime factor +
/// per-batch overhead), the demand unit of the analytic model.
fn effective_service_s() -> f64 {
    PER_REQ_S * backends::TRIS.runtime_factor + backends::TRIS.batch_overhead_s
}

fn model(name: &str, rate: f64) -> ModelSpec {
    ModelSpec {
        name: name.into(),
        service: ServiceModel::Measured { per_batch: vec![(1, PER_REQ_S)], utilization: 0.6 },
        policy: Policy::Single,
        weight_bytes: 200_000_000,
        max_queue: 400_000,
        pattern: Pattern::Poisson { rate },
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Shared,
    Dedicated,
}

impl Mode {
    fn label(self) -> &'static str {
        match self {
            Mode::Shared => "shared",
            Mode::Dedicated => "dedicated",
        }
    }
}

/// One grid cell: K models at `rate` each, colocated or dedicated.
fn config_for(mode: Mode, degree: usize, rate: f64, seed: u64) -> MultiModelConfig {
    let models: Vec<ModelSpec> =
        (0..degree).map(|i| model(&format!("m{i}"), rate)).collect();
    let replicas = match mode {
        // One replica hosting every model (16 GB budget holds them all).
        Mode::Shared => vec![MultiReplicaConfig {
            software: &backends::TRIS,
            mem_bytes: 16_000_000_000,
            hosted: (0..degree).collect(),
        }],
        // One exclusive replica per model.
        Mode::Dedicated => (0..degree)
            .map(|i| MultiReplicaConfig {
                software: &backends::TRIS,
                mem_bytes: 16_000_000_000,
                hosted: vec![i],
            })
            .collect(),
    };
    MultiModelConfig {
        models,
        replicas,
        router: RouterPolicy::LeastOutstanding,
        duration_s: DURATION,
        placement_ops: vec![],
        contention: ContentionModel::default(),
        path: RequestPath::local(Processors::none()),
        metrics: MetricsMode::Exact,
        admission: None,
        faults: None,
        retry: None,
        seed,
    }
}

/// Fleet cost for the run window at the cheapest G1 (V100) list price —
/// the §3.3 cost axis: dedicated pays one device per model.
fn fleet_cost_usd(replicas: usize) -> f64 {
    let hourly = cloud::cheapest_hourly_usd("G1").expect("G1 offered in the price table");
    hourly / 3600.0 * DURATION * replicas as f64
}

fn assert_conserved(r: &MultiModelResult, label: &str) {
    for m in &r.models {
        assert!(
            m.conserved(),
            "{label}/{}: {} issued != {} completed + {} dropped",
            m.name,
            m.issued,
            m.collector.completed,
            m.collector.dropped
        );
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads = if smoke { 2 } else { sweep::default_threads() };
    let degrees: &[usize] = if smoke { &[2] } else { &[2, 3] };
    let rates: &[f64] = if smoke { &[40.0, 120.0] } else { &[40.0, 80.0, 120.0] };
    let service = effective_service_s();
    println!(
        "=== Sharing vs Dedicate: colocation degree x per-model rate \
         ({} s horizon, {:.1} ms effective service, MPS eff {:.2}, grid on {threads} threads) ===\n",
        DURATION,
        service * 1e3,
        inferbench::hardware::sharing::MPS_EFFICIENCY
    );

    // Grid: every (degree, rate) in both modes; cells through
    // map_indexed with plan-position seeds, exactly like the SweepPlan
    // benches.
    let mut grid: Vec<(Mode, usize, f64)> = Vec::new();
    for &k in degrees {
        for &rate in rates {
            grid.push((Mode::Shared, k, rate));
            grid.push((Mode::Dedicated, k, rate));
        }
    }
    let run_grid = |threads: usize| -> Vec<MultiModelResult> {
        sweep::map_indexed(&grid, threads, |i, &(mode, k, rate)| {
            // Seed by *pair* (shared and dedicated cells are adjacent), so
            // each comparison sees identical arrival streams and the p99
            // delta isolates the sharing model, not sampling noise.
            multimodel::run(&config_for(mode, k, rate, sweep::cell_seed(SEED, (i / 2) as u64)))
        })
    };
    let results = run_grid(threads);
    if smoke {
        // Bit-identity of the multi-model grid, serial vs threaded.
        let serial = run_grid(1);
        for ((a, b), &(mode, k, rate)) in results.iter().zip(&serial).zip(&grid) {
            assert_eq!(
                a.collector.fingerprint(),
                b.collector.fingerprint(),
                "{}/{k}@{rate}: parallel grid must be bit-identical",
                mode.label()
            );
            assert_eq!(a.events, b.events);
        }
    }

    let mut rows = Vec::new();
    for (&(mode, k, rate), r) in grid.iter().zip(&results) {
        assert_conserved(r, mode.label());
        let total_demand = k as f64 * rate * service;
        let p99 = r.collector.e2e.percentile(99.0);
        rows.push(vec![
            k.to_string(),
            format!("{rate:.0}"),
            format!("{total_demand:.2}"),
            mode.label().to_string(),
            r.replica_count().to_string(),
            format!("{:.1}", r.collector.e2e.percentile(50.0) * 1e3),
            format!("{:.1}", p99 * 1e3),
            format!("{}", r.collector.completed),
            r.dropped.to_string(),
            format!("{:.4}", fleet_cost_usd(r.replica_count())),
        ]);
    }
    print!(
        "{}",
        render::table(
            &[
                "Models",
                "Rate/model",
                "Demand",
                "Mode",
                "Replicas",
                "p50 ms",
                "p99 ms",
                "Done",
                "Dropped",
                "Cost $",
            ],
            &rows
        )
    );

    // Pair up shared/dedicated cells (adjacent in the grid) and assert
    // the §3.3 trade-off.
    println!();
    for pair in grid.chunks(2).zip(results.chunks(2)) {
        let (&[(_, k, rate), _], [shared, dedicated]) = pair else { unreachable!() };
        let total_demand = k as f64 * rate * service;
        let overcommitted = total_demand > inferbench::hardware::sharing::MPS_EFFICIENCY;
        let (p99_s, p99_d) = (
            shared.collector.e2e.percentile(99.0),
            dedicated.collector.e2e.percentile(99.0),
        );
        println!(
            "{k} models @ {rate:.0} rps (demand {total_demand:.2}, {}): shared p99 {:.1} ms \
             on {} replica(s) vs dedicated p99 {:.1} ms on {} — delta {:+.1} ms, \
             cost {:.4}$ vs {:.4}$",
            if overcommitted { "overcommitted" } else { "fits" },
            p99_s * 1e3,
            shared.replica_count(),
            p99_d * 1e3,
            dedicated.replica_count(),
            (p99_s - p99_d) * 1e3,
            fleet_cost_usd(shared.replica_count()),
            fleet_cost_usd(dedicated.replica_count()),
        );
        // The cost side of the trade-off holds everywhere: sharing packs
        // K models onto strictly fewer devices.
        assert!(
            shared.replica_count() < dedicated.replica_count(),
            "sharing must use strictly fewer replicas"
        );
        assert!(fleet_cost_usd(shared.replica_count()) < fleet_cost_usd(dedicated.replica_count()));
        if overcommitted {
            // The latency side: overcommitted colocation is strictly
            // worse than dedicating (the acceptance criterion).
            assert!(
                p99_s > p99_d,
                "{k}@{rate}: overcommitted shared p99 ({p99_s}s) must exceed dedicated ({p99_d}s)"
            );
        } else {
            // Light colocation is nearly free: within a few ms of
            // dedicated (MPS overhead + mild queueing noise).
            assert!(
                p99_s < p99_d + 0.010,
                "{k}@{rate}: light sharing should be near-free, {p99_s}s vs {p99_d}s"
            );
        }
    }
    println!(
        "\nPASS: overcommitted colocation strictly worse on p99, strictly cheaper on replicas; \
         per-stream conservation exact"
    );
}
