//! L3 hot-path microbenchmarks (the §Perf harness): batcher decisions,
//! DES event throughput, PerfDB insert/query, JSON codec, RNG draw rate,
//! and the live-runtime single-inference latency when artifacts exist.
//!
//! Hand-rolled timing harness (no criterion offline): median-of-N wall
//! time with warmup, reported as ns/op and ops/s.

use inferbench::coordinator::job::service_model_for;
use inferbench::pipeline::{Processors, RequestPath};
use inferbench::serving::{run, backends, Batcher, Policy, SimConfig};
use inferbench::util::json;
use inferbench::util::rng::Pcg64;
use inferbench::workload::{Pattern, Workload};
use std::time::Instant;

/// Time `f` over `iters` inner ops, repeated `reps` times; report median.
fn bench(name: &str, iters: u64, reps: usize, mut f: impl FnMut() -> u64) {
    // Warmup.
    let mut sink = 0u64;
    sink = sink.wrapping_add(f());
    let mut samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            sink = sink.wrapping_add(f());
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[reps / 2];
    let ns_per_op = median / iters as f64 * 1e9;
    println!(
        "{name:<42} {:>12.1} ns/op {:>14.0} ops/s   (sink {sink})",
        ns_per_op,
        iters as f64 / median
    );
}

fn main() {
    println!("=== L3 microbenchmarks (median of 7) ===\n");

    bench("rng: Pcg64 next_u64", 1_000_000, 7, || {
        let mut rng = Pcg64::seeded(1);
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        acc
    });

    bench("rng: exponential sample", 1_000_000, 7, || {
        let mut rng = Pcg64::seeded(2);
        let mut acc = 0.0;
        for _ in 0..1_000_000 {
            acc += rng.exponential(100.0);
        }
        acc as u64
    });

    bench("batcher: on_arrival+dispatch (dyn b8)", 100_000, 7, || {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 8, max_wait_s: 0.005 });
        let mut n = 0u64;
        for i in 0..100_000u64 {
            if let inferbench::serving::Decision::Dispatch(sz) = b.on_arrival(i, i as f64 * 1e-5)
            {
                n += sz as u64;
            }
        }
        n
    });

    let workload = Workload::Stream { pattern: Pattern::Poisson { rate: 2000.0 }, seed: 3 };
    let n_arrivals = workload.count_in(30.0);
    bench(
        &format!("DES: full sim, {n_arrivals} requests"),
        n_arrivals,
        7,
        || {
            let config = SimConfig {
                workload: workload.clone(),
                duration_s: 30.0,
                policy: Policy::Dynamic { max_size: 16, max_wait_s: 0.002 },
                software: &backends::TRIS,
                service: service_model_for("resnet50", "G1").unwrap(),
                path: RequestPath::local(Processors::image()),
                max_queue: 100_000,
                seed: 7,
            };
            run(&config).collector.completed
        },
    );

    bench("perfdb: insert+metric", 100_000, 7, || {
        let mut db = inferbench::perfdb::PerfDb::new();
        for i in 0..100_000 {
            db.insert(
                inferbench::perfdb::Record::new("t", "m", "p", "s")
                    .with_metric("v", i as f64),
            );
        }
        db.len() as u64
    });

    let doc = r#"{"task":"serving_sim","model":"resnet50","platform":"G1","software":"tfs","metrics":{"p50_ms":12.5,"p99_ms":48.2,"throughput_rps":312.0}}"#;
    bench("json: parse PerfDB record", 10_000, 7, || {
        let mut n = 0u64;
        for _ in 0..10_000 {
            n += json::parse(doc).unwrap().as_obj().unwrap().len() as u64;
        }
        n
    });

    bench("stats: summary record+p99 (10k samples)", 10_000, 7, || {
        let mut s = inferbench::util::stats::Summary::new();
        let mut rng = Pcg64::seeded(5);
        for _ in 0..10_000 {
            s.record(rng.lognormal(0.0, 1.0));
        }
        s.percentile(99.0) as u64
    });

    // Runtime hot path: real XLA inference (needs artifacts).
    match inferbench::runtime::Engine::cpu("artifacts") {
        Ok(engine) => {
            let model = engine.load("mlp_d8_w512_b1", 0).unwrap();
            let x = model.make_input(1);
            // Warmup.
            for _ in 0..3 {
                model.infer(&x).unwrap();
            }
            bench("runtime: mlp_d8_w512 b1 real inference", 20, 7, || {
                let mut n = 0u64;
                for _ in 0..20 {
                    n += model.infer(&x).unwrap().len() as u64;
                }
                n
            });
            let model8 = engine.load("mlp_d8_w512_b8", 0).unwrap();
            let x8 = model8.make_input(1);
            for _ in 0..3 {
                model8.infer(&x8).unwrap();
            }
            bench("runtime: mlp_d8_w512 b8 real inference", 20, 7, || {
                let mut n = 0u64;
                for _ in 0..20 {
                    n += model8.infer(&x8).unwrap().len() as u64;
                }
                n
            });
        }
        Err(_) => println!("(runtime benches skipped: run `make artifacts`)"),
    }
}
