//! L4: DES engine throughput — simulated requests/sec and events/sec of
//! the cluster simulator itself, plus cells/sec of the parallel sweep
//! engine that runs whole benchmark grids (PERF.md). This is the perf
//! trajectory tracker for the engine every fig7–fig17 benchmark runs on:
//! InferBench's value proposition is cheap day-to-day evaluation, and
//! serving studies need million-request scales to resolve tail behavior,
//! so the simulator — and now the sweep layer above it — is benchmarked
//! like any other hot path.
//!
//! Single-run matrix, three scenarios × three scales (10k / 100k / 1M
//! requests), executed serially so each cell's wall time is unpolluted:
//!  * `fixed-fleet`  — 4 heterogeneous replicas, dynamic batching,
//!    least-outstanding routing, Poisson open-loop arrivals;
//!  * `autoscale`    — spike load against an elastic 2→8 fleet
//!    (queue-depth policy, cold starts, drain-on-remove);
//!  * `closed-loop`  — 64 closed-loop clients over 4 replicas (slot reuse:
//!    the steady-state allocation-free path).
//!
//! Sweep matrix: a fig16-style grid (replicas × all four routers, load
//! scaled per replica) run serially and then on the worker pool,
//! reporting cells/sec and the parallel speedup — with a bit-identity
//! assertion between the two runs (the engine's core guarantee).
//!
//! Distributed rows: the same-shaped grid sharded across 2 followers
//! through each wire codec (`coordinator::distributed`), reporting
//! sharded cells/sec plus bytes-on-wire per cell for the binary and
//! JSON-lines codecs — the satellite metric for PERF.md §Distributed
//! sweeps. Bit-identity against the serial run is asserted here too.
//!
//! Streaming scale row: the `streaming-sketch` scenario runs the
//! fixed-fleet config with a lazily generated workload and sketch-mode
//! metrics — no arrival vector, no per-sample latency tables — at 10⁸
//! requests (10⁷ under `--smoke`, where the row carries a hard
//! RSS-growth assertion: the run must not grow resident memory by more
//! than a fraction of what materializing the arrivals alone would cost).
//! Peak/delta RSS is read from `/proc/self/status` and written into the
//! JSON row, so the flat-memory claim is tracked alongside req/s.
//!
//! Everything is written to `BENCH_des.json` at the repository root so
//! the trajectory is tracked in-repo. Pass `--smoke` for the CI variant:
//! the 10k single-run scale plus a small 2-thread sweep grid and the
//! 10⁷ streaming row, printed into the job summary.
//!
//! Run: `cargo bench --bench l4_des_throughput [-- --smoke]`

use inferbench::codec::CodecKind;
use inferbench::coordinator::distributed::run_sharded;
use inferbench::coordinator::job::{self, JobKind, JobSpec};
use inferbench::coordinator::DistConfig;
use inferbench::metrics::MetricsMode;
use inferbench::pipeline::{Processors, RequestPath};
use inferbench::serving::autoscale::{AutoscaleConfig, ScalePolicy};
use inferbench::serving::cluster::{run, ClusterConfig, ClusterResult, ReplicaConfig};
use inferbench::serving::{backends, Policy, RouterPolicy, ServiceModel};
use inferbench::sweep::SweepPlan;
use inferbench::util::render;
use inferbench::workload::{Pattern, Workload};
use std::path::Path;
use std::time::Instant;

fn replica(per_req_ms: f64) -> ReplicaConfig {
    ReplicaConfig {
        software: &backends::TRIS,
        service: ServiceModel::Measured {
            per_batch: vec![(1, per_req_ms / 1e3), (16, per_req_ms * 3.0 / 1e3)],
            utilization: 0.6,
        },
        policy: Policy::Dynamic { max_size: 16, max_wait_s: 0.002 },
        max_queue: 100_000,
    }
}

/// Fixed 4-replica fleet; Poisson arrivals sized for ~`n` requests.
fn fixed_fleet(n: u64) -> ClusterConfig {
    let rate = 2000.0;
    let duration = n as f64 / rate;
    ClusterConfig {
        workload: Workload::Stream { pattern: Pattern::Poisson { rate }, seed: 42 },
        duration_s: duration,
        replicas: vec![replica(2.0), replica(3.0), replica(5.0), replica(8.0)],
        router: RouterPolicy::LeastOutstanding,
        autoscale: None,
        cold_start: None,
        path: RequestPath::local(Processors::none()),
        metrics: MetricsMode::Exact,
        admission: None,
        faults: None,
        retry: None,
        seed: 42,
    }
}

/// The 10⁸-scale row: the fixed fleet with lazily streamed Poisson
/// arrivals and sketch-mode metrics. Nothing in this config — or in the
/// run it drives — is O(requests).
fn streaming_sketch(n: u64) -> ClusterConfig {
    ClusterConfig { metrics: MetricsMode::Sketch { alpha: 0.01 }, ..fixed_fleet(n) }
}

/// Elastic fleet under spike load; sized for ~`n` requests.
fn autoscale(n: u64) -> ClusterConfig {
    // Base 1000 rps with a 4000 rps burst over the middle fifth:
    // average offered rate ~1600 rps.
    let duration = n as f64 / 1600.0;
    ClusterConfig {
        workload: Workload::Stream {
            pattern: Pattern::Spike {
                base_rate: 1000.0,
                burst_rate: 4000.0,
                start_s: duration * 0.4,
                duration_s: duration * 0.2,
            },
            seed: 43,
        },
        duration_s: duration,
        replicas: vec![replica(2.0), replica(2.0)],
        router: RouterPolicy::LeastOutstanding,
        autoscale: Some(AutoscaleConfig {
            policy: ScalePolicy::QueueDepth {
                up_per_replica: 8.0,
                down_per_replica: 0.5,
                cooldown_s: 0.5,
            },
            min_replicas: 2,
            max_replicas: 8,
            template: replica(2.0),
            weight_bytes: 50_000_000,
            eval_interval_s: 0.25,
        }),
        cold_start: None,
        path: RequestPath::local(Processors::none()),
        metrics: MetricsMode::Exact,
        admission: None,
        faults: None,
        retry: None,
        seed: 43,
    }
}

/// 64 closed-loop clients over 4 replicas; sized for ~`n` requests.
/// Exercises the steady-state slot-reuse path: only ~64 traces are ever
/// live at once.
fn closed_loop(n: u64) -> ClusterConfig {
    // 64 clients over 4 replicas at ~2.4 ms effective -> ~2400 rps.
    let duration = n as f64 / 2400.0;
    ClusterConfig {
        workload: Workload::ClosedLoop { clients: 64 },
        duration_s: duration,
        replicas: vec![replica(2.0), replica(2.0), replica(2.0), replica(2.0)],
        router: RouterPolicy::LeastOutstanding,
        autoscale: None,
        cold_start: None,
        path: RequestPath::local(Processors::none()),
        metrics: MetricsMode::Exact,
        admission: None,
        faults: None,
        retry: None,
        seed: 44,
    }
}

struct Cell {
    scenario: &'static str,
    requests: u64,
    issued: u64,
    completed: u64,
    events: u64,
    wall_s: f64,
}

/// Current resident set size in MB from `/proc/self/status` (Linux);
/// `None` elsewhere, which skips the flat-RSS assertion but still runs
/// the row.
fn rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmRSS:"))?;
    let kb: f64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb / 1024.0)
}

struct StreamingRow {
    requests: u64,
    issued: u64,
    events: u64,
    wall_s: f64,
    p99_ms: f64,
    /// RSS growth over the run in MB; `None` off Linux.
    rss_growth_mb: Option<f64>,
}

impl StreamingRow {
    fn requests_per_s(&self) -> f64 {
        self.issued as f64 / self.wall_s
    }
}

/// Run the streamed sketch-mode scale row and enforce the flat-RSS
/// contract: the run may not grow resident memory by more than
/// `budget_mb`, a small constant far below the ~16 B/request it would
/// take just to materialize the arrival vector.
fn measure_streaming(n: u64, budget_mb: f64) -> StreamingRow {
    let cfg = streaming_sketch(n);
    let before = rss_mb();
    let t0 = Instant::now();
    let r = run(&cfg);
    let wall_s = t0.elapsed().as_secs_f64();
    let after = rss_mb();
    assert_eq!(r.collector.completed + r.dropped, r.issued, "streaming-sketch: conservation");
    assert!(r.collector.is_bounded(), "streaming-sketch: collector must be in sketch mode");
    let rss_growth_mb = match (before, after) {
        (Some(b), Some(a)) => Some(a - b),
        _ => None,
    };
    if let Some(g) = rss_growth_mb {
        let vector_mb = n as f64 * 16.0 / (1024.0 * 1024.0);
        assert!(
            g < budget_mb,
            "streaming-sketch: RSS grew {g:.1} MB over a {n}-request run (budget {budget_mb} MB; \
             the arrival vector alone would be ~{vector_mb:.0} MB)"
        );
    }
    StreamingRow {
        requests: n,
        issued: r.issued,
        events: r.events,
        wall_s,
        p99_ms: r.collector.e2e.percentile(99.0) * 1e3,
        rss_growth_mb,
    }
}

impl Cell {
    fn requests_per_s(&self) -> f64 {
        self.issued as f64 / self.wall_s
    }

    fn events_per_s(&self) -> f64 {
        self.events as f64 / self.wall_s
    }
}

fn measure(scenario: &'static str, requests: u64, cfg: &ClusterConfig) -> Cell {
    // One warmup pass at small scale already happened (the smoke row);
    // measure the best of two runs to shave scheduler noise.
    let mut best: Option<(f64, ClusterResult)> = None;
    for _ in 0..2 {
        let t0 = Instant::now();
        let r = run(cfg);
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(r.collector.completed + r.dropped, r.issued, "{scenario}: conservation");
        let better = match &best {
            None => true,
            Some((w, _)) => wall < *w,
        };
        if better {
            best = Some((wall, r));
        }
    }
    let (wall_s, r) = best.expect("measured");
    Cell {
        scenario,
        requests,
        issued: r.issued,
        completed: r.collector.completed,
        events: r.events,
        wall_s,
    }
}

/// The fig16-style sweep grid: fleet sizes × all four routers, offered
/// load scaled per replica, per-cell seeds derived from the plan seed
/// (the real sweep path — arrivals and engine both keyed to the cell).
fn sweep_grid(fleets: &[usize], duration_s: f64) -> SweepPlan {
    let mut plan = SweepPlan::new(4242);
    for &n in fleets {
        for router in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastOutstanding,
            RouterPolicy::PowerOfTwoChoices { seed: 4242 },
            RouterPolicy::LatencyEwma { alpha: 0.3, stale_s: 0.1 },
        ] {
            plan.push(format!("{n}x{}", router.label()), move |seed| ClusterConfig {
                workload: Workload::Stream {
                    pattern: Pattern::Poisson { rate: 170.0 * n as f64 },
                    seed,
                },
                duration_s,
                replicas: (0..n).map(|_| replica(5.0)).collect(),
                router,
                autoscale: None,
                cold_start: None,
                path: RequestPath::local(Processors::none()),
                metrics: MetricsMode::Exact,
                admission: None,
                faults: None,
                retry: None,
                seed,
            });
        }
    }
    plan
}

/// Wire accounting attached to a sharded-sweep row.
struct WireInfo {
    codec: &'static str,
    followers: usize,
    bytes_to_leader: u64,
    bytes_to_followers: u64,
    /// First-round cells per follower — the shard-balance view.
    shard_cells: Vec<usize>,
    /// Dispatch rounds the leader ran (1 unless shards failed).
    rounds: usize,
    /// Result frames discarded as duplicates during absorption.
    duplicate_frames: u64,
    /// Cells re-queued after a shard failure.
    cells_rerun: u64,
}

struct SweepRow {
    grid: String,
    cells: usize,
    threads: usize,
    serial_wall_s: f64,
    parallel_wall_s: f64,
    events: u64,
    /// `Some` for distributed rows (cells crossed a codec on the way
    /// back); `None` for the in-process worker-pool rows.
    wire: Option<WireInfo>,
}

impl SweepRow {
    fn cells_per_s_serial(&self) -> f64 {
        self.cells as f64 / self.serial_wall_s
    }

    fn cells_per_s_parallel(&self) -> f64 {
        self.cells as f64 / self.parallel_wall_s
    }

    fn speedup(&self) -> f64 {
        self.serial_wall_s / self.parallel_wall_s
    }
}

/// Run the plan at `threads` and compare against an already-measured
/// serial baseline (run the baseline once; reuse it for every budget).
fn measure_sweep(
    grid: &str,
    plan: &SweepPlan,
    threads: usize,
    serial: &inferbench::sweep::SweepOutcome,
    serial_wall_s: f64,
) -> SweepRow {
    let t1 = Instant::now();
    let parallel = plan.run(threads);
    let parallel_wall_s = t1.elapsed().as_secs_f64();
    // The engine's core guarantee, asserted on every tracked row: the
    // parallel run is bit-identical to the serial one, cell for cell.
    assert_eq!(serial.cells.len(), parallel.cells.len());
    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(a.result.events, b.result.events, "{grid}/{}: event count drift", a.label);
        assert_eq!(
            a.result.collector.fingerprint(),
            b.result.collector.fingerprint(),
            "{grid}/{}: collector output drift",
            a.label
        );
    }
    SweepRow {
        grid: grid.to_string(),
        cells: plan.len(),
        threads,
        serial_wall_s,
        parallel_wall_s,
        events: serial.total_events(),
        wire: None,
    }
}

/// The distributed grid as a `task: sweep` submission — the sharded path
/// needs the self-describing grid doc, so this goes through the job
/// layer rather than building a `SweepPlan` directly.
fn dist_grid_kind(fleets: &[usize], duration_s: f64) -> JobKind {
    let reps = fleets.iter().map(|n| n.to_string()).collect::<Vec<_>>().join(", ");
    let yaml = format!(
        "name: dist-bench\ntask: sweep\nmodel: resnet50\nplatform: G1\nsoftware: tris\n\
         routers: [round-robin, least-outstanding, power-of-two, latency-ewma]\n\
         replicas: [{reps}]\nworkload:\n  rate_per_replica: 150.0\n  duration_s: {duration_s}\n\
         batching:\n  max_size: 16\n  max_wait_ms: 2\n"
    );
    JobSpec::parse_yaml(&yaml).expect("dist grid parses").kind
}

/// Shard the grid across 2 followers over `codec`, assert bit-identity
/// against the serial baseline, and return a sweep row carrying the wire
/// accounting (bytes/cell is the codec-efficiency metric).
fn measure_distributed(
    kind: &JobKind,
    seed: u64,
    codec: CodecKind,
    serial: &inferbench::sweep::SweepOutcome,
    serial_wall_s: f64,
) -> SweepRow {
    const FOLLOWERS: usize = 2;
    let threads = 4;
    let t0 = Instant::now();
    let dist = run_sharded(kind, seed, &DistConfig::uniform(FOLLOWERS, threads, codec))
        .expect("sharded run succeeds");
    let parallel_wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(serial.cells.len(), dist.outcome.cells.len());
    for (a, b) in serial.cells.iter().zip(&dist.outcome.cells) {
        assert_eq!(
            a.result.collector.fingerprint(),
            b.result.collector.fingerprint(),
            "sharded/{}: output drift vs serial",
            a.label
        );
    }
    SweepRow {
        grid: format!("sharded-{FOLLOWERS}-followers-{}", codec.name()),
        cells: serial.cells.len(),
        threads,
        serial_wall_s,
        parallel_wall_s,
        events: serial.total_events(),
        wire: Some(WireInfo {
            codec: codec.name(),
            followers: FOLLOWERS,
            bytes_to_leader: dist.stats.bytes_to_leader,
            bytes_to_followers: dist.stats.bytes_to_followers,
            shard_cells: dist.stats.shard_cells.clone(),
            rounds: dist.stats.rounds,
            duplicate_frames: dist.stats.duplicate_frames,
            cells_rerun: dist.stats.cells_rerun,
        }),
    }
}

fn json_results(cells: &[Cell]) -> Vec<String> {
    cells
        .iter()
        .map(|c| {
            format!(
                "    {{\"scenario\": \"{}\", \"requests\": {}, \"issued\": {}, \"completed\": {}, \
                 \"events\": {}, \"wall_s\": {:.4}, \"requests_per_s\": {:.0}, \"events_per_s\": {:.0}}}",
                c.scenario,
                c.requests,
                c.issued,
                c.completed,
                c.events,
                c.wall_s,
                c.requests_per_s(),
                c.events_per_s()
            )
        })
        .collect()
}

fn json_sweeps(rows: &[SweepRow]) -> Vec<String> {
    rows.iter()
        .map(|s| {
            let mut row = format!(
                "    {{\"grid\": \"{}\", \"cells\": {}, \"threads\": {}, \"serial_wall_s\": {:.4}, \
                 \"parallel_wall_s\": {:.4}, \"cells_per_s_serial\": {:.2}, \
                 \"cells_per_s_parallel\": {:.2}, \"speedup\": {:.2}, \"events\": {}",
                s.grid,
                s.cells,
                s.threads,
                s.serial_wall_s,
                s.parallel_wall_s,
                s.cells_per_s_serial(),
                s.cells_per_s_parallel(),
                s.speedup(),
                s.events
            );
            if let Some(w) = &s.wire {
                row.push_str(&format!(
                    ", \"codec\": \"{}\", \"followers\": {}, \"bytes_to_leader\": {}, \
                     \"bytes_to_followers\": {}, \"bytes_per_cell\": {:.0}, \
                     \"shard_cells\": [{}], \"rounds\": {}, \"duplicate_frames\": {}, \
                     \"cells_rerun\": {}",
                    w.codec,
                    w.followers,
                    w.bytes_to_leader,
                    w.bytes_to_followers,
                    w.bytes_to_leader as f64 / s.cells.max(1) as f64,
                    w.shard_cells
                        .iter()
                        .map(|c| c.to_string())
                        .collect::<Vec<_>>()
                        .join(", "),
                    w.rounds,
                    w.duplicate_frames,
                    w.cells_rerun
                ));
            }
            row.push('}');
            row
        })
        .collect()
}

fn json_streaming(rows: &[StreamingRow]) -> Vec<String> {
    rows.iter()
        .map(|s| {
            format!(
                "    {{\"scenario\": \"streaming-sketch\", \"requests\": {}, \"issued\": {}, \
                 \"events\": {}, \"wall_s\": {:.4}, \"requests_per_s\": {:.0}, \
                 \"p99_ms\": {:.4}, \"rss_growth_mb\": {}}}",
                s.requests,
                s.issued,
                s.events,
                s.wall_s,
                s.requests_per_s(),
                s.p99_ms,
                s.rss_growth_mb.map_or("null".to_string(), |g| format!("{g:.1}"))
            )
        })
        .collect()
}

fn write_json(cells: &[Cell], sweeps: &[SweepRow], streaming: &[StreamingRow]) -> std::io::Result<()> {
    // The repo root is one level above the rust package.
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("BENCH_des.json");
    let doc = format!(
        "{{\n  \"bench\": \"l4_des_throughput\",\n  \"unit\": \"simulated requests (issued) and \
         DES events per wall-clock second; sweep rows add grid cells per second, serial vs \
         parallel; streaming rows add sketch-mode scale runs with RSS growth\",\n  \
         \"regenerate\": \"cargo bench --bench l4_des_throughput\",\n  \
         \"results\": [\n{}\n  ],\n  \"sweep\": [\n{}\n  ],\n  \"streaming\": [\n{}\n  ]\n}}\n",
        json_results(cells).join(",\n"),
        json_sweeps(sweeps).join(",\n"),
        json_streaming(streaming).join(",\n")
    );
    std::fs::write(path, doc)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let scales: &[u64] = if smoke { &[10_000] } else { &[10_000, 100_000, 1_000_000] };

    println!("=== L4: DES engine throughput (simulated requests/sec) ===\n");
    // The scenario × scale matrix as a flat, data-driven cell list of
    // config *builders* — each cell's config (arrival vectors included)
    // is materialized only while it is being measured, so peak memory
    // stays at one scale's worth. Executed serially on purpose: each
    // cell's wall time is the metric, so cells must not compete for
    // cores (the parallel path is measured separately below, where
    // cells/sec is the metric).
    let builders: [(&'static str, fn(u64) -> ClusterConfig); 3] =
        [("fixed-fleet", fixed_fleet), ("autoscale", autoscale), ("closed-loop", closed_loop)];
    let matrix: Vec<(&'static str, u64, fn(u64) -> ClusterConfig)> = scales
        .iter()
        .flat_map(|&n| builders.iter().map(move |&(scenario, build)| (scenario, n, build)))
        .collect();
    let mut cells: Vec<Cell> = Vec::new();
    let mut rows = Vec::new();
    for &(scenario, n, build) in &matrix {
        let cfg = build(n);
        let cell = measure(scenario, n, &cfg);
        rows.push(vec![
            scenario.to_string(),
            format!("{n}"),
            format!("{}", cell.issued),
            format!("{}", cell.events),
            format!("{:.3}", cell.wall_s),
            format!("{:.0}", cell.requests_per_s()),
            format!("{:.0}", cell.events_per_s()),
        ]);
        println!(
            "{scenario:<12} {n:>9} requests: {:>8.3}s wall, {:>12.0} req/s, {:>12.0} events/s",
            cell.wall_s,
            cell.requests_per_s(),
            cell.events_per_s()
        );
        cells.push(cell);
    }
    println!();
    print!(
        "{}",
        render::table(
            &["Scenario", "Target", "Issued", "Events", "Wall s", "Req/s", "Events/s"],
            &rows
        )
    );

    // Determinism sanity at the smallest scale: identical event counts
    // and collector output across two runs of the same config.
    let (a, b) = (run(&fixed_fleet(10_000)), run(&fixed_fleet(10_000)));
    assert_eq!(a.events, b.events, "event count must be deterministic");
    assert_eq!(a.collector.completed, b.collector.completed);
    assert_eq!(a.collector.e2e.percentile(99.0), b.collector.e2e.percentile(99.0));

    // Streaming + sketch scale row: the whole point of the streaming
    // pipeline — request counts that could never be materialized, at a
    // resident set that does not grow with the horizon.
    println!("\n=== Streaming + sketch: constant-memory scale row ===\n");
    let stream_n: u64 = if smoke { 10_000_000 } else { 100_000_000 };
    let streaming_row = measure_streaming(stream_n, 64.0);
    println!(
        "streaming-sketch {:>11} requests: {:>8.3}s wall, {:>12.0} req/s, p99 {:.3} ms, \
         RSS growth {}",
        streaming_row.requests,
        streaming_row.wall_s,
        streaming_row.requests_per_s(),
        streaming_row.p99_ms,
        streaming_row
            .rss_growth_mb
            .map_or("n/a".to_string(), |g| format!("{g:.1} MB (flat)")),
    );
    let streaming_rows = vec![streaming_row];

    // Sweep engine: cells/sec and parallel speedup on the fig16-style
    // grid, with bit-identity between the serial and threaded runs
    // asserted inside measure_sweep.
    println!("\n=== Sweep engine: grid cells/sec, serial vs parallel ===\n");
    let mut sweeps = Vec::new();
    if smoke {
        // CI smoke: small grid on 2 threads, one line for the summary.
        let plan = sweep_grid(&[1, 2], 5.0);
        let t0 = Instant::now();
        let serial = plan.run(1);
        let serial_wall_s = t0.elapsed().as_secs_f64();
        let row = measure_sweep("smoke-replicas-x-routers", &plan, 2, &serial, serial_wall_s);
        println!(
            "sweep-smoke  {} cells on {} threads: serial {:.3}s ({:.1} cells/s), \
             parallel {:.3}s ({:.1} cells/s), speedup {:.2}x",
            row.cells,
            row.threads,
            row.serial_wall_s,
            row.cells_per_s_serial(),
            row.parallel_wall_s,
            row.cells_per_s_parallel(),
            row.speedup()
        );
        sweeps.push(row);
    } else {
        // Tracked rows: the full fig16-shaped grid at 4 threads (the
        // acceptance point) and, when the host has more cores, at full
        // parallelism too. The serial baseline runs once and is shared
        // by every budget row.
        let plan = sweep_grid(&[1, 2, 4, 8], 40.0);
        let t0 = Instant::now();
        let serial = plan.run(1);
        let serial_wall_s = t0.elapsed().as_secs_f64();
        let mut budgets = vec![4];
        let avail = inferbench::sweep::default_threads();
        if avail > 4 {
            budgets.push(avail);
        }
        for threads in budgets {
            let row =
                measure_sweep("fig16-replicas-x-routers", &plan, threads, &serial, serial_wall_s);
            println!(
                "{:<26} {} cells on {} threads: serial {:.3}s ({:.2} cells/s), \
                 parallel {:.3}s ({:.2} cells/s), speedup {:.2}x",
                row.grid,
                row.cells,
                row.threads,
                row.serial_wall_s,
                row.cells_per_s_serial(),
                row.parallel_wall_s,
                row.cells_per_s_parallel(),
                row.speedup()
            );
            sweeps.push(row);
        }
    }
    // Distributed sweep: the same-shaped grid sharded across 2 followers
    // through each wire codec, with bit-identity asserted against the
    // serial run and bytes-on-wire per cell as the codec metric.
    println!("\n=== Distributed sweep: sharded cells/sec + bytes on the wire ===\n");
    let (dist_fleets, dist_dur): (&[usize], f64) =
        if smoke { (&[1, 2], 4.0) } else { (&[1, 2, 4], 20.0) };
    let dist_kind = dist_grid_kind(dist_fleets, dist_dur);
    let dist_seed = 4242;
    let (dist_plan, _) = job::build_sweep_plan(&dist_kind, dist_seed).expect("plan builds");
    let t0 = Instant::now();
    let dist_serial = dist_plan.run(1);
    let dist_serial_wall_s = t0.elapsed().as_secs_f64();
    for codec in [CodecKind::Binary, CodecKind::JsonLines] {
        let row = measure_distributed(&dist_kind, dist_seed, codec, &dist_serial, dist_serial_wall_s);
        let w = row.wire.as_ref().expect("distributed rows carry wire stats");
        println!(
            "sharded-{}   {} cells over {} followers (balance {:?}): {:.3}s \
             ({:.2} cells/s, serial {:.2}), \
             wire {} B/cell to leader ({} B total, {} B assignments)",
            w.codec,
            row.cells,
            w.followers,
            w.shard_cells,
            row.parallel_wall_s,
            row.cells_per_s_parallel(),
            row.cells_per_s_serial(),
            w.bytes_to_leader / row.cells.max(1) as u64,
            w.bytes_to_leader,
            w.bytes_to_followers
        );
        // Greppable wire accounting for the CI distributed-smoke summary
        // (the same numbers `task: sweep` jobs surface per record).
        println!(
            "wire-stats: codec={} followers={} bytes_sent={} bytes_received={} duplicates={} \
             cells_rerun={} rounds={}",
            w.codec,
            w.followers,
            w.bytes_to_followers,
            w.bytes_to_leader,
            w.duplicate_frames,
            w.cells_rerun,
            w.rounds
        );
        sweeps.push(row);
    }
    // The per-cell fingerprint asserts above are the verdict; this line
    // exists so CI can grep a human-readable confirmation into the job
    // summary (the bench aborts before printing it on any drift).
    println!("sharded == serial: bit-identical fingerprints on every cell, both codecs");

    println!(
        "\nPASS: conservation + determinism on every scenario; sweep parallel == serial \
         bit-for-bit (sharded runs included); streaming scale row at flat RSS"
    );

    if smoke {
        // Don't clobber the committed full matrix with 10k-only rows.
        println!("(smoke run: BENCH_des.json left untouched)");
    } else {
        match write_json(&cells, &sweeps, &streaming_rows) {
            Ok(()) => {
                let (nc, ns) = (cells.len(), sweeps.len());
                println!("wrote BENCH_des.json ({nc} cells, {ns} sweep rows, 1 streaming row)");
            }
            Err(e) => eprintln!("WARNING: could not write BENCH_des.json: {e}"),
        }
    }
}
