//! L4: DES engine throughput — simulated requests/sec and events/sec of
//! the cluster simulator itself (PERF.md). This is the perf trajectory
//! tracker for the engine every fig7–fig17 benchmark runs on: InferBench's
//! value proposition is cheap day-to-day evaluation, and serving studies
//! need million-request scales to resolve tail behavior, so the simulator
//! is benchmarked like any other hot path.
//!
//! Three scenarios × three scales (10k / 100k / 1M requests):
//!  * `fixed-fleet`  — 4 heterogeneous replicas, dynamic batching,
//!    least-outstanding routing, Poisson open-loop arrivals;
//!  * `autoscale`    — spike load against an elastic 2→8 fleet
//!    (queue-depth policy, cold starts, drain-on-remove);
//!  * `closed-loop`  — 64 closed-loop clients over 4 replicas (slot reuse:
//!    the steady-state allocation-free path).
//!
//! Each cell reports wall time, simulated requests/sec, and processed
//! events/sec, and the full matrix is written to `BENCH_des.json` at the
//! repository root so the trajectory is tracked in-repo from this PR
//! onward. Pass `--smoke` to run only the 10k scale (CI).
//!
//! Run: `cargo bench --bench l4_des_throughput [-- --smoke]`

use inferbench::pipeline::{Processors, RequestPath};
use inferbench::serving::autoscale::{AutoscaleConfig, ScalePolicy};
use inferbench::serving::cluster::{run, ClusterConfig, ClusterResult, ReplicaConfig};
use inferbench::serving::{backends, Policy, RouterPolicy, ServiceModel};
use inferbench::util::render;
use inferbench::workload::{generate, Pattern};
use std::path::Path;
use std::time::Instant;

fn replica(per_req_ms: f64) -> ReplicaConfig {
    ReplicaConfig {
        software: &backends::TRIS,
        service: ServiceModel::Measured {
            per_batch: vec![(1, per_req_ms / 1e3), (16, per_req_ms * 3.0 / 1e3)],
            utilization: 0.6,
        },
        policy: Policy::Dynamic { max_size: 16, max_wait_s: 0.002 },
        max_queue: 100_000,
    }
}

/// Fixed 4-replica fleet; Poisson arrivals sized for ~`n` requests.
fn fixed_fleet(n: u64) -> ClusterConfig {
    let rate = 2000.0;
    let duration = n as f64 / rate;
    ClusterConfig {
        arrivals: generate(&Pattern::Poisson { rate }, duration, 42),
        closed_loop: None,
        duration_s: duration,
        replicas: vec![replica(2.0), replica(3.0), replica(5.0), replica(8.0)],
        router: RouterPolicy::LeastOutstanding,
        autoscale: None,
        cold_start: None,
        path: RequestPath::local(Processors::none()),
        seed: 42,
    }
}

/// Elastic fleet under spike load; sized for ~`n` requests.
fn autoscale(n: u64) -> ClusterConfig {
    // Base 1000 rps with a 4000 rps burst over the middle fifth:
    // average offered rate ~1600 rps.
    let duration = n as f64 / 1600.0;
    ClusterConfig {
        arrivals: generate(
            &Pattern::Spike {
                base_rate: 1000.0,
                burst_rate: 4000.0,
                start_s: duration * 0.4,
                duration_s: duration * 0.2,
            },
            duration,
            43,
        ),
        closed_loop: None,
        duration_s: duration,
        replicas: vec![replica(2.0), replica(2.0)],
        router: RouterPolicy::LeastOutstanding,
        autoscale: Some(AutoscaleConfig {
            policy: ScalePolicy::QueueDepth {
                up_per_replica: 8.0,
                down_per_replica: 0.5,
                cooldown_s: 0.5,
            },
            min_replicas: 2,
            max_replicas: 8,
            template: replica(2.0),
            weight_bytes: 50_000_000,
            eval_interval_s: 0.25,
        }),
        cold_start: None,
        path: RequestPath::local(Processors::none()),
        seed: 43,
    }
}

/// 64 closed-loop clients over 4 replicas; sized for ~`n` requests.
/// Exercises the steady-state slot-reuse path: only ~64 traces are ever
/// live at once.
fn closed_loop(n: u64) -> ClusterConfig {
    // 64 clients over 4 replicas at ~2.4 ms effective -> ~2400 rps.
    let duration = n as f64 / 2400.0;
    ClusterConfig {
        arrivals: vec![],
        closed_loop: Some(64),
        duration_s: duration,
        replicas: vec![replica(2.0), replica(2.0), replica(2.0), replica(2.0)],
        router: RouterPolicy::LeastOutstanding,
        autoscale: None,
        cold_start: None,
        path: RequestPath::local(Processors::none()),
        seed: 44,
    }
}

struct Cell {
    scenario: &'static str,
    requests: u64,
    issued: u64,
    completed: u64,
    events: u64,
    wall_s: f64,
}

impl Cell {
    fn requests_per_s(&self) -> f64 {
        self.issued as f64 / self.wall_s
    }

    fn events_per_s(&self) -> f64 {
        self.events as f64 / self.wall_s
    }
}

fn measure(scenario: &'static str, requests: u64, cfg: &ClusterConfig) -> Cell {
    // One warmup pass at small scale already happened (the smoke row);
    // measure the best of two runs to shave scheduler noise.
    let mut best: Option<(f64, ClusterResult)> = None;
    for _ in 0..2 {
        let t0 = Instant::now();
        let r = run(cfg);
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(r.collector.completed + r.dropped, r.issued, "{scenario}: conservation");
        let better = match &best {
            None => true,
            Some((w, _)) => wall < *w,
        };
        if better {
            best = Some((wall, r));
        }
    }
    let (wall_s, r) = best.expect("measured");
    Cell {
        scenario,
        requests,
        issued: r.issued,
        completed: r.collector.completed,
        events: r.events,
        wall_s,
    }
}

fn write_json(cells: &[Cell]) -> std::io::Result<()> {
    // The repo root is one level above the rust package.
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("BENCH_des.json");
    let mut rows = Vec::new();
    for c in cells {
        rows.push(format!(
            "    {{\"scenario\": \"{}\", \"requests\": {}, \"issued\": {}, \"completed\": {}, \
             \"events\": {}, \"wall_s\": {:.4}, \"requests_per_s\": {:.0}, \"events_per_s\": {:.0}}}",
            c.scenario,
            c.requests,
            c.issued,
            c.completed,
            c.events,
            c.wall_s,
            c.requests_per_s(),
            c.events_per_s()
        ));
    }
    let doc = format!(
        "{{\n  \"bench\": \"l4_des_throughput\",\n  \"unit\": \"simulated requests (issued) and \
         DES events per wall-clock second\",\n  \"regenerate\": \"cargo bench --bench \
         l4_des_throughput\",\n  \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write(path, doc)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let scales: &[u64] = if smoke { &[10_000] } else { &[10_000, 100_000, 1_000_000] };

    println!("=== L4: DES engine throughput (simulated requests/sec) ===\n");
    let mut cells: Vec<Cell> = Vec::new();
    let mut rows = Vec::new();
    for &n in scales {
        for (scenario, cfg) in [
            ("fixed-fleet", fixed_fleet(n)),
            ("autoscale", autoscale(n)),
            ("closed-loop", closed_loop(n)),
        ] {
            let cell = measure(scenario, n, &cfg);
            rows.push(vec![
                scenario.to_string(),
                format!("{n}"),
                format!("{}", cell.issued),
                format!("{}", cell.events),
                format!("{:.3}", cell.wall_s),
                format!("{:.0}", cell.requests_per_s()),
                format!("{:.0}", cell.events_per_s()),
            ]);
            println!(
                "{scenario:<12} {n:>9} requests: {:>8.3}s wall, {:>12.0} req/s, {:>12.0} events/s",
                cell.wall_s,
                cell.requests_per_s(),
                cell.events_per_s()
            );
            cells.push(cell);
        }
    }
    println!();
    print!(
        "{}",
        render::table(
            &["Scenario", "Target", "Issued", "Events", "Wall s", "Req/s", "Events/s"],
            &rows
        )
    );

    // Determinism sanity at the smallest scale: identical event counts
    // and collector output across two runs of the same config.
    let (a, b) = (run(&fixed_fleet(10_000)), run(&fixed_fleet(10_000)));
    assert_eq!(a.events, b.events, "event count must be deterministic");
    assert_eq!(a.collector.completed, b.collector.completed);
    assert_eq!(a.collector.e2e.percentile(99.0), b.collector.e2e.percentile(99.0));
    println!("\nPASS: conservation + determinism on every scenario");

    if smoke {
        // Don't clobber the committed full matrix with 10k-only rows.
        println!("(smoke run: BENCH_des.json left untouched)");
    } else {
        match write_json(&cells) {
            Ok(()) => println!("wrote BENCH_des.json ({} cells)", cells.len()),
            Err(e) => eprintln!("WARNING: could not write BENCH_des.json: {e}"),
        }
    }
}
