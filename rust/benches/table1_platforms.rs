//! Table 1: the five hardware platforms, with model-derived ridge points
//! and the cloud instances that carry them. Regenerates the paper's
//! platform table plus the derived roofline parameters every other bench
//! relies on.

use inferbench::hardware::{cloud, PLATFORMS};
use inferbench::util::render;

fn main() {
    println!("=== Table 1: hardware platforms ===\n");
    let rows: Vec<Vec<String>> = PLATFORMS
        .iter()
        .map(|p| {
            let instances = cloud::instances_for(p);
            let offers = if instances.is_empty() {
                "-".to_string()
            } else {
                instances
                    .iter()
                    .map(|i| format!("{}/{} ${:.2}h", i.provider, i.instance, i.hourly_usd))
                    .collect::<Vec<_>>()
                    .join(", ")
            };
            vec![
                p.id.to_string(),
                p.name.to_string(),
                format!("{:?}", p.arch),
                format!("{}", p.memory_gb),
                if p.is_gpu() {
                    format!("{:.2} ({:.1})", p.peak_fp32_tflops, p.peak_fp16_tflops)
                } else {
                    format!("{:.2} sustained", p.peak_fp32_tflops)
                },
                format!("{:.0}", p.mem_bw_gbs),
                if p.is_gpu() { format!("{:.1}", p.ridge_point()) } else { "-".into() },
                offers,
            ]
        })
        .collect();
    print!(
        "{}",
        render::table(
            &[
                "ID",
                "Platform",
                "Arch",
                "Mem GB",
                "TFLOPS (FP32/FP16)",
                "BW GB/s",
                "Ridge FLOP/B",
                "Cloud offers"
            ],
            &rows
        )
    );
    println!("\nPaper check: V100 > 2080Ti > T4 > P4 in peak and bandwidth; V100 on 2 providers.");
}
