//! Stage-4 analysis models (paper §4.2.5, §4.3.1): Roofline, speedup/SLO,
//! the configuration recommender, and the leaderboard-style aggregation
//! helpers the benches print figures from.

pub mod recommender;
pub mod roofline;
pub mod speedup;

pub use recommender::{recommend, Candidate, Recommendation};
pub use roofline::{roofline_point, RooflinePoint};
pub use speedup::{speedup_under_slo, SpeedupRow};
