//! Configuration recommender (paper §4.2.1 Utility Functions): "Users
//! need to input an SLO (e.g., latency), and the system will return the
//! top 3 configurations."
//!
//! Candidates are (platform, software, batch) triples scored by cost per
//! request, filtered by the latency SLO at the expected arrival rate.

use crate::hardware::{cloud, roofline, Parallelism, Platform, PLATFORMS};
use crate::models::catalog::CatalogModel;
use crate::serving::backends::{self, Software};

/// One serving configuration the recommender considers.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub platform: &'static Platform,
    pub software: &'static Software,
    pub batch: usize,
    /// Modeled per-request end-to-end latency at the operating point
    /// (batch fill wait at the arrival rate + service), seconds.
    pub latency_s: f64,
    /// Max sustainable throughput, requests/second.
    pub throughput_rps: f64,
    /// Cheapest cloud cost per 1k requests (USD), if purchasable.
    pub cost_per_1k_usd: Option<f64>,
}

/// A recommendation: the top candidates under the SLO, cheapest first.
#[derive(Debug, Clone)]
pub struct Recommendation {
    pub slo_s: f64,
    pub rate_rps: f64,
    pub top: Vec<Candidate>,
    /// Candidates evaluated in total (for reporting).
    pub considered: usize,
}

/// Score all (GPU platform x software x batch) configs for a model and
/// return the top-k meeting `slo_s` at `rate_rps`, cheapest first.
pub fn recommend(
    model: &CatalogModel,
    par: Parallelism,
    slo_s: f64,
    rate_rps: f64,
    k: usize,
) -> Recommendation {
    let batches = [1usize, 2, 4, 8, 16, 32, 64];
    let mut candidates = Vec::new();
    let mut considered = 0;
    for platform in PLATFORMS.iter().filter(|p| p.is_gpu()) {
        for software in backends::ALL {
            for &batch in &batches {
                considered += 1;
                let est =
                    roofline::estimate(platform, &model.profile, par, batch, model.request_bytes);
                let service_s = est.total_s * software.runtime_factor
                    + software.batch_overhead_s
                    + software.request_overhead_s;
                // Expected wait to fill the batch at the arrival rate
                // (mean: (b-1)/2 inter-arrival gaps).
                let fill_wait_s = if batch > 1 { (batch as f64 - 1.0) / (2.0 * rate_rps) } else { 0.0 };
                let latency_s = service_s + fill_wait_s;
                let throughput = batch as f64 / service_s;
                if latency_s > slo_s || throughput < rate_rps {
                    continue;
                }
                let cost = cloud::instances_for(platform)
                    .iter()
                    .map(|i| i.hourly_usd / (throughput.min(rate_rps.max(1.0)) * 3.6))
                    .fold(f64::INFINITY, f64::min);
                candidates.push(Candidate {
                    platform,
                    software,
                    batch,
                    latency_s,
                    throughput_rps: throughput,
                    cost_per_1k_usd: if cost.is_finite() { Some(cost) } else { None },
                });
            }
        }
    }
    // Cheapest first; configs without cloud pricing sort last.
    candidates.sort_by(|a, b| {
        let ca = a.cost_per_1k_usd.unwrap_or(f64::INFINITY);
        let cb = b.cost_per_1k_usd.unwrap_or(f64::INFINITY);
        ca.partial_cmp(&cb).unwrap().then(a.latency_s.partial_cmp(&b.latency_s).unwrap())
    });
    candidates.truncate(k);
    Recommendation { slo_s, rate_rps, top: candidates, considered }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::catalog;

    #[test]
    fn returns_top_3_meeting_slo() {
        let m = catalog::find("resnet50").unwrap();
        let rec = recommend(m, Parallelism::cnn(224), 0.100, 50.0, 3);
        assert!(rec.top.len() <= 3);
        assert!(!rec.top.is_empty(), "100ms SLO at 50rps should be satisfiable");
        for c in &rec.top {
            assert!(c.latency_s <= 0.100);
            assert!(c.throughput_rps >= 50.0);
        }
        assert!(rec.considered > 50);
    }

    #[test]
    fn sorted_cheapest_first() {
        let m = catalog::find("resnet50").unwrap();
        let rec = recommend(m, Parallelism::cnn(224), 0.2, 20.0, 5);
        let costs: Vec<f64> =
            rec.top.iter().filter_map(|c| c.cost_per_1k_usd).collect();
        for w in costs.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn tight_slo_prefers_fast_config() {
        let m = catalog::find("bert_large").unwrap();
        let tight = recommend(m, Parallelism::sequence(128), 0.020, 10.0, 3);
        for c in &tight.top {
            assert!(c.latency_s <= 0.020, "{:?}", c.latency_s);
        }
    }

    #[test]
    fn impossible_slo_returns_empty() {
        let m = catalog::find("cyclegan").unwrap();
        let rec = recommend(m, Parallelism::cnn(224), 1e-6, 1000.0, 3);
        assert!(rec.top.is_empty());
    }

    #[test]
    fn higher_rate_requires_higher_throughput() {
        let m = catalog::find("resnet50").unwrap();
        let rec = recommend(m, Parallelism::cnn(224), 0.2, 400.0, 10);
        for c in &rec.top {
            assert!(c.throughput_rps >= 400.0);
        }
    }
}
