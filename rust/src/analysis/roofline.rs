//! Roofline analysis model (paper §4.3.1, Fig 10; Williams et al. 2009).
//!
//! Places a (model, batch) point at (arithmetic intensity, achieved
//! ops/second) against a platform's ceilings: the bandwidth roof
//! `bw * intensity` and the compute roof `peak`.

use crate::hardware::{roofline as hw, Parallelism, Platform};
use crate::models::Profile;

/// One point on the Roofline plot.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub label: String,
    /// FLOPs per HBM byte.
    pub intensity: f64,
    /// Achieved FLOP/s.
    pub achieved_flops: f64,
    /// Attainable roof at this intensity: min(peak, bw * intensity).
    pub roof_flops: f64,
    pub memory_bound: bool,
}

impl RooflinePoint {
    /// Achieved fraction of the attainable roof (quality of attained
    /// performance — what the paper argues Roofline adds over
    /// percent-of-peak).
    pub fn attainment(&self) -> f64 {
        self.achieved_flops / self.roof_flops
    }
}

/// Compute the Roofline point for a model at a batch on a platform.
pub fn roofline_point(
    label: &str,
    platform: &Platform,
    profile: &Profile,
    par: Parallelism,
    batch: usize,
) -> RooflinePoint {
    let est = hw::estimate(platform, profile, par, batch, 0);
    let intensity = profile.arithmetic_intensity(batch);
    let achieved = profile.batch_flops(batch) / est.total_s;
    let peak = platform.peak_fp32_tflops * 1e12;
    let bw_roof = platform.mem_bw_gbs * 1e9 * intensity;
    RooflinePoint {
        label: label.to_string(),
        intensity,
        achieved_flops: achieved,
        roof_flops: peak.min(bw_roof),
        memory_bound: est.memory_bound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::find;
    use crate::models::{analytic, catalog};

    #[test]
    fn achieved_below_roof() {
        let v100 = find("G1").unwrap();
        for m in catalog::CATALOG {
            for b in [1, 8, 32] {
                let p = roofline_point(m.name, v100, &m.profile, Parallelism::cnn(224), b);
                assert!(
                    p.achieved_flops <= p.roof_flops * 1.0001,
                    "{} b{b}: {} > {}",
                    m.name,
                    p.achieved_flops,
                    p.roof_flops
                );
                assert!(p.attainment() > 0.0 && p.attainment() <= 1.0001);
            }
        }
    }

    #[test]
    fn fig10a_mobilenet_memory_bound_resnet_compute_bound() {
        let v100 = find("G1").unwrap();
        let rn = catalog::find("resnet50").unwrap();
        let mb = catalog::find("mobilenet_v1").unwrap();
        let ridge = v100.ridge_point();
        let prn = roofline_point("rn", v100, &rn.profile, Parallelism::cnn(224), 32);
        let pmb = roofline_point("mb", v100, &mb.profile, Parallelism::cnn(224), 32);
        assert!(prn.intensity > ridge, "resnet right of ridge");
        assert!(pmb.intensity < ridge, "mobilenet left of ridge");
        assert!(pmb.memory_bound && !prn.memory_bound);
    }

    #[test]
    fn fig10b_batch_moves_generated_models_right_and_up() {
        let v100 = find("G1").unwrap();
        let mlp = analytic::mlp(8, 1024, 256, 16);
        let p1 = roofline_point("b1", v100, &mlp, Parallelism::mlp(), 1);
        let p64 = roofline_point("b64", v100, &mlp, Parallelism::mlp(), 64);
        assert!(p64.intensity > p1.intensity);
        assert!(p64.achieved_flops > p1.achieved_flops);
    }

    #[test]
    fn roof_is_min_of_ceilings() {
        let v100 = find("G1").unwrap();
        let mlp = analytic::mlp(4, 256, 256, 16);
        let p = roofline_point("x", v100, &mlp, Parallelism::mlp(), 1);
        let peak = v100.peak_fp32_tflops * 1e12;
        let bw = v100.mem_bw_gbs * 1e9 * p.intensity;
        assert!((p.roof_flops - peak.min(bw)).abs() < 1.0);
    }
}
