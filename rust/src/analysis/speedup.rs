//! GPU/CPU speedup under an SLO (paper Fig 7c).
//!
//! The paper's method: use each service's CPU latency as its SLO, then
//! find the best batch size whose *per-request* GPU latency still meets
//! the SLO, and report the throughput speedup at that operating point.

use crate::hardware::{roofline, Parallelism, Platform};
use crate::models::Profile;

/// One row of the Fig 7c study.
#[derive(Debug, Clone)]
pub struct SpeedupRow {
    pub model: String,
    /// The SLO used (the CPU latency), seconds.
    pub slo_s: f64,
    /// Best batch size meeting the SLO on the GPU.
    pub best_batch: usize,
    /// GPU per-request latency at that batch.
    pub gpu_latency_s: f64,
    /// Throughput speedup over the CPU at batch 1.
    pub speedup: f64,
}

/// Compute the speedup row for one model. `cpu_latency_s` is the measured
/// or modeled CPU (C1) latency at batch 1 — it doubles as the SLO.
pub fn speedup_under_slo(
    model: &str,
    gpu: &Platform,
    profile: &Profile,
    par: Parallelism,
    request_bytes: u64,
    cpu_latency_s: f64,
    candidate_batches: &[usize],
) -> SpeedupRow {
    let cpu_throughput = 1.0 / cpu_latency_s;
    let mut best_batch = 1;
    let mut best_throughput = 0.0;
    let mut best_latency = f64::INFINITY;
    for &b in candidate_batches {
        let est = roofline::estimate(gpu, profile, par, b, request_bytes);
        // SLO check on the full batch latency: a request admitted into a
        // batch waits for the whole batch to return.
        if est.total_s <= cpu_latency_s {
            let tput = b as f64 / est.total_s;
            if tput > best_throughput {
                best_throughput = tput;
                best_batch = b;
                best_latency = est.total_s;
            }
        }
    }
    if best_throughput == 0.0 {
        // Even batch 1 misses the SLO; report batch 1 as the paper would.
        let est = roofline::estimate(gpu, profile, par, 1, request_bytes);
        best_batch = 1;
        best_latency = est.total_s;
        best_throughput = 1.0 / est.total_s;
    }
    SpeedupRow {
        model: model.to_string(),
        slo_s: cpu_latency_s,
        best_batch,
        gpu_latency_s: best_latency,
        speedup: best_throughput / cpu_throughput,
    }
}

/// Model the CPU (C1) latency of a profile (used when no measured value
/// is available — e.g. full-scale catalog models too big to run here).
pub fn modeled_cpu_latency(cpu: &Platform, profile: &Profile, par: Parallelism) -> f64 {
    roofline::estimate(cpu, profile, par, 1, 0).total_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::find;
    use crate::models::catalog;

    const BATCHES: &[usize] = &[1, 2, 4, 8, 16, 32, 64, 128];

    #[test]
    fn speedups_in_paper_range() {
        // Paper Fig 7c: 3.6x .. 47.4x across OD/GAN/TC/IC on V100.
        let v100 = find("G1").unwrap();
        let cpu = find("C1").unwrap();
        for m in catalog::speedup_study_models() {
            let par = Parallelism::cnn(28);
            let cpu_lat = modeled_cpu_latency(cpu, &m.profile, par);
            let row =
                speedup_under_slo(m.name, v100, &m.profile, par, m.request_bytes, cpu_lat, BATCHES);
            assert!(
                row.speedup > 2.0 && row.speedup < 100.0,
                "{}: speedup {} out of plausible range",
                m.name,
                row.speedup
            );
        }
    }

    #[test]
    fn heavier_models_speed_up_more() {
        // GPU advantage grows with compute intensity: CycleGAN >> TextCNN.
        let v100 = find("G1").unwrap();
        let cpu = find("C1").unwrap();
        let gan = catalog::find("cyclegan").unwrap();
        let tc = catalog::find("textlstm").unwrap();
        let par = Parallelism::cnn(224);
        let row_gan = speedup_under_slo(
            "gan", v100, &gan.profile, par, gan.request_bytes,
            modeled_cpu_latency(cpu, &gan.profile, par), BATCHES,
        );
        let row_tc = speedup_under_slo(
            "tc", v100, &tc.profile, par, tc.request_bytes,
            modeled_cpu_latency(cpu, &tc.profile, par), BATCHES,
        );
        assert!(row_gan.speedup > row_tc.speedup);
    }

    #[test]
    fn chosen_batch_meets_slo() {
        let v100 = find("G1").unwrap();
        let m = catalog::find("resnet50").unwrap();
        let par = Parallelism::cnn(224);
        let slo = 0.050; // 50 ms
        let row = speedup_under_slo("rn", v100, &m.profile, par, m.request_bytes, slo, BATCHES);
        assert!(row.gpu_latency_s <= slo + 1e-9);
        assert!(row.best_batch >= 1);
    }

    #[test]
    fn impossible_slo_falls_back_to_batch_1() {
        let v100 = find("G1").unwrap();
        let m = catalog::find("cyclegan").unwrap();
        let par = Parallelism::cnn(224);
        let row = speedup_under_slo("gan", v100, &m.profile, par, 0, 1e-6, BATCHES);
        assert_eq!(row.best_batch, 1);
    }
}
