//! Self-describing wire codec for distributed sweeps.
//!
//! The leader shards one `SweepPlan` across followers (see
//! `coordinator::distributed`); everything that crosses the leader/follower
//! boundary is a [`Frame`] serialized through a [`Codec`]. Two impls share
//! the frame vocabulary:
//!
//! * [`JsonLinesCodec`] — one compact-JSON object per line. Every frame is
//!   human-readable (`frame` key names its type), greppable, and diffable;
//!   the debugging format.
//! * [`BinaryCodec`] — `[magic][kind][len u32 LE][payload]` with raw
//!   `f64::to_bits` floats and length-prefixed strings; the hot-path
//!   format (~6-8x fewer bytes per exact-mode cell than JSON, and no
//!   float formatting on either end).
//!
//! Both are *self-describing* in the sense that matters for a stream: each
//! frame carries its own type in-band (the `frame` key / the kind byte)
//! and its own extent (the newline / the length prefix), so a reader never
//! needs out-of-band schema agreement to walk a stream, skip a frame, or
//! resynchronize diagnostics. Determinism is part of the contract: both
//! encoders are byte-deterministic (sorted object keys, shortest-roundtrip
//! float text on the JSON side; fixed field order on the binary side), so
//! encode → decode → encode reproduces the original bytes exactly.
//!
//! Frames stream in both directions: the leader sends one
//! [`ShardAssignment`] per follower, followers stream one
//! [`CellResultFrame`] per finished cell (not one blob per shard), then
//! close with `ShardDone`/`ShardFailed`. [`FrameReader`] reassembles
//! frames from arbitrary transport chunking and reports malformed input
//! loudly with absolute byte offsets ([`CodecError`]); a partial frame is
//! never an error, just "feed me more bytes".
//!
//! Latency payloads ride as the snapshot types ([`SummarySnapshot`],
//! [`CollectorSnapshot`], [`ClassSnapshot`]) whose restore is bit-identical
//! in both metric modes — the foundation of the distributed determinism
//! guarantee (PERF.md §Distributed sweeps).

use crate::metrics::{ClassSnapshot, CollectorSnapshot, DROP_REASONS};
use crate::util::json::{self, Json};
use crate::util::stats::SummarySnapshot;
use std::fmt;

/// Decode failure: the stream holds bytes that cannot be a frame. The
/// offset is relative to the start of the buffer handed to
/// [`Codec::decode`]; [`FrameReader`] rebases it to the absolute stream
/// position before surfacing it.
#[derive(Debug, Clone, PartialEq)]
pub struct CodecError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "codec error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for CodecError {}

/// One message on the distributed-sweep wire.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Leader → follower: run these cells of the shared grid.
    Shard(ShardAssignment),
    /// Follower → leader: one finished cell, streamed as it completes.
    CellResult(CellResultFrame),
    /// Follower → leader: the shard finished; `cells` results were sent.
    ShardDone { shard: u32, cells: u32 },
    /// Follower → leader: the shard died after sending `completed`
    /// results. The leader re-queues the outstanding cells elsewhere.
    ShardFailed { shard: u32, completed: u32, error: String },
    /// A trace span (see `obs`): follower shards stream cell spans to
    /// the leader alongside `CellResult`s, and `obs::TraceSink` writes
    /// any span set as line-delimited frames for offline tooling.
    Span(SpanFrame),
}

impl Frame {
    /// The in-band type tag (`frame` key / kind-byte name).
    pub fn kind(&self) -> &'static str {
        match self {
            Frame::Shard(_) => "shard",
            Frame::CellResult(_) => "cell_result",
            Frame::ShardDone { .. } => "shard_done",
            Frame::ShardFailed { .. } => "shard_failed",
            Frame::Span(_) => "span",
        }
    }
}

/// One trace span on the wire: a named `[start_s, end_s]` interval on a
/// track, optionally parented (`parent` is a span id, `-1` = root),
/// with stringified attributes. Sim-time extents, so a follower's cell
/// spans are as deterministic as its `CellResult`s.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanFrame {
    /// Lane the span renders on, e.g. `shard-1` or `requests`.
    pub track: String,
    /// Span id within its track (cell index for shard cell spans).
    pub id: u64,
    /// Parent span id within the same track; `-1` for roots.
    pub parent: i64,
    pub name: String,
    pub start_s: f64,
    pub end_s: f64,
    /// Attribute key/value pairs (values pre-rendered to strings).
    pub attrs: Vec<(String, String)>,
}

/// One follower's slice of a sweep: the shared grid description (the job
/// layer's YAML-shaped doc, opaque to the codec) plus the assigned cells.
/// Followers rebuild the full plan from `grid` and run only their indices,
/// so a cell computes from `cell_seed(plan_seed, index)` no matter where
/// it lands — the sharding-is-invisible determinism argument.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardAssignment {
    pub shard: u32,
    pub plan_seed: u64,
    /// Grid config doc (`GridSpec::to_json` shape). Codec-opaque: it
    /// round-trips as a JSON value, validated by the job layer's parser.
    pub grid: Json,
    pub cells: Vec<CellSpec>,
}

/// One assigned cell: its global plan index, its derived per-cell seed
/// (redundant with `cell_seed(plan_seed, index)` — shipped so followers
/// can cross-check for seed drift), and its human-readable axes label.
#[derive(Debug, Clone, PartialEq)]
pub struct CellSpec {
    pub index: u32,
    pub seed: u64,
    pub label: String,
}

/// One finished cell, streamed back as soon as it completes: the ledger
/// counters plus the full latency payload (collector snapshot and
/// per-class snapshots). Everything a sweep-level PerfDB record reads.
#[derive(Debug, Clone, PartialEq)]
pub struct CellResultFrame {
    /// Global plan index — the reconciliation key for duplicate frames.
    pub cell: u32,
    /// The per-cell seed the cell actually ran with.
    pub seed: u64,
    pub label: String,
    pub issued: u64,
    pub events: u64,
    pub dropped: u64,
    pub downtime_s: f64,
    pub collector: CollectorSnapshot,
    pub classes: Vec<ClassSnapshot>,
}

/// A streaming frame codec. `encode` appends one frame; `decode` reads one
/// frame off the front of a buffer:
///
/// * `Ok(Some((frame, consumed)))` — a complete frame; drop `consumed`
///   bytes and go again.
/// * `Ok(None)` — the buffer holds only a prefix of a frame; read more.
/// * `Err(CodecError)` — the bytes cannot be a frame (corruption, schema
///   violation, counters that do not reconcile); the offset names the bad
///   byte relative to the buffer start.
pub trait Codec: Send + Sync {
    fn name(&self) -> &'static str;
    fn encode(&self, frame: &Frame, out: &mut Vec<u8>);
    fn decode(&self, buf: &[u8]) -> Result<Option<(Frame, usize)>, CodecError>;
}

/// Codec selection knob — what job YAML and bench flags name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    JsonLines,
    Binary,
}

impl CodecKind {
    pub fn codec(&self) -> &'static dyn Codec {
        match self {
            CodecKind::JsonLines => &JsonLinesCodec,
            CodecKind::Binary => &BinaryCodec,
        }
    }

    pub fn name(&self) -> &'static str {
        self.codec().name()
    }
}

/// Incremental frame reassembly over arbitrary transport chunking: push
/// byte chunks as they arrive, pull frames as they complete. Error offsets
/// are rebased to absolute stream positions (bytes since the first push),
/// so "codec error at byte 1048600" points into the real stream, not the
/// current window.
pub struct FrameReader {
    codec: &'static dyn Codec,
    buf: Vec<u8>,
    drained: usize,
}

impl FrameReader {
    pub fn new(kind: CodecKind) -> Self {
        FrameReader { codec: kind.codec(), buf: Vec::new(), drained: 0 }
    }

    pub fn push(&mut self, chunk: &[u8]) {
        self.buf.extend_from_slice(chunk);
    }

    /// Next complete frame, or `None` if the buffered bytes are a frame
    /// prefix. After an error the reader is poisoned for that stream —
    /// callers treat it as a failed peer (there is no resync heuristic
    /// that could not also fabricate results).
    pub fn next_frame(&mut self) -> Result<Option<Frame>, CodecError> {
        match self.codec.decode(&self.buf) {
            Ok(Some((frame, consumed))) => {
                self.buf.drain(..consumed);
                self.drained += consumed;
                Ok(Some(frame))
            }
            Ok(None) => Ok(None),
            Err(e) => Err(CodecError { offset: self.drained + e.offset, message: e.message }),
        }
    }

    /// Bytes buffered but not yet consumed by a complete frame.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }
}

/// Frame-level semantic validation shared by both decoders: snapshots must
/// restore without panicking and ledgers must reconcile. Rejecting here
/// keeps "malformed frame" a loud decode error instead of a panic (or a
/// silent corruption) deep inside the leader's absorption path.
fn validate_frame(frame: &Frame) -> Result<(), String> {
    fn check_collector(c: &CollectorSnapshot, what: &str) -> Result<(), String> {
        c.e2e.validate().map_err(|e| format!("{what} e2e summary: {e}"))?;
        for (i, s) in c.per_stage.iter().enumerate() {
            s.validate().map_err(|e| format!("{what} stage {i} summary: {e}"))?;
        }
        let by_reason: u64 = c.dropped_by_reason.iter().sum();
        if by_reason != c.dropped {
            return Err(format!(
                "{what}: drop counters do not reconcile ({by_reason} by reason vs {} total)",
                c.dropped
            ));
        }
        if c.e2e.len() as u64 != c.completed {
            return Err(format!(
                "{what}: e2e sample count {} disagrees with completed {}",
                c.e2e.len(),
                c.completed
            ));
        }
        Ok(())
    }
    match frame {
        Frame::CellResult(r) => {
            check_collector(&r.collector, "cell collector")?;
            for cl in &r.classes {
                check_collector(&cl.collector, &format!("class {} collector", cl.class))?;
            }
            if r.collector.completed + r.dropped != r.issued {
                return Err(format!(
                    "cell {} ledger does not conserve: {} completed + {} dropped != {} issued",
                    r.cell, r.collector.completed, r.dropped, r.issued
                ));
            }
            Ok(())
        }
        Frame::Shard(s) => {
            for c in &s.cells {
                if c.seed != crate::sweep::cell_seed(s.plan_seed, c.index as u64) {
                    return Err(format!(
                        "shard {}: cell {} seed {:#x} disagrees with cell_seed(plan_seed, index)",
                        s.shard, c.index, c.seed
                    ));
                }
            }
            Ok(())
        }
        Frame::Span(s) => {
            if !s.start_s.is_finite() || !s.end_s.is_finite() {
                return Err(format!(
                    "span {}/{}: non-finite extent [{}, {}]",
                    s.track, s.id, s.start_s, s.end_s
                ));
            }
            if s.end_s < s.start_s {
                return Err(format!(
                    "span {}/{}: ends before it starts ({} < {})",
                    s.track, s.id, s.end_s, s.start_s
                ));
            }
            if s.parent < -1 {
                return Err(format!("span {}/{}: parent id {} below -1", s.track, s.id, s.parent));
            }
            Ok(())
        }
        Frame::ShardDone { .. } | Frame::ShardFailed { .. } => Ok(()),
    }
}

// ---------------------------------------------------------------------------
// JSON lines
// ---------------------------------------------------------------------------

/// Line-delimited JSON: one compact object per frame, `frame` key first
/// (alphabetical accident of `BTreeMap`, but guaranteed present) naming the
/// type. Floats use the writer's shortest-roundtrip formatting, so finite
/// values survive bit-exactly; IEEE specials (`±inf`, `nan`), which JSON
/// cannot carry as numbers, ride as the strings `"inf"` / `"-inf"` /
/// `"nan"`. u64 counters beyond `i64::MAX` (per-cell seeds are full-width
/// PCG outputs) ride as decimal strings.
pub struct JsonLinesCodec;

impl Codec for JsonLinesCodec {
    fn name(&self) -> &'static str {
        "jsonl"
    }

    fn encode(&self, frame: &Frame, out: &mut Vec<u8>) {
        out.extend_from_slice(frame_to_json(frame).to_string_compact().as_bytes());
        out.push(b'\n');
    }

    fn decode(&self, buf: &[u8]) -> Result<Option<(Frame, usize)>, CodecError> {
        let Some(nl) = buf.iter().position(|&b| b == b'\n') else {
            return Ok(None);
        };
        let text = std::str::from_utf8(&buf[..nl]).map_err(|e| CodecError {
            offset: e.valid_up_to(),
            message: "invalid utf-8 in jsonl frame".into(),
        })?;
        let doc = json::parse(text)
            .map_err(|e| CodecError { offset: e.offset, message: e.message })?;
        let frame = frame_from_json(&doc)
            .map_err(|m| CodecError { offset: 0, message: format!("jsonl frame: {m}") })?;
        validate_frame(&frame)
            .map_err(|m| CodecError { offset: 0, message: format!("jsonl frame: {m}") })?;
        Ok(Some((frame, nl + 1)))
    }
}

fn jf64(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else if x.is_nan() {
        Json::Str("nan".into())
    } else if x > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

fn ju64(x: u64) -> Json {
    if x <= i64::MAX as u64 {
        Json::Int(x as i64)
    } else {
        Json::Str(x.to_string())
    }
}

fn pf64(v: &Json, what: &str) -> Result<f64, String> {
    if let Some(s) = v.as_str() {
        return match s {
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "nan" => Ok(f64::NAN),
            _ => Err(format!("{what}: unrecognized float string {s:?}")),
        };
    }
    v.as_f64().ok_or_else(|| format!("{what}: expected a number"))
}

fn pu64(v: &Json, what: &str) -> Result<u64, String> {
    if let Some(i) = v.as_i64() {
        return u64::try_from(i).map_err(|_| format!("{what}: negative count {i}"));
    }
    if let Some(s) = v.as_str() {
        return s.parse::<u64>().map_err(|_| format!("{what}: unparseable u64 string {s:?}"));
    }
    Err(format!("{what}: expected a u64"))
}

fn pu32(v: &Json, what: &str) -> Result<u32, String> {
    u32::try_from(pu64(v, what)?).map_err(|_| format!("{what}: exceeds u32"))
}

fn field<'a>(v: &'a Json, key: &str, what: &str) -> Result<&'a Json, String> {
    v.get(key).ok_or_else(|| format!("{what}: missing {key:?}"))
}

fn pstr(v: &Json, what: &str) -> Result<String, String> {
    v.as_str().map(str::to_string).ok_or_else(|| format!("{what}: expected a string"))
}

fn summary_to_json(s: &SummarySnapshot) -> Json {
    let mut o = Json::obj();
    match s {
        SummarySnapshot::Exact { samples } => {
            o.set("kind", Json::Str("exact".into()));
            o.set("samples", Json::Arr(samples.iter().map(|&x| jf64(x)).collect()));
        }
        SummarySnapshot::Sketch { alpha, buckets, zero_count, count, sum_sq, sum, min, max } => {
            o.set("kind", Json::Str("sketch".into()));
            o.set("alpha", jf64(*alpha));
            o.set(
                "buckets",
                Json::Arr(
                    buckets
                        .iter()
                        .map(|&(k, c)| Json::Arr(vec![Json::Int(k as i64), ju64(c)]))
                        .collect(),
                ),
            );
            o.set("zero_count", ju64(*zero_count));
            o.set("count", ju64(*count));
            o.set("sum_sq", jf64(*sum_sq));
            o.set("sum", jf64(*sum));
            o.set("min", jf64(*min));
            o.set("max", jf64(*max));
        }
    }
    o
}

fn summary_from_json(v: &Json, what: &str) -> Result<SummarySnapshot, String> {
    match field(v, "kind", what)?.as_str() {
        Some("exact") => {
            let arr = field(v, "samples", what)?
                .as_arr()
                .ok_or_else(|| format!("{what}: samples must be an array"))?;
            let mut samples = Vec::with_capacity(arr.len());
            for (i, x) in arr.iter().enumerate() {
                samples.push(pf64(x, &format!("{what} sample {i}"))?);
            }
            Ok(SummarySnapshot::Exact { samples })
        }
        Some("sketch") => {
            let arr = field(v, "buckets", what)?
                .as_arr()
                .ok_or_else(|| format!("{what}: buckets must be an array"))?;
            let mut buckets = Vec::with_capacity(arr.len());
            for (i, pair) in arr.iter().enumerate() {
                let pair = pair
                    .as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| format!("{what} bucket {i}: expected [index, count]"))?;
                buckets.push((
                    pu32(&pair[0], &format!("{what} bucket {i} index"))?,
                    pu64(&pair[1], &format!("{what} bucket {i} count"))?,
                ));
            }
            Ok(SummarySnapshot::Sketch {
                alpha: pf64(field(v, "alpha", what)?, &format!("{what} alpha"))?,
                buckets,
                zero_count: pu64(field(v, "zero_count", what)?, &format!("{what} zero_count"))?,
                count: pu64(field(v, "count", what)?, &format!("{what} count"))?,
                sum_sq: pf64(field(v, "sum_sq", what)?, &format!("{what} sum_sq"))?,
                sum: pf64(field(v, "sum", what)?, &format!("{what} sum"))?,
                min: pf64(field(v, "min", what)?, &format!("{what} min"))?,
                max: pf64(field(v, "max", what)?, &format!("{what} max"))?,
            })
        }
        Some(k) => Err(format!("{what}: unknown summary kind {k:?}")),
        None => Err(format!("{what}: summary kind must be a string")),
    }
}

fn collector_to_json(c: &CollectorSnapshot) -> Json {
    let mut o = Json::obj();
    o.set("e2e", summary_to_json(&c.e2e));
    o.set("stages", Json::Arr(c.per_stage.iter().map(summary_to_json).collect()));
    o.set("bounded", Json::Bool(c.bounded));
    o.set("completed", ju64(c.completed));
    o.set("dropped", ju64(c.dropped));
    o.set("drops", Json::Arr(c.dropped_by_reason.iter().map(|&d| ju64(d)).collect()));
    o.set("first_arrival_s", jf64(c.first_arrival_s));
    o.set("last_completion_s", jf64(c.last_completion_s));
    o
}

fn collector_from_json(v: &Json, what: &str) -> Result<CollectorSnapshot, String> {
    let stages = field(v, "stages", what)?
        .as_arr()
        .filter(|a| a.len() == 5)
        .ok_or_else(|| format!("{what}: stages must be an array of 5 summaries"))?;
    let mut per_stage: [SummarySnapshot; 5] =
        std::array::from_fn(|_| SummarySnapshot::Exact { samples: Vec::new() });
    for (i, s) in stages.iter().enumerate() {
        per_stage[i] = summary_from_json(s, &format!("{what} stage {i}"))?;
    }
    let drops = field(v, "drops", what)?
        .as_arr()
        .filter(|a| a.len() == DROP_REASONS.len())
        .ok_or_else(|| format!("{what}: drops must list {} counters", DROP_REASONS.len()))?;
    let mut dropped_by_reason = [0u64; DROP_REASONS.len()];
    for (i, d) in drops.iter().enumerate() {
        dropped_by_reason[i] = pu64(d, &format!("{what} drop reason {i}"))?;
    }
    Ok(CollectorSnapshot {
        e2e: summary_from_json(field(v, "e2e", what)?, &format!("{what} e2e"))?,
        per_stage,
        bounded: field(v, "bounded", what)?
            .as_bool()
            .ok_or_else(|| format!("{what}: bounded must be a boolean"))?,
        completed: pu64(field(v, "completed", what)?, &format!("{what} completed"))?,
        dropped: pu64(field(v, "dropped", what)?, &format!("{what} dropped"))?,
        dropped_by_reason,
        first_arrival_s: pf64(field(v, "first_arrival_s", what)?, &format!("{what} first_arrival_s"))?,
        last_completion_s: pf64(
            field(v, "last_completion_s", what)?,
            &format!("{what} last_completion_s"),
        )?,
    })
}

fn class_to_json(c: &ClassSnapshot) -> Json {
    let mut o = Json::obj();
    o.set("class", Json::Int(c.class as i64));
    o.set("issued", ju64(c.issued));
    o.set("collector", collector_to_json(&c.collector));
    o
}

fn class_from_json(v: &Json, what: &str) -> Result<ClassSnapshot, String> {
    let class = pu64(field(v, "class", what)?, &format!("{what} class"))?;
    let class = u8::try_from(class).map_err(|_| format!("{what}: class {class} exceeds u8"))?;
    Ok(ClassSnapshot {
        class,
        issued: pu64(field(v, "issued", what)?, &format!("{what} issued"))?,
        collector: collector_from_json(field(v, "collector", what)?, what)?,
    })
}

fn frame_to_json(frame: &Frame) -> Json {
    let mut o = Json::obj();
    o.set("frame", Json::Str(frame.kind().into()));
    match frame {
        Frame::Shard(s) => {
            o.set("shard", Json::Int(s.shard as i64));
            o.set("plan_seed", ju64(s.plan_seed));
            o.set("grid", s.grid.clone());
            o.set(
                "cells",
                Json::Arr(
                    s.cells
                        .iter()
                        .map(|c| {
                            let mut cell = Json::obj();
                            cell.set("index", Json::Int(c.index as i64));
                            cell.set("seed", ju64(c.seed));
                            cell.set("label", Json::Str(c.label.clone()));
                            cell
                        })
                        .collect(),
                ),
            );
        }
        Frame::CellResult(r) => {
            o.set("cell", Json::Int(r.cell as i64));
            o.set("seed", ju64(r.seed));
            o.set("label", Json::Str(r.label.clone()));
            o.set("issued", ju64(r.issued));
            o.set("events", ju64(r.events));
            o.set("dropped", ju64(r.dropped));
            o.set("downtime_s", jf64(r.downtime_s));
            o.set("collector", collector_to_json(&r.collector));
            o.set("classes", Json::Arr(r.classes.iter().map(class_to_json).collect()));
        }
        Frame::ShardDone { shard, cells } => {
            o.set("shard", Json::Int(*shard as i64));
            o.set("cells", Json::Int(*cells as i64));
        }
        Frame::ShardFailed { shard, completed, error } => {
            o.set("shard", Json::Int(*shard as i64));
            o.set("completed", Json::Int(*completed as i64));
            o.set("error", Json::Str(error.clone()));
        }
        Frame::Span(s) => {
            o.set("track", Json::Str(s.track.clone()));
            o.set("id", ju64(s.id));
            o.set("parent", Json::Int(s.parent));
            o.set("name", Json::Str(s.name.clone()));
            o.set("start_s", jf64(s.start_s));
            o.set("end_s", jf64(s.end_s));
            o.set(
                "attrs",
                Json::Arr(
                    s.attrs
                        .iter()
                        .map(|(k, v)| {
                            Json::Arr(vec![Json::Str(k.clone()), Json::Str(v.clone())])
                        })
                        .collect(),
                ),
            );
        }
    }
    o
}

fn frame_from_json(v: &Json) -> Result<Frame, String> {
    let kind = field(v, "frame", "frame")?
        .as_str()
        .ok_or_else(|| "frame: type tag must be a string".to_string())?;
    match kind {
        "shard" => {
            let cells_arr = field(v, "cells", "shard")?
                .as_arr()
                .ok_or_else(|| "shard: cells must be an array".to_string())?;
            let mut cells = Vec::with_capacity(cells_arr.len());
            for (i, c) in cells_arr.iter().enumerate() {
                let what = format!("shard cell {i}");
                cells.push(CellSpec {
                    index: pu32(field(c, "index", &what)?, &format!("{what} index"))?,
                    seed: pu64(field(c, "seed", &what)?, &format!("{what} seed"))?,
                    label: pstr(field(c, "label", &what)?, &format!("{what} label"))?,
                });
            }
            Ok(Frame::Shard(ShardAssignment {
                shard: pu32(field(v, "shard", "shard")?, "shard index")?,
                plan_seed: pu64(field(v, "plan_seed", "shard")?, "shard plan_seed")?,
                grid: field(v, "grid", "shard")?.clone(),
                cells,
            }))
        }
        "cell_result" => {
            let classes_arr = field(v, "classes", "cell_result")?
                .as_arr()
                .ok_or_else(|| "cell_result: classes must be an array".to_string())?;
            let mut classes = Vec::with_capacity(classes_arr.len());
            for (i, c) in classes_arr.iter().enumerate() {
                classes.push(class_from_json(c, &format!("cell_result class {i}"))?);
            }
            Ok(Frame::CellResult(CellResultFrame {
                cell: pu32(field(v, "cell", "cell_result")?, "cell_result cell")?,
                seed: pu64(field(v, "seed", "cell_result")?, "cell_result seed")?,
                label: pstr(field(v, "label", "cell_result")?, "cell_result label")?,
                issued: pu64(field(v, "issued", "cell_result")?, "cell_result issued")?,
                events: pu64(field(v, "events", "cell_result")?, "cell_result events")?,
                dropped: pu64(field(v, "dropped", "cell_result")?, "cell_result dropped")?,
                downtime_s: pf64(field(v, "downtime_s", "cell_result")?, "cell_result downtime_s")?,
                collector: collector_from_json(
                    field(v, "collector", "cell_result")?,
                    "cell_result collector",
                )?,
                classes,
            }))
        }
        "shard_done" => Ok(Frame::ShardDone {
            shard: pu32(field(v, "shard", "shard_done")?, "shard_done shard")?,
            cells: pu32(field(v, "cells", "shard_done")?, "shard_done cells")?,
        }),
        "shard_failed" => Ok(Frame::ShardFailed {
            shard: pu32(field(v, "shard", "shard_failed")?, "shard_failed shard")?,
            completed: pu32(field(v, "completed", "shard_failed")?, "shard_failed completed")?,
            error: pstr(field(v, "error", "shard_failed")?, "shard_failed error")?,
        }),
        "span" => {
            let attrs_arr = field(v, "attrs", "span")?
                .as_arr()
                .ok_or_else(|| "span: attrs must be an array".to_string())?;
            let mut attrs = Vec::with_capacity(attrs_arr.len());
            for (i, pair) in attrs_arr.iter().enumerate() {
                let pair = pair
                    .as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| format!("span attr {i}: expected [key, value]"))?;
                attrs.push((
                    pstr(&pair[0], &format!("span attr {i} key"))?,
                    pstr(&pair[1], &format!("span attr {i} value"))?,
                ));
            }
            Ok(Frame::Span(SpanFrame {
                track: pstr(field(v, "track", "span")?, "span track")?,
                id: pu64(field(v, "id", "span")?, "span id")?,
                parent: field(v, "parent", "span")?
                    .as_i64()
                    .ok_or_else(|| "span parent: expected an integer".to_string())?,
                name: pstr(field(v, "name", "span")?, "span name")?,
                start_s: pf64(field(v, "start_s", "span")?, "span start_s")?,
                end_s: pf64(field(v, "end_s", "span")?, "span end_s")?,
                attrs,
            }))
        }
        other => Err(format!("unknown frame type {other:?}")),
    }
}

// ---------------------------------------------------------------------------
// Binary
// ---------------------------------------------------------------------------

/// First header byte of every binary frame.
const MAGIC: u8 = 0xB5;
/// Header: `[MAGIC][kind][payload len u32 LE]`.
const HDR: usize = 6;
/// Sanity cap on the declared payload length — a corrupt length prefix
/// fails loudly instead of making the reader wait for gigabytes that will
/// never arrive.
const MAX_FRAME: usize = 1 << 30;

const KIND_SHARD: u8 = 1;
const KIND_CELL_RESULT: u8 = 2;
const KIND_SHARD_DONE: u8 = 3;
const KIND_SHARD_FAILED: u8 = 4;
const KIND_SPAN: u8 = 5;

/// Compact length-prefixed binary: little-endian integers, `f64::to_bits`
/// floats (bit-exact by construction, no formatter in the loop),
/// length-prefixed UTF-8 strings, and sparse sketch buckets. The one
/// JSON-shaped field, the shard grid doc, rides as an embedded
/// compact-JSON string: it is cold config sent once per shard, and reusing
/// the job layer's parser beats maintaining a second schema for it.
pub struct BinaryCodec;

impl Codec for BinaryCodec {
    fn name(&self) -> &'static str {
        "binary"
    }

    fn encode(&self, frame: &Frame, out: &mut Vec<u8>) {
        let start = out.len();
        out.extend_from_slice(&[MAGIC, 0, 0, 0, 0, 0]); // kind + len patched below
        match frame {
            Frame::Shard(s) => {
                out[start + 1] = KIND_SHARD;
                put_u32(out, s.shard);
                put_u64(out, s.plan_seed);
                put_str(out, &s.grid.to_string_compact());
                put_u32(out, s.cells.len() as u32);
                for c in &s.cells {
                    put_u32(out, c.index);
                    put_u64(out, c.seed);
                    put_str(out, &c.label);
                }
            }
            Frame::CellResult(r) => {
                out[start + 1] = KIND_CELL_RESULT;
                put_u32(out, r.cell);
                put_u64(out, r.seed);
                put_str(out, &r.label);
                put_u64(out, r.issued);
                put_u64(out, r.events);
                put_u64(out, r.dropped);
                put_f64(out, r.downtime_s);
                put_collector(out, &r.collector);
                put_u32(out, r.classes.len() as u32);
                for cl in &r.classes {
                    out.push(cl.class);
                    put_u64(out, cl.issued);
                    put_collector(out, &cl.collector);
                }
            }
            Frame::ShardDone { shard, cells } => {
                out[start + 1] = KIND_SHARD_DONE;
                put_u32(out, *shard);
                put_u32(out, *cells);
            }
            Frame::ShardFailed { shard, completed, error } => {
                out[start + 1] = KIND_SHARD_FAILED;
                put_u32(out, *shard);
                put_u32(out, *completed);
                put_str(out, error);
            }
            Frame::Span(s) => {
                out[start + 1] = KIND_SPAN;
                put_str(out, &s.track);
                put_u64(out, s.id);
                put_u64(out, s.parent as u64); // two's complement round-trips
                put_str(out, &s.name);
                put_f64(out, s.start_s);
                put_f64(out, s.end_s);
                put_u32(out, s.attrs.len() as u32);
                for (k, v) in &s.attrs {
                    put_str(out, k);
                    put_str(out, v);
                }
            }
        }
        let len = (out.len() - start - HDR) as u32;
        out[start + 2..start + HDR].copy_from_slice(&len.to_le_bytes());
    }

    fn decode(&self, buf: &[u8]) -> Result<Option<(Frame, usize)>, CodecError> {
        if buf.len() < HDR {
            return Ok(None);
        }
        if buf[0] != MAGIC {
            return Err(CodecError {
                offset: 0,
                message: format!("bad magic byte {:#04x} (expected {MAGIC:#04x})", buf[0]),
            });
        }
        let kind = buf[1];
        let len = u32::from_le_bytes([buf[2], buf[3], buf[4], buf[5]]) as usize;
        if len > MAX_FRAME {
            return Err(CodecError {
                offset: 2,
                message: format!("frame length {len} exceeds the {MAX_FRAME}-byte cap"),
            });
        }
        if buf.len() < HDR + len {
            return Ok(None);
        }
        let mut cur = Cur { buf: &buf[HDR..HDR + len], pos: 0, base: HDR };
        let frame = match kind {
            KIND_SHARD => {
                let shard = cur.u32()?;
                let plan_seed = cur.u64()?;
                let grid_at = cur.base + cur.pos + 4; // first byte past the length prefix
                let grid_text = cur.str("grid doc")?;
                let grid = json::parse(&grid_text).map_err(|e| CodecError {
                    offset: grid_at + e.offset,
                    message: format!("embedded grid doc: {}", e.message),
                })?;
                let n = cur.u32()? as usize;
                let mut cells = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    cells.push(CellSpec {
                        index: cur.u32()?,
                        seed: cur.u64()?,
                        label: cur.str("cell label")?,
                    });
                }
                Frame::Shard(ShardAssignment { shard, plan_seed, grid, cells })
            }
            KIND_CELL_RESULT => {
                let cell = cur.u32()?;
                let seed = cur.u64()?;
                let label = cur.str("cell label")?;
                let issued = cur.u64()?;
                let events = cur.u64()?;
                let dropped = cur.u64()?;
                let downtime_s = cur.f64()?;
                let collector = cur.collector()?;
                let n = cur.u32()? as usize;
                let mut classes = Vec::with_capacity(n.min(1 << 10));
                for _ in 0..n {
                    classes.push(ClassSnapshot {
                        class: cur.u8()?,
                        issued: cur.u64()?,
                        collector: cur.collector()?,
                    });
                }
                Frame::CellResult(CellResultFrame {
                    cell,
                    seed,
                    label,
                    issued,
                    events,
                    dropped,
                    downtime_s,
                    collector,
                    classes,
                })
            }
            KIND_SHARD_DONE => Frame::ShardDone { shard: cur.u32()?, cells: cur.u32()? },
            KIND_SHARD_FAILED => Frame::ShardFailed {
                shard: cur.u32()?,
                completed: cur.u32()?,
                error: cur.str("error text")?,
            },
            KIND_SPAN => {
                let track = cur.str("span track")?;
                let id = cur.u64()?;
                let parent = cur.u64()? as i64;
                let name = cur.str("span name")?;
                let start_s = cur.f64()?;
                let end_s = cur.f64()?;
                let n = cur.u32()? as usize;
                let mut attrs = Vec::with_capacity(n.min(1 << 12));
                for _ in 0..n {
                    attrs.push((cur.str("span attr key")?, cur.str("span attr value")?));
                }
                Frame::Span(SpanFrame { track, id, parent, name, start_s, end_s, attrs })
            }
            k => {
                return Err(CodecError {
                    offset: 1,
                    message: format!("unknown binary frame kind {k}"),
                })
            }
        };
        if cur.pos != len {
            return Err(CodecError {
                offset: HDR + cur.pos,
                message: format!("{} trailing bytes in frame payload", len - cur.pos),
            });
        }
        validate_frame(&frame)
            .map_err(|m| CodecError { offset: 0, message: format!("binary frame: {m}") })?;
        Ok(Some((frame, HDR + len)))
    }
}

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, x: f64) {
    out.extend_from_slice(&x.to_bits().to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_summary(out: &mut Vec<u8>, s: &SummarySnapshot) {
    match s {
        SummarySnapshot::Exact { samples } => {
            out.push(0);
            put_u64(out, samples.len() as u64);
            for &x in samples {
                put_f64(out, x);
            }
        }
        SummarySnapshot::Sketch { alpha, buckets, zero_count, count, sum_sq, sum, min, max } => {
            out.push(1);
            put_f64(out, *alpha);
            put_u32(out, buckets.len() as u32);
            for &(k, c) in buckets {
                put_u32(out, k);
                put_u64(out, c);
            }
            put_u64(out, *zero_count);
            put_u64(out, *count);
            put_f64(out, *sum_sq);
            put_f64(out, *sum);
            put_f64(out, *min);
            put_f64(out, *max);
        }
    }
}

fn put_collector(out: &mut Vec<u8>, c: &CollectorSnapshot) {
    put_summary(out, &c.e2e);
    for s in &c.per_stage {
        put_summary(out, s);
    }
    out.push(c.bounded as u8);
    put_u64(out, c.completed);
    put_u64(out, c.dropped);
    for &d in &c.dropped_by_reason {
        put_u64(out, d);
    }
    put_f64(out, c.first_arrival_s);
    put_f64(out, c.last_completion_s);
}

/// Payload cursor: bounds-checked reads with absolute-offset errors. The
/// payload length is already known from the header, so running out of
/// bytes mid-field is corruption ("truncated field"), not "read more".
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
    base: usize,
}

impl<'a> Cur<'a> {
    fn err(&self, msg: String) -> CodecError {
        CodecError { offset: self.base + self.pos, message: msg }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.buf.len() - self.pos < n {
            return Err(self.err(format!(
                "truncated field: needed {n} bytes, {} left in payload",
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self, what: &str) -> Result<String, CodecError> {
        let at = self.base + self.pos;
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| CodecError {
            offset: at,
            message: format!("{what}: invalid utf-8"),
        })
    }

    fn summary(&mut self) -> Result<SummarySnapshot, CodecError> {
        let at = self.base + self.pos;
        match self.u8()? {
            0 => {
                let n = self.u64()? as usize;
                let mut samples = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    samples.push(self.f64()?);
                }
                Ok(SummarySnapshot::Exact { samples })
            }
            1 => {
                let alpha = self.f64()?;
                let n = self.u32()? as usize;
                let mut buckets = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    buckets.push((self.u32()?, self.u64()?));
                }
                Ok(SummarySnapshot::Sketch {
                    alpha,
                    buckets,
                    zero_count: self.u64()?,
                    count: self.u64()?,
                    sum_sq: self.f64()?,
                    sum: self.f64()?,
                    min: self.f64()?,
                    max: self.f64()?,
                })
            }
            t => Err(CodecError {
                offset: at,
                message: format!("unknown summary tag {t} (expected 0=exact, 1=sketch)"),
            }),
        }
    }

    fn collector(&mut self) -> Result<CollectorSnapshot, CodecError> {
        let e2e = self.summary()?;
        let mut per_stage: [SummarySnapshot; 5] =
            std::array::from_fn(|_| SummarySnapshot::Exact { samples: Vec::new() });
        for s in per_stage.iter_mut() {
            *s = self.summary()?;
        }
        let at = self.base + self.pos;
        let bounded = match self.u8()? {
            0 => false,
            1 => true,
            b => {
                return Err(CodecError {
                    offset: at,
                    message: format!("bounded flag must be 0 or 1, got {b}"),
                })
            }
        };
        let completed = self.u64()?;
        let dropped = self.u64()?;
        let mut dropped_by_reason = [0u64; DROP_REASONS.len()];
        for d in dropped_by_reason.iter_mut() {
            *d = self.u64()?;
        }
        Ok(CollectorSnapshot {
            e2e,
            per_stage,
            bounded,
            completed,
            dropped,
            dropped_by_reason,
            first_arrival_s: self.f64()?,
            last_completion_s: self.f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{Collector, DropReason, MetricsMode, RequestTrace, Stage};

    fn collector_snapshot(mode: MetricsMode, seed: u64) -> CollectorSnapshot {
        let mut c = Collector::with_mode(mode);
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        for i in 0..200u64 {
            let mut t = RequestTrace::new(i, i as f64 * 0.05);
            if i % 9 == 0 {
                t.dropped = true;
                t.drop_reason =
                    if i % 2 == 0 { DropReason::QueueFull } else { DropReason::Shed };
            } else {
                t.record_stage(Stage::Batching, rng.lognormal(-6.0, 0.4));
                t.record_stage(Stage::Inference, rng.lognormal(-4.0, 0.9));
            }
            c.ingest(&t);
        }
        c.snapshot()
    }

    fn cell_result(mode: MetricsMode, with_classes: bool) -> Frame {
        let collector = collector_snapshot(mode, 7);
        let mut classes = Vec::new();
        if with_classes {
            for class in 0..3u8 {
                let inner = collector_snapshot(mode, 20 + class as u64);
                classes.push(ClassSnapshot {
                    class,
                    issued: inner.completed + inner.dropped,
                    collector: inner,
                });
            }
        }
        Frame::CellResult(CellResultFrame {
            cell: 11,
            seed: u64::MAX - 3, // exercises the beyond-i64 string path in JSON
            label: "4xleast-outstanding@5.0ms".into(),
            issued: collector.completed + collector.dropped,
            events: 123_456,
            dropped: collector.dropped,
            downtime_s: 1.25,
            collector,
            classes,
        })
    }

    fn grid_doc() -> Json {
        let mut g = Json::obj();
        g.set("model", Json::Str("resnet50".into()));
        g.set("replicas", Json::Arr(vec![Json::Int(1), Json::Int(2)]));
        g.set("rate", Json::Num(120.5));
        g
    }

    fn shard_frame() -> Frame {
        let plan_seed = 4242;
        let cells = [0u32, 3, 5]
            .iter()
            .map(|&i| CellSpec {
                index: i,
                seed: crate::sweep::cell_seed(plan_seed, i as u64),
                label: format!("cell-{i}"),
            })
            .collect();
        Frame::Shard(ShardAssignment { shard: 1, plan_seed, grid: grid_doc(), cells })
    }

    fn span_frame() -> Frame {
        Frame::Span(SpanFrame {
            track: "shard-1".into(),
            id: 5,
            parent: -1,
            name: "1xround-robin@2.0ms".into(),
            start_s: 0.125,
            end_s: 4.75,
            attrs: vec![
                ("seed".into(), "18446744073709551598".into()),
                ("issued".into(), "240".into()),
            ],
        })
    }

    fn all_frames() -> Vec<Frame> {
        vec![
            shard_frame(),
            cell_result(MetricsMode::Exact, false),
            cell_result(MetricsMode::Exact, true),
            cell_result(MetricsMode::Sketch { alpha: 0.01 }, true),
            Frame::ShardDone { shard: 2, cells: 9 },
            Frame::ShardFailed { shard: 0, completed: 4, error: "worker panic: \"boom\"".into() },
            span_frame(),
        ]
    }

    #[test]
    fn both_codecs_round_trip_every_frame_type() {
        for kind in [CodecKind::JsonLines, CodecKind::Binary] {
            let codec = kind.codec();
            for frame in all_frames() {
                let mut bytes = Vec::new();
                codec.encode(&frame, &mut bytes);
                let (decoded, consumed) =
                    codec.decode(&bytes).unwrap().unwrap_or_else(|| {
                        panic!("{}: complete {} frame must decode", codec.name(), frame.kind())
                    });
                assert_eq!(consumed, bytes.len(), "{}", codec.name());
                assert_eq!(decoded, frame, "{} {}", codec.name(), frame.kind());
            }
        }
    }

    #[test]
    fn binary_re_encode_is_byte_exact() {
        // encode -> decode -> encode reproduces the original bytes exactly,
        // for both codecs (byte-determinism is part of the contract).
        for kind in [CodecKind::JsonLines, CodecKind::Binary] {
            let codec = kind.codec();
            for frame in all_frames() {
                let mut first = Vec::new();
                codec.encode(&frame, &mut first);
                let (decoded, _) = codec.decode(&first).unwrap().unwrap();
                let mut second = Vec::new();
                codec.encode(&decoded, &mut second);
                assert_eq!(first, second, "{} {}", codec.name(), frame.kind());
            }
        }
    }

    #[test]
    fn json_and_binary_agree_on_every_frame() {
        // JSON ≡ binary: decoding each codec's bytes yields the same Frame
        // value, so the two wire formats are views of one vocabulary.
        for frame in all_frames() {
            let mut jb = Vec::new();
            JsonLinesCodec.encode(&frame, &mut jb);
            let (from_json, _) = JsonLinesCodec.decode(&jb).unwrap().unwrap();
            let mut bb = Vec::new();
            BinaryCodec.encode(&frame, &mut bb);
            let (from_bin, _) = BinaryCodec.decode(&bb).unwrap().unwrap();
            assert_eq!(from_json, from_bin, "{}", frame.kind());
        }
    }

    #[test]
    fn binary_is_much_smaller_for_exact_cells() {
        let frame = cell_result(MetricsMode::Exact, true);
        let (mut jb, mut bb) = (Vec::new(), Vec::new());
        JsonLinesCodec.encode(&frame, &mut jb);
        BinaryCodec.encode(&frame, &mut bb);
        assert!(
            bb.len() * 2 < jb.len(),
            "binary {}B should be well under half of JSON {}B",
            bb.len(),
            jb.len()
        );
    }

    #[test]
    fn every_strict_prefix_is_incomplete_not_an_error() {
        // Truncation is a transport condition, not corruption: any strict
        // prefix of a valid frame must yield Ok(None) (JSON: no newline
        // yet; binary: header or payload still short).
        for kind in [CodecKind::JsonLines, CodecKind::Binary] {
            let codec = kind.codec();
            let mut bytes = Vec::new();
            codec.encode(&Frame::ShardDone { shard: 3, cells: 17 }, &mut bytes);
            for cut in 0..bytes.len() {
                assert_eq!(
                    codec.decode(&bytes[..cut]).unwrap(),
                    None,
                    "{} prefix of {cut}/{} bytes",
                    codec.name(),
                    bytes.len()
                );
            }
        }
    }

    #[test]
    fn frame_reader_reassembles_single_byte_chunks() {
        for kind in [CodecKind::JsonLines, CodecKind::Binary] {
            let codec = kind.codec();
            let frames = all_frames();
            let mut stream = Vec::new();
            for f in &frames {
                codec.encode(f, &mut stream);
            }
            let mut reader = FrameReader::new(kind);
            let mut got = Vec::new();
            for &b in &stream {
                reader.push(&[b]);
                while let Some(f) = reader.next_frame().unwrap() {
                    got.push(f);
                }
            }
            assert_eq!(got, frames, "{}", codec.name());
            assert_eq!(reader.buffered(), 0);
        }
    }

    #[test]
    fn bad_magic_fails_at_offset_zero() {
        let err = BinaryCodec.decode(b"XXXXXX").unwrap_err();
        assert_eq!(err.offset, 0);
        assert!(err.message.contains("magic"), "{err}");
    }

    #[test]
    fn unknown_binary_kind_fails_at_offset_one() {
        let mut buf = vec![MAGIC, 99];
        buf.extend_from_slice(&0u32.to_le_bytes());
        let err = BinaryCodec.decode(&buf).unwrap_err();
        assert_eq!(err.offset, 1);
        assert!(err.message.contains("unknown binary frame kind 99"), "{err}");
    }

    #[test]
    fn absurd_length_prefix_fails_at_the_length_bytes() {
        let mut buf = vec![MAGIC, KIND_SHARD_DONE];
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = BinaryCodec.decode(&buf).unwrap_err();
        assert_eq!(err.offset, 2);
        assert!(err.message.contains("exceeds"), "{err}");
    }

    #[test]
    fn corrupt_summary_tag_reports_payload_offset() {
        let mut bytes = Vec::new();
        BinaryCodec.encode(&cell_result(MetricsMode::Exact, false), &mut bytes);
        // The e2e summary tag sits right after cell(4) seed(8) label(4+len)
        // issued(8) events(8) dropped(8) downtime(8) in the payload.
        let label_len = "4xleast-outstanding@5.0ms".len();
        let tag_at = HDR + 4 + 8 + 4 + label_len + 8 + 8 + 8 + 8;
        assert!(bytes[tag_at] == 0, "expected the exact-summary tag here");
        bytes[tag_at] = 7;
        let err = BinaryCodec.decode(&bytes).unwrap_err();
        assert_eq!(err.offset, tag_at);
        assert!(err.message.contains("unknown summary tag 7"), "{err}");
    }

    #[test]
    fn trailing_payload_bytes_are_rejected() {
        let mut bytes = Vec::new();
        BinaryCodec.encode(&Frame::ShardDone { shard: 1, cells: 2 }, &mut bytes);
        bytes.push(0xEE); // extra payload byte the fields do not account for
        let len = (bytes.len() - HDR) as u32;
        bytes[2..HDR].copy_from_slice(&len.to_le_bytes());
        let err = BinaryCodec.decode(&bytes).unwrap_err();
        assert!(err.message.contains("trailing"), "{err}");
        assert_eq!(err.offset, bytes.len() - 1);
    }

    #[test]
    fn malformed_json_line_reports_parse_offset() {
        let err = JsonLinesCodec.decode(b"{\"frame\": nope}\n").unwrap_err();
        assert!(err.offset >= 10, "offset {} should point at the bad token", err.offset);
        let rendered = err.to_string();
        assert!(rendered.contains("at byte"), "{rendered}");
    }

    #[test]
    fn json_without_newline_is_incomplete() {
        assert_eq!(JsonLinesCodec.decode(b"{\"frame\":\"shard_done\"").unwrap(), None);
    }

    #[test]
    fn unknown_json_frame_type_is_rejected() {
        let err = JsonLinesCodec.decode(b"{\"frame\":\"mystery\"}\n").unwrap_err();
        assert!(err.message.contains("unknown frame type \"mystery\""), "{err}");
    }

    #[test]
    fn unreconciled_drop_counters_are_rejected_by_both_codecs() {
        let Frame::CellResult(mut r) = cell_result(MetricsMode::Exact, false) else {
            unreachable!()
        };
        r.collector.dropped_by_reason[0] += 1; // no longer sums to dropped
        let bad = Frame::CellResult(r);
        for kind in [CodecKind::JsonLines, CodecKind::Binary] {
            let codec = kind.codec();
            let mut bytes = Vec::new();
            codec.encode(&bad, &mut bytes);
            let err = codec.decode(&bytes).unwrap_err();
            assert!(err.message.contains("reconcile"), "{}: {err}", codec.name());
        }
    }

    #[test]
    fn shard_frames_with_seed_drift_are_rejected() {
        let Frame::Shard(mut s) = shard_frame() else { unreachable!() };
        s.cells[1].seed ^= 1;
        let bad = Frame::Shard(s);
        for kind in [CodecKind::JsonLines, CodecKind::Binary] {
            let codec = kind.codec();
            let mut bytes = Vec::new();
            codec.encode(&bad, &mut bytes);
            let err = codec.decode(&bytes).unwrap_err();
            assert!(err.message.contains("seed"), "{}: {err}", codec.name());
        }
    }

    #[test]
    fn sketch_bucket_out_of_range_is_a_decode_error_not_a_panic() {
        let frame = cell_result(MetricsMode::Sketch { alpha: 0.01 }, false);
        let Frame::CellResult(mut r) = frame else { unreachable!() };
        if let SummarySnapshot::Sketch { buckets, .. } = &mut r.collector.e2e {
            buckets.push((u32::MAX, 1));
        } else {
            panic!("sketch mode expected");
        }
        if let SummarySnapshot::Sketch { count, .. } = &mut r.collector.e2e {
            *count += 1; // keep totals reconciled so only the range check fires
        }
        let bad = Frame::CellResult(r);
        for kind in [CodecKind::JsonLines, CodecKind::Binary] {
            let codec = kind.codec();
            let mut bytes = Vec::new();
            codec.encode(&bad, &mut bytes);
            let err = codec.decode(&bytes).unwrap_err();
            assert!(err.message.contains("outside space"), "{}: {err}", codec.name());
        }
    }

    #[test]
    fn inverted_or_nonfinite_span_extents_are_rejected() {
        let Frame::Span(base) = span_frame() else { unreachable!() };
        let mut inverted = base.clone();
        inverted.end_s = inverted.start_s - 1.0;
        let mut nan = base.clone();
        nan.start_s = f64::NAN;
        for bad in [Frame::Span(inverted), Frame::Span(nan)] {
            for kind in [CodecKind::JsonLines, CodecKind::Binary] {
                let codec = kind.codec();
                let mut bytes = Vec::new();
                codec.encode(&bad, &mut bytes);
                let err = codec.decode(&bytes).unwrap_err();
                assert!(
                    err.message.contains("span"),
                    "{}: {err}",
                    codec.name()
                );
            }
        }
    }

    #[test]
    fn frame_reader_reports_absolute_stream_offsets() {
        let mut stream = Vec::new();
        BinaryCodec.encode(&Frame::ShardDone { shard: 0, cells: 1 }, &mut stream);
        let good_len = stream.len();
        stream.push(0x00); // not MAGIC: corruption after one good frame
        let mut reader = FrameReader::new(CodecKind::Binary);
        reader.push(&stream);
        assert!(reader.next_frame().unwrap().is_some());
        let err = reader.next_frame().unwrap_err();
        assert_eq!(err.offset, good_len, "offset must be absolute, past the drained frame");
    }

    #[test]
    fn restored_wire_collector_fingerprints_match() {
        // End-to-end through the codec: snapshot -> encode -> decode ->
        // restore preserves the collector fingerprint in both modes and
        // both formats.
        for mode in [MetricsMode::Exact, MetricsMode::Sketch { alpha: 0.01 }] {
            let frame = cell_result(mode, true);
            let Frame::CellResult(orig) = &frame else { unreachable!() };
            for kind in [CodecKind::JsonLines, CodecKind::Binary] {
                let codec = kind.codec();
                let mut bytes = Vec::new();
                codec.encode(&frame, &mut bytes);
                let (Frame::CellResult(back), _) = codec.decode(&bytes).unwrap().unwrap() else {
                    panic!("cell_result expected");
                };
                assert_eq!(
                    back.collector.restore().fingerprint(),
                    orig.collector.restore().fingerprint(),
                    "{} {mode:?}",
                    codec.name()
                );
                for (a, b) in back.classes.iter().zip(&orig.classes) {
                    assert_eq!(
                        a.collector.restore().fingerprint(),
                        b.collector.restore().fingerprint()
                    );
                }
            }
        }
    }
}
