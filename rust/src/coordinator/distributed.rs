//! Distributed sweep engine: shard one `SweepPlan` across followers over
//! the wire codec, absorb results as they stream back, re-queue the cells
//! of crashed or straggling shards (PERF.md §Distributed sweeps).
//!
//! PR 4's sweep engine stops at one machine's cores: a `task: sweep` job
//! saturates a single follower's `threads_per_worker` budget while the
//! rest of the fleet idles. This module is the next multiplicative lever —
//! cells/sec scales with fleet size — without giving up the determinism
//! contract, which per-cell seeding already guarantees: cell `i` computes
//! from `cell_seed(plan_seed, i)` no matter which follower runs it, or
//! how many times.
//!
//! ## Protocol
//!
//! Everything crosses the leader/follower boundary as [`Frame`]s through
//! a [`CodecKind`] codec (`crate::codec`), exactly as it would over a
//! socket — followers see only bytes, never leader memory:
//!
//! 1. The leader builds the plan, splits the outstanding cells into
//!    contiguous shards sized by each follower's thread budget
//!    (`scheduler::shard_sizes`), and sends each follower one
//!    `Shard` frame: the self-contained grid doc
//!    ([`job::sweep_grid_doc`]) plus its assigned `CellSpec`s.
//! 2. A follower rebuilds the *full* plan from the grid doc
//!    ([`job::sweep_kind_from_grid_doc`] → [`job::build_sweep_plan`]),
//!    cross-checks the assignment's seeds and labels against its own
//!    derivation (drift fails loudly), runs only its indices on its
//!    thread budget (`SweepPlan::run_indices`, the same `map_indexed`
//!    pool as a local run), and streams one `CellResult` frame back **as
//!    each cell finishes** — not one blob at shard end — closing with
//!    `ShardDone` or `ShardFailed`.
//! 3. The leader absorbs frames incrementally: each fresh cell fills its
//!    slot in the outstanding-cells ledger and fires the streaming hook
//!    (partial grids are usable — e.g. inserted into a PerfDB — before
//!    the sweep completes). Duplicate frames for an already-filled cell
//!    index are counted and dropped (first frame wins): re-queued cells
//!    are bit-identical re-runs, so which copy lands first cannot matter.
//! 4. If a shard dies (`ShardFailed`, or decode poison on its stream),
//!    its unfinished cells are re-queued onto the healthy followers in
//!    the next round — the shard-level analogue of PR 8's in-place cell
//!    retry, and the same argument applies: a re-run from the per-cell
//!    seed is bit-identical, so failure handling is invisible in the
//!    output.
//!
//! The final [`SweepOutcome`] is assembled **in plan order** from the
//! ledger, so aggregation (`SweepOutcome::aggregate_classes`, via
//! `Collector::absorb`) and the per-cell PerfDB records are bit-for-bit
//! what `SweepPlan::run` produces serially — at any follower count, any
//! thread budget, any crash schedule that leaves at least one follower
//! alive. `tests/distributed_sweep.rs` asserts this end-to-end.
//!
//! Followers here are scoped threads speaking the full wire protocol
//! in-process. The transport is the only stub: swapping the `mpsc`
//! channels for sockets changes no frame, no codec byte, and no
//! determinism argument.

use crate::codec::{
    CellResultFrame, CellSpec, CodecKind, Frame, FrameReader, ShardAssignment, SpanFrame,
};
use crate::coordinator::job::{self, JobKind};
use crate::coordinator::scheduler::shard_sizes;
use crate::metrics::ScaleTimeline;
use crate::serving::cluster::ClusterResult;
use crate::sweep::{CellOutcome, SweepOutcome};
use anyhow::{anyhow, bail, Result};
use std::sync::mpsc;

/// One follower of the distributed engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FollowerSpec {
    /// Sweep-cell thread budget (the follower's `threads_per_worker`);
    /// also its weight in shard sizing.
    pub threads: usize,
    /// Fault-injection knob: complete only this many assigned cells, then
    /// report `ShardFailed` and stay dead for later rounds. Deterministic
    /// by construction — the crash point is a cell count, not a timer.
    pub crash_after: Option<usize>,
}

impl FollowerSpec {
    pub fn healthy(threads: usize) -> FollowerSpec {
        FollowerSpec { threads, crash_after: None }
    }
}

/// Distributed-run configuration.
#[derive(Debug, Clone)]
pub struct DistConfig {
    pub followers: Vec<FollowerSpec>,
    /// Wire codec for every frame in both directions.
    pub codec: CodecKind,
    /// Transport chunk size for follower→leader streams, bytes. Frames
    /// are deliberately split across chunks so the leader's
    /// [`FrameReader`] reassembly path is always exercised.
    pub chunk_bytes: usize,
    /// Duplicate-injection knob: each surviving follower re-sends its
    /// first N cell frames after finishing (late duplicates), exercising
    /// the leader's by-cell-index reconciliation.
    pub duplicate_first: usize,
    /// Trace the sweep (obs): followers stream one [`Frame::Span`] per
    /// completed cell (sim-time extents, so the spans are as
    /// deterministic as the cell results), and the leader closes the set
    /// with a root `sweep` span carrying the [`DistStats`] as
    /// attributes. Off by default; the result cells are bit-identical
    /// either way.
    pub trace: bool,
}

impl DistConfig {
    /// `followers` equal followers splitting `total_threads` between them
    /// (each at least 1), no fault injection — what a `task: sweep` job
    /// with a `followers:` knob runs under.
    pub fn uniform(followers: usize, total_threads: usize, codec: CodecKind) -> DistConfig {
        let n = followers.max(1);
        let per = shard_sizes(total_threads.max(n), &vec![1; n]);
        DistConfig {
            followers: per.into_iter().map(|t| FollowerSpec::healthy(t.max(1))).collect(),
            codec,
            chunk_bytes: 4096,
            duplicate_first: 0,
            trace: false,
        }
    }
}

/// Wire and re-queue accounting for one distributed run. Deterministic:
/// both codecs are byte-deterministic and the crash/duplicate knobs are
/// cell counts, so the same config reproduces the same stats.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DistStats {
    /// Re-queue rounds executed (1 = no failures).
    pub rounds: usize,
    /// Shard assignment bytes, leader → followers (all rounds).
    pub bytes_to_followers: u64,
    /// Result stream bytes, followers → leader (all rounds).
    pub bytes_to_leader: u64,
    /// Cell-result frames received, duplicates included.
    pub frames_to_leader: u64,
    /// Late duplicate frames dropped by the cell-index reconciliation.
    pub duplicate_frames: u64,
    /// Cells re-queued onto healthy followers after a shard failure.
    pub cells_rerun: u64,
    /// First-round shard sizes by follower — the balance view.
    pub shard_cells: Vec<usize>,
}

/// A distributed run's outcome: plan-order cell results (bit-identical to
/// `SweepPlan::run`) plus the wire accounting.
pub struct DistOutcome {
    pub outcome: SweepOutcome,
    pub stats: DistStats,
    /// Shard→cell spans plus the root `sweep` span when
    /// [`DistConfig::trace`] is on, sorted by `(track, id)` so the set
    /// is byte-stable regardless of frame arrival order. Empty
    /// otherwise.
    pub spans: Vec<SpanFrame>,
}

/// Run a `JobKind::Sweep` grid sharded across `cfg.followers`, absorbing
/// streamed results into the outstanding-cells ledger and re-queuing the
/// cells of failed shards. See the module doc for the protocol and the
/// determinism argument.
pub fn run_sharded(kind: &JobKind, seed: u64, cfg: &DistConfig) -> Result<DistOutcome> {
    run_sharded_with(kind, seed, cfg, &mut |_| {})
}

/// [`run_sharded`] with a streaming hook: `on_cell` fires once per fresh
/// (non-duplicate) cell result, in **arrival order** — which follower
/// finishes first is scheduling-dependent, so a caller wanting
/// deterministic output must key by `frame.cell` (a PerfDB record per
/// cell does exactly that; `benches/l4_des_throughput.rs` streams records
/// this way). The returned outcome is plan-ordered and deterministic
/// regardless of the hook.
pub fn run_sharded_with(
    kind: &JobKind,
    seed: u64,
    cfg: &DistConfig,
    on_cell: &mut dyn FnMut(&CellResultFrame),
) -> Result<DistOutcome> {
    if cfg.followers.is_empty() {
        bail!("distributed sweep needs at least one follower");
    }
    let (plan, _axes) = job::build_sweep_plan(kind, seed)?;
    let total = plan.len();
    let grid = job::sweep_grid_doc(kind);
    let nf = cfg.followers.len();
    let chunk = cfg.chunk_bytes.max(1);

    let mut slots: Vec<Option<CellResultFrame>> = Vec::with_capacity(total);
    slots.resize_with(total, || None);
    let mut alive = vec![true; nf];
    let mut outstanding: Vec<usize> = (0..total).collect();
    let mut stats = DistStats::default();
    let mut spans: Vec<SpanFrame> = Vec::new();

    while !outstanding.is_empty() {
        let healthy: Vec<usize> = (0..nf).filter(|&f| alive[f]).collect();
        if healthy.is_empty() {
            bail!(
                "distributed sweep: every follower failed with {} of {total} cells unfinished",
                outstanding.len()
            );
        }
        stats.rounds += 1;

        // Contiguous budget-proportional shards over the outstanding cells.
        let budgets: Vec<usize> = healthy.iter().map(|&f| cfg.followers[f].threads).collect();
        let sizes = shard_sizes(outstanding.len(), &budgets);
        let mut shards: Vec<(usize, Vec<usize>)> = Vec::new();
        let mut cursor = 0;
        for (&f, &size) in healthy.iter().zip(&sizes) {
            if size > 0 {
                shards.push((f, outstanding[cursor..cursor + size].to_vec()));
                cursor += size;
            }
        }
        if stats.rounds == 1 {
            stats.shard_cells = vec![0; nf];
            for (f, cells) in &shards {
                stats.shard_cells[*f] = cells.len();
            }
        }

        // Serialize one shard assignment per participating follower.
        let codec = cfg.codec.codec();
        let mut wires: Vec<(usize, Vec<u8>)> = Vec::with_capacity(shards.len());
        for (f, cells) in &shards {
            let assignment = ShardAssignment {
                shard: *f as u32,
                plan_seed: seed,
                grid: grid.clone(),
                cells: cells
                    .iter()
                    .map(|&i| CellSpec {
                        index: i as u32,
                        seed: plan.cell_seed(i),
                        label: plan.cells()[i].label().to_string(),
                    })
                    .collect(),
            };
            let mut bytes = Vec::new();
            codec.encode(&Frame::Shard(assignment), &mut bytes);
            stats.bytes_to_followers += bytes.len() as u64;
            wires.push((*f, bytes));
        }

        // One round: spawn the participating followers, drain their
        // streams until every sender hangs up, then reconcile.
        let (tx, rx) = mpsc::channel::<(usize, Vec<u8>)>();
        let mut deaths = 0usize;
        let absorbed_before = slots.iter().filter(|s| s.is_some()).count();
        std::thread::scope(|scope| -> Result<()> {
            for (f, shard_bytes) in wires {
                let tx = tx.clone();
                let spec = cfg.followers[f];
                scope.spawn(move || follower_round(f, spec, cfg, shard_bytes, tx));
            }
            drop(tx);

            let mut readers: Vec<Option<FrameReader>> = (0..nf).map(|_| None).collect();
            for (f, chunk_bytes) in rx {
                if !alive[f] {
                    // Late chunks from a follower already marked dead
                    // (failed shard or poisoned stream) carry nothing the
                    // re-queue rounds won't recompute.
                    continue;
                }
                stats.bytes_to_leader += chunk_bytes.len() as u64;
                let reader = readers[f].get_or_insert_with(|| FrameReader::new(cfg.codec));
                reader.push(&chunk_bytes);
                loop {
                    let frame = match reader.next_frame() {
                        Ok(Some(frame)) => frame,
                        Ok(None) => break,
                        // A poisoned stream is a failed peer: drop the
                        // follower, keep its already-absorbed cells, and
                        // let the re-queue round cover the rest.
                        Err(e) => {
                            eprintln!("distributed sweep: follower {f} stream corrupt: {e}");
                            alive[f] = false;
                            deaths += 1;
                            break;
                        }
                    };
                    match frame {
                        Frame::CellResult(r) => {
                            stats.frames_to_leader += 1;
                            let i = r.cell as usize;
                            if i >= total {
                                bail!("follower {f} reported unknown cell {i} (grid has {total})");
                            }
                            if slots[i].is_some() {
                                // Late duplicate (a re-queued cell's first
                                // copy, or an injected re-send): identical
                                // bits by the seeding argument, so first
                                // frame wins and the copy is dropped.
                                stats.duplicate_frames += 1;
                                continue;
                            }
                            if r.seed != plan.cell_seed(i) || r.label != plan.cells()[i].label() {
                                bail!(
                                    "follower {f} cell {i} drifted: seed/label disagree with the plan"
                                );
                            }
                            on_cell(&r);
                            slots[i] = Some(r);
                        }
                        Frame::Span(s) => {
                            // A re-queued cell re-runs on a different
                            // follower (dead ones stay dead), so a
                            // duplicate (track, id) only means a re-sent
                            // frame: first copy wins.
                            if !spans.iter().any(|p| p.track == s.track && p.id == s.id) {
                                spans.push(s);
                            }
                        }
                        Frame::ShardDone { .. } => {}
                        Frame::ShardFailed { shard, completed, error } => {
                            eprintln!(
                                "distributed sweep: shard {shard} failed after {completed} cells: {error}"
                            );
                            alive[shard as usize] = false;
                            deaths += 1;
                        }
                        Frame::Shard(_) => {
                            bail!("follower {f} sent a shard assignment to the leader")
                        }
                    }
                }
            }
            Ok(())
        })?;

        let absorbed_after = slots.iter().filter(|s| s.is_some()).count();
        outstanding.retain(|&i| slots[i].is_none());
        if !outstanding.is_empty() {
            if absorbed_after == absorbed_before && deaths == 0 {
                bail!(
                    "distributed sweep stalled in round {}: {} cells outstanding, no progress, no failures",
                    stats.rounds,
                    outstanding.len()
                );
            }
            stats.cells_rerun += outstanding.len() as u64;
        }
    }

    // Assemble in plan order: this—not arrival order—is what makes the
    // sharded outcome byte-for-byte the serial one.
    let mut cells = Vec::with_capacity(total);
    for (i, slot) in slots.into_iter().enumerate() {
        let r = slot.ok_or_else(|| anyhow!("cell {i} never absorbed despite drained ledger"))?;
        cells.push(CellOutcome {
            label: r.label,
            seed: r.seed,
            result: ClusterResult {
                collector: r.collector.restore(),
                // Per-replica views and the scale timeline stay on the
                // follower: sweep records never read them, and shipping
                // them would dominate the wire for nothing.
                replicas: Vec::new(),
                scale: ScaleTimeline::new(0),
                dropped: r.dropped,
                classes: r.classes.iter().map(|c| c.restore()).collect(),
                issued: r.issued,
                downtime_s: r.downtime_s,
                events: r.events,
                trace: None,
            },
        });
    }
    // Close the traced set: sort for arrival-order independence, then a
    // root `sweep` span carrying the wire accounting as attributes.
    if cfg.trace {
        spans.sort_by(|a, b| a.track.cmp(&b.track).then(a.id.cmp(&b.id)));
        let end_s = spans.iter().fold(0.0f64, |m, s| m.max(s.end_s));
        spans.push(SpanFrame {
            track: "sweep".to_string(),
            id: 0,
            parent: -1,
            name: "sweep".to_string(),
            start_s: 0.0,
            end_s,
            attrs: vec![
                ("rounds".to_string(), stats.rounds.to_string()),
                ("bytes_sent".to_string(), stats.bytes_to_followers.to_string()),
                ("bytes_received".to_string(), stats.bytes_to_leader.to_string()),
                ("frames".to_string(), stats.frames_to_leader.to_string()),
                ("duplicates".to_string(), stats.duplicate_frames.to_string()),
                ("cells_rerun".to_string(), stats.cells_rerun.to_string()),
            ],
        });
    }
    Ok(DistOutcome { outcome: SweepOutcome { cells }, stats, spans })
}

/// One follower's round: decode the shard from bytes, rebuild the plan
/// from the grid doc, run the assigned cells on the local thread budget,
/// stream each result back as it completes. Every failure mode —
/// malformed shard, grid drift, injected crash — reports `ShardFailed`
/// rather than leaving the leader hanging.
fn follower_round(
    f: usize,
    spec: FollowerSpec,
    cfg: &DistConfig,
    shard_bytes: Vec<u8>,
    tx: mpsc::Sender<(usize, Vec<u8>)>,
) {
    let codec = cfg.codec.codec();
    let send = |bytes: Vec<u8>| {
        // Deliberately chunked so the leader's reassembly path always
        // runs; a dropped receiver means the leader already bailed.
        for piece in bytes.chunks(cfg.chunk_bytes.max(1)) {
            if tx.send((f, piece.to_vec())).is_err() {
                return;
            }
        }
    };
    let fail = |completed: u32, error: String| {
        let mut bytes = Vec::new();
        codec.encode(&Frame::ShardFailed { shard: f as u32, completed, error }, &mut bytes);
        send(bytes);
    };

    // Decode the assignment (the codec validates seeds against the plan
    // seed in-band).
    let mut reader = FrameReader::new(cfg.codec);
    reader.push(&shard_bytes);
    let assignment = match reader.next_frame() {
        Ok(Some(Frame::Shard(a))) => a,
        Ok(_) => return fail(0, "expected a shard frame".into()),
        Err(e) => return fail(0, format!("shard decode: {e}")),
    };

    // Rebuild the full plan from the wire-carried grid doc — the follower
    // shares no memory with the leader's plan.
    let plan = match job::sweep_kind_from_grid_doc(&assignment.grid)
        .and_then(|kind| job::build_sweep_plan(&kind, assignment.plan_seed))
    {
        Ok((plan, _axes)) => plan,
        Err(e) => return fail(0, format!("grid doc: {e}")),
    };
    // Drift check: the rebuilt plan must derive the exact seeds and labels
    // the leader assigned, or the "sharding is invisible" contract is
    // already broken — fail the shard loudly instead of computing wrong
    // cells.
    for c in &assignment.cells {
        let i = c.index as usize;
        if i >= plan.len()
            || plan.cell_seed(i) != c.seed
            || plan.cells()[i].label() != c.label
        {
            return fail(0, format!("assignment cell {i} disagrees with the rebuilt plan"));
        }
    }

    let assigned: Vec<usize> = assignment.cells.iter().map(|c| c.index as usize).collect();
    let run_count = spec.crash_after.map_or(assigned.len(), |k| k.min(assigned.len()));
    let crashed = run_count < assigned.len();

    // Stream each finished cell immediately. `run_indices` computes cells
    // through the same pool and seed derivation as a local run, so what
    // goes on the wire is bit-identical to serial by construction.
    let mut first_frames: Vec<Vec<u8>> = Vec::new();
    for (i, outcome) in plan.run_indices(&assigned[..run_count], spec.threads.max(1)) {
        let r = &outcome.result;
        let frame = Frame::CellResult(CellResultFrame {
            cell: i as u32,
            seed: outcome.seed,
            label: outcome.label.clone(),
            issued: r.issued,
            events: r.events,
            dropped: r.dropped,
            downtime_s: r.downtime_s,
            collector: r.collector.snapshot(),
            classes: r.classes.iter().map(|c| c.snapshot()).collect(),
        });
        let mut bytes = Vec::new();
        codec.encode(&frame, &mut bytes);
        if first_frames.len() < cfg.duplicate_first {
            first_frames.push(bytes.clone());
        }
        send(bytes);
        if cfg.trace {
            // One span per finished cell: the cell's simulated horizon on
            // this shard's track, with the conservation counters as
            // attributes. Sim-time extents — no wall clock — so the
            // traced wire stream is as deterministic as the results.
            let span = Frame::Span(SpanFrame {
                track: format!("shard-{f}"),
                id: i as u64,
                parent: -1,
                name: outcome.label.clone(),
                start_s: 0.0,
                end_s: plan.cells()[i].config_for(outcome.seed).duration_s,
                attrs: vec![
                    ("issued".to_string(), r.issued.to_string()),
                    ("events".to_string(), r.events.to_string()),
                    ("dropped".to_string(), r.dropped.to_string()),
                ],
            });
            let mut bytes = Vec::new();
            codec.encode(&span, &mut bytes);
            send(bytes);
        }
    }

    if crashed {
        return fail(run_count as u32, "injected crash (FollowerSpec::crash_after)".into());
    }
    // Late duplicates (injection knob): re-send the first N frames after
    // the fact, exercising the leader's by-index reconciliation.
    for bytes in first_frames {
        send(bytes);
    }
    let mut bytes = Vec::new();
    codec.encode(
        &Frame::ShardDone { shard: f as u32, cells: run_count as u32 },
        &mut bytes,
    );
    send(bytes);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::JobSpec;

    fn grid_spec(extra: &str) -> JobKind {
        let yaml = format!(
            "name: dist-grid\ntask: sweep\nmodel: resnet50\nplatform: G1\nsoftware: tris\n\
             routers: [round-robin, least-outstanding]\nreplicas: [1, 2]\n\
             batch_timeouts_ms: [2, 5]\nworkload:\n  rate_per_replica: 80.0\n  duration_s: 3\n\
             batching:\n  max_size: 8\n  max_wait_ms: 2\n{extra}"
        );
        JobSpec::parse_yaml(&yaml).expect("grid yaml parses").kind
    }

    fn fingerprints(outcome: &SweepOutcome) -> Vec<u64> {
        outcome.cells.iter().map(|c| c.result.collector.fingerprint()).collect()
    }

    #[test]
    fn sharded_matches_serial_for_both_codecs() {
        let kind = grid_spec("");
        let (plan, _) = job::build_sweep_plan(&kind, 42).unwrap();
        let serial = plan.run(2);
        for codec in [CodecKind::Binary, CodecKind::JsonLines] {
            let dist = run_sharded(&kind, 42, &DistConfig::uniform(3, 6, codec)).unwrap();
            assert_eq!(dist.outcome.len(), serial.len());
            assert_eq!(fingerprints(&dist.outcome), fingerprints(&serial), "{codec:?}");
            for (a, b) in dist.outcome.cells.iter().zip(&serial.cells) {
                assert_eq!(a.label, b.label);
                assert_eq!(a.seed, b.seed);
                assert_eq!(a.result.issued, b.result.issued);
                assert_eq!(a.result.events, b.result.events);
            }
            assert_eq!(dist.stats.rounds, 1);
            assert_eq!(dist.stats.cells_rerun, 0);
            assert_eq!(dist.stats.shard_cells.iter().sum::<usize>(), serial.len());
            assert!(dist.stats.bytes_to_leader > 0);
        }
    }

    #[test]
    fn crashed_shard_cells_are_requeued_and_identical() {
        let kind = grid_spec("");
        let (plan, _) = job::build_sweep_plan(&kind, 7).unwrap();
        let serial = plan.run(1);
        let cfg = DistConfig {
            followers: vec![
                FollowerSpec::healthy(2),
                FollowerSpec { threads: 2, crash_after: Some(1) },
            ],
            codec: CodecKind::Binary,
            chunk_bytes: 64,
            duplicate_first: 0,
            trace: false,
        };
        let dist = run_sharded(&kind, 7, &cfg).unwrap();
        assert_eq!(fingerprints(&dist.outcome), fingerprints(&serial));
        assert!(dist.stats.rounds >= 2, "crash must force a re-queue round");
        assert!(dist.stats.cells_rerun > 0);
    }

    #[test]
    fn duplicate_late_frames_are_dropped_by_cell_index() {
        let kind = grid_spec("");
        let (plan, _) = job::build_sweep_plan(&kind, 9).unwrap();
        let serial = plan.run(1);
        let mut cfg = DistConfig::uniform(2, 4, CodecKind::Binary);
        cfg.duplicate_first = 2;
        let mut streamed = 0usize;
        let dist = run_sharded_with(&kind, 9, &cfg, &mut |_| streamed += 1).unwrap();
        assert_eq!(fingerprints(&dist.outcome), fingerprints(&serial));
        assert_eq!(dist.stats.duplicate_frames, 4, "2 followers x 2 re-sent frames");
        assert_eq!(streamed, serial.len(), "the hook sees each cell exactly once");
        assert_eq!(
            dist.stats.frames_to_leader,
            serial.len() as u64 + dist.stats.duplicate_frames
        );
    }

    #[test]
    fn all_followers_dead_is_a_loud_error() {
        let kind = grid_spec("");
        let cfg = DistConfig {
            followers: vec![FollowerSpec { threads: 2, crash_after: Some(0) }],
            codec: CodecKind::Binary,
            chunk_bytes: 512,
            duplicate_first: 0,
            trace: false,
        };
        let err = run_sharded(&kind, 1, &cfg).unwrap_err().to_string();
        assert!(err.contains("every follower failed"), "{err}");
    }
}
