//! Benchmark-job specifications (paper §4.2.2: "From their submission
//! (a YAML file), the system first chooses ...") and their execution.
//!
//! A submission parses into a [`JobSpec`]; a follower worker executes it
//! with [`execute`], producing PerfDB records. Job kinds cover the tasks
//! the paper's system automates: serving-tier simulations, N-replica
//! cluster simulations with optional autoscaling, hardware-tier sweeps,
//! whole benchmark grids run by the parallel sweep engine (`task: sweep`),
//! and (for scheduler studies / tests) calibrated sleeps.
//!
//! A `sweep` submission fans a router × fleet-size grid across the
//! worker's `threads_per_worker` budget (one PerfDB record per cell;
//! per-cell seeds derive from the job seed, so the records are identical
//! at any thread budget):
//!
//! ```yaml
//! name: router-replica-grid
//! task: sweep
//! model: resnet50
//! platform: G1
//! software: tris
//! routers: [round-robin, least-outstanding, power-of-two, latency-ewma]
//! replicas: [1, 2, 4]
//! workload:
//!   rate_per_replica: 120.0
//!   duration_s: 30
//! batching:
//!   max_size: 8
//!   max_wait_ms: 2
//! ```
//!
//! A `cluster_sim` submission requesting an autoscaled spike study
//! (Fig 11c burst against a cold-starting fleet) looks like:
//!
//! ```yaml
//! name: resnet-spike-autoscale
//! task: cluster_sim
//! model: resnet50
//! platform: G1
//! software: tris
//! replicas: 2                  # initial fleet
//! router: least-outstanding    # or round-robin / power-of-two / latency-ewma
//! workload:
//!   rate: 120.0
//!   duration_s: 60
//!   burst:                     # optional spike window
//!     rate: 600.0
//!     start_s: 20
//!     duration_s: 10
//! batching:
//!   max_size: 8
//!   max_wait_ms: 2
//! autoscale:                   # optional; fixed fleet when omitted
//!   policy: queue-depth        # or utilization
//!   min_replicas: 2
//!   max_replicas: 8
//!   up: 8.0                    # outstanding/replica (or busy fraction)
//!   down: 1.0
//!   cooldown_s: 2.0
//!   eval_interval_s: 0.5
//! ```

use crate::hardware::{self, Parallelism};
use crate::models::catalog;
use crate::perfdb::Record;
use crate::pipeline::{Processors, RequestPath, LAN};
use crate::serving::cluster::{self, ClusterConfig, ReplicaConfig};
use crate::serving::{
    self, backends, AutoscaleConfig, Policy, RouterPolicy, ScalePolicy, ServiceModel, SimConfig,
};
use crate::sweep::SweepPlan;
use crate::util::json::Json;
use crate::util::yamlish;
use crate::workload::{generate, Pattern};
use anyhow::{anyhow, bail, Result};

/// What a worker should run.
#[derive(Debug, Clone, PartialEq)]
pub enum JobKind {
    /// Simulate a serving pipeline (software/pipeline tiers).
    ServingSim {
        model: String,
        platform: String,
        software: String,
        rate_rps: f64,
        duration_s: f64,
        max_batch: usize,
        max_wait_s: f64,
    },
    /// Simulate an N-replica serving cluster, optionally autoscaled —
    /// scale-out and spike studies submitted through the leader.
    ClusterSim {
        model: String,
        platform: String,
        software: String,
        /// Initial fleet size.
        replicas: usize,
        /// Router policy name: round-robin, least-outstanding,
        /// power-of-two, or latency-ewma.
        router: String,
        rate_rps: f64,
        duration_s: f64,
        /// Optional spike window on top of the base rate (Fig 11c).
        burst: Option<BurstSpec>,
        max_batch: usize,
        max_wait_s: f64,
        /// Optional elasticity; fixed fleet when absent.
        autoscale: Option<AutoscaleSpec>,
    },
    /// Roofline sweep of a model across batch sizes (hardware tier).
    HardwareSweep { model: String, platform: String, batches: Vec<usize> },
    /// A grid of independent cluster simulations — router policies ×
    /// fleet sizes, offered load scaled per replica — executed by the
    /// parallel sweep engine (`crate::sweep`) on the worker's
    /// `threads_per_worker` budget. Per-cell seeds derive from the job
    /// seed, so results are identical at any thread budget.
    Sweep {
        model: String,
        platform: String,
        software: String,
        /// Router policy names, one grid axis (same vocabulary as
        /// `cluster_sim`'s `router`).
        routers: Vec<String>,
        /// Fleet sizes, the other grid axis.
        replicas: Vec<usize>,
        /// Offered Poisson rate per replica (cells stay comparably
        /// loaded as the fleet axis grows).
        rate_per_replica: f64,
        duration_s: f64,
        max_batch: usize,
        max_wait_s: f64,
    },
    /// Do nothing for a fixed time (scheduler studies; time is scaled by
    /// the leader's `time_scale`).
    Sleep { seconds: f64 },
}

/// Burst window of a `cluster_sim` workload (spike load, Fig 11c).
#[derive(Debug, Clone, PartialEq)]
pub struct BurstSpec {
    pub rate_rps: f64,
    pub start_s: f64,
    pub duration_s: f64,
}

/// Autoscaling parameters of a `cluster_sim` submission.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleSpec {
    /// "queue-depth" or "utilization".
    pub policy: String,
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Scale-up threshold: outstanding per replica (queue-depth) or busy
    /// fraction in [0,1] (utilization).
    pub up: f64,
    /// Scale-down threshold, same units as `up`.
    pub down: f64,
    pub cooldown_s: f64,
    pub eval_interval_s: f64,
}

/// A parsed benchmark submission.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub name: String,
    pub kind: JobKind,
    /// Scheduler's duration estimate (paper: processing times are known).
    pub est_duration_s: f64,
}

impl JobSpec {
    /// Parse a YAML submission (see `examples/submissions/` for samples).
    pub fn parse_yaml(text: &str) -> Result<JobSpec> {
        let doc = yamlish::parse(text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<JobSpec> {
        let name = doc
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("unnamed")
            .to_string();
        let task = doc
            .get("task")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("submission missing 'task'"))?;
        let kind = match task {
            "serving_sim" => {
                let wl = doc.get("workload");
                JobKind::ServingSim {
                    model: str_or(doc, "model", "resnet50"),
                    platform: str_or(doc, "platform", "G1"),
                    software: str_or(doc, "software", "tfs"),
                    rate_rps: wl.and_then(|w| w.get("rate")).and_then(|v| v.as_f64()).unwrap_or(30.0),
                    duration_s: wl
                        .and_then(|w| w.get("duration_s"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(60.0),
                    max_batch: doc
                        .get("batching")
                        .and_then(|b| b.get("max_size"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(8) as usize,
                    max_wait_s: doc
                        .get("batching")
                        .and_then(|b| b.get("max_wait_ms"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(5.0)
                        / 1e3,
                }
            }
            "cluster_sim" => {
                let wl = doc.get("workload");
                let burst = wl.and_then(|w| w.get("burst")).map(|b| BurstSpec {
                    rate_rps: b.get("rate").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    start_s: b.get("start_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    duration_s: b.get("duration_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
                });
                if let Some(b) = &burst {
                    if b.rate_rps <= 0.0 || b.duration_s <= 0.0 {
                        bail!("cluster_sim burst needs positive rate and duration_s");
                    }
                }
                let autoscale = doc.get("autoscale").map(|a| AutoscaleSpec {
                    policy: str_or(a, "policy", "queue-depth"),
                    min_replicas: a
                        .get("min_replicas")
                        .and_then(|v| v.as_i64())
                        .unwrap_or(1)
                        .max(1) as usize,
                    max_replicas: a
                        .get("max_replicas")
                        .and_then(|v| v.as_i64())
                        .unwrap_or(8)
                        .max(1) as usize,
                    up: a.get("up").and_then(|v| v.as_f64()).unwrap_or(8.0),
                    down: a.get("down").and_then(|v| v.as_f64()).unwrap_or(1.0),
                    cooldown_s: a.get("cooldown_s").and_then(|v| v.as_f64()).unwrap_or(2.0),
                    eval_interval_s: a
                        .get("eval_interval_s")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.5),
                });
                JobKind::ClusterSim {
                    model: str_or(doc, "model", "resnet50"),
                    platform: str_or(doc, "platform", "G1"),
                    software: str_or(doc, "software", "tfs"),
                    replicas: doc
                        .get("replicas")
                        .and_then(|v| v.as_i64())
                        .unwrap_or(2)
                        .max(1) as usize,
                    router: str_or(doc, "router", "least-outstanding"),
                    rate_rps: wl
                        .and_then(|w| w.get("rate"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(60.0),
                    duration_s: wl
                        .and_then(|w| w.get("duration_s"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(60.0),
                    burst,
                    max_batch: doc
                        .get("batching")
                        .and_then(|b| b.get("max_size"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(8) as usize,
                    max_wait_s: doc
                        .get("batching")
                        .and_then(|b| b.get("max_wait_ms"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(5.0)
                        / 1e3,
                    autoscale,
                }
            }
            "hardware_sweep" => JobKind::HardwareSweep {
                model: str_or(doc, "model", "resnet50"),
                platform: str_or(doc, "platform", "G1"),
                batches: doc
                    .get("batches")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|i| i as usize).collect())
                    .unwrap_or_else(|| vec![1, 2, 4, 8, 16, 32]),
            },
            "sweep" => {
                let wl = doc.get("workload");
                let routers: Vec<String> = match doc.get("routers").and_then(|v| v.as_arr()) {
                    Some(a) => {
                        // Same contract as `replicas` below: a bad entry
                        // fails the submission instead of silently
                        // shrinking the grid (yamlish types unquoted
                        // scalars, so a numeric/bool-looking entry is
                        // not a string).
                        let mut out = Vec::with_capacity(a.len());
                        for x in a {
                            match x.as_str() {
                                Some(s) => out.push(s.to_string()),
                                None => bail!("sweep 'routers' entries must be strings"),
                            }
                        }
                        out
                    }
                    None => vec!["round-robin".to_string(), "least-outstanding".to_string()],
                };
                let replicas: Vec<usize> = match doc.get("replicas").and_then(|v| v.as_arr()) {
                    Some(a) => {
                        // Reject bad entries loudly: silently dropping a
                        // `0` or a typo would shrink the grid and produce
                        // fewer PerfDB records than the submission asked
                        // for, with no error anywhere.
                        let mut out = Vec::with_capacity(a.len());
                        for x in a {
                            match x.as_i64() {
                                Some(i) if i > 0 => out.push(i as usize),
                                _ => bail!("sweep 'replicas' entries must be positive integers"),
                            }
                        }
                        out
                    }
                    None => vec![1, 2, 4],
                };
                if routers.is_empty() || replicas.is_empty() {
                    bail!("sweep needs non-empty 'routers' and 'replicas' lists");
                }
                JobKind::Sweep {
                    model: str_or(doc, "model", "resnet50"),
                    platform: str_or(doc, "platform", "G1"),
                    software: str_or(doc, "software", "tris"),
                    routers,
                    replicas,
                    rate_per_replica: wl
                        .and_then(|w| w.get("rate_per_replica"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(120.0),
                    duration_s: wl
                        .and_then(|w| w.get("duration_s"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(30.0),
                    max_batch: doc
                        .get("batching")
                        .and_then(|b| b.get("max_size"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(8) as usize,
                    max_wait_s: doc
                        .get("batching")
                        .and_then(|b| b.get("max_wait_ms"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(5.0)
                        / 1e3,
                }
            }
            "sleep" => JobKind::Sleep {
                seconds: doc.get("seconds").and_then(|v| v.as_f64()).unwrap_or(1.0),
            },
            other => bail!("unknown task kind {other:?}"),
        };
        let est = doc
            .get("est_duration_s")
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| default_estimate(&kind));
        Ok(JobSpec { name, kind, est_duration_s: est })
    }
}

fn str_or(doc: &Json, key: &str, default: &str) -> String {
    doc.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
}

/// Duration estimate used by the scheduler when the submission omits one.
fn default_estimate(kind: &JobKind) -> f64 {
    match kind {
        JobKind::ServingSim { duration_s, .. } => duration_s * 0.05 + 2.0, // sim runs much faster than simulated time
        JobKind::ClusterSim { duration_s, replicas, .. } => {
            duration_s * 0.05 * (*replicas as f64).max(1.0) + 2.0
        }
        JobKind::HardwareSweep { batches, .. } => 0.5 + batches.len() as f64 * 0.1,
        // Serial estimate: the sum of the per-cell cluster_sim estimates.
        // The leader divides this by its workers' thread budget when
        // charging backlog (see `LeaderConfig::charged_estimate_s`).
        JobKind::Sweep { duration_s, replicas, routers, .. } => {
            let total_replicas: usize = replicas.iter().sum();
            duration_s * 0.05 * total_replicas as f64 * routers.len() as f64 + 2.0
        }
        JobKind::Sleep { seconds } => *seconds,
    }
}

/// Resolve a `cluster_sim` router name.
fn router_policy(name: &str, seed: u64) -> Result<RouterPolicy> {
    Ok(match name {
        "round-robin" | "rr" => RouterPolicy::RoundRobin,
        "least-outstanding" | "lo" => RouterPolicy::LeastOutstanding,
        "power-of-two" | "p2c" => RouterPolicy::PowerOfTwoChoices { seed },
        "latency-ewma" | "ewma" => RouterPolicy::LatencyEwma { alpha: 0.3, stale_s: 0.1 },
        other => bail!("unknown router {other:?}"),
    })
}

/// Family parallelism for a catalog model (the roofline occupancy input).
fn parallelism_for(model: &catalog::CatalogModel) -> Parallelism {
    match model.task {
        // Conv nets: per-sample row parallelism is bounded by the
        // mid/late feature maps (~28x28), not the input resolution —
        // this is what produces the paper's flat small-batch latency.
        catalog::Task::IC | catalog::Task::OD | catalog::Task::GAN => Parallelism::cnn(28),
        catalog::Task::NLP => Parallelism::sequence(128),
        catalog::Task::TC => Parallelism::sequence(64),
    }
}

/// Build the serving-sim service model for (model, platform).
pub fn service_model_for(model_name: &str, platform_id: &str) -> Result<ServiceModel> {
    let model = catalog::find(model_name)
        .ok_or_else(|| anyhow!("model {model_name:?} not in catalog"))?;
    let platform = hardware::find(platform_id)
        .ok_or_else(|| anyhow!("platform {platform_id:?} not in Table 1"))?;
    Ok(ServiceModel::Analytic {
        platform,
        profile: model.profile,
        parallelism: parallelism_for(model),
        request_bytes: model.request_bytes,
    })
}

/// Execute a job, producing PerfDB records. `time_scale` divides sleep
/// durations (scheduler studies run faster than real time); `threads` is
/// the intra-job parallelism budget — sweep jobs run their grid cells on
/// up to this many worker threads, every other kind runs single-threaded
/// and ignores it. Results never depend on `threads` (the sweep engine is
/// bit-identical at any thread count).
pub fn execute(spec: &JobSpec, seed: u64, time_scale: f64, threads: usize) -> Result<Vec<Record>> {
    match &spec.kind {
        JobKind::ServingSim { model, platform, software, rate_rps, duration_s, max_batch, max_wait_s } => {
            let sw = backends::find(software)
                .ok_or_else(|| anyhow!("software {software:?} unknown"))?;
            let m = catalog::find(model).ok_or_else(|| anyhow!("model {model:?} unknown"))?;
            let config = SimConfig {
                arrivals: generate(&Pattern::Poisson { rate: *rate_rps }, *duration_s, seed),
                closed_loop: None,
                duration_s: *duration_s,
                policy: Policy::Dynamic { max_size: *max_batch, max_wait_s: *max_wait_s },
                software: sw,
                service: service_model_for(model, platform)?,
                path: RequestPath {
                    processors: Processors::image(),
                    network: LAN,
                    payload_bytes: m.request_bytes,
                },
                max_queue: 4096,
                seed,
            };
            let result = serving::run(&config);
            let collector = &result.collector;
            let record = Record::new("serving_sim", model, platform, software)
                .with_metric("rate_rps", *rate_rps)
                .with_metric("p50_ms", collector.e2e.percentile(50.0) * 1e3)
                .with_metric("p95_ms", collector.e2e.percentile(95.0) * 1e3)
                .with_metric("p99_ms", collector.e2e.percentile(99.0) * 1e3)
                .with_metric("throughput_rps", collector.throughput_rps())
                .with_metric("mean_batch", result.mean_batch())
                .with_metric("utilization", result.timeline.mean())
                .with_metric("dropped", result.dropped as f64);
            Ok(vec![record])
        }
        JobKind::ClusterSim {
            model,
            platform,
            software,
            replicas,
            router,
            rate_rps,
            duration_s,
            burst,
            max_batch,
            max_wait_s,
            autoscale,
        } => {
            let sw = backends::find(software)
                .ok_or_else(|| anyhow!("software {software:?} unknown"))?;
            let m = catalog::find(model).ok_or_else(|| anyhow!("model {model:?} unknown"))?;
            let template = ReplicaConfig {
                software: sw,
                service: service_model_for(model, platform)?,
                policy: Policy::Dynamic { max_size: *max_batch, max_wait_s: *max_wait_s },
                max_queue: 4096,
            };
            let pattern = match burst {
                Some(b) => Pattern::Spike {
                    base_rate: *rate_rps,
                    burst_rate: b.rate_rps,
                    start_s: b.start_s,
                    duration_s: b.duration_s,
                },
                None => Pattern::Poisson { rate: *rate_rps },
            };
            let autoscale_cfg = autoscale
                .as_ref()
                .map(|a| -> Result<AutoscaleConfig> {
                    let policy = match a.policy.as_str() {
                        "queue-depth" => ScalePolicy::QueueDepth {
                            up_per_replica: a.up,
                            down_per_replica: a.down,
                            cooldown_s: a.cooldown_s,
                        },
                        "utilization" => ScalePolicy::Utilization {
                            up: a.up,
                            down: a.down,
                            cooldown_s: a.cooldown_s,
                        },
                        other => bail!("unknown autoscale policy {other:?}"),
                    };
                    // Initial fleet must sit inside [min, max]: below min
                    // the engine refuses to start; above max the declared
                    // capacity bound would be silently violated.
                    if a.max_replicas < a.min_replicas
                        || *replicas < a.min_replicas
                        || *replicas > a.max_replicas
                    {
                        bail!(
                            "autoscale bounds invalid: initial {} vs min {} / max {}",
                            replicas,
                            a.min_replicas,
                            a.max_replicas
                        );
                    }
                    if a.eval_interval_s <= 0.0 {
                        bail!("autoscale eval_interval_s must be positive");
                    }
                    Ok(AutoscaleConfig {
                        policy,
                        min_replicas: a.min_replicas,
                        max_replicas: a.max_replicas,
                        template: template.clone(),
                        weight_bytes: m.profile.weight_bytes,
                        eval_interval_s: a.eval_interval_s,
                    })
                })
                .transpose()?;
            let config = ClusterConfig {
                arrivals: generate(&pattern, *duration_s, seed),
                closed_loop: None,
                duration_s: *duration_s,
                replicas: (0..*replicas).map(|_| template.clone()).collect(),
                router: router_policy(router, seed)?,
                autoscale: autoscale_cfg,
                cold_start: None,
                path: RequestPath {
                    processors: Processors::image(),
                    network: LAN,
                    payload_bytes: m.request_bytes,
                },
                seed,
            };
            let result = cluster::run(&config);
            // Conservation is part of the contract: drain-on-remove must
            // complete every accepted request across scale events.
            if result.collector.completed + result.dropped != result.issued {
                bail!(
                    "cluster_sim conservation violated: {} completed + {} dropped != {} issued",
                    result.collector.completed,
                    result.dropped,
                    result.issued
                );
            }
            let collector = &result.collector;
            let mut record = Record::new("cluster_sim", model, platform, software)
                .with_metric("rate_rps", *rate_rps)
                .with_metric("replicas_initial", *replicas as f64)
                .with_metric("replicas_max", result.scale.max_active() as f64)
                .with_metric(
                    "scale_ups",
                    result.scale.count(crate::metrics::ScaleEventKind::AddRequested) as f64,
                )
                .with_metric(
                    "scale_retires",
                    result.scale.count(crate::metrics::ScaleEventKind::Retired) as f64,
                )
                .with_metric("p50_ms", collector.e2e.percentile(50.0) * 1e3)
                .with_metric("p99_ms", collector.e2e.percentile(99.0) * 1e3)
                .with_metric("throughput_rps", collector.throughput_rps())
                .with_metric("dropped", result.dropped as f64)
                .with_metric("issued", result.issued as f64);
            if let Some(b) = burst {
                let w = collector.e2e_in_window(b.start_s, b.start_s + b.duration_s);
                if !w.is_empty() {
                    record = record.with_metric("burst_p99_ms", w.percentile(99.0) * 1e3);
                }
            }
            Ok(vec![record])
        }
        JobKind::HardwareSweep { model, platform, batches } => {
            let m = catalog::find(model).ok_or_else(|| anyhow!("model {model:?} unknown"))?;
            let p = hardware::find(platform)
                .ok_or_else(|| anyhow!("platform {platform:?} unknown"))?;
            let par = parallelism_for(m);
            let mut out = Vec::new();
            for &b in batches {
                let est = hardware::estimate(p, &m.profile, par, b, m.request_bytes);
                out.push(
                    Record::new("hardware_sweep", model, platform, "-")
                        .with_metric("batch", b as f64)
                        .with_metric("latency_ms", est.total_s * 1e3)
                        .with_metric("latency_per_sample_ms", est.total_s / b as f64 * 1e3)
                        .with_metric("throughput_rps", b as f64 / est.total_s)
                        .with_metric("utilization", est.utilization)
                        .with_metric("memory_bound", if est.memory_bound { 1.0 } else { 0.0 }),
                );
            }
            Ok(out)
        }
        JobKind::Sweep {
            model,
            platform,
            software,
            routers,
            replicas,
            rate_per_replica,
            duration_s,
            max_batch,
            max_wait_s,
        } => {
            let sw = backends::find(software)
                .ok_or_else(|| anyhow!("software {software:?} unknown"))?;
            let m = catalog::find(model).ok_or_else(|| anyhow!("model {model:?} unknown"))?;
            let service = service_model_for(model, platform)?;
            // Resolve router names eagerly: a typo fails the whole job
            // before any cell burns cycles.
            let mut resolved = Vec::with_capacity(routers.len());
            for name in routers {
                resolved.push((name.clone(), router_policy(name, seed)?));
            }
            let mut plan = SweepPlan::new(seed);
            let mut axes = Vec::new(); // (fleet size, router name, rate) per cell
            for &n in replicas {
                for (name, policy) in &resolved {
                    let rate = rate_per_replica * n as f64;
                    let template = ReplicaConfig {
                        software: sw,
                        service: service.clone(),
                        policy: Policy::Dynamic { max_size: *max_batch, max_wait_s: *max_wait_s },
                        max_queue: 4096,
                    };
                    let router = *policy;
                    let duration = *duration_s;
                    let payload = m.request_bytes;
                    plan.push(format!("{n}x{name}"), move |cell_seed| ClusterConfig {
                        arrivals: generate(&Pattern::Poisson { rate }, duration, cell_seed),
                        closed_loop: None,
                        duration_s: duration,
                        replicas: (0..n).map(|_| template.clone()).collect(),
                        router,
                        autoscale: None,
                        cold_start: None,
                        path: RequestPath {
                            processors: Processors::image(),
                            network: LAN,
                            payload_bytes: payload,
                        },
                        seed: cell_seed,
                    });
                    axes.push((n, name.clone(), rate));
                }
            }
            let outcome = plan.run(threads.max(1));
            let mut out = Vec::with_capacity(outcome.cells.len());
            for (cell, (n, router_name, rate)) in outcome.cells.iter().zip(&axes) {
                let r = &cell.result;
                if r.collector.completed + r.dropped != r.issued {
                    bail!(
                        "sweep cell {} conservation violated: {} completed + {} dropped != {} issued",
                        cell.label,
                        r.collector.completed,
                        r.dropped,
                        r.issued
                    );
                }
                out.push(
                    Record::new("sweep", model, platform, software)
                        .with_label("cell", &cell.label)
                        .with_label("router", router_name)
                        .with_metric("replicas", *n as f64)
                        .with_metric("rate_rps", *rate)
                        .with_metric("p50_ms", r.collector.e2e.percentile(50.0) * 1e3)
                        .with_metric("p99_ms", r.collector.e2e.percentile(99.0) * 1e3)
                        .with_metric("throughput_rps", r.collector.throughput_rps())
                        .with_metric("dropped", r.dropped as f64)
                        .with_metric("issued", r.issued as f64),
                );
            }
            Ok(out)
        }
        JobKind::Sleep { seconds } => {
            std::thread::sleep(std::time::Duration::from_secs_f64(seconds / time_scale.max(1e-9)));
            Ok(vec![Record::new("sleep", "-", "-", "-").with_metric("seconds", *seconds)])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SUBMISSION: &str = r#"
name: resnet-tail-latency
task: serving_sim
model: resnet50
platform: G1
software: tris
workload:
  rate: 80.0
  duration_s: 10
batching:
  max_size: 16
  max_wait_ms: 2
"#;

    #[test]
    fn parses_serving_submission() {
        let spec = JobSpec::parse_yaml(SUBMISSION).unwrap();
        assert_eq!(spec.name, "resnet-tail-latency");
        match &spec.kind {
            JobKind::ServingSim { model, software, rate_rps, max_batch, max_wait_s, .. } => {
                assert_eq!(model, "resnet50");
                assert_eq!(software, "tris");
                assert_eq!(*rate_rps, 80.0);
                assert_eq!(*max_batch, 16);
                assert!((max_wait_s - 0.002).abs() < 1e-12);
            }
            k => panic!("{k:?}"),
        }
        assert!(spec.est_duration_s > 0.0);
    }

    const CLUSTER_SUBMISSION: &str = r#"
name: spike-autoscale
task: cluster_sim
model: resnet50
platform: G1
software: tfs
replicas: 2
router: least-outstanding
workload:
  rate: 120.0
  duration_s: 30
  burst:
    rate: 2000.0
    start_s: 8
    duration_s: 6
batching:
  max_size: 8
  max_wait_ms: 2
autoscale:
  policy: queue-depth
  min_replicas: 2
  max_replicas: 6
  up: 8.0
  down: 1.0
  cooldown_s: 1.0
  eval_interval_s: 0.5
"#;

    #[test]
    fn parses_cluster_submission() {
        let spec = JobSpec::parse_yaml(CLUSTER_SUBMISSION).unwrap();
        match &spec.kind {
            JobKind::ClusterSim { replicas, router, burst, autoscale, rate_rps, .. } => {
                assert_eq!(*replicas, 2);
                assert_eq!(router, "least-outstanding");
                assert_eq!(*rate_rps, 120.0);
                let b = burst.as_ref().unwrap();
                assert_eq!(b.rate_rps, 2000.0);
                assert_eq!(b.start_s, 8.0);
                let a = autoscale.as_ref().unwrap();
                assert_eq!(a.policy, "queue-depth");
                assert_eq!(a.max_replicas, 6);
                assert_eq!(a.eval_interval_s, 0.5);
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn executes_cluster_sim_with_autoscale() {
        let spec = JobSpec::parse_yaml(CLUSTER_SUBMISSION).unwrap();
        let records = execute(&spec, 3, 1.0, 1).unwrap();
        assert_eq!(records.len(), 1);
        let r = &records[0];
        // Conservation checked inside execute; the record carries the
        // autoscaling outcome.
        assert!(r.metric("replicas_max").unwrap() > 2.0, "no scale-up recorded");
        assert!(r.metric("scale_ups").unwrap() >= 1.0);
        assert!(r.metric("burst_p99_ms").unwrap() >= r.metric("p50_ms").unwrap());
        assert!(r.metric("throughput_rps").unwrap() > 0.0);
    }

    #[test]
    fn cluster_sim_fixed_fleet_without_autoscale_block() {
        let spec = JobSpec::parse_yaml(
            "task: cluster_sim\nmodel: resnet50\nplatform: G1\nsoftware: tris\nreplicas: 3\n\
             workload:\n  rate: 90.0\n  duration_s: 10\n",
        )
        .unwrap();
        let records = execute(&spec, 0, 1.0, 1).unwrap();
        let r = &records[0];
        assert_eq!(r.metric("replicas_initial").unwrap(), 3.0);
        assert_eq!(r.metric("replicas_max").unwrap(), 3.0);
        assert_eq!(r.metric("scale_ups").unwrap(), 0.0);
    }

    #[test]
    fn cluster_sim_rejects_unknown_router_and_policy() {
        let bad_router = JobSpec::parse_yaml(
            "task: cluster_sim\nmodel: resnet50\nplatform: G1\nrouter: teleport\n",
        )
        .unwrap();
        assert!(execute(&bad_router, 0, 1.0, 1).is_err());
        let bad_policy = JobSpec::parse_yaml(
            "task: cluster_sim\nmodel: resnet50\nplatform: G1\nautoscale:\n  policy: vibes\n",
        )
        .unwrap();
        assert!(execute(&bad_policy, 0, 1.0, 1).is_err());
    }

    #[test]
    fn parses_hardware_sweep() {
        let spec =
            JobSpec::parse_yaml("task: hardware_sweep\nmodel: bert_large\nplatform: G3\nbatches: [1, 8]\n")
                .unwrap();
        match &spec.kind {
            JobKind::HardwareSweep { batches, platform, .. } => {
                assert_eq!(batches, &vec![1, 8]);
                assert_eq!(platform, "G3");
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn rejects_unknown_task() {
        assert!(JobSpec::parse_yaml("task: mine_bitcoin\n").is_err());
        assert!(JobSpec::parse_yaml("name: x\n").is_err());
    }

    #[test]
    fn executes_serving_sim() {
        let spec = JobSpec::parse_yaml(SUBMISSION).unwrap();
        let records = execute(&spec, 7, 1.0, 1).unwrap();
        assert_eq!(records.len(), 1);
        let r = &records[0];
        assert!(r.metric("p99_ms").unwrap() >= r.metric("p50_ms").unwrap());
        assert!(r.metric("throughput_rps").unwrap() > 0.0);
    }

    #[test]
    fn executes_hardware_sweep() {
        let spec = JobSpec::parse_yaml(
            "task: hardware_sweep\nmodel: resnet50\nplatform: G1\nbatches: [1, 4, 16]\n",
        )
        .unwrap();
        let records = execute(&spec, 0, 1.0, 1).unwrap();
        assert_eq!(records.len(), 3);
        // Per-sample latency should fall with batch.
        let l1 = records[0].metric("latency_per_sample_ms").unwrap();
        let l16 = records[2].metric("latency_per_sample_ms").unwrap();
        assert!(l16 < l1);
    }

    #[test]
    fn execute_rejects_unknown_model() {
        let spec =
            JobSpec::parse_yaml("task: hardware_sweep\nmodel: alexnet9000\nplatform: G1\n").unwrap();
        assert!(execute(&spec, 0, 1.0, 1).is_err());
    }

    #[test]
    fn sleep_respects_time_scale() {
        let spec = JobSpec::parse_yaml("task: sleep\nseconds: 0.2\n").unwrap();
        let t0 = std::time::Instant::now();
        execute(&spec, 0, 100.0, 1).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 0.1);
    }

    const SWEEP_SUBMISSION: &str = r#"
name: router-replica-grid
task: sweep
model: resnet50
platform: G1
software: tris
routers: [round-robin, least-outstanding]
replicas: [1, 2]
workload:
  rate_per_replica: 60.0
  duration_s: 4
batching:
  max_size: 8
  max_wait_ms: 2
"#;

    #[test]
    fn parses_sweep_submission() {
        let spec = JobSpec::parse_yaml(SWEEP_SUBMISSION).unwrap();
        match &spec.kind {
            JobKind::Sweep { routers, replicas, rate_per_replica, duration_s, .. } => {
                let want = vec!["round-robin".to_string(), "least-outstanding".to_string()];
                assert_eq!(routers, &want);
                assert_eq!(replicas, &vec![1, 2]);
                assert_eq!(*rate_per_replica, 60.0);
                assert_eq!(*duration_s, 4.0);
            }
            k => panic!("{k:?}"),
        }
        assert!(spec.est_duration_s > 0.0);
    }

    #[test]
    fn executes_sweep_grid_one_record_per_cell() {
        let spec = JobSpec::parse_yaml(SWEEP_SUBMISSION).unwrap();
        let records = execute(&spec, 11, 1.0, 2).unwrap();
        assert_eq!(records.len(), 4, "2 fleet sizes x 2 routers");
        assert_eq!(records[0].label("router"), Some("round-robin"));
        assert_eq!(records[1].label("router"), Some("least-outstanding"));
        assert_eq!(records[0].metric("replicas"), Some(1.0));
        assert_eq!(records[3].metric("replicas"), Some(2.0));
        for r in &records {
            assert!(r.metric("throughput_rps").unwrap() > 0.0, "{:?}", r.label("cell"));
            assert!(r.metric("p99_ms").unwrap() >= r.metric("p50_ms").unwrap());
        }
    }

    #[test]
    fn sweep_records_identical_at_any_thread_budget() {
        let spec = JobSpec::parse_yaml(SWEEP_SUBMISSION).unwrap();
        let serial = execute(&spec, 11, 1.0, 1).unwrap();
        let parallel = execute(&spec, 11, 1.0, 8).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.label("cell"), b.label("cell"));
            for key in ["p50_ms", "p99_ms", "throughput_rps", "issued", "dropped"] {
                assert_eq!(
                    a.metric(key).unwrap().to_bits(),
                    b.metric(key).unwrap().to_bits(),
                    "{key} must be bit-identical across thread budgets"
                );
            }
        }
    }

    #[test]
    fn sweep_rejects_unknown_router() {
        let spec = JobSpec::parse_yaml(
            "task: sweep\nmodel: resnet50\nplatform: G1\nrouters: [teleport]\nreplicas: [1]\n",
        )
        .unwrap();
        assert!(execute(&spec, 0, 1.0, 2).is_err());
    }

    #[test]
    fn sweep_rejects_empty_or_invalid_axes() {
        assert!(JobSpec::parse_yaml("task: sweep\nrouters: []\n").is_err());
        assert!(JobSpec::parse_yaml("task: sweep\nreplicas: []\n").is_err());
        assert!(JobSpec::parse_yaml("task: sweep\nreplicas: [0]\n").is_err());
        // A single bad entry fails the whole submission — the grid must
        // never silently shrink.
        assert!(JobSpec::parse_yaml("task: sweep\nreplicas: [4, 0, 8]\n").is_err());
        assert!(JobSpec::parse_yaml("task: sweep\nreplicas: [4, oops]\n").is_err());
        // Same contract on the router axis: yamlish types unquoted
        // scalars, so a numeric entry is not a router name.
        assert!(JobSpec::parse_yaml("task: sweep\nrouters: [round-robin, 42]\n").is_err());
    }
}
