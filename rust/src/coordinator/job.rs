//! Benchmark-job specifications (paper §4.2.2: "From their submission
//! (a YAML file), the system first chooses ...") and their execution.
//!
//! A submission parses into a [`JobSpec`]; a follower worker executes it
//! with [`execute`], producing PerfDB records. Job kinds cover the tasks
//! the paper's system automates: serving-tier simulations, N-replica
//! cluster simulations with optional autoscaling, hardware-tier sweeps,
//! whole benchmark grids run by the parallel sweep engine (`task: sweep`),
//! and (for scheduler studies / tests) calibrated sleeps.
//!
//! A `sweep` submission fans a router × fleet-size × batching-timeout
//! grid across the worker's `threads_per_worker` budget (one PerfDB
//! record per cell; per-cell seeds derive from the job seed, so the
//! records are identical at any thread budget). `batch_timeouts_ms` is
//! optional — when omitted the single `batching.max_wait_ms` value is
//! used — and, like the other axes, malformed entries fail the submission
//! loudly instead of silently shrinking the grid:
//!
//! ```yaml
//! name: router-replica-grid
//! task: sweep
//! model: resnet50
//! platform: G1
//! software: tris
//! routers: [round-robin, least-outstanding, power-of-two, latency-ewma]
//! replicas: [1, 2, 4]
//! batch_timeouts_ms: [1, 2, 5]   # optional batching-policy axis
//! workload:
//!   rate_per_replica: 120.0
//!   duration_s: 30
//! batching:
//!   max_size: 8
//!   max_wait_ms: 2
//! ```
//!
//! A `multimodel` submission runs the multi-model replica engine
//! (`serving::multimodel`) for the paper's Sharing-versus-Dedicate study:
//! named per-model Poisson streams against either a shared fleet (every
//! replica hosts all models under the MPS contention model and the
//! per-replica weight-memory budget) or a dedicated fleet (one replica
//! per model), producing one PerfDB record per model stream:
//!
//! ```yaml
//! name: share-vs-dedicate
//! task: multimodel
//! platform: G1
//! software: tris
//! models: [resnet50, mobilenet_v1]
//! rates: [120.0, 90.0]          # per-stream Poisson rates, one per model
//! mode: shared                  # or dedicated (one replica per model)
//! replicas: 1                   # shared fleet size (each hosts all models)
//! mem_gb: 16.0                  # per-replica weight-memory budget
//! router: least-outstanding     # applied per model over its hosts
//! workload:
//!   duration_s: 30
//! batching:
//!   max_size: 8
//!   max_wait_ms: 2
//! ```
//!
//! `cluster_sim`, `sweep`, and `multimodel` submissions also accept an
//! optional top-level `admission:` block attaching the ingress tier's
//! per-tenant QoS (token-bucket rate limits, priority classes shed
//! lowest-first under overload, weighted-fair release — see
//! `serving::ingress`). For `multimodel`, tenant i governs model stream
//! i (counts must match); for `cluster_sim` and `sweep`, the offered
//! rate splits evenly across the tenants, one tagged stream each. With
//! admission on, the job emits one extra record per priority class
//! (label `class`) carrying issued/goodput/shed_fraction and the
//! per-reason drop breakdown; every record's `dropped` is also broken
//! down by reason (`dropped_queue_full`, `dropped_shed`,
//! `dropped_evicted_backlog`, `dropped_rejected_placement`,
//! `dropped_replica_failed`, `dropped_timed_out`):
//!
//! ```yaml
//! admission:
//!   shed_depth: [600, 200, 60]  # in-system cap per class, class 0 first
//!   tenants:
//!     - name: gold
//!       class: 0
//!       weight: 4.0             # weighted-fair share of held releases
//!     - name: bronze
//!       class: 2
//!       rate: 50.0              # token-bucket limit (rps), optional
//!       burst: 10.0             # bucket depth in tokens
//! ```
//!
//! The same three tasks accept optional top-level `faults:` and `retry:`
//! blocks (the robustness tier — see `serving::faults`). `faults`
//! injects replica crashes, recoveries-through-cold-start and straggler
//! slowdowns, either scripted at fixed times or drawn from an
//! exponential MTTF/MTTR profile whose PCG streams are disjoint from
//! the workload's; the schedule is fixed by the block itself (not the
//! job seed), so every cell of a sweep runs under *identical* faults
//! and the grid axes stay comparable. `retry` attaches the ingress
//! tier's [`RetryPolicy`]: requests stranded on a crashed replica are
//! re-issued with exponential backoff under a per-request deadline
//! instead of dropping as `replica-failed`; `hedge: true` (`cluster_sim`
//! and `sweep` only) duplicates retried requests onto a second replica
//! and keeps whichever finishes first:
//!
//! ```yaml
//! faults:
//!   script:                     # explicit ops, reproducible verbatim
//!     - op: crash
//!       replica: 1
//!       at_s: 5.0
//!     - op: recover
//!       replica: 1
//!       at_s: 8.0
//!     - op: degrade             # straggler window: 2.5x service times
//!       replica: 0
//!       at_s: 2.0
//!       until_s: 6.0
//!       factor: 2.5
//!   profile:                    # random layer on top of the script
//!     mttf_s: 20.0              # exponential mean time to failure
//!     mttr_s: 2.0               # exponential mean time to recovery
//!   seed: 7                     # profile streams (default 0)
//! retry:
//!   max_attempts: 4             # first try + up to 3 retries
//!   deadline_s: 10.0            # give up past arrival + deadline
//!   backoff_ms: 50              # doubles per retry, capped at 16x
//!   hedge: true                 # duplicate retries onto a 2nd replica
//! ```
//!
//! The same three tasks accept an optional top-level `trace:` block
//! switching on the deterministic tracing layer (see [`crate::obs`]):
//! request span trees head-sampled by a pure function of the request id,
//! gauge timelines on a fixed sim-time grid, and a Chrome-trace/Perfetto
//! JSON export that `ui.perfetto.dev` loads directly. Tracing is
//! observational only — the results (and every
//! `Collector::fingerprint()`) are bit-identical with the block present
//! or absent; only the exported spans differ. For distributed sweeps
//! (`followers: 2+`) the block also turns on shard→cell span streaming:
//! followers emit one span per completed cell and the leader closes the
//! set with a root `sweep` span carrying the wire stats:
//!
//! ```yaml
//! trace:
//!   sample: 0.05             # off | all | a fraction in (0, 1]
//!   every_nth: 100           # alternative to sample: every Nth request id
//!   detail: full             # stages | full (batch attrs, retry links)
//!   gauge_interval_ms: 100   # gauge sampling grid; 0 disables timelines
//!   gauge_cap: 4096          # bounded ring capacity per gauge series
//!   max_spans: 65536         # sampled request roots kept (arrival order)
//!   out: trace.json          # optional Perfetto export path
//! ```
//!
//! Submissions are validated loudly: malformed grid axes, bad admission
//! shapes, and *unknown top-level keys* all fail the parse with an error
//! naming the offender — a typo'd key never silently runs a different
//! benchmark than the one submitted.
//!
//! `cluster_sim`, `sweep`, and `multimodel` submissions accept an
//! optional top-level `scale` knob selecting the metrics backend:
//! `scale: exact` (default) retains every latency sample; `scale: sketch`
//! switches the engines to the bounded-memory quantile sketch
//! (`sketch_alpha` tunes the relative-error bound, default 0.01), which
//! is what lets a 10⁸-request streamed run finish at flat RSS. Counts,
//! throughput, min/max, and conservation checks are identical in both
//! modes; sketch percentiles carry the configured relative error, and
//! window-scoped metrics (`burst_p99_ms`) are exact-only and omitted.
//!
//! A `cluster_sim` submission requesting an autoscaled spike study
//! (Fig 11c burst against a cold-starting fleet) looks like:
//!
//! ```yaml
//! name: resnet-spike-autoscale
//! task: cluster_sim
//! model: resnet50
//! platform: G1
//! software: tris
//! replicas: 2                  # initial fleet
//! router: least-outstanding    # or round-robin / power-of-two / latency-ewma
//! workload:
//!   rate: 120.0
//!   duration_s: 60
//!   burst:                     # optional spike window
//!     rate: 600.0
//!     start_s: 20
//!     duration_s: 10
//! batching:
//!   max_size: 8
//!   max_wait_ms: 2
//! autoscale:                   # optional; fixed fleet when omitted
//!   policy: queue-depth        # or utilization
//!   min_replicas: 2
//!   max_replicas: 8
//!   up: 8.0                    # outstanding/replica (or busy fraction)
//!   down: 1.0
//!   cooldown_s: 2.0
//!   eval_interval_s: 0.5
//! ```

use crate::hardware::{self, Parallelism};
use crate::metrics::MetricsMode;
use crate::models::catalog;
use crate::perfdb::Record;
use crate::pipeline::{Processors, RequestPath, LAN};
use crate::serving::cluster::{self, ClusterConfig, ReplicaConfig};
use crate::serving::multimodel::{
    self, ModelSpec as MmModelSpec, MultiModelConfig, MultiReplicaConfig,
};
use crate::serving::{
    self, backends, AdmissionConfig, AutoscaleConfig, DegradeProfile, FaultOp, FaultPlan,
    FaultProfile, Policy, RetryPolicy, RouterPolicy, ScalePolicy, ServiceModel, SimConfig,
    TenantSpec,
};
use crate::codec::{CodecKind, SpanFrame};
use crate::coordinator::distributed;
use crate::obs::{self, Detail, SampleSpec, TraceConfig};
use crate::sweep::SweepPlan;
use crate::util::json::Json;
use crate::util::yamlish;
use crate::workload::{Pattern, StreamSpec, Workload};
use anyhow::{anyhow, bail, Result};

/// What a worker should run.
#[derive(Debug, Clone, PartialEq)]
pub enum JobKind {
    /// Simulate a serving pipeline (software/pipeline tiers).
    ServingSim {
        model: String,
        platform: String,
        software: String,
        rate_rps: f64,
        duration_s: f64,
        max_batch: usize,
        max_wait_s: f64,
    },
    /// Simulate an N-replica serving cluster, optionally autoscaled —
    /// scale-out and spike studies submitted through the leader.
    ClusterSim {
        model: String,
        platform: String,
        software: String,
        /// Initial fleet size.
        replicas: usize,
        /// Router policy name: round-robin, least-outstanding,
        /// power-of-two, or latency-ewma.
        router: String,
        rate_rps: f64,
        duration_s: f64,
        /// Optional spike window on top of the base rate (Fig 11c).
        burst: Option<BurstSpec>,
        max_batch: usize,
        max_wait_s: f64,
        /// Optional elasticity; fixed fleet when absent.
        autoscale: Option<AutoscaleSpec>,
        /// Metrics backend (`scale:` knob): exact retention or the
        /// bounded-memory quantile sketch for long-horizon runs.
        metrics: MetricsMode,
        /// Optional per-tenant ingress control (`admission:` block). When
        /// present the offered rate is split evenly across the tenants,
        /// each becoming a tagged workload stream.
        admission: Option<AdmissionConfig>,
        /// Optional fault injection (`faults:` block): scripted or
        /// MTTF/MTTR-profile crashes, recoveries and stragglers.
        faults: Option<FaultPlan>,
        /// Optional retry policy (`retry:` block) for requests stranded
        /// on crashed replicas.
        retry: Option<RetryPolicy>,
    },
    /// Roofline sweep of a model across batch sizes (hardware tier).
    HardwareSweep { model: String, platform: String, batches: Vec<usize> },
    /// A grid of independent cluster simulations — router policies ×
    /// fleet sizes × batching timeouts, offered load scaled per replica —
    /// executed by the parallel sweep engine (`crate::sweep`) on the
    /// worker's `threads_per_worker` budget. Per-cell seeds derive from
    /// the job seed, so results are identical at any thread budget.
    Sweep {
        model: String,
        platform: String,
        software: String,
        /// Router policy names, one grid axis (same vocabulary as
        /// `cluster_sim`'s `router`).
        routers: Vec<String>,
        /// Fleet sizes, the second grid axis.
        replicas: Vec<usize>,
        /// Dynamic-batching timeouts (seconds), the batching-policy axis;
        /// a single-element list when the submission names no
        /// `batch_timeouts_ms`.
        batch_timeouts_s: Vec<f64>,
        /// Offered Poisson rate per replica (cells stay comparably
        /// loaded as the fleet axis grows).
        rate_per_replica: f64,
        duration_s: f64,
        max_batch: usize,
        /// Metrics backend (`scale:` knob), applied to every cell.
        metrics: MetricsMode,
        /// Optional per-tenant ingress control, applied to every cell
        /// (each cell's offered rate splits evenly across the tenants).
        admission: Option<AdmissionConfig>,
        /// Optional fault injection, applied to every cell — the plan's
        /// own seed fixes the schedule, so the grid axes are compared
        /// under identical faults.
        faults: Option<FaultPlan>,
        /// Optional retry policy, applied to every cell.
        retry: Option<RetryPolicy>,
        /// Shard the grid across this many followers through the
        /// distributed sweep engine (`coordinator::distributed`); `0` or
        /// `1` runs locally on the worker's thread budget. Results are
        /// bit-identical either way (PERF.md §Distributed sweeps).
        followers: usize,
        /// Wire codec for shard and result frames when `followers >= 2`.
        codec: CodecKind,
    },
    /// Multi-model replica serving (Sharing versus Dedicate, §3.3): one
    /// Poisson stream per model against a shared fleet (co-located under
    /// MPS contention and the weight-memory budget) or a dedicated fleet
    /// (one replica per model). One PerfDB record per model stream.
    MultiModel {
        platform: String,
        software: String,
        /// Catalog model names, one stream each.
        models: Vec<String>,
        /// Per-stream Poisson rates, index-aligned with `models`.
        rates: Vec<f64>,
        /// "shared" or "dedicated".
        mode: String,
        /// Shared fleet size (each replica hosts every model); ignored
        /// for `dedicated`, which always uses one replica per model.
        replicas: usize,
        /// Per-replica weight-memory budget (GB).
        mem_gb: f64,
        /// Router policy name, applied per model over its hosts.
        router: String,
        duration_s: f64,
        max_batch: usize,
        max_wait_s: f64,
        /// Metrics backend (`scale:` knob), applied per model stream.
        metrics: MetricsMode,
        /// Optional per-tenant ingress control; tenant i governs model
        /// stream i (the tenant list must match `models` in length).
        admission: Option<AdmissionConfig>,
        /// Optional fault injection across the fleet.
        faults: Option<FaultPlan>,
        /// Optional retry policy. Hedging is rejected at parse time:
        /// each model owns its routing domain, retries re-route within
        /// it.
        retry: Option<RetryPolicy>,
    },
    /// Do nothing for a fixed time (scheduler studies; time is scaled by
    /// the leader's `time_scale`).
    Sleep { seconds: f64 },
}

/// Burst window of a `cluster_sim` workload (spike load, Fig 11c).
#[derive(Debug, Clone, PartialEq)]
pub struct BurstSpec {
    pub rate_rps: f64,
    pub start_s: f64,
    pub duration_s: f64,
}

/// Autoscaling parameters of a `cluster_sim` submission.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleSpec {
    /// "queue-depth" or "utilization".
    pub policy: String,
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Scale-up threshold: outstanding per replica (queue-depth) or busy
    /// fraction in [0,1] (utilization).
    pub up: f64,
    /// Scale-down threshold, same units as `up`.
    pub down: f64,
    pub cooldown_s: f64,
    pub eval_interval_s: f64,
}

/// Parsed top-level `trace:` block — the deterministic tracing knobs of
/// a `cluster_sim`, `sweep`, or `multimodel` submission (see
/// [`crate::obs`] and the module docs for the YAML shape).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    pub config: TraceConfig,
    /// Write the run's spans and gauge timelines as Chrome-trace/Perfetto
    /// JSON here after the job completes (`ui.perfetto.dev` loads it).
    pub out: Option<String>,
}

/// A parsed benchmark submission.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub name: String,
    pub kind: JobKind,
    /// Scheduler's duration estimate (paper: processing times are known).
    pub est_duration_s: f64,
    /// Optional tracing block. Observational only: results are
    /// bit-identical whether it is present or absent.
    pub trace: Option<TraceSpec>,
}

impl JobSpec {
    /// Parse a YAML submission (see `examples/submissions/` for samples).
    pub fn parse_yaml(text: &str) -> Result<JobSpec> {
        let doc = yamlish::parse(text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<JobSpec> {
        let name = doc
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("unnamed")
            .to_string();
        let task = doc
            .get("task")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("submission missing 'task'"))?;
        let kind = match task {
            "serving_sim" => {
                reject_unknown_keys(doc, task, &["model", "platform", "software", "workload", "batching"])?;
                let wl = doc.get("workload");
                JobKind::ServingSim {
                    model: str_or(doc, "model", "resnet50"),
                    platform: str_or(doc, "platform", "G1"),
                    software: str_or(doc, "software", "tfs"),
                    rate_rps: wl.and_then(|w| w.get("rate")).and_then(|v| v.as_f64()).unwrap_or(30.0),
                    duration_s: wl
                        .and_then(|w| w.get("duration_s"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(60.0),
                    max_batch: doc
                        .get("batching")
                        .and_then(|b| b.get("max_size"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(8) as usize,
                    max_wait_s: doc
                        .get("batching")
                        .and_then(|b| b.get("max_wait_ms"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(5.0)
                        / 1e3,
                }
            }
            "cluster_sim" => {
                reject_unknown_keys(
                    doc,
                    task,
                    &["model", "platform", "software", "replicas", "router", "workload",
                      "batching", "autoscale", "scale", "sketch_alpha", "admission",
                      "faults", "retry", "trace"],
                )?;
                let wl = doc.get("workload");
                let burst = wl.and_then(|w| w.get("burst")).map(|b| BurstSpec {
                    rate_rps: b.get("rate").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    start_s: b.get("start_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    duration_s: b.get("duration_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
                });
                if let Some(b) = &burst {
                    if b.rate_rps <= 0.0 || b.duration_s <= 0.0 {
                        bail!("cluster_sim burst needs positive rate and duration_s");
                    }
                }
                let autoscale = doc.get("autoscale").map(|a| AutoscaleSpec {
                    policy: str_or(a, "policy", "queue-depth"),
                    min_replicas: a
                        .get("min_replicas")
                        .and_then(|v| v.as_i64())
                        .unwrap_or(1)
                        .max(1) as usize,
                    max_replicas: a
                        .get("max_replicas")
                        .and_then(|v| v.as_i64())
                        .unwrap_or(8)
                        .max(1) as usize,
                    up: a.get("up").and_then(|v| v.as_f64()).unwrap_or(8.0),
                    down: a.get("down").and_then(|v| v.as_f64()).unwrap_or(1.0),
                    cooldown_s: a.get("cooldown_s").and_then(|v| v.as_f64()).unwrap_or(2.0),
                    eval_interval_s: a
                        .get("eval_interval_s")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.5),
                });
                JobKind::ClusterSim {
                    model: str_or(doc, "model", "resnet50"),
                    platform: str_or(doc, "platform", "G1"),
                    software: str_or(doc, "software", "tfs"),
                    replicas: doc
                        .get("replicas")
                        .and_then(|v| v.as_i64())
                        .unwrap_or(2)
                        .max(1) as usize,
                    router: str_or(doc, "router", "least-outstanding"),
                    rate_rps: wl
                        .and_then(|w| w.get("rate"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(60.0),
                    duration_s: wl
                        .and_then(|w| w.get("duration_s"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(60.0),
                    burst,
                    max_batch: doc
                        .get("batching")
                        .and_then(|b| b.get("max_size"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(8) as usize,
                    max_wait_s: doc
                        .get("batching")
                        .and_then(|b| b.get("max_wait_ms"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(5.0)
                        / 1e3,
                    autoscale,
                    metrics: scale_mode(doc)?,
                    admission: admission_spec(doc)?,
                    faults: faults_spec(doc)?,
                    retry: retry_spec(doc)?,
                }
            }
            "hardware_sweep" => {
                reject_unknown_keys(doc, task, &["model", "platform", "batches"])?;
                JobKind::HardwareSweep {
                    model: str_or(doc, "model", "resnet50"),
                    platform: str_or(doc, "platform", "G1"),
                    batches: doc
                        .get("batches")
                        .and_then(|v| v.as_arr())
                        .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|i| i as usize).collect())
                        .unwrap_or_else(|| vec![1, 2, 4, 8, 16, 32]),
                }
            }
            "sweep" => {
                reject_unknown_keys(
                    doc,
                    task,
                    &["model", "platform", "software", "routers", "replicas",
                      "batch_timeouts_ms", "workload", "batching", "scale", "sketch_alpha",
                      "admission", "faults", "retry", "followers", "codec", "trace"],
                )?;
                let wl = doc.get("workload");
                let routers: Vec<String> = match doc.get("routers").and_then(|v| v.as_arr()) {
                    Some(a) => {
                        // Same contract as `replicas` below: a bad entry
                        // fails the submission instead of silently
                        // shrinking the grid (yamlish types unquoted
                        // scalars, so a numeric/bool-looking entry is
                        // not a string).
                        let mut out = Vec::with_capacity(a.len());
                        for x in a {
                            match x.as_str() {
                                Some(s) => out.push(s.to_string()),
                                None => bail!("sweep 'routers' entries must be strings"),
                            }
                        }
                        out
                    }
                    None => vec!["round-robin".to_string(), "least-outstanding".to_string()],
                };
                let replicas: Vec<usize> = match doc.get("replicas").and_then(|v| v.as_arr()) {
                    Some(a) => {
                        // Reject bad entries loudly: silently dropping a
                        // `0` or a typo would shrink the grid and produce
                        // fewer PerfDB records than the submission asked
                        // for, with no error anywhere.
                        let mut out = Vec::with_capacity(a.len());
                        for x in a {
                            match x.as_i64() {
                                Some(i) if i > 0 => out.push(i as usize),
                                _ => bail!("sweep 'replicas' entries must be positive integers"),
                            }
                        }
                        out
                    }
                    None => vec![1, 2, 4],
                };
                let default_wait_s = doc
                    .get("batching")
                    .and_then(|b| b.get("max_wait_ms"))
                    .and_then(|v| v.as_f64())
                    .unwrap_or(5.0)
                    / 1e3;
                let batch_timeouts_s: Vec<f64> =
                    match doc.get("batch_timeouts_ms").and_then(|v| v.as_arr()) {
                        Some(a) => {
                            // Same loud-failure contract as the other two
                            // axes: one malformed timeout fails the whole
                            // submission, never a silently smaller grid.
                            let mut out = Vec::with_capacity(a.len());
                            for x in a {
                                match x.as_f64() {
                                    Some(t) if t > 0.0 => out.push(t / 1e3),
                                    _ => bail!(
                                        "sweep 'batch_timeouts_ms' entries must be positive numbers"
                                    ),
                                }
                            }
                            out
                        }
                        None => vec![default_wait_s],
                    };
                if routers.is_empty() || replicas.is_empty() || batch_timeouts_s.is_empty() {
                    bail!(
                        "sweep needs non-empty 'routers', 'replicas', and 'batch_timeouts_ms' lists"
                    );
                }
                let followers = match doc.get("followers") {
                    None => 0,
                    Some(v) => match v.as_i64() {
                        Some(n) if n >= 0 => n as usize,
                        _ => bail!("sweep 'followers' must be a non-negative integer"),
                    },
                };
                let codec = match doc.get("codec") {
                    None => CodecKind::Binary,
                    Some(v) => match v.as_str() {
                        Some("binary") => CodecKind::Binary,
                        Some("jsonl") => CodecKind::JsonLines,
                        _ => bail!("sweep 'codec' must be 'binary' or 'jsonl'"),
                    },
                };
                JobKind::Sweep {
                    model: str_or(doc, "model", "resnet50"),
                    platform: str_or(doc, "platform", "G1"),
                    software: str_or(doc, "software", "tris"),
                    routers,
                    replicas,
                    batch_timeouts_s,
                    rate_per_replica: wl
                        .and_then(|w| w.get("rate_per_replica"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(120.0),
                    duration_s: wl
                        .and_then(|w| w.get("duration_s"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(30.0),
                    max_batch: doc
                        .get("batching")
                        .and_then(|b| b.get("max_size"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(8) as usize,
                    metrics: scale_mode(doc)?,
                    admission: admission_spec(doc)?,
                    faults: faults_spec(doc)?,
                    retry: retry_spec(doc)?,
                    followers,
                    codec,
                }
            }
            "multimodel" => {
                reject_unknown_keys(
                    doc,
                    task,
                    &["platform", "software", "models", "rates", "mode", "replicas", "mem_gb",
                      "router", "workload", "batching", "scale", "sketch_alpha", "admission",
                      "faults", "retry", "trace"],
                )?;
                let wl = doc.get("workload");
                let models: Vec<String> = match doc.get("models").and_then(|v| v.as_arr()) {
                    Some(a) => {
                        let mut out = Vec::with_capacity(a.len());
                        for x in a {
                            match x.as_str() {
                                Some(s) => out.push(s.to_string()),
                                None => bail!("multimodel 'models' entries must be strings"),
                            }
                        }
                        out
                    }
                    None => bail!("multimodel needs a 'models' list"),
                };
                if models.is_empty() {
                    bail!("multimodel 'models' list must be non-empty");
                }
                let rates: Vec<f64> = match doc.get("rates").and_then(|v| v.as_arr()) {
                    Some(a) => {
                        let mut out = Vec::with_capacity(a.len());
                        for x in a {
                            match x.as_f64() {
                                Some(r) if r > 0.0 => out.push(r),
                                _ => bail!("multimodel 'rates' entries must be positive numbers"),
                            }
                        }
                        out
                    }
                    None => models.iter().map(|_| 60.0).collect(),
                };
                if rates.len() != models.len() {
                    bail!(
                        "multimodel 'rates' must match 'models' ({} rates vs {} models)",
                        rates.len(),
                        models.len()
                    );
                }
                // Tenant i governs model stream i; a count mismatch is a
                // submission error, caught before any worker runs it.
                let admission = admission_spec(doc)?;
                if let Some(a) = &admission {
                    if a.tenants.len() != models.len() {
                        bail!(
                            "multimodel admission defines {} tenants but there are {} models",
                            a.tenants.len(),
                            models.len()
                        );
                    }
                }
                // Hedging duplicates a retry across replicas of one
                // routing domain; multimodel retries re-route within the
                // crashed model's own hosts instead, so a hedge request
                // would silently do nothing — reject it loudly.
                let retry = retry_spec(doc)?;
                if let Some(r) = &retry {
                    if r.hedge {
                        bail!("multimodel retry does not support 'hedge' (retries re-route within the model's hosts)");
                    }
                }
                JobKind::MultiModel {
                    platform: str_or(doc, "platform", "G1"),
                    software: str_or(doc, "software", "tris"),
                    models,
                    rates,
                    mode: str_or(doc, "mode", "shared"),
                    replicas: doc.get("replicas").and_then(|v| v.as_i64()).unwrap_or(1).max(1)
                        as usize,
                    mem_gb: doc.get("mem_gb").and_then(|v| v.as_f64()).unwrap_or(16.0),
                    router: str_or(doc, "router", "least-outstanding"),
                    duration_s: wl
                        .and_then(|w| w.get("duration_s"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(30.0),
                    max_batch: doc
                        .get("batching")
                        .and_then(|b| b.get("max_size"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(8) as usize,
                    max_wait_s: doc
                        .get("batching")
                        .and_then(|b| b.get("max_wait_ms"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(5.0)
                        / 1e3,
                    metrics: scale_mode(doc)?,
                    admission,
                    faults: faults_spec(doc)?,
                    retry,
                }
            }
            "sleep" => {
                reject_unknown_keys(doc, task, &["seconds"])?;
                JobKind::Sleep {
                    seconds: doc.get("seconds").and_then(|v| v.as_f64()).unwrap_or(1.0),
                }
            }
            other => bail!("unknown task kind {other:?}"),
        };
        let est = doc
            .get("est_duration_s")
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| default_estimate(&kind));
        Ok(JobSpec { name, kind, est_duration_s: est, trace: trace_spec(doc)? })
    }
}

fn str_or(doc: &Json, key: &str, default: &str) -> String {
    doc.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
}

/// Parse the top-level `scale:` knob into a [`MetricsMode`]. Absent means
/// exact; `sketch` reads the optional `sketch_alpha` relative-error bound.
/// Unknown names fail the submission loudly.
fn scale_mode(doc: &Json) -> Result<MetricsMode> {
    match doc.get("scale").and_then(|v| v.as_str()) {
        None | Some("exact") => Ok(MetricsMode::Exact),
        Some("sketch") => {
            let alpha = doc.get("sketch_alpha").and_then(|v| v.as_f64()).unwrap_or(0.01);
            if !(alpha > 0.0 && alpha < 1.0) {
                bail!("sketch_alpha must be in (0, 1), got {alpha}");
            }
            Ok(MetricsMode::Sketch { alpha })
        }
        Some(other) => bail!("scale must be 'exact' or 'sketch', got {other:?}"),
    }
}

/// Keys every submission may carry regardless of task.
const COMMON_KEYS: [&str; 3] = ["name", "task", "est_duration_s"];

/// Reject unknown top-level keys loudly. A typo'd key (`replcas: 3`)
/// would otherwise fall back to a default and run a different benchmark
/// than the one submitted, with no error anywhere — the same silent-shrink
/// hazard the grid axes guard against, one level up.
fn reject_unknown_keys(doc: &Json, task: &str, allowed: &[&str]) -> Result<()> {
    let Some(map) = doc.as_obj() else { return Ok(()) };
    for key in map.keys() {
        if !COMMON_KEYS.contains(&key.as_str()) && !allowed.contains(&key.as_str()) {
            bail!(
                "unknown key {key:?} in a {task:?} submission (accepted: {})",
                allowed.join(", ")
            );
        }
    }
    Ok(())
}

/// Parse the optional top-level `admission:` block into an
/// [`AdmissionConfig`]. Shape errors fail the submission loudly here; the
/// engines re-validate tenant count against the workload's streams.
///
/// ```yaml
/// admission:
///   shed_depth: [600, 200, 60]   # in-system cap per class, class 0 first
///   tenants:
///     - name: gold
///       class: 0
///       weight: 4.0              # WFQ share of held-queue release
///     - name: bronze
///       class: 2
///       rate: 50.0               # token-bucket rate limit (rps)
///       burst: 10.0              # bucket depth (tokens)
/// ```
fn admission_spec(doc: &Json) -> Result<Option<AdmissionConfig>> {
    let Some(block) = doc.get("admission") else { return Ok(None) };
    let shed_depth: Vec<usize> = match block.get("shed_depth").and_then(|v| v.as_arr()) {
        Some(a) if !a.is_empty() => {
            let mut out = Vec::with_capacity(a.len());
            for x in a {
                match x.as_i64() {
                    Some(d) if d > 0 => out.push(d as usize),
                    _ => bail!("admission 'shed_depth' entries must be positive integers"),
                }
            }
            out
        }
        _ => bail!("admission needs a non-empty 'shed_depth' list (one depth per class)"),
    };
    let tenants_json = block
        .get("tenants")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("admission needs a 'tenants' list"))?;
    if tenants_json.is_empty() {
        bail!("admission 'tenants' list must be non-empty");
    }
    let mut tenants = Vec::with_capacity(tenants_json.len());
    for (i, t) in tenants_json.iter().enumerate() {
        if let Some(map) = t.as_obj() {
            for key in map.keys() {
                if !["name", "class", "weight", "rate", "burst"].contains(&key.as_str()) {
                    bail!("unknown key {key:?} in admission tenant {i} (accepted: name, class, weight, rate, burst)");
                }
            }
        }
        let name = t
            .get("name")
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .unwrap_or_else(|| format!("tenant{i}"));
        let class = match t.get("class").and_then(|v| v.as_i64()).unwrap_or(0) {
            c if c >= 0 && (c as usize) < shed_depth.len() => c as u8,
            c => bail!("admission tenant {name:?}: class {c} has no shed_depth entry"),
        };
        let weight = t.get("weight").and_then(|v| v.as_f64()).unwrap_or(1.0);
        if !(weight > 0.0) {
            bail!("admission tenant {name:?}: weight must be positive, got {weight}");
        }
        let mut spec = TenantSpec::new(name.clone()).with_class(class).with_weight(weight);
        match t.get("rate").and_then(|v| v.as_f64()) {
            Some(rate) if rate > 0.0 => {
                let burst = t.get("burst").and_then(|v| v.as_f64()).unwrap_or(1.0);
                if !(burst >= 1.0) {
                    bail!("admission tenant {name:?}: burst must be >= 1 token, got {burst}");
                }
                spec = spec.with_rate(rate, burst);
            }
            Some(rate) => bail!("admission tenant {name:?}: rate must be positive, got {rate}"),
            None => {
                if t.get("burst").is_some() {
                    bail!("admission tenant {name:?}: burst without a rate has no effect");
                }
            }
        }
        tenants.push(spec);
    }
    Ok(Some(AdmissionConfig { tenants, shed_depth }))
}

/// Parse the optional top-level `faults:` block into a [`FaultPlan`].
/// Shape and value errors fail the submission loudly here, mirroring
/// `FaultPlan::validate` — which would otherwise panic inside a worker
/// thread instead of failing the parse.
fn faults_spec(doc: &Json) -> Result<Option<FaultPlan>> {
    let Some(block) = doc.get("faults") else { return Ok(None) };
    if let Some(map) = block.as_obj() {
        for key in map.keys() {
            if !["script", "profile", "seed", "recovery_gb"].contains(&key.as_str()) {
                bail!(
                    "unknown key {key:?} in faults (accepted: script, profile, seed, recovery_gb)"
                );
            }
        }
    }
    let mut script = Vec::new();
    if let Some(ops) = block.get("script").and_then(|v| v.as_arr()) {
        for (i, op) in ops.iter().enumerate() {
            if let Some(map) = op.as_obj() {
                for key in map.keys() {
                    if !["op", "replica", "at_s", "until_s", "factor"].contains(&key.as_str()) {
                        bail!(
                            "unknown key {key:?} in faults script op {i} \
                             (accepted: op, replica, at_s, until_s, factor)"
                        );
                    }
                }
            }
            let kind = op.get("op").and_then(|v| v.as_str()).ok_or_else(|| {
                anyhow!("faults script op {i} needs an 'op' (crash, recover, or degrade)")
            })?;
            let replica = match op.get("replica").and_then(|v| v.as_i64()) {
                Some(r) if r >= 0 => r as usize,
                _ => bail!("faults script op {i} needs a non-negative 'replica' index"),
            };
            let at_s = match op.get("at_s").and_then(|v| v.as_f64()) {
                Some(t) if t >= 0.0 => t,
                _ => bail!("faults script op {i} needs 'at_s' >= 0"),
            };
            script.push(match kind {
                "crash" => FaultOp::Crash { replica, at_s },
                "recover" => FaultOp::Recover { replica, at_s },
                "degrade" => {
                    let until_s = match op.get("until_s").and_then(|v| v.as_f64()) {
                        Some(t) if t > at_s => t,
                        _ => bail!("faults degrade op {i} needs 'until_s' > at_s"),
                    };
                    let factor = match op.get("factor").and_then(|v| v.as_f64()) {
                        Some(f) if f >= 1.0 => f,
                        _ => bail!(
                            "faults degrade op {i} needs 'factor' >= 1.0 (slowdowns only)"
                        ),
                    };
                    FaultOp::Degrade { replica, at_s, until_s, factor }
                }
                other => bail!(
                    "faults script op {i}: unknown op {other:?} (crash, recover, or degrade)"
                ),
            });
        }
    }
    let profile = match block.get("profile") {
        None => None,
        Some(p) => {
            if let Some(map) = p.as_obj() {
                for key in map.keys() {
                    if !["mttf_s", "mttr_s", "degrade"].contains(&key.as_str()) {
                        bail!(
                            "unknown key {key:?} in faults profile \
                             (accepted: mttf_s, mttr_s, degrade)"
                        );
                    }
                }
            }
            let mttf_s = match p.get("mttf_s").and_then(|v| v.as_f64()) {
                Some(t) if t > 0.0 => t,
                _ => bail!("faults profile needs 'mttf_s' > 0"),
            };
            let mttr_s = match p.get("mttr_s").and_then(|v| v.as_f64()) {
                Some(t) if t > 0.0 => t,
                _ => bail!("faults profile needs 'mttr_s' > 0"),
            };
            let degrade = match p.get("degrade") {
                None => None,
                Some(d) => {
                    if let Some(map) = d.as_obj() {
                        for key in map.keys() {
                            if !["mtbd_s", "duration_s", "factor"].contains(&key.as_str()) {
                                bail!(
                                    "unknown key {key:?} in faults degrade \
                                     (accepted: mtbd_s, duration_s, factor)"
                                );
                            }
                        }
                    }
                    let mtbd_s = match d.get("mtbd_s").and_then(|v| v.as_f64()) {
                        Some(t) if t > 0.0 => t,
                        _ => bail!("faults degrade needs 'mtbd_s' > 0"),
                    };
                    let duration_s = match d.get("duration_s").and_then(|v| v.as_f64()) {
                        Some(t) if t > 0.0 => t,
                        _ => bail!("faults degrade needs 'duration_s' > 0"),
                    };
                    let factor = match d.get("factor").and_then(|v| v.as_f64()) {
                        Some(f) if f >= 1.0 => f,
                        _ => bail!("faults degrade needs 'factor' >= 1.0 (slowdowns only)"),
                    };
                    Some(DegradeProfile { mtbd_s, duration_s, factor })
                }
            };
            Some(FaultProfile { mttf_s, mttr_s, degrade })
        }
    };
    if script.is_empty() && profile.is_none() {
        bail!("faults needs a 'script' list or a 'profile' (an empty block injects nothing)");
    }
    let seed = match block.get("seed").and_then(|v| v.as_i64()) {
        Some(s) if s >= 0 => s as u64,
        Some(s) => bail!("faults seed must be non-negative, got {s}"),
        None => 0,
    };
    let recovery_bytes = match block.get("recovery_gb").and_then(|v| v.as_f64()) {
        Some(g) if g > 0.0 => (g * 1e9) as u64,
        Some(g) => bail!("faults recovery_gb must be positive, got {g}"),
        None => 0, // engines fall back to their configured cold-start size
    };
    Ok(Some(FaultPlan { script, profile, seed, recovery_bytes }))
}

/// Parse the optional top-level `retry:` block into a [`RetryPolicy`].
/// Defaults mirror `RetryPolicy::new`: 3 attempts, a 10 s per-request
/// deadline, a 50 ms first backoff that doubles per retry (capped at
/// 16x). `hedge` is opt-in; the multimodel arm rejects it separately.
fn retry_spec(doc: &Json) -> Result<Option<RetryPolicy>> {
    let Some(block) = doc.get("retry") else { return Ok(None) };
    if let Some(map) = block.as_obj() {
        for key in map.keys() {
            if !["max_attempts", "deadline_s", "backoff_ms", "hedge"].contains(&key.as_str()) {
                bail!(
                    "unknown key {key:?} in retry \
                     (accepted: max_attempts, deadline_s, backoff_ms, hedge)"
                );
            }
        }
    }
    let max_attempts = match block.get("max_attempts").and_then(|v| v.as_i64()) {
        Some(n) if n >= 1 => n as u32,
        Some(n) => bail!("retry max_attempts must be >= 1, got {n}"),
        None => 3,
    };
    let deadline_s = match block.get("deadline_s").and_then(|v| v.as_f64()) {
        Some(t) if t > 0.0 => t,
        Some(t) => bail!("retry deadline_s must be positive, got {t}"),
        None => 10.0,
    };
    let backoff_s = match block.get("backoff_ms").and_then(|v| v.as_f64()) {
        Some(t) if t > 0.0 => t / 1e3,
        Some(t) => bail!("retry backoff_ms must be positive, got {t}"),
        None => 0.05,
    };
    let mut policy = RetryPolicy::new(max_attempts, deadline_s, backoff_s);
    if let Some(h) = block.get("hedge") {
        match h.as_bool() {
            Some(true) => policy = policy.with_hedge(),
            Some(false) => {}
            None => bail!("retry hedge must be a boolean"),
        }
    }
    Ok(Some(policy))
}

/// Parse the optional top-level `trace:` block into a [`TraceSpec`]
/// (see the module docs for the YAML shape). Defaults are
/// [`TraceConfig::full`] — every request sampled at full detail, gauges
/// on a 100 ms grid — so a bare `trace:` block with only `out:` already
/// produces a complete export. Head-sampling is a pure function of the
/// request id, so any `sample`/`every_nth` choice is deterministic.
fn trace_spec(doc: &Json) -> Result<Option<TraceSpec>> {
    let Some(block) = doc.get("trace") else { return Ok(None) };
    if let Some(map) = block.as_obj() {
        for key in map.keys() {
            if !["sample", "every_nth", "detail", "gauge_interval_ms", "gauge_cap", "max_spans",
                 "out"]
                .contains(&key.as_str())
            {
                bail!(
                    "unknown key {key:?} in trace (accepted: sample, every_nth, detail, \
                     gauge_interval_ms, gauge_cap, max_spans, out)"
                );
            }
        }
    }
    let mut config = TraceConfig::full();
    if block.get("sample").is_some() && block.get("every_nth").is_some() {
        bail!("trace takes 'sample' or 'every_nth', not both");
    }
    if let Some(s) = block.get("sample") {
        config.sample = match (s.as_str(), s.as_f64()) {
            (Some("off"), _) => SampleSpec::Off,
            (Some("all"), _) => SampleSpec::All,
            (None, Some(p)) if p > 0.0 && p < 1.0 => SampleSpec::Rate(p),
            (None, Some(p)) if p == 1.0 => SampleSpec::All,
            _ => bail!("trace sample must be 'off', 'all', or a fraction in (0, 1]"),
        };
    }
    if let Some(n) = block.get("every_nth") {
        config.sample = match n.as_i64() {
            Some(n) if n >= 1 => SampleSpec::EveryNth(n as u64),
            _ => bail!("trace every_nth must be a positive integer"),
        };
    }
    if let Some(d) = block.get("detail") {
        config.detail = match d.as_str() {
            Some("stages") => Detail::Stages,
            Some("full") => Detail::Full,
            _ => bail!("trace detail must be 'stages' or 'full'"),
        };
    }
    if let Some(g) = block.get("gauge_interval_ms") {
        config.gauge_interval_s = match g.as_f64() {
            Some(ms) if ms > 0.0 => Some(ms / 1e3),
            Some(ms) if ms == 0.0 => None, // 0 disables the timelines
            _ => bail!("trace gauge_interval_ms must be a non-negative number"),
        };
    }
    if let Some(c) = block.get("gauge_cap") {
        config.gauge_cap = match c.as_i64() {
            Some(n) if n >= 1 => n as usize,
            _ => bail!("trace gauge_cap must be a positive integer"),
        };
    }
    if let Some(m) = block.get("max_spans") {
        config.max_spans = match m.as_i64() {
            Some(n) if n >= 1 => n as usize,
            _ => bail!("trace max_spans must be a positive integer"),
        };
    }
    let out = match block.get("out") {
        None => None,
        Some(p) => match p.as_str() {
            Some(path) if !path.is_empty() => Some(path.to_string()),
            _ => bail!("trace out must be a non-empty path string"),
        },
    };
    Ok(Some(TraceSpec { config, out }))
}

/// Split the offered pattern evenly across admission tenants, one tagged
/// stream per tenant — how `cluster_sim` and `sweep` submissions (a
/// single offered rate) meet the ingress tier's tenant-tagged workload
/// requirement. Stream i carries tenant i's class/weight tags.
fn split_streams(adm: &AdmissionConfig, pattern: &Pattern) -> Vec<StreamSpec> {
    let n = adm.tenants.len() as f64;
    adm.tenants
        .iter()
        .map(|t| {
            let share = match *pattern {
                Pattern::Poisson { rate } => Pattern::Poisson { rate: rate / n },
                Pattern::Spike { base_rate, burst_rate, start_s, duration_s } => Pattern::Spike {
                    base_rate: base_rate / n,
                    burst_rate: burst_rate / n,
                    start_s,
                    duration_s,
                },
                ref p => p.clone(),
            };
            StreamSpec::new(t.name.clone(), share).with_qos(t.class, t.weight)
        })
        .collect()
}

fn u64_json(x: u64) -> Json {
    if x <= i64::MAX as u64 {
        Json::Int(x as i64)
    } else {
        // JSON integers top out at i64 here; full-width u64s (PCG seeds,
        // byte counts) ride as decimal strings.
        Json::Str(x.to_string())
    }
}

fn json_u64(v: &Json, what: &str) -> Result<u64> {
    if let Some(i) = v.as_i64() {
        return u64::try_from(i).map_err(|_| anyhow!("{what} must be non-negative, got {i}"));
    }
    if let Some(s) = v.as_str() {
        return s.parse::<u64>().map_err(|_| anyhow!("{what}: unparseable u64 string {s:?}"));
    }
    bail!("{what} must be a u64")
}

/// Serialize a `JobKind::Sweep` into the self-contained grid doc that
/// rides inside a distributed-sweep shard frame (`codec::ShardAssignment`).
///
/// The doc carries the *parsed* field values (timeouts in seconds, retry
/// backoff in seconds, fault recovery in bytes) rather than the YAML
/// submission shape, so no unit conversion happens on the wire and
/// [`sweep_kind_from_grid_doc`] rebuilds the kind **exactly** — the
/// follower's plan is field-for-field the leader's plan, which is what
/// makes re-queued cells bit-identical. `followers`/`codec` are not
/// carried: a follower always runs its shard locally.
///
/// Panics on a non-sweep kind (programmer error — only the distributed
/// engine builds grid docs).
pub fn sweep_grid_doc(kind: &JobKind) -> Json {
    let JobKind::Sweep {
        model,
        platform,
        software,
        routers,
        replicas,
        batch_timeouts_s,
        rate_per_replica,
        duration_s,
        max_batch,
        metrics,
        admission,
        faults,
        retry,
        followers: _,
        codec: _,
    } = kind
    else {
        panic!("sweep_grid_doc on a non-sweep job kind");
    };
    let mut doc = Json::obj();
    doc.set("model", Json::Str(model.clone()));
    doc.set("platform", Json::Str(platform.clone()));
    doc.set("software", Json::Str(software.clone()));
    doc.set("routers", Json::Arr(routers.iter().map(|r| Json::Str(r.clone())).collect()));
    doc.set("replicas", Json::Arr(replicas.iter().map(|&n| Json::Int(n as i64)).collect()));
    doc.set(
        "batch_timeouts_s",
        Json::Arr(batch_timeouts_s.iter().map(|&t| Json::Num(t)).collect()),
    );
    doc.set("rate_per_replica", Json::Num(*rate_per_replica));
    doc.set("duration_s", Json::Num(*duration_s));
    doc.set("max_batch", Json::Int(*max_batch as i64));
    let mut m = Json::obj();
    match metrics {
        MetricsMode::Exact => {
            m.set("mode", Json::Str("exact".into()));
        }
        MetricsMode::Sketch { alpha } => {
            m.set("mode", Json::Str("sketch".into()));
            m.set("alpha", Json::Num(*alpha));
        }
    }
    doc.set("metrics", m);
    if let Some(adm) = admission {
        let mut a = Json::obj();
        a.set(
            "shed_depth",
            Json::Arr(adm.shed_depth.iter().map(|&d| Json::Int(d as i64)).collect()),
        );
        a.set(
            "tenants",
            Json::Arr(
                adm.tenants
                    .iter()
                    .map(|t| {
                        let mut o = Json::obj();
                        o.set("name", Json::Str(t.name.clone()));
                        o.set("class", Json::Int(t.class as i64));
                        o.set("weight", Json::Num(t.weight));
                        if let Some(rate) = t.rate {
                            o.set("rate", Json::Num(rate));
                        }
                        o.set("burst", Json::Num(t.burst));
                        o
                    })
                    .collect(),
            ),
        );
        doc.set("admission", a);
    }
    if let Some(plan) = faults {
        let mut f = Json::obj();
        f.set(
            "script",
            Json::Arr(
                plan.script
                    .iter()
                    .map(|op| {
                        let mut o = Json::obj();
                        match *op {
                            FaultOp::Crash { replica, at_s } => {
                                o.set("op", Json::Str("crash".into()));
                                o.set("replica", Json::Int(replica as i64));
                                o.set("at_s", Json::Num(at_s));
                            }
                            FaultOp::Recover { replica, at_s } => {
                                o.set("op", Json::Str("recover".into()));
                                o.set("replica", Json::Int(replica as i64));
                                o.set("at_s", Json::Num(at_s));
                            }
                            FaultOp::Degrade { replica, at_s, until_s, factor } => {
                                o.set("op", Json::Str("degrade".into()));
                                o.set("replica", Json::Int(replica as i64));
                                o.set("at_s", Json::Num(at_s));
                                o.set("until_s", Json::Num(until_s));
                                o.set("factor", Json::Num(factor));
                            }
                        }
                        o
                    })
                    .collect(),
            ),
        );
        if let Some(p) = &plan.profile {
            let mut pj = Json::obj();
            pj.set("mttf_s", Json::Num(p.mttf_s));
            pj.set("mttr_s", Json::Num(p.mttr_s));
            if let Some(d) = &p.degrade {
                let mut dj = Json::obj();
                dj.set("mtbd_s", Json::Num(d.mtbd_s));
                dj.set("duration_s", Json::Num(d.duration_s));
                dj.set("factor", Json::Num(d.factor));
                pj.set("degrade", dj);
            }
            f.set("profile", pj);
        }
        f.set("seed", u64_json(plan.seed));
        f.set("recovery_bytes", u64_json(plan.recovery_bytes));
        doc.set("faults", f);
    }
    if let Some(rp) = retry {
        let mut r = Json::obj();
        r.set("max_attempts", Json::Int(rp.max_attempts as i64));
        r.set("deadline_s", Json::Num(rp.deadline_s));
        r.set("backoff_s", Json::Num(rp.backoff_s));
        r.set("backoff_cap_s", Json::Num(rp.backoff_cap_s));
        r.set("hedge", Json::Bool(rp.hedge));
        doc.set("retry", r);
    }
    doc
}

/// Rebuild a `JobKind::Sweep` from a grid doc ([`sweep_grid_doc`]) —
/// the follower side of a shard assignment. Exact inverse: every field
/// round-trips value-for-value (floats bit-for-bit; the JSON writer uses
/// shortest-roundtrip formatting and the binary codec embeds that same
/// text). Missing or mistyped fields fail loudly — a malformed grid doc
/// means wire corruption the codec's structural checks cannot see.
pub fn sweep_kind_from_grid_doc(doc: &Json) -> Result<JobKind> {
    fn need<'a>(doc: &'a Json, key: &str) -> Result<&'a Json> {
        doc.get(key).ok_or_else(|| anyhow!("grid doc missing {key:?}"))
    }
    fn need_str(doc: &Json, key: &str) -> Result<String> {
        need(doc, key)?
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| anyhow!("grid doc {key:?} must be a string"))
    }
    fn need_f64(doc: &Json, key: &str) -> Result<f64> {
        need(doc, key)?.as_f64().ok_or_else(|| anyhow!("grid doc {key:?} must be a number"))
    }
    fn need_usize(doc: &Json, key: &str) -> Result<usize> {
        match need(doc, key)?.as_i64() {
            Some(n) if n >= 0 => Ok(n as usize),
            _ => bail!("grid doc {key:?} must be a non-negative integer"),
        }
    }
    let routers = need(doc, "routers")?
        .as_arr()
        .ok_or_else(|| anyhow!("grid doc 'routers' must be an array"))?
        .iter()
        .map(|v| v.as_str().map(str::to_string))
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| anyhow!("grid doc 'routers' entries must be strings"))?;
    let replicas = need(doc, "replicas")?
        .as_arr()
        .ok_or_else(|| anyhow!("grid doc 'replicas' must be an array"))?
        .iter()
        .map(|v| v.as_i64().filter(|&n| n > 0).map(|n| n as usize))
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| anyhow!("grid doc 'replicas' entries must be positive integers"))?;
    let batch_timeouts_s = need(doc, "batch_timeouts_s")?
        .as_arr()
        .ok_or_else(|| anyhow!("grid doc 'batch_timeouts_s' must be an array"))?
        .iter()
        .map(|v| v.as_f64().filter(|&t| t > 0.0))
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| anyhow!("grid doc 'batch_timeouts_s' entries must be positive numbers"))?;
    if routers.is_empty() || replicas.is_empty() || batch_timeouts_s.is_empty() {
        bail!("grid doc axes must be non-empty");
    }
    let metrics = {
        let m = need(doc, "metrics")?;
        match m.get("mode").and_then(|v| v.as_str()) {
            Some("exact") => MetricsMode::Exact,
            Some("sketch") => {
                let alpha = need_f64(m, "alpha")?;
                if !(alpha > 0.0 && alpha < 1.0) {
                    bail!("grid doc sketch alpha must be in (0, 1), got {alpha}");
                }
                MetricsMode::Sketch { alpha }
            }
            _ => bail!("grid doc 'metrics.mode' must be 'exact' or 'sketch'"),
        }
    };
    let admission = match doc.get("admission") {
        None => None,
        Some(a) => {
            let shed_depth = need(a, "shed_depth")?
                .as_arr()
                .ok_or_else(|| anyhow!("grid doc 'admission.shed_depth' must be an array"))?
                .iter()
                .map(|v| v.as_i64().filter(|&d| d > 0).map(|d| d as usize))
                .collect::<Option<Vec<_>>>()
                .ok_or_else(|| anyhow!("grid doc shed_depth entries must be positive"))?;
            let tenants = need(a, "tenants")?
                .as_arr()
                .ok_or_else(|| anyhow!("grid doc 'admission.tenants' must be an array"))?
                .iter()
                .map(|t| -> Result<TenantSpec> {
                    Ok(TenantSpec {
                        name: need_str(t, "name")?,
                        class: u8::try_from(need_usize(t, "class")?)
                            .map_err(|_| anyhow!("grid doc tenant class exceeds u8"))?,
                        weight: need_f64(t, "weight")?,
                        rate: match t.get("rate") {
                            None => None,
                            Some(v) => Some(
                                v.as_f64()
                                    .ok_or_else(|| anyhow!("grid doc tenant rate must be a number"))?,
                            ),
                        },
                        burst: need_f64(t, "burst")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            Some(AdmissionConfig { tenants, shed_depth })
        }
    };
    let faults = match doc.get("faults") {
        None => None,
        Some(f) => {
            let script = need(f, "script")?
                .as_arr()
                .ok_or_else(|| anyhow!("grid doc 'faults.script' must be an array"))?
                .iter()
                .map(|op| -> Result<FaultOp> {
                    let replica = need_usize(op, "replica")?;
                    let at_s = need_f64(op, "at_s")?;
                    Ok(match op.get("op").and_then(|v| v.as_str()) {
                        Some("crash") => FaultOp::Crash { replica, at_s },
                        Some("recover") => FaultOp::Recover { replica, at_s },
                        Some("degrade") => FaultOp::Degrade {
                            replica,
                            at_s,
                            until_s: need_f64(op, "until_s")?,
                            factor: need_f64(op, "factor")?,
                        },
                        _ => bail!("grid doc fault op must be crash, recover, or degrade"),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let profile = match f.get("profile") {
                None => None,
                Some(p) => Some(FaultProfile {
                    mttf_s: need_f64(p, "mttf_s")?,
                    mttr_s: need_f64(p, "mttr_s")?,
                    degrade: match p.get("degrade") {
                        None => None,
                        Some(d) => Some(DegradeProfile {
                            mtbd_s: need_f64(d, "mtbd_s")?,
                            duration_s: need_f64(d, "duration_s")?,
                            factor: need_f64(d, "factor")?,
                        }),
                    },
                }),
            };
            Some(FaultPlan {
                script,
                profile,
                seed: json_u64(need(f, "seed")?, "grid doc faults seed")?,
                recovery_bytes: json_u64(
                    need(f, "recovery_bytes")?,
                    "grid doc faults recovery_bytes",
                )?,
            })
        }
    };
    let retry = match doc.get("retry") {
        None => None,
        Some(r) => Some(RetryPolicy {
            max_attempts: u32::try_from(need_usize(r, "max_attempts")?)
                .map_err(|_| anyhow!("grid doc retry max_attempts exceeds u32"))?,
            deadline_s: need_f64(r, "deadline_s")?,
            backoff_s: need_f64(r, "backoff_s")?,
            backoff_cap_s: need_f64(r, "backoff_cap_s")?,
            hedge: need(r, "hedge")?
                .as_bool()
                .ok_or_else(|| anyhow!("grid doc retry hedge must be a boolean"))?,
        }),
    };
    Ok(JobKind::Sweep {
        model: need_str(doc, "model")?,
        platform: need_str(doc, "platform")?,
        software: need_str(doc, "software")?,
        routers,
        replicas,
        batch_timeouts_s,
        rate_per_replica: need_f64(doc, "rate_per_replica")?,
        duration_s: need_f64(doc, "duration_s")?,
        max_batch: need_usize(doc, "max_batch")?,
        metrics,
        admission,
        faults,
        retry,
        followers: 0,
        codec: CodecKind::Binary,
    })
}

/// Per-cell report axes of a sweep grid, in plan order:
/// `(fleet size, router name, offered rate, batching timeout s)`.
pub type SweepAxes = (usize, String, f64, f64);

/// Build the sweep plan and per-cell axes for a `JobKind::Sweep`.
///
/// Shared by the local execute path and the distributed followers
/// (`coordinator::distributed`): both sides construct cells through this
/// one function from the same grid description, so cell `i` is the same
/// closure over the same config on every machine — the structural half of
/// the sharding-is-invisible guarantee (per-cell seeds are the other
/// half). `seed` is the job seed; per-cell seeds derive from it inside
/// the plan.
pub fn build_sweep_plan(kind: &JobKind, seed: u64) -> Result<(SweepPlan, Vec<SweepAxes>)> {
    let JobKind::Sweep {
        model,
        platform,
        software,
        routers,
        replicas,
        batch_timeouts_s,
        rate_per_replica,
        duration_s,
        max_batch,
        metrics,
        admission,
        faults,
        retry,
        ..
    } = kind
    else {
        bail!("build_sweep_plan on a non-sweep job kind");
    };
    let sw = backends::find(software).ok_or_else(|| anyhow!("software {software:?} unknown"))?;
    let m = catalog::find(model).ok_or_else(|| anyhow!("model {model:?} unknown"))?;
    let service = service_model_for(model, platform)?;
    // Resolve router names eagerly: a typo fails the whole job before any
    // cell burns cycles.
    let mut resolved = Vec::with_capacity(routers.len());
    for name in routers {
        resolved.push((name.clone(), router_policy(name, seed)?));
    }
    let mut plan = SweepPlan::new(seed);
    let mut axes = Vec::new();
    for &n in replicas {
        for (name, policy) in &resolved {
            for &wait_s in batch_timeouts_s {
                let rate = rate_per_replica * n as f64;
                let template = ReplicaConfig {
                    software: sw,
                    service: service.clone(),
                    policy: Policy::Dynamic { max_size: *max_batch, max_wait_s: wait_s },
                    max_queue: 4096,
                };
                let router = *policy;
                let duration = *duration_s;
                let payload = m.request_bytes;
                let mode = *metrics;
                let adm = admission.clone();
                let flt = faults.clone();
                let rp = *retry;
                let label = format!("{n}x{name}@{:.1}ms", wait_s * 1e3);
                plan.push(label, move |cell_seed| ClusterConfig {
                    workload: match &adm {
                        Some(a) => Workload::Streams {
                            streams: split_streams(a, &Pattern::Poisson { rate }),
                            seed: cell_seed,
                        },
                        None => Workload::Stream {
                            pattern: Pattern::Poisson { rate },
                            seed: cell_seed,
                        },
                    },
                    duration_s: duration,
                    replicas: (0..n).map(|_| template.clone()).collect(),
                    router,
                    autoscale: None,
                    cold_start: None,
                    path: RequestPath {
                        processors: Processors::image(),
                        network: LAN,
                        payload_bytes: payload,
                    },
                    metrics: mode,
                    admission: adm.clone(),
                    faults: flt.clone(),
                    retry: rp,
                    seed: cell_seed,
                });
                axes.push((n, name.clone(), rate, wait_s));
            }
        }
    }
    Ok((plan, axes))
}

/// Duration estimate used by the scheduler when the submission omits one.
fn default_estimate(kind: &JobKind) -> f64 {
    match kind {
        JobKind::ServingSim { duration_s, .. } => duration_s * 0.05 + 2.0, // sim runs much faster than simulated time
        JobKind::ClusterSim { duration_s, replicas, .. } => {
            duration_s * 0.05 * (*replicas as f64).max(1.0) + 2.0
        }
        JobKind::HardwareSweep { batches, .. } => 0.5 + batches.len() as f64 * 0.1,
        // Serial estimate: the sum of the per-cell cluster_sim estimates.
        // The leader divides this by its workers' thread budget when
        // charging backlog (see `LeaderConfig::charged_estimate_s`).
        JobKind::Sweep { duration_s, replicas, routers, batch_timeouts_s, .. } => {
            let total_replicas: usize = replicas.iter().sum();
            duration_s * 0.05 * total_replicas as f64 * routers.len() as f64
                * batch_timeouts_s.len() as f64
                + 2.0
        }
        JobKind::MultiModel { duration_s, models, .. } => {
            duration_s * 0.05 * models.len() as f64 + 2.0
        }
        JobKind::Sleep { seconds } => *seconds,
    }
}

/// Resolve a `cluster_sim` router name.
fn router_policy(name: &str, seed: u64) -> Result<RouterPolicy> {
    Ok(match name {
        "round-robin" | "rr" => RouterPolicy::RoundRobin,
        "least-outstanding" | "lo" => RouterPolicy::LeastOutstanding,
        "power-of-two" | "p2c" => RouterPolicy::PowerOfTwoChoices { seed },
        "latency-ewma" | "ewma" => RouterPolicy::LatencyEwma { alpha: 0.3, stale_s: 0.1 },
        other => bail!("unknown router {other:?}"),
    })
}

/// Family parallelism for a catalog model (the roofline occupancy input).
fn parallelism_for(model: &catalog::CatalogModel) -> Parallelism {
    match model.task {
        // Conv nets: per-sample row parallelism is bounded by the
        // mid/late feature maps (~28x28), not the input resolution —
        // this is what produces the paper's flat small-batch latency.
        catalog::Task::IC | catalog::Task::OD | catalog::Task::GAN => Parallelism::cnn(28),
        catalog::Task::NLP => Parallelism::sequence(128),
        catalog::Task::TC => Parallelism::sequence(64),
    }
}

/// Build the serving-sim service model for (model, platform).
pub fn service_model_for(model_name: &str, platform_id: &str) -> Result<ServiceModel> {
    let model = catalog::find(model_name)
        .ok_or_else(|| anyhow!("model {model_name:?} not in catalog"))?;
    let platform = hardware::find(platform_id)
        .ok_or_else(|| anyhow!("platform {platform_id:?} not in Table 1"))?;
    Ok(ServiceModel::Analytic {
        platform,
        profile: model.profile,
        parallelism: parallelism_for(model),
        request_bytes: model.request_bytes,
    })
}

/// Attach the per-reason drop breakdown (satellite of the ingress tier:
/// `dropped` alone no longer says *why*). Metric keys are the
/// [`DropReason`](crate::metrics::DropReason) labels with `-` → `_`:
/// `dropped_queue_full`, `dropped_shed`, `dropped_evicted_backlog`,
/// `dropped_rejected_placement`, `dropped_replica_failed`,
/// `dropped_timed_out`.
fn with_drop_breakdown(mut record: Record, collector: &crate::metrics::Collector) -> Record {
    for (label, n) in collector.drop_breakdown() {
        record = record.with_metric(&format!("dropped_{}", label.replace('-', "_")), n as f64);
    }
    record
}

/// The engine trace config a submission asks for (`off()` — the
/// zero-cost path — when it carries no `trace:` block).
fn trace_config_of(spec: &JobSpec) -> TraceConfig {
    spec.trace.as_ref().map_or_else(TraceConfig::off, |t| t.config.clone())
}

/// Write the Chrome-trace/Perfetto export when the submission asked for
/// one (`trace.out`). The document bytes are deterministic for a fixed
/// seed (sorted keys, canonical float rendering), so re-running the job
/// rewrites the identical file.
fn write_trace_out(spec: &JobSpec, trace: Option<&obs::TraceOutput>) -> Result<()> {
    let Some(path) = spec.trace.as_ref().and_then(|t| t.out.as_deref()) else {
        return Ok(());
    };
    let empty = obs::TraceOutput::default();
    let doc = obs::perfetto::trace_json(trace.unwrap_or(&empty));
    std::fs::write(path, doc.to_string_compact())
        .map_err(|e| anyhow!("writing trace export {path:?}: {e}"))?;
    Ok(())
}

/// Convert sweep cell-span wire frames into an [`obs::TraceOutput`] for
/// the Perfetto export. The string track (`shard-3`, `sweep`, `local`)
/// rides as a `track` attribute; the frame id becomes the display lane.
fn frames_to_trace(frames: &[SpanFrame]) -> obs::TraceOutput {
    let spans = frames
        .iter()
        .enumerate()
        .map(|(i, f)| obs::Span {
            id: i as u32,
            parent: if f.parent >= 0 { Some(f.parent as u32) } else { None },
            name: f.name.clone(),
            track: f.id,
            start_s: f.start_s,
            end_s: f.end_s,
            attrs: std::iter::once(("track".to_string(), obs::Attr::S(f.track.clone())))
                .chain(f.attrs.iter().map(|(k, v)| (k.clone(), obs::Attr::S(v.clone()))))
                .collect(),
        })
        .collect();
    obs::TraceOutput { spans, gauges: Vec::new(), truncated: 0 }
}

/// One record per priority class — the per-tenant QoS view of a run with
/// an `admission:` block. Class records share the run's task name and are
/// distinguished by the `class` label; conservation is enforced per class
/// (a violation fails the job, same contract as the run-level ledger).
fn class_records(
    task: &str,
    model: &str,
    platform: &str,
    software: &str,
    classes: &[crate::metrics::ClassMetrics],
) -> Result<Vec<Record>> {
    let mut out = Vec::with_capacity(classes.len());
    for cm in classes {
        if !cm.conserved() {
            bail!(
                "class {} conservation violated: {} issued != {} completed + {} dropped",
                cm.class,
                cm.issued,
                cm.collector.completed,
                cm.collector.dropped
            );
        }
        let mut r = Record::new(task, model, platform, software)
            .with_label("class", &cm.class.to_string())
            .with_metric("issued", cm.issued as f64)
            .with_metric("completed", cm.collector.completed as f64)
            .with_metric("dropped", cm.collector.dropped as f64)
            .with_metric("goodput", cm.goodput())
            .with_metric("shed_fraction", cm.shed_fraction());
        if cm.collector.completed > 0 {
            r = r
                .with_metric("p50_ms", cm.collector.e2e.percentile(50.0) * 1e3)
                .with_metric("p99_ms", cm.collector.e2e.percentile(99.0) * 1e3);
        }
        out.push(with_drop_breakdown(r, &cm.collector));
    }
    Ok(out)
}

/// Execute a job, producing PerfDB records. `time_scale` divides sleep
/// durations (scheduler studies run faster than real time); `threads` is
/// the intra-job parallelism budget — sweep jobs run their grid cells on
/// up to this many worker threads, every other kind runs single-threaded
/// and ignores it. Results never depend on `threads` (the sweep engine is
/// bit-identical at any thread count).
pub fn execute(spec: &JobSpec, seed: u64, time_scale: f64, threads: usize) -> Result<Vec<Record>> {
    match &spec.kind {
        JobKind::ServingSim { model, platform, software, rate_rps, duration_s, max_batch, max_wait_s } => {
            let sw = backends::find(software)
                .ok_or_else(|| anyhow!("software {software:?} unknown"))?;
            let m = catalog::find(model).ok_or_else(|| anyhow!("model {model:?} unknown"))?;
            let config = SimConfig {
                workload: Workload::Stream { pattern: Pattern::Poisson { rate: *rate_rps }, seed },
                duration_s: *duration_s,
                policy: Policy::Dynamic { max_size: *max_batch, max_wait_s: *max_wait_s },
                software: sw,
                service: service_model_for(model, platform)?,
                path: RequestPath {
                    processors: Processors::image(),
                    network: LAN,
                    payload_bytes: m.request_bytes,
                },
                max_queue: 4096,
                seed,
            };
            let result = serving::run(&config);
            let collector = &result.collector;
            let record = Record::new("serving_sim", model, platform, software)
                .with_metric("rate_rps", *rate_rps)
                .with_metric("p50_ms", collector.e2e.percentile(50.0) * 1e3)
                .with_metric("p95_ms", collector.e2e.percentile(95.0) * 1e3)
                .with_metric("p99_ms", collector.e2e.percentile(99.0) * 1e3)
                .with_metric("throughput_rps", collector.throughput_rps())
                .with_metric("mean_batch", result.mean_batch())
                .with_metric("utilization", result.timeline.mean())
                .with_metric("dropped", result.dropped as f64);
            Ok(vec![record])
        }
        JobKind::ClusterSim {
            model,
            platform,
            software,
            replicas,
            router,
            rate_rps,
            duration_s,
            burst,
            max_batch,
            max_wait_s,
            autoscale,
            metrics,
            admission,
            faults,
            retry,
        } => {
            let sw = backends::find(software)
                .ok_or_else(|| anyhow!("software {software:?} unknown"))?;
            let m = catalog::find(model).ok_or_else(|| anyhow!("model {model:?} unknown"))?;
            let template = ReplicaConfig {
                software: sw,
                service: service_model_for(model, platform)?,
                policy: Policy::Dynamic { max_size: *max_batch, max_wait_s: *max_wait_s },
                max_queue: 4096,
            };
            let pattern = match burst {
                Some(b) => Pattern::Spike {
                    base_rate: *rate_rps,
                    burst_rate: b.rate_rps,
                    start_s: b.start_s,
                    duration_s: b.duration_s,
                },
                None => Pattern::Poisson { rate: *rate_rps },
            };
            let autoscale_cfg = autoscale
                .as_ref()
                .map(|a| -> Result<AutoscaleConfig> {
                    let policy = match a.policy.as_str() {
                        "queue-depth" => ScalePolicy::QueueDepth {
                            up_per_replica: a.up,
                            down_per_replica: a.down,
                            cooldown_s: a.cooldown_s,
                        },
                        "utilization" => ScalePolicy::Utilization {
                            up: a.up,
                            down: a.down,
                            cooldown_s: a.cooldown_s,
                        },
                        other => bail!("unknown autoscale policy {other:?}"),
                    };
                    // Initial fleet must sit inside [min, max]: below min
                    // the engine refuses to start; above max the declared
                    // capacity bound would be silently violated.
                    if a.max_replicas < a.min_replicas
                        || *replicas < a.min_replicas
                        || *replicas > a.max_replicas
                    {
                        bail!(
                            "autoscale bounds invalid: initial {} vs min {} / max {}",
                            replicas,
                            a.min_replicas,
                            a.max_replicas
                        );
                    }
                    if a.eval_interval_s <= 0.0 {
                        bail!("autoscale eval_interval_s must be positive");
                    }
                    Ok(AutoscaleConfig {
                        policy,
                        min_replicas: a.min_replicas,
                        max_replicas: a.max_replicas,
                        template: template.clone(),
                        weight_bytes: m.profile.weight_bytes,
                        eval_interval_s: a.eval_interval_s,
                    })
                })
                .transpose()?;
            // The ingress tier wants tenant-tagged streams; a plain
            // `rate:` submission with an `admission:` block becomes one
            // stream per tenant at an even share of the offered rate.
            let workload = match admission {
                Some(adm) => Workload::Streams { streams: split_streams(adm, &pattern), seed },
                None => Workload::Stream { pattern, seed },
            };
            let config = ClusterConfig {
                workload,
                duration_s: *duration_s,
                replicas: (0..*replicas).map(|_| template.clone()).collect(),
                router: router_policy(router, seed)?,
                autoscale: autoscale_cfg,
                cold_start: None,
                path: RequestPath {
                    processors: Processors::image(),
                    network: LAN,
                    payload_bytes: m.request_bytes,
                },
                metrics: *metrics,
                admission: admission.clone(),
                faults: faults.clone(),
                retry: *retry,
                seed,
            };
            let result = cluster::run_traced(&config, &trace_config_of(spec));
            // Conservation is part of the contract: drain-on-remove must
            // complete every accepted request across scale events.
            if result.collector.completed + result.dropped != result.issued {
                bail!(
                    "cluster_sim conservation violated: {} completed + {} dropped != {} issued",
                    result.collector.completed,
                    result.dropped,
                    result.issued
                );
            }
            if !result.collector.drops_conserved() {
                bail!(
                    "cluster_sim drop-reason ledger violated: reasons sum to {} but dropped is {}",
                    result.collector.drop_breakdown().iter().map(|&(_, n)| n).sum::<u64>(),
                    result.collector.dropped
                );
            }
            let collector = &result.collector;
            let mut record = Record::new("cluster_sim", model, platform, software)
                .with_metric("rate_rps", *rate_rps)
                .with_metric("replicas_initial", *replicas as f64)
                .with_metric("replicas_max", result.scale.max_active() as f64)
                .with_metric(
                    "scale_ups",
                    result.scale.count(crate::metrics::ScaleEventKind::AddRequested) as f64,
                )
                .with_metric(
                    "scale_retires",
                    result.scale.count(crate::metrics::ScaleEventKind::Retired) as f64,
                )
                .with_metric("p50_ms", collector.e2e.percentile(50.0) * 1e3)
                .with_metric("p99_ms", collector.e2e.percentile(99.0) * 1e3)
                .with_metric("throughput_rps", collector.throughput_rps())
                .with_metric("dropped", result.dropped as f64)
                .with_metric("issued", result.issued as f64);
            if let Some(b) = burst {
                let w = collector.e2e_in_window(b.start_s, b.start_s + b.duration_s);
                if !w.is_empty() {
                    record = record.with_metric("burst_p99_ms", w.percentile(99.0) * 1e3);
                }
            }
            let mut out = vec![with_drop_breakdown(record, collector)];
            out.extend(class_records("cluster_sim", model, platform, software, &result.classes)?);
            write_trace_out(spec, result.trace.as_ref())?;
            Ok(out)
        }
        JobKind::HardwareSweep { model, platform, batches } => {
            let m = catalog::find(model).ok_or_else(|| anyhow!("model {model:?} unknown"))?;
            let p = hardware::find(platform)
                .ok_or_else(|| anyhow!("platform {platform:?} unknown"))?;
            let par = parallelism_for(m);
            let mut out = Vec::new();
            for &b in batches {
                let est = hardware::estimate(p, &m.profile, par, b, m.request_bytes);
                out.push(
                    Record::new("hardware_sweep", model, platform, "-")
                        .with_metric("batch", b as f64)
                        .with_metric("latency_ms", est.total_s * 1e3)
                        .with_metric("latency_per_sample_ms", est.total_s / b as f64 * 1e3)
                        .with_metric("throughput_rps", b as f64 / est.total_s)
                        .with_metric("utilization", est.utilization)
                        .with_metric("memory_bound", if est.memory_bound { 1.0 } else { 0.0 }),
                );
            }
            Ok(out)
        }
        JobKind::Sweep { model, platform, software, admission, followers, codec, .. } => {
            let (mut plan, axes) = build_sweep_plan(&spec.kind, seed)?;
            if let Some(ts) = &spec.trace {
                plan.set_trace(ts.config.clone());
            }
            let mut wire: Option<distributed::DistStats> = None;
            let mut spans: Vec<SpanFrame> = Vec::new();
            let outcome = if *followers >= 2 {
                // Shard the grid across followers through the wire codec
                // (streaming absorption, straggler re-queue) — bit-
                // identical to the local run by construction (PERF.md
                // §Distributed sweeps).
                let mut dist =
                    distributed::DistConfig::uniform(*followers, threads.max(1), *codec);
                dist.trace = spec.trace.is_some();
                let d = distributed::run_sharded(&spec.kind, seed, &dist)?;
                wire = Some(d.stats);
                spans = d.spans;
                d.outcome
            } else {
                let outcome = plan.run(threads.max(1));
                if spec.trace.is_some() {
                    // Local cell spans mirror the follower-emitted shape
                    // (sim-time extents, conservation-counter attrs) so
                    // the export looks the same sharded or not.
                    spans = outcome
                        .cells
                        .iter()
                        .enumerate()
                        .map(|(i, c)| SpanFrame {
                            track: "local".to_string(),
                            id: i as u64,
                            parent: -1,
                            name: c.label.clone(),
                            start_s: 0.0,
                            end_s: plan.cells()[i].config_for(c.seed).duration_s,
                            attrs: vec![
                                ("issued".to_string(), c.result.issued.to_string()),
                                ("events".to_string(), c.result.events.to_string()),
                                ("dropped".to_string(), c.result.dropped.to_string()),
                            ],
                        })
                        .collect();
                }
                outcome
            };
            let mut out = Vec::with_capacity(outcome.cells.len());
            for (cell, (n, router_name, rate, wait_s)) in outcome.cells.iter().zip(&axes) {
                let r = &cell.result;
                if r.collector.completed + r.dropped != r.issued {
                    bail!(
                        "sweep cell {} conservation violated: {} completed + {} dropped != {} issued",
                        cell.label,
                        r.collector.completed,
                        r.dropped,
                        r.issued
                    );
                }
                if !r.collector.drops_conserved() {
                    bail!(
                        "sweep cell {} drop-reason ledger violated ({} dropped)",
                        cell.label,
                        r.collector.dropped
                    );
                }
                let mut rec = Record::new("sweep", model, platform, software)
                    .with_label("cell", &cell.label)
                    .with_label("router", router_name)
                    .with_metric("replicas", *n as f64)
                    .with_metric("rate_rps", *rate)
                    .with_metric("batch_timeout_ms", wait_s * 1e3)
                    .with_metric("p50_ms", r.collector.e2e.percentile(50.0) * 1e3)
                    .with_metric("p99_ms", r.collector.e2e.percentile(99.0) * 1e3)
                    .with_metric("throughput_rps", r.collector.throughput_rps())
                    .with_metric("dropped", r.dropped as f64)
                    .with_metric("issued", r.issued as f64);
                if let Some(w) = &wire {
                    // Wire accounting of the distributed run, surfaced on
                    // every cell record (the whole grid shares one wire).
                    rec = rec
                        .with_metric("bytes_sent", w.bytes_to_followers as f64)
                        .with_metric("bytes_received", w.bytes_to_leader as f64)
                        .with_metric("duplicates", w.duplicate_frames as f64)
                        .with_metric("cells_rerun", w.cells_rerun as f64)
                        .with_metric("rounds", w.rounds as f64);
                }
                out.push(with_drop_breakdown(rec, &r.collector));
            }
            // Grid-wide per-class view: `aggregate_classes` absorbs every
            // cell's ledgers (thread-count independent, like the cells).
            if admission.is_some() {
                let (_, classes) = outcome.aggregate_classes();
                out.extend(class_records("sweep", model, platform, software, &classes)?);
            }
            write_trace_out(spec, Some(&frames_to_trace(&spans)))?;
            Ok(out)
        }
        JobKind::MultiModel {
            platform,
            software,
            models,
            rates,
            mode,
            replicas,
            mem_gb,
            router,
            duration_s,
            max_batch,
            max_wait_s,
            metrics,
            admission,
            faults,
            retry,
        } => {
            let sw = backends::find(software)
                .ok_or_else(|| anyhow!("software {software:?} unknown"))?;
            let mut specs = Vec::with_capacity(models.len());
            let mut payload = 0u64; // largest request drives the modelled transfer
            for (name, &rate) in models.iter().zip(rates) {
                let cm = catalog::find(name).ok_or_else(|| anyhow!("model {name:?} unknown"))?;
                payload = payload.max(cm.request_bytes);
                specs.push(MmModelSpec {
                    name: name.clone(),
                    service: service_model_for(name, platform)?,
                    policy: Policy::Dynamic { max_size: *max_batch, max_wait_s: *max_wait_s },
                    weight_bytes: cm.profile.weight_bytes,
                    max_queue: 4096,
                    pattern: Pattern::Poisson { rate },
                });
            }
            let mem_bytes = (mem_gb * 1e9) as u64;
            let total_weights: u64 = specs.iter().map(|s| s.weight_bytes).sum();
            let fleet: Vec<MultiReplicaConfig> = match mode.as_str() {
                "shared" => {
                    // Validate the budget here so a misconfigured
                    // submission fails with an error instead of panicking
                    // inside a worker thread.
                    if total_weights > mem_bytes {
                        bail!(
                            "multimodel shared placement overflows mem_gb: {} bytes of weights \
                             vs {} budget",
                            total_weights,
                            mem_bytes
                        );
                    }
                    (0..*replicas)
                        .map(|_| MultiReplicaConfig {
                            software: sw,
                            mem_bytes,
                            hosted: (0..specs.len()).collect(),
                        })
                        .collect()
                }
                "dedicated" => {
                    for s in &specs {
                        if s.weight_bytes > mem_bytes {
                            bail!(
                                "multimodel model {:?} does not fit mem_gb ({} bytes vs {})",
                                s.name,
                                s.weight_bytes,
                                mem_bytes
                            );
                        }
                    }
                    (0..specs.len())
                        .map(|i| MultiReplicaConfig { software: sw, mem_bytes, hosted: vec![i] })
                        .collect()
                }
                other => bail!("multimodel mode must be 'shared' or 'dedicated', got {other:?}"),
            };
            let config = MultiModelConfig {
                models: specs,
                replicas: fleet,
                router: router_policy(router, seed)?,
                duration_s: *duration_s,
                placement_ops: vec![],
                contention: Default::default(),
                path: RequestPath {
                    processors: Processors::image(),
                    network: LAN,
                    payload_bytes: payload,
                },
                metrics: *metrics,
                admission: admission.clone(),
                faults: faults.clone(),
                retry: *retry,
                seed,
            };
            let result = multimodel::run_traced(&config, &trace_config_of(spec));
            let colocated = if mode.as_str() == "shared" { models.len() } else { 1 };
            let mut out = Vec::with_capacity(result.models.len());
            for (mm, &rate) in result.models.iter().zip(rates) {
                // Conservation is part of the contract, per stream.
                if !mm.conserved() {
                    bail!(
                        "multimodel stream {:?} conservation violated: {} issued != {} completed \
                         + {} dropped",
                        mm.name,
                        mm.issued,
                        mm.collector.completed,
                        mm.collector.dropped
                    );
                }
                out.push(with_drop_breakdown(
                    Record::new("multimodel", &mm.name, platform, software)
                        .with_label("mode", mode)
                        .with_metric("rate_rps", rate)
                        .with_metric("replicas", result.replica_count() as f64)
                        .with_metric("colocated", colocated as f64)
                        .with_metric("p50_ms", mm.collector.e2e.percentile(50.0) * 1e3)
                        .with_metric("p99_ms", mm.collector.e2e.percentile(99.0) * 1e3)
                        .with_metric("throughput_rps", mm.collector.throughput_rps())
                        .with_metric("issued", mm.issued as f64)
                        .with_metric("dropped", mm.collector.dropped as f64),
                    &mm.collector,
                ));
            }
            out.extend(class_records("multimodel", "-", platform, software, &result.classes)?);
            write_trace_out(spec, result.trace.as_ref())?;
            Ok(out)
        }
        JobKind::Sleep { seconds } => {
            std::thread::sleep(std::time::Duration::from_secs_f64(seconds / time_scale.max(1e-9)));
            Ok(vec![Record::new("sleep", "-", "-", "-").with_metric("seconds", *seconds)])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SUBMISSION: &str = r#"
name: resnet-tail-latency
task: serving_sim
model: resnet50
platform: G1
software: tris
workload:
  rate: 80.0
  duration_s: 10
batching:
  max_size: 16
  max_wait_ms: 2
"#;

    #[test]
    fn parses_serving_submission() {
        let spec = JobSpec::parse_yaml(SUBMISSION).unwrap();
        assert_eq!(spec.name, "resnet-tail-latency");
        match &spec.kind {
            JobKind::ServingSim { model, software, rate_rps, max_batch, max_wait_s, .. } => {
                assert_eq!(model, "resnet50");
                assert_eq!(software, "tris");
                assert_eq!(*rate_rps, 80.0);
                assert_eq!(*max_batch, 16);
                assert!((max_wait_s - 0.002).abs() < 1e-12);
            }
            k => panic!("{k:?}"),
        }
        assert!(spec.est_duration_s > 0.0);
    }

    const CLUSTER_SUBMISSION: &str = r#"
name: spike-autoscale
task: cluster_sim
model: resnet50
platform: G1
software: tfs
replicas: 2
router: least-outstanding
workload:
  rate: 120.0
  duration_s: 30
  burst:
    rate: 2000.0
    start_s: 8
    duration_s: 6
batching:
  max_size: 8
  max_wait_ms: 2
autoscale:
  policy: queue-depth
  min_replicas: 2
  max_replicas: 6
  up: 8.0
  down: 1.0
  cooldown_s: 1.0
  eval_interval_s: 0.5
"#;

    #[test]
    fn parses_cluster_submission() {
        let spec = JobSpec::parse_yaml(CLUSTER_SUBMISSION).unwrap();
        match &spec.kind {
            JobKind::ClusterSim { replicas, router, burst, autoscale, rate_rps, .. } => {
                assert_eq!(*replicas, 2);
                assert_eq!(router, "least-outstanding");
                assert_eq!(*rate_rps, 120.0);
                let b = burst.as_ref().unwrap();
                assert_eq!(b.rate_rps, 2000.0);
                assert_eq!(b.start_s, 8.0);
                let a = autoscale.as_ref().unwrap();
                assert_eq!(a.policy, "queue-depth");
                assert_eq!(a.max_replicas, 6);
                assert_eq!(a.eval_interval_s, 0.5);
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn executes_cluster_sim_with_autoscale() {
        let spec = JobSpec::parse_yaml(CLUSTER_SUBMISSION).unwrap();
        let records = execute(&spec, 3, 1.0, 1).unwrap();
        assert_eq!(records.len(), 1);
        let r = &records[0];
        // Conservation checked inside execute; the record carries the
        // autoscaling outcome.
        assert!(r.metric("replicas_max").unwrap() > 2.0, "no scale-up recorded");
        assert!(r.metric("scale_ups").unwrap() >= 1.0);
        assert!(r.metric("burst_p99_ms").unwrap() >= r.metric("p50_ms").unwrap());
        assert!(r.metric("throughput_rps").unwrap() > 0.0);
    }

    #[test]
    fn scale_knob_parses_and_rejects_bad_values() {
        let exact = JobSpec::parse_yaml("task: cluster_sim\nmodel: resnet50\n").unwrap();
        match exact.kind {
            JobKind::ClusterSim { metrics, .. } => assert_eq!(metrics, MetricsMode::Exact),
            k => panic!("{k:?}"),
        }
        let sketch =
            JobSpec::parse_yaml("task: cluster_sim\nmodel: resnet50\nscale: sketch\n").unwrap();
        match sketch.kind {
            JobKind::ClusterSim { metrics, .. } => {
                assert_eq!(metrics, MetricsMode::Sketch { alpha: 0.01 })
            }
            k => panic!("{k:?}"),
        }
        let tuned = JobSpec::parse_yaml(
            "task: sweep\nscale: sketch\nsketch_alpha: 0.05\nrouters: [round-robin]\nreplicas: [1]\n",
        )
        .unwrap();
        match tuned.kind {
            JobKind::Sweep { metrics, .. } => {
                assert_eq!(metrics, MetricsMode::Sketch { alpha: 0.05 })
            }
            k => panic!("{k:?}"),
        }
        assert!(JobSpec::parse_yaml("task: cluster_sim\nscale: turbo\n").is_err());
        assert!(
            JobSpec::parse_yaml("task: cluster_sim\nscale: sketch\nsketch_alpha: 0\n").is_err()
        );
        assert!(
            JobSpec::parse_yaml("task: cluster_sim\nscale: sketch\nsketch_alpha: 1.5\n").is_err()
        );
    }

    #[test]
    fn cluster_sim_sketch_scale_matches_exact_ledger() {
        // The `scale` knob changes only metric summarization: the
        // simulation itself (issued/dropped counts, throughput window) is
        // identical, sketch percentiles track exact within alpha, and the
        // exact-only burst window metric is omitted rather than wrong.
        let exact_spec = JobSpec::parse_yaml(CLUSTER_SUBMISSION).unwrap();
        let sketch_yaml = format!("{CLUSTER_SUBMISSION}scale: sketch\n");
        let sketch_spec = JobSpec::parse_yaml(&sketch_yaml).unwrap();
        let e = &execute(&exact_spec, 3, 1.0, 1).unwrap()[0];
        let s = &execute(&sketch_spec, 3, 1.0, 1).unwrap()[0];
        assert_eq!(e.metric("issued"), s.metric("issued"));
        assert_eq!(e.metric("dropped"), s.metric("dropped"));
        assert_eq!(e.metric("replicas_max"), s.metric("replicas_max"));
        assert_eq!(
            e.metric("throughput_rps").unwrap().to_bits(),
            s.metric("throughput_rps").unwrap().to_bits()
        );
        for key in ["p50_ms", "p99_ms"] {
            let (ev, sv) = (e.metric(key).unwrap(), s.metric(key).unwrap());
            assert!((sv / ev - 1.0).abs() <= 0.021, "{key}: exact {ev} sketch {sv}");
        }
        assert!(e.metric("burst_p99_ms").is_some());
        assert!(s.metric("burst_p99_ms").is_none(), "window metrics are exact-only");
    }

    #[test]
    fn multimodel_sketch_scale_keeps_per_stream_ledgers() {
        let yaml = format!("{MULTIMODEL_SUBMISSION}scale: sketch\n");
        let spec = JobSpec::parse_yaml(&yaml).unwrap();
        let exact = execute(&JobSpec::parse_yaml(MULTIMODEL_SUBMISSION).unwrap(), 3, 1.0, 1)
            .unwrap();
        let sketch = execute(&spec, 3, 1.0, 1).unwrap();
        assert_eq!(exact.len(), sketch.len());
        for (e, s) in exact.iter().zip(&sketch) {
            assert_eq!(e.model, s.model);
            assert_eq!(e.metric("issued"), s.metric("issued"));
            assert_eq!(e.metric("dropped"), s.metric("dropped"));
        }
    }

    #[test]
    fn cluster_sim_fixed_fleet_without_autoscale_block() {
        let spec = JobSpec::parse_yaml(
            "task: cluster_sim\nmodel: resnet50\nplatform: G1\nsoftware: tris\nreplicas: 3\n\
             workload:\n  rate: 90.0\n  duration_s: 10\n",
        )
        .unwrap();
        let records = execute(&spec, 0, 1.0, 1).unwrap();
        let r = &records[0];
        assert_eq!(r.metric("replicas_initial").unwrap(), 3.0);
        assert_eq!(r.metric("replicas_max").unwrap(), 3.0);
        assert_eq!(r.metric("scale_ups").unwrap(), 0.0);
    }

    #[test]
    fn cluster_sim_rejects_unknown_router_and_policy() {
        let bad_router = JobSpec::parse_yaml(
            "task: cluster_sim\nmodel: resnet50\nplatform: G1\nrouter: teleport\n",
        )
        .unwrap();
        assert!(execute(&bad_router, 0, 1.0, 1).is_err());
        let bad_policy = JobSpec::parse_yaml(
            "task: cluster_sim\nmodel: resnet50\nplatform: G1\nautoscale:\n  policy: vibes\n",
        )
        .unwrap();
        assert!(execute(&bad_policy, 0, 1.0, 1).is_err());
    }

    #[test]
    fn parses_hardware_sweep() {
        let spec =
            JobSpec::parse_yaml("task: hardware_sweep\nmodel: bert_large\nplatform: G3\nbatches: [1, 8]\n")
                .unwrap();
        match &spec.kind {
            JobKind::HardwareSweep { batches, platform, .. } => {
                assert_eq!(batches, &vec![1, 8]);
                assert_eq!(platform, "G3");
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn rejects_unknown_task() {
        assert!(JobSpec::parse_yaml("task: mine_bitcoin\n").is_err());
        assert!(JobSpec::parse_yaml("name: x\n").is_err());
    }

    #[test]
    fn executes_serving_sim() {
        let spec = JobSpec::parse_yaml(SUBMISSION).unwrap();
        let records = execute(&spec, 7, 1.0, 1).unwrap();
        assert_eq!(records.len(), 1);
        let r = &records[0];
        assert!(r.metric("p99_ms").unwrap() >= r.metric("p50_ms").unwrap());
        assert!(r.metric("throughput_rps").unwrap() > 0.0);
    }

    #[test]
    fn executes_hardware_sweep() {
        let spec = JobSpec::parse_yaml(
            "task: hardware_sweep\nmodel: resnet50\nplatform: G1\nbatches: [1, 4, 16]\n",
        )
        .unwrap();
        let records = execute(&spec, 0, 1.0, 1).unwrap();
        assert_eq!(records.len(), 3);
        // Per-sample latency should fall with batch.
        let l1 = records[0].metric("latency_per_sample_ms").unwrap();
        let l16 = records[2].metric("latency_per_sample_ms").unwrap();
        assert!(l16 < l1);
    }

    #[test]
    fn execute_rejects_unknown_model() {
        let spec =
            JobSpec::parse_yaml("task: hardware_sweep\nmodel: alexnet9000\nplatform: G1\n").unwrap();
        assert!(execute(&spec, 0, 1.0, 1).is_err());
    }

    #[test]
    fn sleep_respects_time_scale() {
        let spec = JobSpec::parse_yaml("task: sleep\nseconds: 0.2\n").unwrap();
        let t0 = std::time::Instant::now();
        execute(&spec, 0, 100.0, 1).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 0.1);
    }

    const SWEEP_SUBMISSION: &str = r#"
name: router-replica-grid
task: sweep
model: resnet50
platform: G1
software: tris
routers: [round-robin, least-outstanding]
replicas: [1, 2]
workload:
  rate_per_replica: 60.0
  duration_s: 4
batching:
  max_size: 8
  max_wait_ms: 2
"#;

    #[test]
    fn parses_sweep_submission() {
        let spec = JobSpec::parse_yaml(SWEEP_SUBMISSION).unwrap();
        match &spec.kind {
            JobKind::Sweep { routers, replicas, rate_per_replica, duration_s, .. } => {
                let want = vec!["round-robin".to_string(), "least-outstanding".to_string()];
                assert_eq!(routers, &want);
                assert_eq!(replicas, &vec![1, 2]);
                assert_eq!(*rate_per_replica, 60.0);
                assert_eq!(*duration_s, 4.0);
            }
            k => panic!("{k:?}"),
        }
        assert!(spec.est_duration_s > 0.0);
    }

    #[test]
    fn executes_sweep_grid_one_record_per_cell() {
        let spec = JobSpec::parse_yaml(SWEEP_SUBMISSION).unwrap();
        let records = execute(&spec, 11, 1.0, 2).unwrap();
        assert_eq!(records.len(), 4, "2 fleet sizes x 2 routers");
        assert_eq!(records[0].label("router"), Some("round-robin"));
        assert_eq!(records[1].label("router"), Some("least-outstanding"));
        assert_eq!(records[0].metric("replicas"), Some(1.0));
        assert_eq!(records[3].metric("replicas"), Some(2.0));
        for r in &records {
            assert!(r.metric("throughput_rps").unwrap() > 0.0, "{:?}", r.label("cell"));
            assert!(r.metric("p99_ms").unwrap() >= r.metric("p50_ms").unwrap());
        }
    }

    #[test]
    fn sweep_records_identical_at_any_thread_budget() {
        let spec = JobSpec::parse_yaml(SWEEP_SUBMISSION).unwrap();
        let serial = execute(&spec, 11, 1.0, 1).unwrap();
        let parallel = execute(&spec, 11, 1.0, 8).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.label("cell"), b.label("cell"));
            for key in ["p50_ms", "p99_ms", "throughput_rps", "issued", "dropped"] {
                assert_eq!(
                    a.metric(key).unwrap().to_bits(),
                    b.metric(key).unwrap().to_bits(),
                    "{key} must be bit-identical across thread budgets"
                );
            }
        }
    }

    #[test]
    fn sweep_batch_timeout_axis_multiplies_the_grid() {
        let spec = JobSpec::parse_yaml(
            "task: sweep\nmodel: resnet50\nplatform: G1\nsoftware: tris\n\
             routers: [round-robin]\nreplicas: [1]\nbatch_timeouts_ms: [1, 2, 5]\n\
             workload:\n  rate_per_replica: 60.0\n  duration_s: 3\n",
        )
        .unwrap();
        match &spec.kind {
            JobKind::Sweep { batch_timeouts_s, .. } => {
                assert_eq!(batch_timeouts_s.len(), 3);
                assert!((batch_timeouts_s[0] - 0.001).abs() < 1e-12);
                assert!((batch_timeouts_s[2] - 0.005).abs() < 1e-12);
            }
            k => panic!("{k:?}"),
        }
        let records = execute(&spec, 5, 1.0, 2).unwrap();
        assert_eq!(records.len(), 3, "1 fleet x 1 router x 3 timeouts");
        for (r, want_ms) in records.iter().zip([1.0, 2.0, 5.0]) {
            assert_eq!(r.metric("batch_timeout_ms"), Some(want_ms));
            assert!(r.label("cell").unwrap().contains("ms"), "{:?}", r.label("cell"));
        }
    }

    #[test]
    fn sweep_defaults_to_single_batching_timeout() {
        let spec = JobSpec::parse_yaml(SWEEP_SUBMISSION).unwrap();
        match &spec.kind {
            JobKind::Sweep { batch_timeouts_s, .. } => {
                assert_eq!(batch_timeouts_s, &vec![0.002], "falls back to batching.max_wait_ms");
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn sweep_rejects_malformed_batch_timeouts() {
        // A bad entry fails the submission loudly — the grid never
        // silently shrinks (same contract as the router/replica axes).
        assert!(JobSpec::parse_yaml("task: sweep\nbatch_timeouts_ms: [2, 0]\n").is_err());
        assert!(JobSpec::parse_yaml("task: sweep\nbatch_timeouts_ms: [2, -1]\n").is_err());
        assert!(JobSpec::parse_yaml("task: sweep\nbatch_timeouts_ms: [2, oops]\n").is_err());
        assert!(JobSpec::parse_yaml("task: sweep\nbatch_timeouts_ms: []\n").is_err());
    }

    const MULTIMODEL_SUBMISSION: &str = r#"
name: share-vs-dedicate
task: multimodel
platform: G1
software: tris
models: [resnet50, mobilenet_v1]
rates: [100.0, 80.0]
mode: shared
replicas: 1
mem_gb: 4.0
router: least-outstanding
workload:
  duration_s: 8
batching:
  max_size: 8
  max_wait_ms: 2
"#;

    #[test]
    fn parses_multimodel_submission() {
        let spec = JobSpec::parse_yaml(MULTIMODEL_SUBMISSION).unwrap();
        match &spec.kind {
            JobKind::MultiModel { models, rates, mode, replicas, mem_gb, router, .. } => {
                assert_eq!(models, &vec!["resnet50".to_string(), "mobilenet_v1".to_string()]);
                assert_eq!(rates, &vec![100.0, 80.0]);
                assert_eq!(mode, "shared");
                assert_eq!(*replicas, 1);
                assert_eq!(*mem_gb, 4.0);
                assert_eq!(router, "least-outstanding");
            }
            k => panic!("{k:?}"),
        }
        assert!(spec.est_duration_s > 0.0);
    }

    #[test]
    fn multimodel_rejects_malformed_submissions() {
        assert!(JobSpec::parse_yaml("task: multimodel\n").is_err(), "models list required");
        assert!(JobSpec::parse_yaml("task: multimodel\nmodels: []\n").is_err());
        assert!(JobSpec::parse_yaml("task: multimodel\nmodels: [resnet50, 42]\n").is_err());
        assert!(
            JobSpec::parse_yaml("task: multimodel\nmodels: [resnet50]\nrates: [0]\n").is_err()
        );
        assert!(
            JobSpec::parse_yaml("task: multimodel\nmodels: [resnet50]\nrates: [10, 20]\n")
                .is_err(),
            "rates must be index-aligned with models"
        );
    }

    #[test]
    fn executes_multimodel_one_record_per_stream() {
        let spec = JobSpec::parse_yaml(MULTIMODEL_SUBMISSION).unwrap();
        let records = execute(&spec, 3, 1.0, 1).unwrap();
        assert_eq!(records.len(), 2, "one record per model stream");
        assert_eq!(records[0].model, "resnet50");
        assert_eq!(records[1].model, "mobilenet_v1");
        for r in &records {
            assert_eq!(r.label("mode"), Some("shared"));
            assert_eq!(r.metric("replicas"), Some(1.0));
            assert_eq!(r.metric("colocated"), Some(2.0));
            // Conservation is enforced inside execute (a violation fails
            // the job); the record carries the stream's ledger.
            assert!(r.metric("issued").unwrap() > 0.0);
            assert!(r.metric("dropped").unwrap() <= r.metric("issued").unwrap());
            assert!(r.metric("throughput_rps").unwrap() > 0.0);
            assert!(r.metric("p99_ms").unwrap() >= r.metric("p50_ms").unwrap());
        }
    }

    #[test]
    fn multimodel_dedicated_uses_one_replica_per_model() {
        let yaml = MULTIMODEL_SUBMISSION.replace("mode: shared", "mode: dedicated");
        let spec = JobSpec::parse_yaml(&yaml).unwrap();
        let records = execute(&spec, 3, 1.0, 1).unwrap();
        assert_eq!(records.len(), 2);
        for r in &records {
            assert_eq!(r.label("mode"), Some("dedicated"));
            assert_eq!(r.metric("replicas"), Some(2.0));
            assert_eq!(r.metric("colocated"), Some(1.0));
        }
    }

    #[test]
    fn multimodel_rejects_bad_mode_model_and_overflow() {
        let bad_mode = JobSpec::parse_yaml("task: multimodel\nmodels: [resnet50]\nmode: vibes\n")
            .unwrap();
        assert!(execute(&bad_mode, 0, 1.0, 1).is_err());
        let bad_model =
            JobSpec::parse_yaml("task: multimodel\nmodels: [alexnet9000]\n").unwrap();
        assert!(execute(&bad_model, 0, 1.0, 1).is_err());
        // bert_large alone is ~1.36 GB of weights: a 1 GB budget must be
        // refused as an error, not a worker panic.
        let overflow = JobSpec::parse_yaml(
            "task: multimodel\nmodels: [bert_large]\nmem_gb: 1.0\nmode: shared\n",
        )
        .unwrap();
        assert!(execute(&overflow, 0, 1.0, 1).is_err());
    }

    #[test]
    fn sweep_rejects_unknown_router() {
        let spec = JobSpec::parse_yaml(
            "task: sweep\nmodel: resnet50\nplatform: G1\nrouters: [teleport]\nreplicas: [1]\n",
        )
        .unwrap();
        assert!(execute(&spec, 0, 1.0, 2).is_err());
    }

    #[test]
    fn sweep_rejects_empty_or_invalid_axes() {
        assert!(JobSpec::parse_yaml("task: sweep\nrouters: []\n").is_err());
        assert!(JobSpec::parse_yaml("task: sweep\nreplicas: []\n").is_err());
        assert!(JobSpec::parse_yaml("task: sweep\nreplicas: [0]\n").is_err());
        // A single bad entry fails the whole submission — the grid must
        // never silently shrink.
        assert!(JobSpec::parse_yaml("task: sweep\nreplicas: [4, 0, 8]\n").is_err());
        assert!(JobSpec::parse_yaml("task: sweep\nreplicas: [4, oops]\n").is_err());
        // Same contract on the router axis: yamlish types unquoted
        // scalars, so a numeric entry is not a router name.
        assert!(JobSpec::parse_yaml("task: sweep\nrouters: [round-robin, 42]\n").is_err());
    }

    #[test]
    fn rejects_unknown_top_level_keys() {
        // A typo'd key would fall back to a default and silently run a
        // different benchmark; the parse must name the offender instead.
        let err = JobSpec::parse_yaml("task: cluster_sim\nmodel: resnet50\nreplcas: 3\n")
            .unwrap_err();
        assert!(err.to_string().contains("replcas"), "{err}");
        assert!(err.to_string().contains("replicas"), "should list accepted keys: {err}");
        assert!(JobSpec::parse_yaml("task: serving_sim\nrouter: round-robin\n").is_err());
        assert!(JobSpec::parse_yaml("task: sweep\nrouter: round-robin\n").is_err());
        assert!(
            JobSpec::parse_yaml("task: multimodel\nmodels: [resnet50]\nmodel: resnet50\n")
                .is_err()
        );
        assert!(JobSpec::parse_yaml("task: hardware_sweep\nscale: sketch\n").is_err());
        assert!(JobSpec::parse_yaml("task: sleep\nseconds: 1\nminutes: 2\n").is_err());
        // name / task / est_duration_s are accepted everywhere.
        assert!(JobSpec::parse_yaml("name: z\ntask: sleep\nseconds: 1\nest_duration_s: 2\n")
            .is_ok());
    }

    const QOS_SUBMISSION: &str = r#"
name: qos-cluster
task: cluster_sim
model: resnet50
platform: G1
software: tris
replicas: 2
workload:
  rate: 300.0
  duration_s: 10
batching:
  max_size: 8
  max_wait_ms: 2
admission:
  shed_depth: [4000, 40]
  tenants:
    - name: gold
      class: 0
      weight: 3.0
    - name: bronze
      class: 1
      rate: 40.0
      burst: 8.0
"#;

    #[test]
    fn parses_admission_block() {
        let spec = JobSpec::parse_yaml(QOS_SUBMISSION).unwrap();
        match &spec.kind {
            JobKind::ClusterSim { admission: Some(a), .. } => {
                assert_eq!(a.shed_depth, vec![4000, 40]);
                assert_eq!(a.tenants.len(), 2);
                assert_eq!(a.tenants[0].name, "gold");
                assert_eq!(a.tenants[0].class, 0);
                assert_eq!(a.tenants[0].weight, 3.0);
                assert_eq!(a.tenants[0].rate, None, "gold is not rate-limited");
                assert_eq!(a.tenants[1].class, 1);
                assert_eq!(a.tenants[1].rate, Some(40.0));
                assert_eq!(a.tenants[1].burst, 8.0);
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn rejects_malformed_admission_blocks() {
        let parse = |block: &str| {
            JobSpec::parse_yaml(&format!("task: cluster_sim\nmodel: resnet50\n{block}"))
        };
        assert!(parse("admission:\n  tenants:\n    - name: a\n").is_err(), "missing shed_depth");
        assert!(parse("admission:\n  shed_depth: [10]\n").is_err(), "missing tenants");
        assert!(parse("admission:\n  shed_depth: []\n  tenants:\n    - name: a\n").is_err());
        assert!(parse("admission:\n  shed_depth: [10, 0]\n  tenants:\n    - name: a\n").is_err());
        let class_oob = "admission:\n  shed_depth: [10]\n  tenants:\n    - name: a\n      class: 3\n";
        assert!(parse(class_oob).is_err(), "class without a shed_depth entry");
        let bad_weight =
            "admission:\n  shed_depth: [10]\n  tenants:\n    - name: a\n      weight: 0\n";
        assert!(parse(bad_weight).is_err());
        let bad_rate = "admission:\n  shed_depth: [10]\n  tenants:\n    - name: a\n      rate: 0\n";
        assert!(parse(bad_rate).is_err());
        let bad_burst =
            "admission:\n  shed_depth: [10]\n  tenants:\n    - name: a\n      rate: 5\n      burst: 0.5\n";
        assert!(parse(bad_burst).is_err());
        let orphan_burst =
            "admission:\n  shed_depth: [10]\n  tenants:\n    - name: a\n      burst: 4\n";
        assert!(parse(orphan_burst).is_err(), "burst without rate is inert — reject it");
        let typo = "admission:\n  shed_depth: [10]\n  tenants:\n    - name: a\n      wieght: 2\n";
        assert!(parse(typo).is_err(), "unknown tenant keys are rejected too");
    }

    #[test]
    fn multimodel_admission_tenant_count_must_match_models() {
        let err = JobSpec::parse_yaml(
            "task: multimodel\nmodels: [resnet50, mobilenet_v1]\n\
             admission:\n  shed_depth: [100]\n  tenants:\n    - name: only\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("1 tenants"), "{err}");
        assert!(err.to_string().contains("2 models"), "{err}");
    }

    #[test]
    fn executes_cluster_sim_with_admission_emits_class_records() {
        let spec = JobSpec::parse_yaml(QOS_SUBMISSION).unwrap();
        let records = execute(&spec, 5, 1.0, 1).unwrap();
        assert_eq!(records.len(), 3, "run record + one per class");
        let main = &records[0];
        assert!(main.label("class").is_none());
        // Satellite: `dropped` is broken down by reason, and the reasons
        // account for every drop exactly.
        let reasons = [
            "dropped_queue_full",
            "dropped_shed",
            "dropped_evicted_backlog",
            "dropped_rejected_placement",
            "dropped_replica_failed",
            "dropped_timed_out",
        ];
        let sum: f64 = reasons.iter().map(|k| main.metric(k).unwrap()).sum();
        assert_eq!(sum, main.metric("dropped").unwrap());
        let gold = &records[1];
        let bronze = &records[2];
        assert_eq!(gold.label("class"), Some("0"));
        assert_eq!(bronze.label("class"), Some("1"));
        // The two tenants partition the offered load.
        assert_eq!(
            gold.metric("issued").unwrap() + bronze.metric("issued").unwrap(),
            main.metric("issued").unwrap()
        );
        // Bronze offers ~150 rps against a 40 rps token bucket: most of
        // it sheds. Gold is unlimited and must not shed at all.
        assert!(bronze.metric("shed_fraction").unwrap() > 0.5);
        assert_eq!(gold.metric("dropped_shed").unwrap(), 0.0);
        assert!(gold.metric("goodput").unwrap() > 0.9);
    }

    #[test]
    fn sweep_with_admission_is_thread_count_independent() {
        let yaml = "task: sweep\nmodel: resnet50\nplatform: G1\nsoftware: tris\n\
                    routers: [round-robin]\nreplicas: [1, 2]\n\
                    workload:\n  rate_per_replica: 120.0\n  duration_s: 3\n\
                    admission:\n  shed_depth: [2000, 400]\n  tenants:\n\
                    \x20   - name: gold\n      class: 0\n      weight: 2.0\n\
                    \x20   - name: bronze\n      class: 1\n      rate: 30.0\n      burst: 5.0\n";
        let spec = JobSpec::parse_yaml(yaml).unwrap();
        let serial = execute(&spec, 9, 1.0, 1).unwrap();
        let threaded = execute(&spec, 9, 1.0, 8).unwrap();
        assert_eq!(serial.len(), 4, "2 cells + 2 grid-wide class records");
        assert_eq!(serial.len(), threaded.len());
        for (a, b) in serial.iter().zip(&threaded) {
            assert_eq!(a.label("class"), b.label("class"));
            for key in ["issued", "dropped", "dropped_shed"] {
                assert_eq!(a.metric(key), b.metric(key), "{key}");
            }
        }
        let classes: Vec<&Record> =
            serial.iter().filter(|r| r.label("class").is_some()).collect();
        assert_eq!(classes.len(), 2);
        assert!(classes[1].metric("shed_fraction").unwrap() > 0.0, "bronze bucket binds");
    }

    const FAULTS_SUBMISSION: &str = r#"
name: crash-retry
task: cluster_sim
model: resnet50
platform: G1
software: tris
replicas: 2
router: least-outstanding
workload:
  rate: 100.0
  duration_s: 12
  burst:
    rate: 2000.0
    start_s: 2.5
    duration_s: 1
batching:
  max_size: 8
  max_wait_ms: 2
faults:
  script:
    - op: crash
      replica: 1
      at_s: 3.0
    - op: recover
      replica: 1
      at_s: 6.0
    - op: degrade
      replica: 0
      at_s: 1.0
      until_s: 2.0
      factor: 2.5
retry:
  max_attempts: 4
  deadline_s: 8.0
  backoff_ms: 20
  hedge: true
"#;

    #[test]
    fn parses_faults_and_retry_blocks() {
        let spec = JobSpec::parse_yaml(FAULTS_SUBMISSION).unwrap();
        match &spec.kind {
            JobKind::ClusterSim { faults: Some(f), retry: Some(r), .. } => {
                assert_eq!(f.script.len(), 3);
                assert_eq!(f.script[0], FaultOp::Crash { replica: 1, at_s: 3.0 });
                assert_eq!(f.script[1], FaultOp::Recover { replica: 1, at_s: 6.0 });
                assert_eq!(
                    f.script[2],
                    FaultOp::Degrade { replica: 0, at_s: 1.0, until_s: 2.0, factor: 2.5 }
                );
                assert!(f.profile.is_none());
                assert_eq!(f.recovery_bytes, 0, "defaults to the engine cold-start size");
                assert_eq!(r.max_attempts, 4);
                assert_eq!(r.deadline_s, 8.0);
                assert!((r.backoff_s - 0.02).abs() < 1e-12);
                assert!(r.hedge);
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn parses_fault_profile_block() {
        let spec = JobSpec::parse_yaml(
            "task: cluster_sim\nmodel: resnet50\nfaults:\n  seed: 9\n\
             \x20 profile:\n    mttf_s: 20.0\n    mttr_s: 2.0\n\
             \x20   degrade:\n      mtbd_s: 30.0\n      duration_s: 2.0\n      factor: 3.0\n",
        )
        .unwrap();
        match &spec.kind {
            JobKind::ClusterSim { faults: Some(f), retry: None, .. } => {
                assert!(f.script.is_empty());
                assert_eq!(f.seed, 9);
                let p = f.profile.as_ref().unwrap();
                assert_eq!(p.mttf_s, 20.0);
                assert_eq!(p.mttr_s, 2.0);
                let d = p.degrade.as_ref().unwrap();
                assert_eq!((d.mtbd_s, d.duration_s, d.factor), (30.0, 2.0, 3.0));
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn rejects_malformed_faults_and_retry_blocks() {
        let parse = |block: &str| {
            JobSpec::parse_yaml(&format!("task: cluster_sim\nmodel: resnet50\n{block}"))
        };
        // An empty faults block is almost certainly a mistake.
        assert!(parse("faults:\n  seed: 3\n").is_err());
        assert!(parse("faults:\n  script:\n    - op: explode\n      replica: 0\n      at_s: 1\n")
            .is_err());
        assert!(parse("faults:\n  script:\n    - op: crash\n      at_s: 1\n").is_err(),
            "missing replica");
        assert!(parse("faults:\n  script:\n    - op: crash\n      replica: 0\n").is_err(),
            "missing at_s");
        let bad_window = "faults:\n  script:\n    - op: degrade\n      replica: 0\n\
                          \x20     at_s: 5\n      until_s: 2\n      factor: 2\n";
        assert!(parse(bad_window).is_err(), "inverted degrade window");
        let speedup = "faults:\n  script:\n    - op: degrade\n      replica: 0\n\
                       \x20     at_s: 1\n      until_s: 2\n      factor: 0.5\n";
        assert!(parse(speedup).is_err(), "factor < 1 is a speedup, rejected");
        assert!(parse("faults:\n  profile:\n    mttf_s: 0\n    mttr_s: 1\n").is_err());
        assert!(parse("faults:\n  profile:\n    mttf_s: 5\n").is_err(), "missing mttr_s");
        assert!(parse("faults:\n  mtbf: 5\n").is_err(), "unknown faults key");
        assert!(parse("retry:\n  max_attempts: 0\n").is_err());
        assert!(parse("retry:\n  deadline_s: -1\n").is_err());
        assert!(parse("retry:\n  backoff_ms: 0\n").is_err());
        assert!(parse("retry:\n  hedge: maybe\n").is_err());
        assert!(parse("retry:\n  attempts: 3\n").is_err(), "unknown retry key");
        // hardware_sweep and serving_sim do not take the blocks at all.
        assert!(JobSpec::parse_yaml(
            "task: hardware_sweep\nmodel: resnet50\nretry:\n  max_attempts: 2\n"
        )
        .is_err());
    }

    #[test]
    fn multimodel_rejects_hedged_retry() {
        let err = JobSpec::parse_yaml(
            "task: multimodel\nmodels: [resnet50]\nretry:\n  hedge: true\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("hedge"), "{err}");
        // Un-hedged retry parses fine.
        let ok = JobSpec::parse_yaml(
            "task: multimodel\nmodels: [resnet50]\nretry:\n  max_attempts: 2\n",
        )
        .unwrap();
        match &ok.kind {
            JobKind::MultiModel { retry: Some(r), .. } => assert_eq!(r.max_attempts, 2),
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn executes_cluster_sim_with_faults_and_retry() {
        let spec = JobSpec::parse_yaml(FAULTS_SUBMISSION).unwrap();
        let records = execute(&spec, 5, 1.0, 1).unwrap();
        let r = &records[0];
        // Conservation (checked inside execute) holds across the crash.
        // The crash lands mid-burst, so replica 1 certainly has a
        // backlog — but with 4 attempts against a 3 s outage and an 8 s
        // deadline every stranded request is re-issued, not dropped.
        assert_eq!(r.metric("dropped_replica_failed"), Some(0.0));
        assert!(r.metric("dropped_timed_out").is_some());
        assert!(r.metric("throughput_rps").unwrap() > 0.0);

        // The same submission without retry drops the stranded requests
        // as replica-failed instead of completing them.
        let no_retry_yaml: String = FAULTS_SUBMISSION
            .lines()
            .take_while(|l| !l.starts_with("retry:"))
            .map(|l| format!("{l}\n"))
            .collect();
        let no_retry = JobSpec::parse_yaml(&no_retry_yaml).unwrap();
        let bare = &execute(&no_retry, 5, 1.0, 1).unwrap()[0];
        assert!(
            bare.metric("dropped_replica_failed").unwrap() > 0.0,
            "a mid-burst crash must kill a backlog"
        );
        assert!(
            r.metric("throughput_rps").unwrap() > bare.metric("throughput_rps").unwrap(),
            "retry should complete requests the bare run drops"
        );
    }

    #[test]
    fn sweep_with_faults_is_thread_count_independent() {
        let yaml = "task: sweep\nmodel: resnet50\nplatform: G1\nsoftware: tris\n\
                    routers: [round-robin, least-outstanding]\nreplicas: [2]\n\
                    workload:\n  rate_per_replica: 100.0\n  duration_s: 6\n\
                    faults:\n  profile:\n    mttf_s: 3.0\n    mttr_s: 1.0\n  seed: 11\n\
                    retry:\n  max_attempts: 3\n  deadline_s: 5.0\n  backoff_ms: 20\n";
        let spec = JobSpec::parse_yaml(yaml).unwrap();
        let serial = execute(&spec, 13, 1.0, 1).unwrap();
        let threaded = execute(&spec, 13, 1.0, 8).unwrap();
        assert_eq!(serial.len(), threaded.len());
        for (a, b) in serial.iter().zip(&threaded) {
            assert_eq!(a.label("cell"), b.label("cell"));
            for key in ["p99_ms", "issued", "dropped", "dropped_replica_failed"] {
                assert_eq!(
                    a.metric(key).unwrap().to_bits(),
                    b.metric(key).unwrap().to_bits(),
                    "{key} must be bit-identical across thread budgets under faults"
                );
            }
        }
    }

    #[test]
    fn sweep_parses_followers_and_codec_knobs() {
        let spec = JobSpec::parse_yaml(
            "task: sweep\nrouters: [round-robin]\nreplicas: [1]\nfollowers: 3\ncodec: jsonl\n",
        )
        .unwrap();
        match spec.kind {
            JobKind::Sweep { followers, codec, .. } => {
                assert_eq!(followers, 3);
                assert_eq!(codec, CodecKind::JsonLines);
            }
            k => panic!("{k:?}"),
        }
        // Defaults: run locally, binary wire.
        match JobSpec::parse_yaml(SWEEP_SUBMISSION).unwrap().kind {
            JobKind::Sweep { followers, codec, .. } => {
                assert_eq!(followers, 0);
                assert_eq!(codec, CodecKind::Binary);
            }
            k => panic!("{k:?}"),
        }
        assert!(JobSpec::parse_yaml("task: sweep\nrouters: [rr]\nfollowers: -1\n").is_err());
        assert!(JobSpec::parse_yaml("task: sweep\nrouters: [rr]\ncodec: morse\n").is_err());
        // The knobs are sweep-only top-level keys.
        assert!(JobSpec::parse_yaml("task: cluster_sim\nfollowers: 2\n").is_err());
    }

    #[test]
    fn sweep_grid_doc_round_trips_field_exactly() {
        // Every optional block populated: the doc a shard frame carries
        // must rebuild this kind field-for-field, or followers would run
        // a different grid than the leader planned.
        let yaml = "task: sweep\nmodel: mobilenet_v1\nplatform: G1\nsoftware: tfs\n\
                    routers: [round-robin, power-of-two]\nreplicas: [1, 3]\n\
                    batch_timeouts_ms: [1, 2.5]\n\
                    workload:\n  rate_per_replica: 90.0\n  duration_s: 5\n\
                    batching:\n  max_size: 16\n  max_wait_ms: 2\n\
                    scale: sketch\nsketch_alpha: 0.02\n\
                    admission:\n  shed_depth: [900, 300]\n  tenants:\n\
                    \x20   - name: gold\n      class: 0\n      weight: 2.0\n\
                    \x20   - name: bronze\n      class: 1\n      rate: 40.0\n      burst: 8.0\n\
                    faults:\n  script:\n    - op: degrade\n      replica: 0\n      at_s: 1.0\n\
                    \x20     until_s: 2.0\n      factor: 2.5\n\
                    \x20 profile:\n    mttf_s: 9.0\n    mttr_s: 1.5\n\
                    \x20   degrade:\n      mtbd_s: 4.0\n      duration_s: 0.5\n      factor: 1.5\n\
                    \x20 seed: 3\n  recovery_gb: 2.0\n\
                    retry:\n  max_attempts: 4\n  deadline_s: 6.0\n  backoff_ms: 30\n  hedge: true\n";
        let mut kind = JobSpec::parse_yaml(yaml).unwrap().kind;
        if let JobKind::Sweep { faults: Some(f), .. } = &mut kind {
            // Past i64: exercises the decimal-string u64 encoding.
            f.seed = u64::MAX - 17;
        }
        let doc = sweep_grid_doc(&kind);
        let back = sweep_kind_from_grid_doc(&doc).unwrap();
        assert_eq!(back, kind, "grid doc must rebuild the kind field-exactly");
        // And through compact-JSON text, which is how the doc actually
        // rides inside both codecs' shard frames.
        let text = doc.to_string_compact();
        let reparsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(sweep_kind_from_grid_doc(&reparsed).unwrap(), kind);
    }

    #[test]
    fn sweep_grid_doc_rejects_malformed_docs() {
        let kind = JobSpec::parse_yaml(SWEEP_SUBMISSION).unwrap().kind;
        let doc = sweep_grid_doc(&kind);
        // Drop a required key.
        if let Json::Obj(map) = &doc {
            let mut broken = map.clone();
            broken.remove("routers");
            assert!(sweep_kind_from_grid_doc(&Json::Obj(broken)).is_err());
            let mut broken = map.clone();
            broken.insert("replicas".into(), Json::Arr(Vec::new()));
            assert!(sweep_kind_from_grid_doc(&Json::Obj(broken)).is_err());
        } else {
            panic!("grid doc must be an object");
        }
        assert!(sweep_kind_from_grid_doc(&Json::Null).is_err());
    }

    #[test]
    fn sweep_with_followers_matches_local_execution() {
        // The execute path itself: `followers: 2` shards through the wire
        // codec, yet the PerfDB records are bit-identical to a local run.
        let local = JobSpec::parse_yaml(SWEEP_SUBMISSION).unwrap();
        let sharded = JobSpec::parse_yaml(&format!(
            "{}followers: 2\n",
            SWEEP_SUBMISSION.trim_start_matches('\n')
        ))
        .unwrap();
        let a = execute(&local, 21, 1.0, 2).unwrap();
        let b = execute(&sharded, 21, 1.0, 2).unwrap();
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.label("cell"), rb.label("cell"));
            for key in ["p99_ms", "throughput_rps", "issued", "dropped"] {
                assert_eq!(
                    ra.metric(key).map(f64::to_bits),
                    rb.metric(key).map(f64::to_bits),
                    "{key} must be bit-identical sharded vs local"
                );
            }
        }
        // Satellite: the distributed run surfaces its wire accounting as
        // metrics on every cell record; a local run has no wire.
        for (ra, rb) in a.iter().zip(&b) {
            for key in ["bytes_sent", "bytes_received", "duplicates", "cells_rerun", "rounds"] {
                assert!(ra.metric(key).is_none(), "{key} must be absent on local records");
                assert!(rb.metric(key).is_some(), "{key} must ride on sharded records");
            }
            assert!(rb.metric("bytes_sent").unwrap() > 0.0);
            assert_eq!(rb.metric("rounds"), Some(1.0), "healthy followers finish in one round");
        }
    }

    #[test]
    fn parses_trace_block() {
        let yaml = format!(
            "{}trace:\n  sample: 0.25\n  detail: stages\n  gauge_interval_ms: 50\n\
             \x20 gauge_cap: 128\n  max_spans: 1000\n  out: /tmp/t.json\n",
            CLUSTER_SUBMISSION.trim_start_matches('\n')
        );
        let spec = JobSpec::parse_yaml(&yaml).unwrap();
        let t = spec.trace.as_ref().unwrap();
        assert_eq!(t.config.sample, SampleSpec::Rate(0.25));
        assert_eq!(t.config.detail, Detail::Stages);
        assert_eq!(t.config.gauge_interval_s, Some(0.05));
        assert_eq!(t.config.gauge_cap, 128);
        assert_eq!(t.config.max_spans, 1000);
        assert_eq!(t.out.as_deref(), Some("/tmp/t.json"));
        // Alternative sampling forms and the full-on defaults.
        let nth = JobSpec::parse_yaml("task: sweep\nrouters: [rr]\nreplicas: [1]\n\
                                       trace:\n  every_nth: 8\n")
            .unwrap();
        let cfg = nth.trace.unwrap().config;
        assert_eq!(cfg.sample, SampleSpec::EveryNth(8));
        assert_eq!(cfg.detail, Detail::Full, "defaults mirror TraceConfig::full()");
        assert_eq!(cfg.gauge_interval_s, Some(0.1));
        let off = JobSpec::parse_yaml("task: multimodel\nmodels: [resnet50]\n\
                                       trace:\n  sample: off\n  gauge_interval_ms: 0\n")
            .unwrap();
        let cfg = off.trace.unwrap().config;
        assert_eq!(cfg.sample, SampleSpec::Off);
        assert_eq!(cfg.gauge_interval_s, None, "0 disables the timelines");
        // No block at all — the zero-cost default.
        assert!(JobSpec::parse_yaml(CLUSTER_SUBMISSION).unwrap().trace.is_none());
    }

    #[test]
    fn rejects_malformed_trace_blocks() {
        let parse = |block: &str| {
            JobSpec::parse_yaml(&format!("task: cluster_sim\nmodel: resnet50\n{block}"))
        };
        assert!(parse("trace:\n  sample: 2.0\n").is_err());
        assert!(parse("trace:\n  sample: -0.1\n").is_err());
        assert!(parse("trace:\n  sample: maybe\n").is_err());
        assert!(parse("trace:\n  sample: all\n  every_nth: 4\n").is_err(), "one or the other");
        assert!(parse("trace:\n  every_nth: 0\n").is_err());
        assert!(parse("trace:\n  detail: everything\n").is_err());
        assert!(parse("trace:\n  gauge_interval_ms: -1\n").is_err());
        assert!(parse("trace:\n  gauge_cap: 0\n").is_err());
        assert!(parse("trace:\n  max_spans: 0\n").is_err());
        assert!(parse("trace:\n  out: 42\n").is_err());
        assert!(parse("trace:\n  verbose: true\n").is_err(), "unknown trace key");
        // Only the three engine tasks take the block.
        assert!(JobSpec::parse_yaml("task: serving_sim\ntrace:\n  sample: all\n").is_err());
        assert!(JobSpec::parse_yaml(
            "task: hardware_sweep\nmodel: resnet50\ntrace:\n  sample: all\n"
        )
        .is_err());
        assert!(
            JobSpec::parse_yaml("task: sleep\nseconds: 1\ntrace:\n  sample: all\n").is_err()
        );
    }

    #[test]
    fn trace_block_is_observational_and_exports_perfetto() {
        let base = "task: cluster_sim\nmodel: resnet50\nplatform: G1\nsoftware: tris\n\
                    replicas: 2\nworkload:\n  rate: 120.0\n  duration_s: 5\n\
                    batching:\n  max_size: 8\n  max_wait_ms: 2\n";
        let path =
            std::env::temp_dir().join(format!("inferbench_job_trace_{}.json", std::process::id()));
        let traced_yaml = format!(
            "{base}trace:\n  sample: all\n  detail: full\n  gauge_interval_ms: 100\n  out: {}\n",
            path.display()
        );
        let plain = execute(&JobSpec::parse_yaml(base).unwrap(), 7, 1.0, 1).unwrap();
        let traced = execute(&JobSpec::parse_yaml(&traced_yaml).unwrap(), 7, 1.0, 1).unwrap();
        // Tracing is observational: every record metric is bit-identical.
        assert_eq!(plain.len(), traced.len());
        for (a, b) in plain.iter().zip(&traced) {
            for key in ["p50_ms", "p99_ms", "throughput_rps", "issued", "dropped"] {
                assert_eq!(
                    a.metric(key).map(f64::to_bits),
                    b.metric(key).map(f64::to_bits),
                    "{key} must not move when tracing is on"
                );
            }
        }
        // And the export is a well-formed, non-empty Chrome-trace doc.
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        match doc.get("traceEvents") {
            Some(Json::Arr(events)) => assert!(!events.is_empty(), "empty trace export"),
            other => panic!("traceEvents missing: {other:?}"),
        }
        std::fs::remove_file(&path).ok();
    }
}
