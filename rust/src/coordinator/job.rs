//! Benchmark-job specifications (paper §4.2.2: "From their submission
//! (a YAML file), the system first chooses ...") and their execution.
//!
//! A submission parses into a [`JobSpec`]; a follower worker executes it
//! with [`execute`], producing PerfDB records. Job kinds cover the tasks
//! the paper's system automates: serving-tier simulations, hardware-tier
//! sweeps, and (for scheduler studies / tests) calibrated sleeps.

use crate::hardware::{self, Parallelism};
use crate::models::catalog;
use crate::perfdb::Record;
use crate::pipeline::{Processors, RequestPath, LAN};
use crate::serving::{self, backends, Policy, ServiceModel, SimConfig};
use crate::util::json::Json;
use crate::util::yamlish;
use crate::workload::{generate, Pattern};
use anyhow::{anyhow, bail, Result};

/// What a worker should run.
#[derive(Debug, Clone, PartialEq)]
pub enum JobKind {
    /// Simulate a serving pipeline (software/pipeline tiers).
    ServingSim {
        model: String,
        platform: String,
        software: String,
        rate_rps: f64,
        duration_s: f64,
        max_batch: usize,
        max_wait_s: f64,
    },
    /// Roofline sweep of a model across batch sizes (hardware tier).
    HardwareSweep { model: String, platform: String, batches: Vec<usize> },
    /// Do nothing for a fixed time (scheduler studies; time is scaled by
    /// the leader's `time_scale`).
    Sleep { seconds: f64 },
}

/// A parsed benchmark submission.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub name: String,
    pub kind: JobKind,
    /// Scheduler's duration estimate (paper: processing times are known).
    pub est_duration_s: f64,
}

impl JobSpec {
    /// Parse a YAML submission (see `examples/submissions/` for samples).
    pub fn parse_yaml(text: &str) -> Result<JobSpec> {
        let doc = yamlish::parse(text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<JobSpec> {
        let name = doc
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("unnamed")
            .to_string();
        let task = doc
            .get("task")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("submission missing 'task'"))?;
        let kind = match task {
            "serving_sim" => {
                let wl = doc.get("workload");
                JobKind::ServingSim {
                    model: str_or(doc, "model", "resnet50"),
                    platform: str_or(doc, "platform", "G1"),
                    software: str_or(doc, "software", "tfs"),
                    rate_rps: wl.and_then(|w| w.get("rate")).and_then(|v| v.as_f64()).unwrap_or(30.0),
                    duration_s: wl
                        .and_then(|w| w.get("duration_s"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(60.0),
                    max_batch: doc
                        .get("batching")
                        .and_then(|b| b.get("max_size"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(8) as usize,
                    max_wait_s: doc
                        .get("batching")
                        .and_then(|b| b.get("max_wait_ms"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(5.0)
                        / 1e3,
                }
            }
            "hardware_sweep" => JobKind::HardwareSweep {
                model: str_or(doc, "model", "resnet50"),
                platform: str_or(doc, "platform", "G1"),
                batches: doc
                    .get("batches")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|i| i as usize).collect())
                    .unwrap_or_else(|| vec![1, 2, 4, 8, 16, 32]),
            },
            "sleep" => JobKind::Sleep {
                seconds: doc.get("seconds").and_then(|v| v.as_f64()).unwrap_or(1.0),
            },
            other => bail!("unknown task kind {other:?}"),
        };
        let est = doc
            .get("est_duration_s")
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| default_estimate(&kind));
        Ok(JobSpec { name, kind, est_duration_s: est })
    }
}

fn str_or(doc: &Json, key: &str, default: &str) -> String {
    doc.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
}

/// Duration estimate used by the scheduler when the submission omits one.
fn default_estimate(kind: &JobKind) -> f64 {
    match kind {
        JobKind::ServingSim { duration_s, .. } => duration_s * 0.05 + 2.0, // sim runs much faster than simulated time
        JobKind::HardwareSweep { batches, .. } => 0.5 + batches.len() as f64 * 0.1,
        JobKind::Sleep { seconds } => *seconds,
    }
}

/// Family parallelism for a catalog model (the roofline occupancy input).
fn parallelism_for(model: &catalog::CatalogModel) -> Parallelism {
    match model.task {
        // Conv nets: per-sample row parallelism is bounded by the
        // mid/late feature maps (~28x28), not the input resolution —
        // this is what produces the paper's flat small-batch latency.
        catalog::Task::IC | catalog::Task::OD | catalog::Task::GAN => Parallelism::cnn(28),
        catalog::Task::NLP => Parallelism::sequence(128),
        catalog::Task::TC => Parallelism::sequence(64),
    }
}

/// Build the serving-sim service model for (model, platform).
pub fn service_model_for(model_name: &str, platform_id: &str) -> Result<ServiceModel> {
    let model = catalog::find(model_name)
        .ok_or_else(|| anyhow!("model {model_name:?} not in catalog"))?;
    let platform = hardware::find(platform_id)
        .ok_or_else(|| anyhow!("platform {platform_id:?} not in Table 1"))?;
    Ok(ServiceModel::Analytic {
        platform,
        profile: model.profile,
        parallelism: parallelism_for(model),
        request_bytes: model.request_bytes,
    })
}

/// Execute a job, producing PerfDB records. `time_scale` divides sleep
/// durations (scheduler studies run faster than real time).
pub fn execute(spec: &JobSpec, seed: u64, time_scale: f64) -> Result<Vec<Record>> {
    match &spec.kind {
        JobKind::ServingSim { model, platform, software, rate_rps, duration_s, max_batch, max_wait_s } => {
            let sw = backends::find(software)
                .ok_or_else(|| anyhow!("software {software:?} unknown"))?;
            let m = catalog::find(model).ok_or_else(|| anyhow!("model {model:?} unknown"))?;
            let config = SimConfig {
                arrivals: generate(&Pattern::Poisson { rate: *rate_rps }, *duration_s, seed),
                closed_loop: None,
                duration_s: *duration_s,
                policy: Policy::Dynamic { max_size: *max_batch, max_wait_s: *max_wait_s },
                software: sw,
                service: service_model_for(model, platform)?,
                path: RequestPath {
                    processors: Processors::image(),
                    network: LAN,
                    payload_bytes: m.request_bytes,
                },
                max_queue: 4096,
                seed,
            };
            let result = serving::run(&config);
            let mut collector = result.collector;
            let record = Record::new("serving_sim", model, platform, software)
                .with_metric("rate_rps", *rate_rps)
                .with_metric("p50_ms", collector.e2e.percentile(50.0) * 1e3)
                .with_metric("p95_ms", collector.e2e.percentile(95.0) * 1e3)
                .with_metric("p99_ms", collector.e2e.percentile(99.0) * 1e3)
                .with_metric("throughput_rps", collector.throughput_rps())
                .with_metric("mean_batch", result.batch_sizes.iter().sum::<usize>() as f64 / result.batch_sizes.len().max(1) as f64)
                .with_metric("utilization", result.timeline.mean())
                .with_metric("dropped", result.dropped as f64);
            Ok(vec![record])
        }
        JobKind::HardwareSweep { model, platform, batches } => {
            let m = catalog::find(model).ok_or_else(|| anyhow!("model {model:?} unknown"))?;
            let p = hardware::find(platform)
                .ok_or_else(|| anyhow!("platform {platform:?} unknown"))?;
            let par = parallelism_for(m);
            let mut out = Vec::new();
            for &b in batches {
                let est = hardware::estimate(p, &m.profile, par, b, m.request_bytes);
                out.push(
                    Record::new("hardware_sweep", model, platform, "-")
                        .with_metric("batch", b as f64)
                        .with_metric("latency_ms", est.total_s * 1e3)
                        .with_metric("latency_per_sample_ms", est.total_s / b as f64 * 1e3)
                        .with_metric("throughput_rps", b as f64 / est.total_s)
                        .with_metric("utilization", est.utilization)
                        .with_metric("memory_bound", if est.memory_bound { 1.0 } else { 0.0 }),
                );
            }
            Ok(out)
        }
        JobKind::Sleep { seconds } => {
            std::thread::sleep(std::time::Duration::from_secs_f64(seconds / time_scale.max(1e-9)));
            Ok(vec![Record::new("sleep", "-", "-", "-").with_metric("seconds", *seconds)])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SUBMISSION: &str = r#"
name: resnet-tail-latency
task: serving_sim
model: resnet50
platform: G1
software: tris
workload:
  rate: 80.0
  duration_s: 10
batching:
  max_size: 16
  max_wait_ms: 2
"#;

    #[test]
    fn parses_serving_submission() {
        let spec = JobSpec::parse_yaml(SUBMISSION).unwrap();
        assert_eq!(spec.name, "resnet-tail-latency");
        match &spec.kind {
            JobKind::ServingSim { model, software, rate_rps, max_batch, max_wait_s, .. } => {
                assert_eq!(model, "resnet50");
                assert_eq!(software, "tris");
                assert_eq!(*rate_rps, 80.0);
                assert_eq!(*max_batch, 16);
                assert!((max_wait_s - 0.002).abs() < 1e-12);
            }
            k => panic!("{k:?}"),
        }
        assert!(spec.est_duration_s > 0.0);
    }

    #[test]
    fn parses_hardware_sweep() {
        let spec =
            JobSpec::parse_yaml("task: hardware_sweep\nmodel: bert_large\nplatform: G3\nbatches: [1, 8]\n")
                .unwrap();
        match &spec.kind {
            JobKind::HardwareSweep { batches, platform, .. } => {
                assert_eq!(batches, &vec![1, 8]);
                assert_eq!(platform, "G3");
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn rejects_unknown_task() {
        assert!(JobSpec::parse_yaml("task: mine_bitcoin\n").is_err());
        assert!(JobSpec::parse_yaml("name: x\n").is_err());
    }

    #[test]
    fn executes_serving_sim() {
        let spec = JobSpec::parse_yaml(SUBMISSION).unwrap();
        let records = execute(&spec, 7, 1.0).unwrap();
        assert_eq!(records.len(), 1);
        let r = &records[0];
        assert!(r.metric("p99_ms").unwrap() >= r.metric("p50_ms").unwrap());
        assert!(r.metric("throughput_rps").unwrap() > 0.0);
    }

    #[test]
    fn executes_hardware_sweep() {
        let spec = JobSpec::parse_yaml(
            "task: hardware_sweep\nmodel: resnet50\nplatform: G1\nbatches: [1, 4, 16]\n",
        )
        .unwrap();
        let records = execute(&spec, 0, 1.0).unwrap();
        assert_eq!(records.len(), 3);
        // Per-sample latency should fall with batch.
        let l1 = records[0].metric("latency_per_sample_ms").unwrap();
        let l16 = records[2].metric("latency_per_sample_ms").unwrap();
        assert!(l16 < l1);
    }

    #[test]
    fn execute_rejects_unknown_model() {
        let spec =
            JobSpec::parse_yaml("task: hardware_sweep\nmodel: alexnet9000\nplatform: G1\n").unwrap();
        assert!(execute(&spec, 0, 1.0).is_err());
    }

    #[test]
    fn sleep_respects_time_scale() {
        let spec = JobSpec::parse_yaml("task: sleep\nseconds: 0.2\n").unwrap();
        let t0 = std::time::Instant::now();
        execute(&spec, 0, 100.0).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 0.1);
    }
}
