//! The leader/follower benchmark cluster (paper §4.1, Fig 1/Fig 5).
//!
//! The leader accepts submissions (task manager), places them on follower
//! workers via the two-tier scheduler (queue-aware LB at the leader, SJF
//! at each worker), monitors worker status, and aggregates results into
//! the PerfDB. Followers are worker threads here instead of cluster nodes
//! (DESIGN.md §2) — the scheduling dynamics are identical; only the
//! transport differs.

use super::job::{self, JobSpec};
use super::scheduler::{LoadBalance, LocalOrder, SchedulerPolicy};
use crate::obs::{self, Attr};
use crate::perfdb::{PerfDb, Record};
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// A submitted job tracked by the task manager.
#[derive(Debug, Clone)]
struct Pending {
    id: u64,
    spec: JobSpec,
    submitted: Instant,
    /// Execution attempts already made (0 for a fresh submission).
    attempts: u32,
    /// Retry backoff gate: the job is not eligible to start before this
    /// instant. `None` for fresh submissions.
    not_before: Option<Instant>,
}

/// Completion log entry (the task manager's record, paper §4.2.1).
#[derive(Debug, Clone)]
pub struct Completed {
    pub id: u64,
    pub name: String,
    pub worker: usize,
    /// Queue wait, seconds.
    pub waited_s: f64,
    /// Execution time of the final attempt, seconds.
    pub ran_s: f64,
    /// Execution attempts consumed: 1 = first try succeeded; `ok: false`
    /// with `attempts == max_job_attempts` means the job gave up after
    /// exhausting its retries.
    pub attempts: u32,
    pub ok: bool,
    /// Completion instant on the leader's wall clock, seconds since
    /// `Leader::start`. Together with `waited_s`/`ran_s` this anchors the
    /// job's queue→run intervals on one shared timeline
    /// ([`Leader::job_spans`]).
    pub finished_s: f64,
}

impl Completed {
    pub fn jct_s(&self) -> f64 {
        self.waited_s + self.ran_s
    }
}

/// Monitor snapshot of one worker (paper §4.2.1 Monitor).
#[derive(Debug, Clone)]
pub struct WorkerStatus {
    pub worker: usize,
    pub queued: usize,
    /// Estimated seconds of queued (not yet started) work — the published
    /// queue length of Algorithm 1.
    pub backlog_s: f64,
    /// Remaining estimate of the job currently executing, in submitted
    /// (unscaled) seconds; 0 when idle.
    pub running_remaining_s: f64,
    pub busy: bool,
    pub completed: u64,
}

/// The job a worker is currently executing: its scheduler estimate and
/// when it started, so the leader can charge the *remaining* estimate in
/// queue-aware placement instead of a flat busy penalty.
#[derive(Debug, Clone)]
struct RunningJob {
    /// Identity for the monitor and `wait_for`'s timeout report — a
    /// timed-out caller is told *which* jobs are outstanding and where.
    id: u64,
    name: String,
    est_s: f64,
    started: Instant,
    /// Whether this job executes at `1/time_scale` real time (only Sleep
    /// jobs do; sims and sweeps run in real time regardless of the
    /// leader's scale).
    time_scaled: bool,
}

struct WorkerShared {
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    backlog_s: Mutex<f64>,
    running: Mutex<Option<RunningJob>>,
    busy: AtomicBool,
    completed: AtomicU64,
    stop: AtomicBool,
}

/// The task manager's completion log plus the condvar workers signal on
/// every push — `Leader::wait_for` blocks on it instead of polling.
struct CompletionLog {
    entries: Mutex<Vec<Completed>>,
    cv: Condvar,
}

impl WorkerShared {
    /// Remaining estimate of the running job in submitted (unscaled)
    /// seconds. Wall-clock elapsed is mapped back to job seconds via
    /// `time_scale` only for jobs that execute scaled (Sleep runs at
    /// `seconds / time_scale` real time; everything else runs in real
    /// time), and clamped at 0 for jobs running past their estimate.
    fn running_remaining_s(&self, time_scale: f64) -> f64 {
        self.running
            .lock()
            .unwrap()
            .as_ref()
            .map(|r| {
                let scale = if r.time_scaled { time_scale } else { 1.0 };
                (r.est_s - r.started.elapsed().as_secs_f64() * scale).max(0.0)
            })
            .unwrap_or(0.0)
    }
}

/// Leader configuration.
#[derive(Debug, Clone)]
pub struct LeaderConfig {
    pub workers: usize,
    pub policy: SchedulerPolicy,
    /// Divides Sleep-job durations (scheduler studies run scaled).
    pub time_scale: f64,
    /// Intra-job parallelism budget per worker: a `sweep` job runs its
    /// grid cells on up to this many threads (`crate::sweep`); every
    /// other job kind runs single-threaded and ignores it. This extends
    /// the paper's two-tier scheduler (queue-aware placement at the
    /// leader, SJF at the worker) with a third tier inside the job.
    pub threads_per_worker: usize,
    /// Total execution attempts a job gets before the leader gives up on
    /// it (>= 1). A failed attempt is re-queued on its worker behind a
    /// capped exponential backoff, its cost estimate re-charged to the
    /// published backlog so queue-aware placement keeps seeing the truth;
    /// retries re-run with the same derived seed, so a deterministic job
    /// retries bit-identically. The final failure lands in the PerfDB as
    /// a `job_failed` record (`status: failed` + attempt count).
    pub max_job_attempts: usize,
    pub seed: u64,
}

impl Default for LeaderConfig {
    fn default() -> Self {
        LeaderConfig {
            workers: 4,
            policy: SchedulerPolicy::qa_sjf(),
            time_scale: 1.0,
            threads_per_worker: 1,
            max_job_attempts: 3,
            seed: 0,
        }
    }
}

impl LeaderConfig {
    /// Wall-clock estimate of `spec` on one of this leader's workers:
    /// sweep jobs divide their serial estimate across the worker's
    /// thread budget (ideal intra-job speedup is the scheduler's model,
    /// matching the paper's known-processing-times premise); everything
    /// else runs serially. Backlog accounting and the running-job
    /// remaining estimate both charge this, so queue-aware placement
    /// sees the time the job will actually occupy the worker.
    fn charged_estimate_s(&self, spec: &JobSpec) -> f64 {
        match &spec.kind {
            job::JobKind::Sweep { routers, replicas, batch_timeouts_s, .. } => {
                // The pool can't use more workers than the grid has
                // cells, so the effective speedup divisor is capped by
                // the cell count (a 2-cell sweep on a 16-thread budget
                // still occupies the worker for ~half its serial time).
                let cells =
                    (routers.len() * replicas.len() * batch_timeouts_s.len()).max(1);
                let budget = self.threads_per_worker.max(1).min(cells);
                spec.est_duration_s / budget as f64
            }
            _ => spec.est_duration_s,
        }
    }
}

/// The running cluster.
pub struct Leader {
    config: LeaderConfig,
    shared: Vec<Arc<WorkerShared>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub perfdb: Arc<Mutex<PerfDb>>,
    completions: Arc<CompletionLog>,
    next_id: AtomicU64,
    rr: AtomicU64,
}

impl Leader {
    /// Start the cluster: spawns follower worker threads.
    pub fn start(config: LeaderConfig) -> Leader {
        let perfdb = Arc::new(Mutex::new(PerfDb::new()));
        let completions =
            Arc::new(CompletionLog { entries: Mutex::new(Vec::new()), cv: Condvar::new() });
        let mut shared = Vec::new();
        let mut handles = Vec::new();
        let epoch = Instant::now();
        for w in 0..config.workers {
            let ws = Arc::new(WorkerShared {
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                backlog_s: Mutex::new(0.0),
                running: Mutex::new(None),
                busy: AtomicBool::new(false),
                completed: AtomicU64::new(0),
                stop: AtomicBool::new(false),
            });
            shared.push(ws.clone());
            let db = perfdb.clone();
            let done = completions.clone();
            let cfg = config.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("inferbench-worker-{w}"))
                    .spawn(move || worker_loop(w, ws, db, done, cfg, epoch))
                    .expect("spawn worker"),
            );
        }
        Leader {
            config,
            shared,
            handles,
            perfdb,
            completions,
            next_id: AtomicU64::new(0),
            rr: AtomicU64::new(0),
        }
    }

    /// Tier-1 placement: submit a job; returns (job id, chosen worker).
    pub fn submit(&self, spec: JobSpec) -> Result<(u64, usize)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let w = match self.config.policy.lb {
            LoadBalance::RoundRobin => {
                (self.rr.fetch_add(1, Ordering::Relaxed) as usize) % self.shared.len()
            }
            LoadBalance::QueueAware => {
                // Workers publish queue length (backlog seconds) plus the
                // remaining estimate of the job they are executing; pick
                // min. A flat busy penalty here (the old `+1.0`) made a
                // worker finishing a 0.1 s job tie with one mid-way
                // through a 20-minute sweep.
                let mut best = 0;
                let mut best_backlog = f64::INFINITY;
                for (i, ws) in self.shared.iter().enumerate() {
                    let b = *ws.backlog_s.lock().unwrap()
                        + ws.running_remaining_s(self.config.time_scale);
                    if b < best_backlog {
                        best_backlog = b;
                        best = i;
                    }
                }
                best
            }
        };
        let ws = &self.shared[w];
        {
            let mut q = ws.queue.lock().unwrap();
            let charged = self.config.charged_estimate_s(&spec);
            q.push_back(Pending {
                id,
                spec: spec.clone(),
                submitted: Instant::now(),
                attempts: 0,
                not_before: None,
            });
            *ws.backlog_s.lock().unwrap() += charged;
        }
        ws.cv.notify_one();
        Ok((id, w))
    }

    /// Parse + submit a YAML submission.
    pub fn submit_yaml(&self, text: &str) -> Result<(u64, usize)> {
        self.submit(JobSpec::parse_yaml(text)?)
    }

    /// Monitor: current status of every worker.
    pub fn status(&self) -> Vec<WorkerStatus> {
        self.shared
            .iter()
            .enumerate()
            .map(|(i, ws)| WorkerStatus {
                worker: i,
                queued: ws.queue.lock().unwrap().len(),
                backlog_s: *ws.backlog_s.lock().unwrap(),
                running_remaining_s: ws.running_remaining_s(self.config.time_scale),
                busy: ws.busy.load(Ordering::Relaxed),
                completed: ws.completed.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Block until `n` jobs have completed (or timeout). Workers signal
    /// the completion condvar on every push, so this wakes exactly when
    /// progress happens instead of polling on a sleep — no wasted
    /// wakeups, and completion is observed the instant it lands.
    pub fn wait_for(&self, n: usize, timeout: std::time::Duration) -> Result<Vec<Completed>> {
        let deadline = Instant::now() + timeout;
        let mut done = self.completions.entries.lock().unwrap();
        loop {
            if done.len() >= n {
                return Ok(done.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                // Name the stragglers, don't just count them: list every
                // outstanding job (running or queued) with its id and the
                // worker it sits on, so a timed-out caller can see *what*
                // is stuck *where* instead of re-deriving it from logs.
                let completed = done.len();
                drop(done);
                let mut outstanding = Vec::new();
                for (w, ws) in self.shared.iter().enumerate() {
                    if let Some(r) = ws.running.lock().unwrap().as_ref() {
                        outstanding
                            .push(format!("job {} '{}' running on worker {w}", r.id, r.name));
                    }
                    for p in ws.queue.lock().unwrap().iter() {
                        outstanding.push(format!(
                            "job {} '{}' queued on worker {w}",
                            p.id, p.spec.name
                        ));
                    }
                }
                return Err(anyhow!(
                    "timeout: {completed} of {n} jobs completed; outstanding: [{}]",
                    outstanding.join(", ")
                ));
            }
            let (guard, _timed_out) =
                self.completions.cv.wait_timeout(done, deadline - now).unwrap();
            done = guard;
        }
    }

    /// All completions so far.
    pub fn completions(&self) -> Vec<Completed> {
        self.completions.entries.lock().unwrap().clone()
    }

    /// Coordinator job spans from the completion log: one `job` root per
    /// completed job (track = job id, sorted by id) with `queued` and
    /// `run` children on the leader's wall-clock timeline, carrying
    /// name/worker/attempts/outcome as attributes. Export through
    /// [`crate::obs::perfetto::trace_json`] like any engine trace.
    ///
    /// Wall-clock, not sim time: this is the one tracing pillar that is
    /// **not** byte-stable across runs — the engine-side spans and gauges
    /// are, and the bit-identity tests cover only those.
    pub fn job_spans(&self) -> obs::TraceOutput {
        let mut entries = self.completions();
        entries.sort_by_key(|c| c.id);
        let mut spans = Vec::with_capacity(entries.len() * 3);
        for c in &entries {
            let run_start = (c.finished_s - c.ran_s).max(0.0);
            let queue_start = (run_start - c.waited_s).max(0.0);
            let outcome = if c.ok { "completed" } else { "failed" };
            let root = spans.len() as u32;
            spans.push(obs::Span {
                id: root,
                parent: None,
                name: "job".to_string(),
                track: c.id,
                start_s: queue_start,
                end_s: c.finished_s,
                attrs: vec![
                    ("name".to_string(), Attr::S(c.name.clone())),
                    ("worker".to_string(), Attr::U(c.worker as u64)),
                    ("attempts".to_string(), Attr::U(c.attempts as u64)),
                    ("outcome".to_string(), Attr::S(outcome.to_string())),
                ],
            });
            spans.push(obs::Span {
                id: root + 1,
                parent: Some(root),
                name: "queued".to_string(),
                track: c.id,
                start_s: queue_start,
                end_s: run_start,
                attrs: Vec::new(),
            });
            spans.push(obs::Span {
                id: root + 2,
                parent: Some(root),
                name: "run".to_string(),
                track: c.id,
                start_s: run_start,
                end_s: c.finished_s,
                attrs: Vec::new(),
            });
        }
        obs::TraceOutput { spans, gauges: Vec::new(), truncated: 0 }
    }

    /// Stop workers (drains nothing; call after wait_for).
    pub fn shutdown(mut self) {
        for ws in &self.shared {
            ws.stop.store(true, Ordering::Relaxed);
            ws.cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    wid: usize,
    ws: Arc<WorkerShared>,
    db: Arc<Mutex<PerfDb>>,
    done: Arc<CompletionLog>,
    cfg: LeaderConfig,
    epoch: Instant,
) {
    loop {
        // Tier-2 ordering: pick the next job from the local queue.
        let pending = {
            let mut q = ws.queue.lock().unwrap();
            loop {
                if let Some(job) = pick(&mut q, cfg.policy.order, &cfg) {
                    break Some(job);
                }
                if ws.stop.load(Ordering::Relaxed) {
                    break None;
                }
                let (guard, _) =
                    ws.cv.wait_timeout(q, std::time::Duration::from_millis(50)).unwrap();
                q = guard;
            }
        };
        let Some(pending) = pending else { return };

        // The job leaves the queue now: move its estimate out of the
        // published backlog and into the running-job slot, so placement
        // charges remaining work, never a double-count of both. Both
        // sides charge the same thread-budget-adjusted estimate.
        let charged = cfg.charged_estimate_s(&pending.spec);
        {
            let mut b = ws.backlog_s.lock().unwrap();
            *b = (*b - charged).max(0.0);
        }
        *ws.running.lock().unwrap() = Some(RunningJob {
            id: pending.id,
            name: pending.spec.name.clone(),
            est_s: charged,
            started: Instant::now(),
            time_scaled: matches!(pending.spec.kind, job::JobKind::Sleep { .. }),
        });
        ws.busy.store(true, Ordering::Relaxed);
        let waited_s = pending.submitted.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let result = job::execute(
            &pending.spec,
            cfg.seed ^ pending.id,
            cfg.time_scale,
            cfg.threads_per_worker.max(1),
        );
        let ran_s = t0.elapsed().as_secs_f64();
        ws.busy.store(false, Ordering::Relaxed);
        *ws.running.lock().unwrap() = None;

        let ok = match result {
            Ok(records) => {
                let mut db = db.lock().unwrap();
                for r in records {
                    db.insert(r);
                }
                true
            }
            Err(e) => {
                // Failure visibility: every attempt's error lands in the
                // PerfDB, whether or not a retry follows.
                let attempt = pending.attempts + 1;
                db.lock().unwrap().insert(
                    Record::new("job_error", &pending.spec.name, "-", "-")
                        .with_metric("error", 1.0)
                        .with_metric("attempt", attempt as f64),
                );
                if (attempt as usize) < cfg.max_job_attempts.max(1) {
                    // Re-queue behind a capped exponential backoff (50 ms
                    // base, 500 ms cap, mapped through the leader's time
                    // scale like Sleep durations are), re-charging the
                    // cost estimate the dequeue subtracted so queue-aware
                    // placement still sees the pending work. Same id, so
                    // the retry re-runs with the same derived seed.
                    let backoff_ms = (50u64 << (attempt - 1).min(16)).min(500);
                    let backoff = std::time::Duration::from_secs_f64(
                        backoff_ms as f64 / 1e3 / cfg.time_scale.max(1e-9),
                    );
                    eprintln!(
                        "worker {wid}: job {} failed (attempt {attempt}/{}), retrying: {e:#}",
                        pending.spec.name, cfg.max_job_attempts
                    );
                    {
                        let mut q = ws.queue.lock().unwrap();
                        *ws.backlog_s.lock().unwrap() += charged;
                        q.push_back(Pending {
                            attempts: attempt,
                            not_before: Some(Instant::now() + backoff),
                            ..pending
                        });
                    }
                    ws.cv.notify_one();
                    continue;
                }
                // Out of attempts: the failure ledger gets a terminal
                // record distinguishable from per-attempt errors.
                db.lock().unwrap().insert(
                    Record::new("job_failed", &pending.spec.name, "-", "-")
                        .with_label("status", "failed")
                        .with_metric("attempts", attempt as f64),
                );
                eprintln!(
                    "worker {wid}: job {} gave up after {attempt} attempts: {e:#}",
                    pending.spec.name
                );
                false
            }
        };
        ws.completed.fetch_add(1, Ordering::Relaxed);
        {
            let mut entries = done.entries.lock().unwrap();
            entries.push(Completed {
                id: pending.id,
                name: pending.spec.name.clone(),
                worker: wid,
                waited_s,
                ran_s,
                attempts: pending.attempts + 1,
                ok,
                finished_s: epoch.elapsed().as_secs_f64(),
            });
        }
        // Wake every `wait_for` caller; each re-checks its own target.
        done.cv.notify_all();
    }
}

/// Tier-2 pick: FCFS = front; SJF = shortest estimate. SJF compares the
/// same thread-budget-adjusted estimate that tier-1 placement charges
/// (`LeaderConfig::charged_estimate_s`) — a sweep that parallelizes to a
/// quarter of its serial estimate really is the shorter job, and ranking
/// it by the serial number would invert shortest-job-first.
/// Jobs re-queued by the retry path carry a backoff gate (`not_before`)
/// and are skipped until it passes — the worker's 50 ms condvar timeout
/// re-polls, so a gated retry starts within one tick of becoming due.
fn pick(q: &mut VecDeque<Pending>, order: LocalOrder, cfg: &LeaderConfig) -> Option<Pending> {
    let now = Instant::now();
    let eligible = |p: &Pending| p.not_before.map_or(true, |t| t <= now);
    let idx = match order {
        LocalOrder::Fcfs => q.iter().position(|p| eligible(p))?,
        LocalOrder::Sjf => q
            .iter()
            .enumerate()
            .filter(|(_, p)| eligible(p))
            .min_by(|a, b| {
                cfg.charged_estimate_s(&a.1.spec)
                    .partial_cmp(&cfg.charged_estimate_s(&b.1.spec))
                    .unwrap()
            })
            .map(|(i, _)| i)?,
    };
    q.remove(idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfdb::Query;

    fn sleep_spec(name: &str, secs: f64) -> JobSpec {
        JobSpec::parse_yaml(&format!("name: {name}\ntask: sleep\nseconds: {secs}\n")).unwrap()
    }

    #[test]
    fn jobs_run_and_complete() {
        let leader = Leader::start(LeaderConfig { workers: 2, time_scale: 100.0, ..Default::default() });
        for i in 0..6 {
            leader.submit(sleep_spec(&format!("job{i}"), 0.5)).unwrap();
        }
        let done = leader.wait_for(6, std::time::Duration::from_secs(10)).unwrap();
        assert_eq!(done.len(), 6);
        assert!(done.iter().all(|c| c.ok));
        // Both workers participated.
        let workers: std::collections::BTreeSet<usize> = done.iter().map(|c| c.worker).collect();
        assert!(workers.len() >= 2);
        leader.shutdown();
    }

    #[test]
    fn results_land_in_perfdb() {
        let leader = Leader::start(LeaderConfig { workers: 1, ..Default::default() });
        leader
            .submit_yaml(
                "name: sweep\ntask: hardware_sweep\nmodel: resnet50\nplatform: G1\nbatches: [1, 8]\n",
            )
            .unwrap();
        leader.wait_for(1, std::time::Duration::from_secs(10)).unwrap();
        let db = leader.perfdb.lock().unwrap();
        assert_eq!(db.query(&Query::default().task("hardware_sweep")).len(), 2);
        drop(db);
        leader.shutdown();
    }

    #[test]
    fn sweep_job_runs_on_worker_thread_budget() {
        // A `sweep` grid dispatched through the leader executes on the
        // worker's intra-job thread budget and lands one record per cell.
        let leader = Leader::start(LeaderConfig {
            workers: 1,
            threads_per_worker: 4,
            ..Default::default()
        });
        leader
            .submit_yaml(
                "name: grid\ntask: sweep\nmodel: resnet50\nplatform: G1\nsoftware: tris\n\
                 routers: [round-robin, least-outstanding]\nreplicas: [1, 2]\n\
                 workload:\n  rate_per_replica: 40.0\n  duration_s: 3\n",
            )
            .unwrap();
        let done = leader.wait_for(1, std::time::Duration::from_secs(60)).unwrap();
        assert!(done[0].ok, "sweep job failed");
        let db = leader.perfdb.lock().unwrap();
        let recs = db.query(&Query::default().task("sweep"));
        assert_eq!(recs.len(), 4, "2 fleet sizes x 2 routers");
        assert!(recs.iter().any(|r| r.label("router") == Some("least-outstanding")));
        drop(db);
        leader.shutdown();
    }

    #[test]
    fn job_spans_cover_the_completion_log() {
        let leader = Leader::start(LeaderConfig {
            workers: 2,
            time_scale: 100.0,
            ..Default::default()
        });
        for i in 0..4 {
            leader.submit(sleep_spec(&format!("job{i}"), 0.5)).unwrap();
        }
        leader.wait_for(4, std::time::Duration::from_secs(10)).unwrap();
        let trace = leader.job_spans();
        assert_eq!(trace.spans.len(), 12, "a root + queued + run triple per job");
        for chunk in trace.spans.chunks(3) {
            let (root, queued, run) = (&chunk[0], &chunk[1], &chunk[2]);
            assert_eq!(root.name, "job");
            assert_eq!((queued.name.as_str(), run.name.as_str()), ("queued", "run"));
            assert_eq!(queued.parent, Some(root.id));
            assert_eq!(run.parent, Some(root.id));
            // The children tile the root exactly on one timeline.
            assert_eq!(root.start_s, queued.start_s);
            assert_eq!(queued.end_s, run.start_s);
            assert_eq!(run.end_s, root.end_s);
            assert!(root.end_s >= root.start_s);
            assert!(root.attrs.iter().any(|(k, v)| k == "outcome" && v.render() == "completed"));
        }
        // Roots are sorted by job id — a deterministic export order even
        // though completion order is scheduling-dependent.
        let tracks: Vec<u64> = trace.spans.iter().step_by(3).map(|s| s.track).collect();
        assert_eq!(tracks, vec![0, 1, 2, 3]);
        leader.shutdown();
    }

    #[test]
    fn wait_for_timeout_names_outstanding_jobs() {
        // One worker, two slow jobs: at the deadline one is running and
        // one is queued, and the error must name both with their ids and
        // placements — not just count them.
        let leader = Leader::start(LeaderConfig {
            workers: 1,
            time_scale: 10.0,
            ..Default::default()
        });
        leader.submit(sleep_spec("glacier", 8.0)).unwrap();
        leader.submit(sleep_spec("queued-up", 8.0)).unwrap();
        let err = leader
            .wait_for(2, std::time::Duration::from_millis(250))
            .unwrap_err()
            .to_string();
        assert!(err.contains("0 of 2 jobs completed"), "{err}");
        assert!(err.contains("'glacier' running on worker 0"), "{err}");
        assert!(err.contains("'queued-up' queued on worker 0"), "{err}");
        assert!(err.contains("job 0") && err.contains("job 1"), "{err}");
        leader.shutdown();
    }

    #[test]
    fn queue_aware_avoids_busy_worker() {
        // One long job on worker A; following shorts should go elsewhere.
        let leader = Leader::start(LeaderConfig {
            workers: 2,
            policy: SchedulerPolicy::qa_sjf(),
            time_scale: 10.0,
            threads_per_worker: 1,
            max_job_attempts: 3,
            seed: 0,
        });
        leader.submit(sleep_spec("long", 5.0)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(30));
        let mut placements = Vec::new();
        for i in 0..4 {
            placements.push(leader.submit(sleep_spec(&format!("s{i}"), 0.1)).unwrap().1);
        }
        leader.wait_for(5, std::time::Duration::from_secs(10)).unwrap();
        // All four short jobs placed on the other worker.
        let long_worker = leader
            .completions()
            .iter()
            .find(|c| c.name == "long")
            .unwrap()
            .worker;
        assert!(placements.iter().all(|&w| w != long_worker), "{placements:?} vs {long_worker}");
        leader.shutdown();
    }

    #[test]
    fn queue_aware_uses_remaining_estimate_not_flat_busy_penalty() {
        // Regression: placement added a constant +1.0 for any busy worker,
        // so a worker mid-way through a long job tied with one about to
        // finish a short one. With remaining-estimate tracking, a short
        // job submitted while w_long runs a 5 s job (~4.5 s remaining) and
        // w_med runs a 1 s job (~0.5 s remaining) must land on w_med.
        let leader = Leader::start(LeaderConfig {
            workers: 2,
            policy: SchedulerPolicy::qa_sjf(),
            time_scale: 10.0,
            threads_per_worker: 1,
            max_job_attempts: 3,
            seed: 0,
        });
        leader.submit(sleep_spec("long", 5.0)).unwrap(); // -> idle worker (both 0): w0
        std::thread::sleep(std::time::Duration::from_millis(50));
        let (_, med_worker) = leader.submit(sleep_spec("med", 1.0)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(50));
        let (_, short_worker) = leader.submit(sleep_spec("short", 0.1)).unwrap();
        let done = leader.wait_for(3, std::time::Duration::from_secs(10)).unwrap();
        let long_worker = done.iter().find(|c| c.name == "long").unwrap().worker;
        assert_ne!(med_worker, long_worker, "med must avoid the long job's worker");
        assert_eq!(
            short_worker, med_worker,
            "short must go behind ~0.5 s remaining, not behind ~4.5 s"
        );
        leader.shutdown();
    }

    #[test]
    fn monitor_exposes_running_remaining_estimate() {
        let leader =
            Leader::start(LeaderConfig { workers: 1, time_scale: 10.0, ..Default::default() });
        leader.submit(sleep_spec("r", 4.0)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(60));
        let status = leader.status();
        // ~0.06 s real elapsed at scale 10 => ~0.6 job-seconds consumed.
        assert!(status[0].busy);
        let rem = status[0].running_remaining_s;
        assert!(rem > 0.0 && rem < 4.0, "remaining {rem}");
        leader.wait_for(1, std::time::Duration::from_secs(10)).unwrap();
        let status = leader.status();
        assert_eq!(status[0].running_remaining_s, 0.0);
        leader.shutdown();
    }

    #[test]
    fn failed_jobs_reported_not_fatal() {
        // A deterministically bad job fails every attempt: the default
        // config retries it twice, then gives up — one job_error record
        // per attempt plus a terminal job_failed record, and the
        // completion entry distinguishes "gave up after N" from "done".
        let leader = Leader::start(LeaderConfig { workers: 1, ..Default::default() });
        leader
            .submit_yaml("name: bad\ntask: hardware_sweep\nmodel: notamodel\nplatform: G1\n")
            .unwrap();
        let done = leader.wait_for(1, std::time::Duration::from_secs(10)).unwrap();
        assert!(!done[0].ok);
        assert_eq!(done[0].attempts, 3, "default budget is 3 attempts");
        let db = leader.perfdb.lock().unwrap();
        assert_eq!(db.query(&Query::default().task("job_error")).len(), 3);
        let failed = db.query(&Query::default().task("job_failed"));
        assert_eq!(failed.len(), 1);
        assert_eq!(failed[0].label("status"), Some("failed"));
        drop(db);
        leader.shutdown();
    }

    #[test]
    fn single_attempt_budget_fails_fast() {
        let leader = Leader::start(LeaderConfig {
            workers: 1,
            max_job_attempts: 1,
            ..Default::default()
        });
        leader
            .submit_yaml("name: bad\ntask: hardware_sweep\nmodel: notamodel\nplatform: G1\n")
            .unwrap();
        let done = leader.wait_for(1, std::time::Duration::from_secs(10)).unwrap();
        assert!(!done[0].ok);
        assert_eq!(done[0].attempts, 1);
        let db = leader.perfdb.lock().unwrap();
        assert_eq!(db.query(&Query::default().task("job_error")).len(), 1);
        assert_eq!(db.query(&Query::default().task("job_failed")).len(), 1);
        drop(db);
        leader.shutdown();
    }

    #[test]
    fn retries_do_not_block_other_jobs_and_good_jobs_report_one_attempt() {
        // While the bad job cycles through its backoff gates, a healthy
        // job submitted behind it still completes — the gate defers the
        // retry, it does not occupy the worker.
        let leader = Leader::start(LeaderConfig {
            workers: 1,
            time_scale: 10.0,
            ..Default::default()
        });
        leader
            .submit_yaml("name: bad\ntask: hardware_sweep\nmodel: notamodel\nplatform: G1\n")
            .unwrap();
        leader.submit(sleep_spec("good", 0.5)).unwrap();
        let done = leader.wait_for(2, std::time::Duration::from_secs(20)).unwrap();
        let good = done.iter().find(|c| c.name == "good").unwrap();
        assert!(good.ok);
        assert_eq!(good.attempts, 1);
        let bad = done.iter().find(|c| c.name == "bad").unwrap();
        assert!(!bad.ok);
        assert_eq!(bad.attempts, 3);
        leader.shutdown();
    }

    #[test]
    fn monitor_reports_queue_state() {
        let leader = Leader::start(LeaderConfig { workers: 1, time_scale: 10.0, ..Default::default() });
        leader.submit(sleep_spec("a", 2.0)).unwrap();
        leader.submit(sleep_spec("b", 2.0)).unwrap();
        leader.submit(sleep_spec("c", 2.0)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(50));
        let status = leader.status();
        assert_eq!(status.len(), 1);
        assert!(status[0].busy || status[0].queued > 0);
        leader.wait_for(3, std::time::Duration::from_secs(10)).unwrap();
        let status = leader.status();
        assert_eq!(status[0].completed, 3);
        leader.shutdown();
    }

    #[test]
    fn sjf_runs_short_job_first() {
        // Single worker; stuff queue while busy, then observe order.
        let leader = Leader::start(LeaderConfig {
            workers: 1,
            policy: SchedulerPolicy::qa_sjf(),
            time_scale: 20.0,
            threads_per_worker: 1,
            max_job_attempts: 3,
            seed: 0,
        });
        leader.submit(sleep_spec("blocker", 2.0)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        leader.submit(sleep_spec("long", 4.0)).unwrap();
        leader.submit(sleep_spec("short", 0.2)).unwrap();
        let done = leader.wait_for(3, std::time::Duration::from_secs(10)).unwrap();
        let order: Vec<&str> = done.iter().map(|c| c.name.as_str()).collect();
        let pos = |n: &str| order.iter().position(|x| *x == n).unwrap();
        assert!(pos("short") < pos("long"), "{order:?}");
        leader.shutdown();
    }
}
