//! The paper's system contribution: the leader/follower benchmark
//! coordinator with its two-tier scheduler (paper §4.1, §4.2.1, §4.3.2).
//!
//! * [`scheduler`] — Algorithm 1 (batch mode) + the online DES used by
//!   the Fig 15 study.
//! * [`job`] — YAML submission parsing and job execution on followers.
//! * [`leader`] — the live threaded cluster: task manager, queue-aware
//!   load balancer, SJF workers, monitor, PerfDB aggregation.
//! * [`distributed`] — the distributed sweep engine: one `SweepPlan`
//!   sharded across followers over the wire codec (`crate::codec`), with
//!   streaming result absorption and straggler re-queue.

pub mod distributed;
pub mod job;
pub mod leader;
pub mod scheduler;

pub use distributed::{DistConfig, DistOutcome, DistStats, FollowerSpec};
pub use job::{JobKind, JobSpec};
pub use leader::{Leader, LeaderConfig};
pub use scheduler::{schedule_batch, simulate_online, Job, SchedulerPolicy};
