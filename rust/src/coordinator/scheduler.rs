//! Two-tier benchmark-job scheduler (paper §4.3.2, Algorithm 1, Fig 15).
//!
//! Tier 1: a load balancer at the leader places each job on a follower
//! worker — round-robin (baseline) or queue-aware (shortest backlog).
//! Tier 2: each worker orders its local queue — FCFS (baseline) or
//! shortest-job-first. The paper's result (Fig 15): QA + SJF reduces
//! average job completion time by ~1.43x (30%) over RR + FCFS.
//!
//! Two execution modes:
//!  * [`schedule_batch`] — Algorithm 1 verbatim: a known job set per
//!    scheduling interval, enqueue to shortest queue, reorder ascending,
//!    run sequentially.
//!  * [`simulate_online`] — the DES generalization with online arrivals,
//!    which the Fig 15 bench sweeps.
//!
//! Since the parallel sweep engine landed, the live leader adds a third
//! tier *inside* a job: `sweep` grids run their cells across the worker's
//! `threads_per_worker` budget, and both tiers above charge the
//! thread-budget-adjusted estimate (`LeaderConfig::charged_estimate_s`)
//! so queue-aware placement keeps seeing the wall-clock a job actually
//! occupies its worker. The distributed sweep engine
//! (`coordinator::distributed`) adds a tier-1-style decision one level
//! down: [`shard_sizes`] splits one grid's cells across followers
//! proportionally to their thread budgets, so every shard finishes in
//! roughly the same wall-clock and no follower idles while another
//! drowns.

/// A benchmark job as the scheduler sees it.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub id: u64,
    /// Submission time (seconds from interval start).
    pub submit_s: f64,
    /// Processing time. The paper assumes deterministic durations
    /// ("we assume that the processing time of every benchmark task is
    /// determined before they are executed").
    pub duration_s: f64,
}

/// Tier-1 placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadBalance {
    RoundRobin,
    /// Paper: "Select an idle worker W_min with the shortest queue".
    QueueAware,
}

/// Tier-2 local ordering policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LocalOrder {
    Fcfs,
    /// Paper: "Re-order jobs in an ascending way" (shortest first).
    Sjf,
}

/// A full scheduler configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulerPolicy {
    pub lb: LoadBalance,
    pub order: LocalOrder,
}

impl SchedulerPolicy {
    /// Paper baseline 1.
    pub fn rr_fcfs() -> Self {
        SchedulerPolicy { lb: LoadBalance::RoundRobin, order: LocalOrder::Fcfs }
    }

    /// Paper baseline 2 ("LB with Short-Job-First").
    pub fn rr_sjf() -> Self {
        SchedulerPolicy { lb: LoadBalance::RoundRobin, order: LocalOrder::Sjf }
    }

    /// The paper's scheduler: queue-aware LB + SJF.
    pub fn qa_sjf() -> Self {
        SchedulerPolicy { lb: LoadBalance::QueueAware, order: LocalOrder::Sjf }
    }

    pub fn label(&self) -> &'static str {
        match (self.lb, self.order) {
            (LoadBalance::RoundRobin, LocalOrder::Fcfs) => "RR+FCFS",
            (LoadBalance::RoundRobin, LocalOrder::Sjf) => "RR+SJF",
            (LoadBalance::QueueAware, LocalOrder::Fcfs) => "QA+FCFS",
            (LoadBalance::QueueAware, LocalOrder::Sjf) => "QA+SJF",
        }
    }
}

/// Where and when a job ran.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub job: Job,
    pub worker: usize,
    pub start_s: f64,
    pub finish_s: f64,
}

impl Placement {
    /// Job completion time: waiting + processing (the paper's t_j).
    pub fn jct_s(&self) -> f64 {
        self.finish_s - self.job.submit_s
    }
}

/// Schedule outcome.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub placements: Vec<Placement>,
    /// Minimum instantaneous queued-backlog (seconds) observed on any
    /// worker during the run — diagnostic for the backlog-accounting
    /// invariant: it must never drift negative (float error across many
    /// add/remove pairs is clamped at 0 where it would).
    pub min_backlog_s: f64,
}

impl Outcome {
    /// Average JCT — the paper's optimization target T/|J|.
    pub fn mean_jct_s(&self) -> f64 {
        if self.placements.is_empty() {
            return 0.0;
        }
        self.placements.iter().map(|p| p.jct_s()).sum::<f64>() / self.placements.len() as f64
    }

    /// Total completion time T = sum of t_j.
    pub fn total_jct_s(&self) -> f64 {
        self.placements.iter().map(|p| p.jct_s()).sum()
    }

    /// Makespan: last finish time.
    pub fn makespan_s(&self) -> f64 {
        self.placements.iter().map(|p| p.finish_s).fold(0.0, f64::max)
    }
}

/// Algorithm 1 verbatim: all jobs available at t=0 within one scheduling
/// interval. Queue-aware placement by queue length (total queued seconds),
/// then each worker optionally re-orders ascending by duration, then runs
/// sequentially.
pub fn schedule_batch(jobs: &[Job], workers: usize, policy: SchedulerPolicy) -> Outcome {
    assert!(workers > 0);
    let mut queues: Vec<Vec<Job>> = vec![Vec::new(); workers];
    let mut backlog = vec![0.0f64; workers];
    let mut rr = 0usize;

    for job in jobs {
        let w = match policy.lb {
            LoadBalance::RoundRobin => {
                let w = rr % workers;
                rr += 1;
                w
            }
            LoadBalance::QueueAware => {
                // Shortest queue = least total queued processing time.
                (0..workers)
                    .min_by(|&a, &b| backlog[a].partial_cmp(&backlog[b]).unwrap())
                    .unwrap()
            }
        };
        backlog[w] += job.duration_s;
        queues[w].push(job.clone());
    }

    let mut placements = Vec::with_capacity(jobs.len());
    for (w, mut queue) in queues.into_iter().enumerate() {
        if policy.order == LocalOrder::Sjf {
            queue.sort_by(|a, b| a.duration_s.partial_cmp(&b.duration_s).unwrap());
        }
        let mut t = 0.0f64;
        for job in queue {
            let start = t.max(job.submit_s);
            let finish = start + job.duration_s;
            t = finish;
            placements.push(Placement { job, worker: w, start_s: start, finish_s: finish });
        }
    }
    placements.sort_by_key(|p| p.job.id);
    Outcome { placements, min_backlog_s: 0.0 }
}

/// Online DES: jobs arrive over time; the LB places on arrival using the
/// *current* backlog; a freed worker picks its next job per the local
/// order. This is how the live leader behaves.
pub fn simulate_online(jobs: &[Job], workers: usize, policy: SchedulerPolicy) -> Outcome {
    assert!(workers > 0);
    let mut jobs: Vec<Job> = jobs.to_vec();
    jobs.sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap().then(a.id.cmp(&b.id)));

    #[derive(Debug)]
    struct Worker {
        queue: Vec<Job>,
        free_at: f64,
        backlog_s: f64, // queued (not started) work
    }
    let mut ws: Vec<Worker> = (0..workers)
        .map(|_| Worker { queue: Vec::new(), free_at: 0.0, backlog_s: 0.0 })
        .collect();
    let mut rr = 0usize;
    let mut placements: Vec<Placement> = Vec::with_capacity(jobs.len());

    // Start as many queued jobs as possible on worker w up to time `now`.
    // `min_backlog` records the lowest backlog value reached before the
    // non-negativity clamp — the invariant probe the tests assert on.
    fn drain(
        w: &mut Worker,
        wid: usize,
        now: f64,
        order: LocalOrder,
        placements: &mut Vec<Placement>,
        min_backlog: &mut f64,
    ) {
        while w.free_at <= now && !w.queue.is_empty() {
            let idx = match order {
                LocalOrder::Fcfs => 0,
                LocalOrder::Sjf => w
                    .queue
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.duration_s.partial_cmp(&b.1.duration_s).unwrap())
                    .map(|(i, _)| i)
                    .unwrap(),
            };
            let job = w.queue.remove(idx);
            let start = w.free_at.max(job.submit_s);
            let finish = start + job.duration_s;
            w.free_at = finish;
            // Backlog must never drift negative: float error accumulated
            // over many add/remove pairs is clamped at exactly 0 so
            // queue-aware comparisons never see phantom negative work.
            let raw = w.backlog_s - job.duration_s;
            *min_backlog = min_backlog.min(raw);
            w.backlog_s = raw.max(0.0);
            placements.push(Placement { job, worker: wid, start_s: start, finish_s: finish });
        }
    }

    let mut min_backlog_s = 0.0f64;
    for job in jobs {
        let now = job.submit_s;
        // Advance every worker to `now` (they keep running queued work).
        for (wid, w) in ws.iter_mut().enumerate() {
            drain(w, wid, now, policy.order, &mut placements, &mut min_backlog_s);
        }
        let w = match policy.lb {
            LoadBalance::RoundRobin => {
                let w = rr % workers;
                rr += 1;
                w
            }
            LoadBalance::QueueAware => (0..workers)
                .min_by(|&a, &b| {
                    let ba = (ws[a].free_at - now).max(0.0) + ws[a].backlog_s;
                    let bb = (ws[b].free_at - now).max(0.0) + ws[b].backlog_s;
                    ba.partial_cmp(&bb).unwrap()
                })
                .unwrap(),
        };
        ws[w].backlog_s += job.duration_s;
        ws[w].queue.push(job);
        drain(&mut ws[w], w, now, policy.order, &mut placements, &mut min_backlog_s);
    }
    // Flush all remaining work.
    for (wid, w) in ws.iter_mut().enumerate() {
        drain(w, wid, f64::INFINITY, policy.order, &mut placements, &mut min_backlog_s);
    }
    placements.sort_by_key(|p| p.job.id);
    Outcome { placements, min_backlog_s }
}

/// Split `cells` sweep cells across followers proportionally to their
/// thread budgets — the distributed sweep engine's shard-sizing decision
/// (`coordinator::distributed`). Returns one cell count per follower,
/// summing to `cells` exactly.
///
/// Uses the deterministic "staircase" rule: follower `i`'s shard ends at
/// `cells * (b_0 + … + b_i) / B` (integer division), so sizes track the
/// budget ratios to within one cell with no accumulated rounding drift —
/// a follower with twice the threads gets (within 1) twice the cells, and
/// every shard finishes in roughly the same wall-clock. Zero budgets are
/// treated as 1 (a follower that exists can run *something*); when
/// `cells < followers`, trailing followers legitimately receive empty
/// shards.
pub fn shard_sizes(cells: usize, budgets: &[usize]) -> Vec<usize> {
    if budgets.is_empty() {
        return Vec::new();
    }
    let norm: Vec<u64> = budgets.iter().map(|&b| b.max(1) as u64).collect();
    let total: u64 = norm.iter().sum();
    let mut sizes = Vec::with_capacity(norm.len());
    let mut cum = 0u64;
    let mut prev_boundary = 0u64;
    for b in norm {
        cum += b;
        let boundary = cells as u64 * cum / total;
        sizes.push((boundary - prev_boundary) as usize);
        prev_boundary = boundary;
    }
    sizes
}

/// The paper's benchmark-job workload for the Fig 15 study: a mix of
/// short submissions (single-model latency checks) and long sweeps
/// (batch-size x platform grids), heavy-tailed like real benchmark queues.
pub fn synthetic_jobs(n: usize, mean_arrival_gap_s: f64, seed: u64) -> Vec<Job> {
    let mut rng = crate::util::rng::Pcg64::seeded(seed);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.exponential(1.0 / mean_arrival_gap_s);
            // Lognormal job lengths: median ~60s, tail to ~20 min —
            // calibrated so QA+SJF vs RR+FCFS lands near the paper's
            // 1.43x mean-JCT improvement (heavier tails inflate it).
            let duration = rng.lognormal(60f64.ln(), 0.8).clamp(5.0, 1200.0);
            Job { id: i as u64, submit_s: t, duration_s: duration }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch_jobs(durations: &[f64]) -> Vec<Job> {
        durations
            .iter()
            .enumerate()
            .map(|(i, &d)| Job { id: i as u64, submit_s: 0.0, duration_s: d })
            .collect()
    }

    #[test]
    fn all_jobs_placed_exactly_once() {
        let jobs = synthetic_jobs(100, 10.0, 1);
        for policy in [SchedulerPolicy::rr_fcfs(), SchedulerPolicy::rr_sjf(), SchedulerPolicy::qa_sjf()] {
            for out in [schedule_batch(&jobs, 4, policy), simulate_online(&jobs, 4, policy)] {
                assert_eq!(out.placements.len(), jobs.len(), "{}", policy.label());
                let mut ids: Vec<u64> = out.placements.iter().map(|p| p.job.id).collect();
                ids.sort_unstable();
                assert_eq!(ids, (0..100).collect::<Vec<u64>>());
            }
        }
    }

    #[test]
    fn no_worker_overlap() {
        let jobs = synthetic_jobs(60, 5.0, 2);
        let out = simulate_online(&jobs, 3, SchedulerPolicy::qa_sjf());
        for w in 0..3 {
            let mut spans: Vec<(f64, f64)> = out
                .placements
                .iter()
                .filter(|p| p.worker == w)
                .map(|p| (p.start_s, p.finish_s))
                .collect();
            spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for pair in spans.windows(2) {
                assert!(pair[1].0 >= pair[0].1 - 1e-9, "worker {w} overlaps: {pair:?}");
            }
        }
    }

    #[test]
    fn jobs_never_start_before_submit() {
        let jobs = synthetic_jobs(80, 3.0, 3);
        let out = simulate_online(&jobs, 2, SchedulerPolicy::rr_fcfs());
        for p in &out.placements {
            assert!(p.start_s >= p.job.submit_s - 1e-9);
        }
    }

    #[test]
    fn shard_sizes_sum_and_track_budgets() {
        // Equal budgets: as even as integers allow.
        assert_eq!(shard_sizes(12, &[4, 4, 4]), vec![4, 4, 4]);
        assert_eq!(shard_sizes(13, &[4, 4]), vec![6, 7]);
        // Proportional: double the threads, double the cells (within 1).
        assert_eq!(shard_sizes(12, &[2, 4, 6]), vec![2, 4, 6]);
        let sizes = shard_sizes(100, &[1, 2, 3, 5]);
        assert_eq!(sizes.iter().sum::<usize>(), 100);
        assert!(sizes[3] >= 4 * sizes[0], "budget-5 follower dwarfs budget-1: {sizes:?}");
        // Fewer cells than followers: trailing shards go empty, sum holds.
        let sparse = shard_sizes(2, &[1, 1, 1, 1]);
        assert_eq!(sparse.iter().sum::<usize>(), 2);
        // Zero budgets are normalized to 1, not divided by.
        assert_eq!(shard_sizes(4, &[0, 0]), vec![2, 2]);
        assert_eq!(shard_sizes(0, &[3, 1]), vec![0, 0]);
        assert!(shard_sizes(5, &[]).is_empty());
    }

    #[test]
    fn sjf_beats_fcfs_on_skewed_batch() {
        // One long job then many short ones: SJF classic win.
        let jobs = batch_jobs(&[1000.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0]);
        let fcfs = schedule_batch(&jobs, 2, SchedulerPolicy::rr_fcfs());
        let sjf = schedule_batch(
            &jobs,
            2,
            SchedulerPolicy { lb: LoadBalance::RoundRobin, order: LocalOrder::Sjf },
        );
        assert!(sjf.mean_jct_s() < fcfs.mean_jct_s());
    }

    #[test]
    fn qa_beats_rr_on_imbalanced_stream() {
        // Alternating long/short: RR piles longs onto one worker.
        let jobs = batch_jobs(&[600.0, 5.0, 600.0, 5.0, 600.0, 5.0, 5.0, 5.0]);
        let rr = schedule_batch(&jobs, 2, SchedulerPolicy::rr_fcfs());
        let qa = schedule_batch(
            &jobs,
            2,
            SchedulerPolicy { lb: LoadBalance::QueueAware, order: LocalOrder::Fcfs },
        );
        assert!(qa.mean_jct_s() <= rr.mean_jct_s());
        assert!(qa.makespan_s() <= rr.makespan_s());
    }

    #[test]
    fn paper_headline_qa_sjf_beats_rr_fcfs_by_large_factor() {
        // Fig 15 shape: on a realistic heavy-tailed queue, QA+SJF should
        // improve mean JCT by well over 1.2x (paper: 1.43x).
        let jobs = synthetic_jobs(200, 20.0, 42);
        let base = simulate_online(&jobs, 4, SchedulerPolicy::rr_fcfs());
        let ours = simulate_online(&jobs, 4, SchedulerPolicy::qa_sjf());
        let speedup = base.mean_jct_s() / ours.mean_jct_s();
        assert!(speedup > 1.2, "speedup {speedup}");
    }

    #[test]
    fn makespan_not_hurt_by_sjf() {
        // SJF reorders but total work per worker is unchanged.
        let jobs = batch_jobs(&[30.0, 10.0, 50.0, 20.0]);
        let a = schedule_batch(&jobs, 1, SchedulerPolicy::rr_fcfs());
        let b = schedule_batch(&jobs, 1, SchedulerPolicy::rr_sjf());
        assert!((a.makespan_s() - b.makespan_s()).abs() < 1e-9);
    }

    #[test]
    fn online_backlog_never_drifts_negative_under_long_runs() {
        // Thousands of heavy-tailed jobs over few overloaded workers:
        // deep queues and thousands of interleaved backlog add/remove
        // pairs per worker. The published backlog must never drift
        // negative — anything below numerical noise would leak into
        // queue-aware placement as phantom idle capacity.
        for (workers, seed) in [(2usize, 5u64), (4, 17), (8, 91)] {
            let jobs = synthetic_jobs(2_000, 0.2, seed);
            for policy in [SchedulerPolicy::rr_fcfs(), SchedulerPolicy::qa_sjf()] {
                let out = simulate_online(&jobs, workers, policy);
                assert_eq!(out.placements.len(), jobs.len());
                assert!(
                    out.min_backlog_s >= -1e-9,
                    "{} workers={workers}: backlog drifted to {}",
                    policy.label(),
                    out.min_backlog_s
                );
            }
        }
    }

    #[test]
    fn synthetic_jobs_deterministic_and_bounded() {
        let a = synthetic_jobs(50, 10.0, 7);
        let b = synthetic_jobs(50, 10.0, 7);
        assert_eq!(a, b);
        for j in &a {
            assert!(j.duration_s >= 5.0 && j.duration_s <= 1200.0);
        }
        assert!(a.windows(2).all(|w| w[0].submit_s <= w[1].submit_s));
    }

    #[test]
    fn single_worker_sjf_is_spt_optimal() {
        // On one machine, SPT minimizes mean completion time; verify SJF
        // achieves <= any other tested order.
        let jobs = batch_jobs(&[40.0, 10.0, 30.0, 20.0]);
        let sjf = schedule_batch(&jobs, 1, SchedulerPolicy::rr_sjf());
        let fcfs = schedule_batch(&jobs, 1, SchedulerPolicy::rr_fcfs());
        assert!(sjf.mean_jct_s() <= fcfs.mean_jct_s());
        // SPT closed form: durations sorted 10,20,30,40 -> JCTs 10,30,60,100.
        assert!((sjf.mean_jct_s() - 50.0).abs() < 1e-9);
    }
}
