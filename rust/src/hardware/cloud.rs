//! Cloud pricing model (paper §3.1 Cost, Fig 8b).
//!
//! Hourly rates for GPU instances across two anonymized providers, matching
//! the paper's convention: providers are [C1, C2], instances [I1, I2, I3].
//! Rates reflect 2020 list prices (AWS p3/g4dn, GCP V100/P4/T4 attach).
//! Cost per request = hourly rate / requests per hour at the achieved
//! throughput.

use super::platforms::Platform;
use super::roofline::Estimate;

/// A purchasable GPU instance at a provider.
#[derive(Debug, Clone)]
pub struct Instance {
    /// Anonymized provider label (paper: C1 = AWS, C2 = Google Cloud).
    pub provider: &'static str,
    /// Anonymized instance label (I1 = V100, I2 = P4, I3 = T4).
    pub instance: &'static str,
    /// Platform id from Table 1 this instance carries.
    pub platform_id: &'static str,
    pub hourly_usd: f64,
}

/// The instance offerings the paper compares (Fig 8b).
pub const INSTANCES: &[Instance] = &[
    Instance { provider: "C1", instance: "I1", platform_id: "G1", hourly_usd: 3.06 }, // AWS p3.2xlarge
    Instance { provider: "C2", instance: "I1", platform_id: "G1", hourly_usd: 2.48 }, // GCP V100
    Instance { provider: "C2", instance: "I2", platform_id: "G4", hourly_usd: 0.60 }, // GCP P4
    Instance { provider: "C1", instance: "I3", platform_id: "G3", hourly_usd: 0.526 }, // AWS g4dn
    Instance { provider: "C2", instance: "I3", platform_id: "G3", hourly_usd: 0.35 }, // GCP T4
];

/// Cost per request at the achieved throughput of `est`.
pub fn cost_per_request_usd(inst: &Instance, est: &Estimate, batch: usize) -> f64 {
    let throughput = batch.max(1) as f64 / est.total_s; // requests/s
    inst.hourly_usd / (throughput * 3600.0)
}

/// All instances carrying a given platform.
pub fn instances_for(platform: &Platform) -> Vec<&'static Instance> {
    INSTANCES.iter().filter(|i| i.platform_id == platform.id).collect()
}

/// Cheapest hourly rate offered for a platform id across providers
/// (`None` when no provider carries it) — the fleet-cost unit of the
/// sharing-versus-dedicate comparison.
pub fn cheapest_hourly_usd(platform_id: &str) -> Option<f64> {
    INSTANCES
        .iter()
        .filter(|i| i.platform_id == platform_id)
        .map(|i| i.hourly_usd)
        .min_by(|a, b| a.partial_cmp(b).expect("NaN price"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::platforms::find;
    use crate::hardware::roofline::{estimate, Parallelism};
    use crate::models::catalog;

    #[test]
    fn same_device_different_providers_differ() {
        // Paper observation 1 (Fig 8b): V100 hourly rate differs across
        // providers.
        let v100_offers: Vec<_> = INSTANCES.iter().filter(|i| i.platform_id == "G1").collect();
        assert_eq!(v100_offers.len(), 2);
        assert_ne!(v100_offers[0].hourly_usd, v100_offers[1].hourly_usd);
    }

    #[test]
    fn t4_cheaper_than_p4_despite_more_powerful() {
        // Paper observation 2 (Fig 8b).
        let t4 = find("G3").unwrap();
        let p4 = find("G4").unwrap();
        assert!(t4.peak_fp32_tflops > p4.peak_fp32_tflops);
        let t4_price = INSTANCES.iter().filter(|i| i.platform_id == "G3").map(|i| i.hourly_usd).fold(f64::MAX, f64::min);
        let p4_price = INSTANCES.iter().filter(|i| i.platform_id == "G4").map(|i| i.hourly_usd).fold(f64::MAX, f64::min);
        assert!(t4_price < p4_price);
    }

    #[test]
    fn cost_per_request_decreases_with_batch() {
        // Paper observation 3 (Fig 8b).
        let v100 = find("G1").unwrap();
        let rn = catalog::find("resnet50").unwrap();
        let inst = &INSTANCES[0];
        let par = Parallelism::cnn(224);
        let c1 = cost_per_request_usd(inst, &estimate(v100, &rn.profile, par, 1, 0), 1);
        let c32 = cost_per_request_usd(inst, &estimate(v100, &rn.profile, par, 32, 0), 32);
        assert!(c32 < c1);
    }

    #[test]
    fn instances_for_lookup() {
        let v100 = find("G1").unwrap();
        assert_eq!(instances_for(v100).len(), 2);
        let cpu = find("C1").unwrap();
        assert!(instances_for(cpu).is_empty());
    }
}
