//! Energy and CO2 cost model (paper §3.1 Cost, Fig 8a).
//!
//! Board power is modeled as idle + (peak-idle) * utilization; energy per
//! request integrates that power over the batch latency and divides by
//! batch. CO2 follows the carbontracker approach the paper cites: energy x
//! grid carbon intensity.

use super::platforms::Platform;
use super::roofline::Estimate;

/// Global-average grid carbon intensity, gCO2eq per kWh (carbontracker's
/// default; the paper cites Anthony et al. 2020).
pub const CARBON_INTENSITY_G_PER_KWH: f64 = 475.0;

/// Energy/CO2 for one batched inference.
#[derive(Debug, Clone, Copy)]
pub struct EnergyCost {
    /// Average board power during the inference, watts.
    pub power_w: f64,
    /// Energy per *request* (batch amortized), joules.
    pub joules_per_request: f64,
    /// CO2 per request, grams.
    pub co2_g_per_request: f64,
}

/// Compute the energy cost of an inference estimate at a given batch.
pub fn energy(platform: &Platform, est: &Estimate, batch: usize) -> EnergyCost {
    let b = batch.max(1) as f64;
    let power_w = platform.idle_w + (platform.peak_w - platform.idle_w) * est.utilization.min(1.0);
    let joules_batch = power_w * est.total_s;
    let joules_per_request = joules_batch / b;
    let kwh_per_request = joules_per_request / 3.6e6;
    EnergyCost {
        power_w,
        joules_per_request,
        co2_g_per_request: kwh_per_request * CARBON_INTENSITY_G_PER_KWH,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::platforms::find;
    use crate::hardware::roofline::{estimate, Parallelism};
    use crate::models::catalog;

    #[test]
    fn batch_one_costs_most_energy_per_request() {
        // Paper Fig 8a: "most energy is consumed with the batch size one"
        // (fixed overhead amortizes with batch).
        let v100 = find("G1").unwrap();
        let rn = catalog::find("resnet50").unwrap();
        let par = Parallelism::cnn(224);
        let e1 = energy(v100, &estimate(v100, &rn.profile, par, 1, 0), 1);
        let e16 = energy(v100, &estimate(v100, &rn.profile, par, 16, 0), 16);
        assert!(e1.joules_per_request > e16.joules_per_request);
    }

    #[test]
    fn more_powerful_gpu_consumes_more() {
        // Paper Fig 8a: V100 > T4 energy per request for the same work.
        let rn = catalog::find("resnet50").unwrap();
        let par = Parallelism::cnn(224);
        let v100 = find("G1").unwrap();
        let t4 = find("G3").unwrap();
        let ev = energy(v100, &estimate(v100, &rn.profile, par, 8, 0), 8);
        let et = energy(t4, &estimate(t4, &rn.profile, par, 8, 0), 8);
        assert!(ev.power_w > et.power_w);
    }

    #[test]
    fn co2_proportional_to_energy() {
        let v100 = find("G1").unwrap();
        let rn = catalog::find("resnet50").unwrap();
        let e = energy(v100, &estimate(v100, &rn.profile, Parallelism::cnn(224), 4, 0), 4);
        let expect = e.joules_per_request / 3.6e6 * CARBON_INTENSITY_G_PER_KWH;
        assert!((e.co2_g_per_request - expect).abs() < 1e-12);
        assert!(e.co2_g_per_request > 0.0);
    }

    #[test]
    fn power_bounded_by_peak() {
        let v100 = find("G1").unwrap();
        let rn = catalog::find("resnet50").unwrap();
        for b in [1, 8, 64, 256] {
            let e = energy(v100, &estimate(v100, &rn.profile, Parallelism::cnn(224), b, 0), b);
            assert!(e.power_w >= v100.idle_w - 1e-9);
            assert!(e.power_w <= v100.peak_w + 1e-9);
        }
    }
}
