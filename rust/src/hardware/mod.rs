//! Tier-1 hardware models: Table-1 platforms, the calibrated roofline
//! latency/utilization estimator, and the energy/CO2/cloud cost models
//! (paper §3.1, §5.2). See DESIGN.md §2 for the GPU-simulation
//! substitution rationale.

pub mod cloud;
pub mod energy;
pub mod platforms;
pub mod roofline;
pub mod sharing;

pub use platforms::{find, Arch, Platform, PLATFORMS};
pub use roofline::{estimate, Estimate, Parallelism};
