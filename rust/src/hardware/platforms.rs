//! The five hardware platforms of the paper's Table 1, plus the performance
//! parameters the roofline/energy models need.
//!
//! Peak TFLOPs and memory bandwidth come straight from Table 1. The added
//! fields (overheads, occupancy saturation, power draw, PCIe bandwidth) are
//! the calibration knobs of the analytic latency model — values chosen to
//! reproduce the *shape* of the paper's measured curves on hardware this
//! testbed does not have (DESIGN.md §2).

/// GPU/CPU architecture generation (Table 1 "Arch").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Cpu,
    Volta,
    Turing,
    Pascal,
}

/// One row of Table 1 + model calibration parameters.
#[derive(Debug, Clone)]
pub struct Platform {
    /// Table-1 id: C1, G1..G4.
    pub id: &'static str,
    pub name: &'static str,
    pub arch: Arch,
    pub memory_gb: u32,
    /// Peak FP32 TFLOPS (Table 1). CPU value is an AVX2 estimate.
    pub peak_fp32_tflops: f64,
    /// Peak FP16 TFLOPS (Table 1).
    pub peak_fp16_tflops: f64,
    /// Memory bandwidth GB/s (Table 1).
    pub mem_bw_gbs: f64,
    /// Fixed per-inference overhead (kernel launches, framework glue).
    pub overhead_s: f64,
    /// Matmul rows at which the device reaches full occupancy; below this
    /// the effective compute peak scales down linearly (idle SMs / MXU
    /// lanes). This is what makes GPU latency flat for small batches
    /// (paper Fig 7a/b).
    pub rows_saturation: f64,
    /// Lower bound on occupancy: even a batch-1 kernel keeps this fraction
    /// of the device busy (wave quantization + per-layer parallelism).
    /// Calibrated so e.g. BERT-Large b=1 on V100 lands near the measured
    /// ~20 ms rather than the naive-linear ~180 ms.
    pub occupancy_floor: f64,
    /// Host->device transfer bandwidth, GB/s (PCIe gen3 x16 ~ 12 GB/s
    /// effective; CPU is memcpy-speed).
    pub pcie_gbs: f64,
    /// Idle / peak board power, watts (energy model, Fig 8a).
    pub idle_w: f64,
    pub peak_w: f64,
}

/// Table 1. C1 is the Xeon E5-2698v4 reference; G1..G4 the four GPUs.
pub const PLATFORMS: &[Platform] = &[
    Platform {
        id: "C1",
        name: "Intel Xeon E5-2698 v4",
        arch: Arch::Cpu,
        memory_gb: 128,
        // Sustained GEMM throughput of 2020-era CPU inference stacks
        // (TF/MKL-DNN) on this part — not the 1.4 TFLOPS AVX2 theoretical
        // peak; the model wants achieved rates (DESIGN.md §2).
        peak_fp32_tflops: 0.35,
        peak_fp16_tflops: 0.35,
        mem_bw_gbs: 68.0,
        overhead_s: 500e-6,
        rows_saturation: 64.0,
        occupancy_floor: 0.5,
        pcie_gbs: 30.0,
        idle_w: 60.0,
        peak_w: 135.0,
    },
    Platform {
        id: "G1",
        name: "Tesla V100",
        arch: Arch::Volta,
        memory_gb: 32,
        peak_fp32_tflops: 15.7,
        peak_fp16_tflops: 31.4,
        mem_bw_gbs: 900.0,
        overhead_s: 1.8e-3,
        rows_saturation: 4096.0,
        occupancy_floor: 0.25,
        pcie_gbs: 12.0,
        idle_w: 70.0,
        peak_w: 300.0,
    },
    Platform {
        id: "G2",
        name: "GeForce 2080Ti",
        arch: Arch::Turing,
        memory_gb: 11,
        peak_fp32_tflops: 14.25,
        peak_fp16_tflops: 28.5,
        mem_bw_gbs: 616.0,
        overhead_s: 1.6e-3,
        rows_saturation: 3584.0,
        occupancy_floor: 0.25,
        pcie_gbs: 12.0,
        idle_w: 55.0,
        peak_w: 250.0,
    },
    Platform {
        id: "G3",
        name: "Tesla T4",
        arch: Arch::Turing,
        memory_gb: 16,
        peak_fp32_tflops: 8.1,
        peak_fp16_tflops: 16.2,
        mem_bw_gbs: 300.0,
        overhead_s: 1.4e-3,
        rows_saturation: 2048.0,
        occupancy_floor: 0.25,
        pcie_gbs: 12.0,
        idle_w: 17.0,
        peak_w: 70.0,
    },
    Platform {
        id: "G4",
        name: "Tesla P4",
        arch: Arch::Pascal,
        memory_gb: 8,
        peak_fp32_tflops: 5.5,
        peak_fp16_tflops: 11.0,
        mem_bw_gbs: 192.0,
        overhead_s: 1.5e-3,
        rows_saturation: 1536.0,
        occupancy_floor: 0.25,
        pcie_gbs: 12.0,
        idle_w: 18.0,
        peak_w: 75.0,
    },
];

/// Look up a platform by Table-1 id (C1, G1..G4).
pub fn find(id: &str) -> Option<&'static Platform> {
    PLATFORMS.iter().find(|p| p.id == id)
}

impl Platform {
    pub fn is_gpu(&self) -> bool {
        self.arch != Arch::Cpu
    }

    /// Ridge point of the roofline: FLOPs/byte where the device moves from
    /// memory- to compute-bound.
    pub fn ridge_point(&self) -> f64 {
        self.peak_fp32_tflops * 1e12 / (self.mem_bw_gbs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_complete() {
        assert_eq!(PLATFORMS.len(), 5);
        for id in ["C1", "G1", "G2", "G3", "G4"] {
            assert!(find(id).is_some(), "{id}");
        }
        assert!(find("G9").is_none());
    }

    #[test]
    fn table1_values_match_paper() {
        let v100 = find("G1").unwrap();
        assert_eq!(v100.peak_fp32_tflops, 15.7);
        assert!(v100.occupancy_floor > 0.0 && v100.occupancy_floor < 1.0);
        assert_eq!(v100.mem_bw_gbs, 900.0);
        assert_eq!(v100.memory_gb, 32);
        let t4 = find("G3").unwrap();
        assert_eq!(t4.peak_fp32_tflops, 8.1);
        assert_eq!(t4.mem_bw_gbs, 300.0);
    }

    #[test]
    fn gpu_ordering_by_capability() {
        // V100 > 2080Ti > T4 > P4 in both compute and bandwidth.
        let ids = ["G1", "G2", "G3", "G4"];
        let ps: Vec<_> = ids.iter().map(|i| find(i).unwrap()).collect();
        for w in ps.windows(2) {
            assert!(w[0].peak_fp32_tflops > w[1].peak_fp32_tflops);
            assert!(w[0].mem_bw_gbs > w[1].mem_bw_gbs);
        }
    }

    #[test]
    fn ridge_points_sane() {
        // V100 ridge ~ 17.4 FLOPs/byte.
        let v100 = find("G1").unwrap();
        assert!((v100.ridge_point() - 17.44).abs() < 0.1);
        assert!(!find("C1").unwrap().is_gpu());
        assert!(v100.is_gpu());
    }
}
