//! Calibrated roofline latency/utilization model for the GPU platforms.
//!
//! This is the substitution for the GPUs this testbed does not have
//! (DESIGN.md §2): every Tier-1 number in the paper is a function of
//! (model compute profile x device roofline x batch), and this module
//! computes exactly that function:
//!
//! ```text
//! rows      = parallel matmul rows the model exposes at batch b
//! occupancy = clamp(rows / rows_saturation, floor, 1)  (idle SMs at small b)
//! t_compute = flops(b) / (peak * occupancy)
//! t_memory  = bytes(b) / mem_bw
//! t_pcie    = request_bytes(b) / pcie_bw              (host->device)
//! t_infer   = max(t_compute, t_memory) + t_pcie + overhead
//! ```
//!
//! Utilization (Fig 9/13) falls out as achieved-FLOPs / peak, which rises
//! with batch (occupancy + overhead amortization) and depth (work vs fixed
//! overhead) — the paper's observed sensitivity directions.

use super::platforms::Platform;
use crate::models::Profile;

/// How many parallel matmul rows a model family exposes per sample.
/// CNNs expose hw*hw pixel rows; sequence models expose seq rows; MLPs one.
#[derive(Debug, Clone, Copy)]
pub struct Parallelism {
    pub rows_per_sample: f64,
}

impl Parallelism {
    pub fn mlp() -> Self {
        Parallelism { rows_per_sample: 1.0 }
    }

    pub fn cnn(hw: u64) -> Self {
        Parallelism { rows_per_sample: (hw * hw) as f64 }
    }

    pub fn sequence(seq: u64) -> Self {
        Parallelism { rows_per_sample: seq as f64 }
    }
}

/// One model-on-platform latency estimate, decomposed.
#[derive(Debug, Clone, Copy)]
pub struct Estimate {
    /// End-to-end device latency for the whole batch, seconds.
    pub total_s: f64,
    pub compute_s: f64,
    pub memory_s: f64,
    pub pcie_s: f64,
    pub overhead_s: f64,
    /// Achieved fraction of peak FP32 (0..1) — the "GPU utilization"
    /// metric of Fig 9 and Fig 13.
    pub utilization: f64,
    /// True if memory traffic, not compute, bounds the kernel.
    pub memory_bound: bool,
}

/// Estimate batched-inference latency of `profile` on `platform`.
///
/// `request_bytes` is the per-sample host->device payload; `par` the
/// family's row parallelism.
pub fn estimate(
    platform: &Platform,
    profile: &Profile,
    par: Parallelism,
    batch: usize,
    request_bytes: u64,
) -> Estimate {
    let b = batch.max(1) as f64;
    let flops = profile.flops as f64 * b;
    let bytes = profile.weight_bytes as f64 + profile.act_bytes as f64 * b;

    let rows = par.rows_per_sample * b;
    let occupancy = (rows / platform.rows_saturation).clamp(platform.occupancy_floor, 1.0);
    let peak = platform.peak_fp32_tflops * 1e12;

    let compute_s = flops / (peak * occupancy);
    let memory_s = bytes / (platform.mem_bw_gbs * 1e9);
    let pcie_s = (request_bytes as f64 * b) / (platform.pcie_gbs * 1e9);
    let work_s = compute_s.max(memory_s);
    let total_s = work_s + pcie_s + platform.overhead_s;

    Estimate {
        total_s,
        compute_s,
        memory_s,
        pcie_s,
        overhead_s: platform.overhead_s,
        utilization: (flops / peak) / total_s,
        memory_bound: memory_s > compute_s,
    }
}

/// Per-sample latency (batch latency / batch) — the cost metric.
pub fn latency_per_sample(e: &Estimate, batch: usize) -> f64 {
    e.total_s / batch.max(1) as f64
}

/// Throughput in samples/second at a given batch.
pub fn throughput(e: &Estimate, batch: usize) -> f64 {
    batch.max(1) as f64 / e.total_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::platforms::find;
    use crate::models::catalog;

    fn v100() -> &'static Platform {
        find("G1").unwrap()
    }

    #[test]
    fn latency_flat_then_growing_with_batch() {
        // Paper Fig 7b: GPU latency ~flat below saturation, grows beyond.
        let rn = catalog::find("resnet50").unwrap();
        let par = Parallelism::cnn(224);
        let l1 = estimate(v100(), &rn.profile, par, 1, rn.request_bytes).total_s;
        let l4 = estimate(v100(), &rn.profile, par, 4, rn.request_bytes).total_s;
        let l64 = estimate(v100(), &rn.profile, par, 64, rn.request_bytes).total_s;
        assert!(l4 < 2.0 * l1, "batch 4 should not cost 4x batch 1: {l1} -> {l4}");
        assert!(l64 > 6.0 * l1, "batch 64 should be near-linear: {l1} -> {l64}");
    }

    #[test]
    fn throughput_improves_with_batch() {
        let rn = catalog::find("resnet50").unwrap();
        let par = Parallelism::cnn(224);
        let t1 = throughput(&estimate(v100(), &rn.profile, par, 1, 0), 1);
        let t32 = throughput(&estimate(v100(), &rn.profile, par, 32, 0), 32);
        assert!(t32 > 2.0 * t1);
    }

    #[test]
    fn v100_faster_than_p4() {
        let rn = catalog::find("resnet50").unwrap();
        let par = Parallelism::cnn(224);
        let p4 = find("G4").unwrap();
        for b in [1, 8, 32] {
            let lv = estimate(v100(), &rn.profile, par, b, 0).total_s;
            let lp = estimate(p4, &rn.profile, par, b, 0).total_s;
            assert!(lv < lp, "batch {b}");
        }
    }

    #[test]
    fn mobilenet_memory_bound_resnet_compute_bound() {
        // Paper Fig 10a at large batch on V100.
        let rn = catalog::find("resnet50").unwrap();
        let mb = catalog::find("mobilenet_v1").unwrap();
        let par = Parallelism::cnn(224);
        assert!(!estimate(v100(), &rn.profile, par, 32, 0).memory_bound);
        assert!(estimate(v100(), &mb.profile, par, 32, 0).memory_bound);
    }

    #[test]
    fn utilization_rises_with_batch() {
        let bert = catalog::find("bert_large").unwrap();
        let par = Parallelism::sequence(128);
        let u1 = estimate(v100(), &bert.profile, par, 1, 0).utilization;
        let u16 = estimate(v100(), &bert.profile, par, 16, 0).utilization;
        assert!(u16 > u1);
        assert!(u16 <= 1.0 + 1e-9);
    }

    #[test]
    fn utilization_rises_with_depth() {
        // Fig 9: deeper generated models use the device more.
        use crate::models::analytic::transformer;
        let par = Parallelism::sequence(64);
        let shallow = transformer(2, 256, 4, 64, 16);
        let deep = transformer(12, 256, 4, 64, 16);
        let us = estimate(v100(), &shallow, par, 4, 0).utilization;
        let ud = estimate(v100(), &deep, par, 4, 0).utilization;
        assert!(ud > us, "depth should raise utilization: {us} -> {ud}");
    }

    #[test]
    fn estimate_decomposition_sums() {
        let rn = catalog::find("resnet50").unwrap();
        let e = estimate(v100(), &rn.profile, Parallelism::cnn(224), 8, rn.request_bytes);
        let expect = e.compute_s.max(e.memory_s) + e.pcie_s + e.overhead_s;
        assert!((e.total_s - expect).abs() < 1e-12);
        assert!(e.pcie_s > 0.0);
    }
}
