//! GPU sharing manager (paper §4.2.1 Utility Functions: "The sharing
//! manager helps users configure MPS ... to support a sharing benchmark";
//! §3.3 "Sharing versus Dedicate" trade-off; §2.2 motivation via MPS and
//! Salus).
//!
//! Models N inference services colocated on one GPU under MPS-style
//! spatial sharing: each service gets a compute fraction, kernels from
//! different services overlap, and contention adds latency. The model:
//!
//! ```text
//! demand_i   = rate_i * t_exclusive_i          (busy fraction alone)
//! total      = sum(demand_i)
//! slowdown   = 1                         if total <= mps_efficiency
//!            = total / mps_efficiency    otherwise (compute contention)
//! t_shared_i = t_exclusive_i * slowdown + mps_overhead
//! ```
//!
//! `mps_efficiency` (< 1) captures MPS's scheduling loss vs a perfectly
//! partitionable device; `mps_overhead` the per-kernel context cost.

use super::platforms::Platform;
use super::roofline::{estimate, Estimate, Parallelism};
use crate::models::Profile;

/// One service colocated on the shared device.
#[derive(Debug, Clone)]
pub struct SharedService {
    pub name: String,
    pub profile: Profile,
    pub parallelism: Parallelism,
    pub batch: usize,
    /// Offered request rate (batches/second = rate/batch).
    pub rate_rps: f64,
}

/// Result for one service under sharing.
#[derive(Debug, Clone)]
pub struct SharingOutcome {
    pub name: String,
    /// Latency when the service owns the device.
    pub exclusive_s: f64,
    /// Latency under MPS sharing with the co-tenants.
    pub shared_s: f64,
    /// exclusive-device busy fraction this service needs.
    pub demand: f64,
}

/// Whole-device sharing report.
#[derive(Debug, Clone)]
pub struct SharingReport {
    pub outcomes: Vec<SharingOutcome>,
    /// Sum of busy fractions (>1 means overcommitted even before MPS loss).
    pub total_demand: f64,
    /// Applied latency multiplier.
    pub slowdown: f64,
    /// GPUs needed to run each service dedicated (for the cost trade-off).
    pub dedicated_gpus: usize,
}

/// MPS scheduling efficiency: fraction of the device that N co-tenants
/// can actually use concurrently (empirically ~0.85 for inference mixes).
pub const MPS_EFFICIENCY: f64 = 0.85;
/// Added per-inference overhead from MPS context switching.
pub const MPS_OVERHEAD_S: f64 = 0.15e-3;

/// Evaluate colocating `services` on `platform` under MPS.
pub fn share(platform: &Platform, services: &[SharedService]) -> SharingReport {
    assert!(!services.is_empty());
    let estimates: Vec<Estimate> = services
        .iter()
        .map(|s| estimate(platform, &s.profile, s.parallelism, s.batch, 0))
        .collect();
    let demands: Vec<f64> = services
        .iter()
        .zip(&estimates)
        .map(|(s, e)| (s.rate_rps / s.batch.max(1) as f64) * e.total_s)
        .collect();
    let total_demand: f64 = demands.iter().sum();
    let slowdown = if total_demand <= MPS_EFFICIENCY {
        1.0
    } else {
        total_demand / MPS_EFFICIENCY
    };
    let outcomes = services
        .iter()
        .zip(&estimates)
        .zip(&demands)
        .map(|((s, e), &demand)| SharingOutcome {
            name: s.name.clone(),
            exclusive_s: e.total_s,
            shared_s: e.total_s * slowdown + MPS_OVERHEAD_S,
            demand,
        })
        .collect();
    SharingReport { outcomes, total_demand, slowdown, dedicated_gpus: services.len() }
}

/// The §3.3 trade-off: sharing saves `dedicated_gpus - gpus_needed`
/// devices when demand packs; returns (gpus under sharing, saved).
pub fn consolidation(report: &SharingReport) -> (usize, usize) {
    let needed = (report.total_demand / MPS_EFFICIENCY).ceil().max(1.0) as usize;
    (needed, report.dedicated_gpus.saturating_sub(needed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::platforms::find;
    use crate::models::catalog;

    fn service(name: &str, model: &str, rate: f64) -> SharedService {
        let m = catalog::find(model).unwrap();
        SharedService {
            name: name.into(),
            profile: m.profile,
            parallelism: Parallelism::cnn(28),
            batch: 1,
            rate_rps: rate,
        }
    }

    #[test]
    fn light_colocation_is_nearly_free() {
        // Fig 13 motivation: two under-utilized services share one GPU
        // with negligible interference.
        let v100 = find("G1").unwrap();
        let r = share(v100, &[service("a", "resnet50", 20.0), service("b", "mobilenet_v1", 30.0)]);
        assert!(r.total_demand < 0.5, "demand {}", r.total_demand);
        assert_eq!(r.slowdown, 1.0);
        for o in &r.outcomes {
            assert!(o.shared_s < o.exclusive_s * 1.2);
        }
    }

    #[test]
    fn overcommit_slows_everyone() {
        let v100 = find("G1").unwrap();
        let r = share(
            v100,
            &[service("a", "cyclegan", 40.0), service("b", "cyclegan", 40.0)],
        );
        assert!(r.total_demand > 1.0, "demand {}", r.total_demand);
        assert!(r.slowdown > 1.0);
        for o in &r.outcomes {
            assert!(o.shared_s > o.exclusive_s);
        }
    }

    #[test]
    fn consolidation_saves_gpus_when_light() {
        let v100 = find("G1").unwrap();
        let services: Vec<SharedService> =
            (0..4).map(|i| service(&format!("s{i}"), "mobilenet_v1", 40.0)).collect();
        let r = share(v100, &services);
        let (needed, saved) = consolidation(&r);
        assert!(needed < 4, "4 light services should pack: need {needed}");
        assert_eq!(needed + saved, 4);
    }

    #[test]
    fn consolidation_never_below_one_gpu() {
        let v100 = find("G1").unwrap();
        let r = share(v100, &[service("tiny", "mobilenet_v1", 1.0)]);
        let (needed, saved) = consolidation(&r);
        assert_eq!(needed, 1);
        assert_eq!(saved, 0);
    }

    #[test]
    fn slowdown_proportional_beyond_capacity() {
        let v100 = find("G1").unwrap();
        let one = share(v100, &[service("a", "cyclegan", 40.0)]);
        let two = share(
            v100,
            &[service("a", "cyclegan", 40.0), service("b", "cyclegan", 40.0)],
        );
        assert!(two.slowdown > one.slowdown);
        assert!((two.total_demand / one.total_demand - 2.0).abs() < 1e-9);
    }
}
