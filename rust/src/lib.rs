//! InferBench: an automatic, distributed benchmark system for deep-learning
//! inference serving — a reproduction of "InferBench / No More 996" (2020)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! Serving tiers: [`serving::sim`] simulates one accelerator behind one
//! serving software (the paper's Fig 4 pipeline); [`serving::cluster`]
//! generalizes it to an N-replica cluster — per-replica batchers and
//! service models (heterogeneous mixes allowed) behind a pluggable
//! [`serving::router`] (round-robin, least-outstanding, seeded
//! power-of-two-choices) — with per-replica [`metrics::ReplicaMetrics`]
//! merged into a cluster-level [`metrics::Collector`]. The scale-out
//! figure (`benches/fig16_scaleout.rs`) reports throughput and tail
//! latency vs replica count × router policy.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! regenerated paper results.

pub mod analysis;
pub mod coordinator;
pub mod hardware;
pub mod metrics;
pub mod models;
pub mod perfdb;
pub mod pipeline;
pub mod runtime;
pub mod serving;
pub mod testing;
pub mod util;
pub mod workload;
