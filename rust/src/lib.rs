//! InferBench: an automatic, distributed benchmark system for deep-learning
//! inference serving — a reproduction of "InferBench / No More 996" (2020)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! Serving tiers: [`serving::sim`] simulates one accelerator behind one
//! serving software (the paper's Fig 4 pipeline); [`serving::cluster`]
//! generalizes it to an N-replica cluster — per-replica batchers and
//! service models (heterogeneous mixes allowed) behind a pluggable
//! [`serving::router`] (round-robin, least-outstanding, seeded
//! power-of-two-choices, latency-aware EWMA) — with per-replica
//! [`metrics::ReplicaMetrics`] merged into a cluster-level
//! [`metrics::Collector`]. [`serving::autoscale`] makes the fleet
//! elastic: replicas added under load pay their software's cold start
//! before taking traffic, and removal drains in-flight + queued work
//! before retiring (`issued == completed + dropped` exactly across scale
//! events; [`metrics::ScaleTimeline`] records the replica-count
//! timeline). The scale-out figure (`benches/fig16_scaleout.rs`) reports
//! throughput and tail latency vs replica count × router policy; the
//! autoscale figure (`benches/fig17_autoscale.rs`) reports burst-vs-
//! recovery p99 for scale policies × cold-start profiles.
//!
//! Multi-model tier: [`serving::multimodel`] co-locates several models on
//! each replica — per-model batchers and queues behind a model-aware
//! [`serving::router::ModelRouter`], a per-replica weight-memory budget
//! (loads pay cold starts; overflowing placements evict idle co-tenants
//! or are rejected), and an MPS contention multiplier derived from
//! [`hardware::sharing`] — the paper's §3.3 Sharing-versus-Dedicate
//! study, reproduced event-driven by `benches/fig_sharing.rs` with exact
//! per-stream conservation ([`metrics::ModelMetrics`]).
//!
//! Sweep tier: [`sweep`] executes whole benchmark grids (the fig7–fig17
//! cell matrices) on a scoped-thread worker pool with per-cell seeds
//! derived from the plan seed, returning results in plan order so a
//! parallel run is bit-identical to a serial one. The coordinator
//! dispatches grids as `task: sweep` YAML jobs executed under each
//! worker's `threads_per_worker` budget, and with `followers: N` shards
//! one plan across followers over the [`codec`] wire frames
//! ([`coordinator::distributed`]): streaming per-cell result absorption,
//! straggler re-queue from per-cell seeds, bit-identical to serial at
//! any follower count.
//!
//! Observability: [`obs`] adds a determinism-preserving tracing and
//! telemetry layer — head-sampled request span trees through admit →
//! hold → route → batch → serve → retry, gauge timelines of engine
//! internals on a fixed sim-time grid in bounded rings, and
//! coordinator job/shard spans — exported as Chrome-trace/Perfetto
//! JSON or line-delimited [`codec`] frames. Enabling it never touches
//! an RNG stream or the event heap, so traced runs are bit-identical
//! to untraced ones (gated by `tests/obs.rs`).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! regenerated paper results.

pub mod analysis;
pub mod codec;
pub mod coordinator;
pub mod hardware;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod perfdb;
pub mod pipeline;
pub mod runtime;
pub mod serving;
pub mod sweep;
pub mod testing;
pub mod util;
pub mod workload;
