//! InferBench: an automatic, distributed benchmark system for deep-learning
//! inference serving — a reproduction of "InferBench / No More 996" (2020)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! Serving tiers: [`serving::sim`] simulates one accelerator behind one
//! serving software (the paper's Fig 4 pipeline); [`serving::cluster`]
//! generalizes it to an N-replica cluster — per-replica batchers and
//! service models (heterogeneous mixes allowed) behind a pluggable
//! [`serving::router`] (round-robin, least-outstanding, seeded
//! power-of-two-choices, latency-aware EWMA) — with per-replica
//! [`metrics::ReplicaMetrics`] merged into a cluster-level
//! [`metrics::Collector`]. [`serving::autoscale`] makes the fleet
//! elastic: replicas added under load pay their software's cold start
//! before taking traffic, and removal drains in-flight + queued work
//! before retiring (`issued == completed + dropped` exactly across scale
//! events; [`metrics::ScaleTimeline`] records the replica-count
//! timeline). The scale-out figure (`benches/fig16_scaleout.rs`) reports
//! throughput and tail latency vs replica count × router policy; the
//! autoscale figure (`benches/fig17_autoscale.rs`) reports burst-vs-
//! recovery p99 for scale policies × cold-start profiles.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! regenerated paper results.

pub mod analysis;
pub mod coordinator;
pub mod hardware;
pub mod metrics;
pub mod models;
pub mod perfdb;
pub mod pipeline;
pub mod runtime;
pub mod serving;
pub mod testing;
pub mod util;
pub mod workload;
