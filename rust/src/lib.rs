//! InferBench: an automatic, distributed benchmark system for deep-learning
//! inference serving — a reproduction of "InferBench / No More 996" (2020)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! regenerated paper results.

pub mod analysis;
pub mod coordinator;
pub mod hardware;
pub mod metrics;
pub mod models;
pub mod perfdb;
pub mod pipeline;
pub mod runtime;
pub mod serving;
pub mod testing;
pub mod util;
pub mod workload;
