//! InferBench CLI — the leader entrypoint (paper Fig 1).
//!
//! Subcommands:
//!   table1                      print the hardware platform table
//!   submit <spec.yaml>...       run submissions on a follower cluster
//!   serve                       live CPU serving of an AOT artifact (e2e)
//!   recommend                   top-3 config recommendation under an SLO
//!   leaderboard                 sort a PerfDB JSONL by a metric
//!   status-demo                 run jobs while printing monitor snapshots

use anyhow::{anyhow, Result};
use inferbench::analysis::recommend;
use inferbench::coordinator::{JobSpec, Leader, LeaderConfig, SchedulerPolicy};
use inferbench::hardware::{Parallelism, PLATFORMS};
use inferbench::models::catalog;
use inferbench::perfdb::{PerfDb, Query};
use inferbench::serving::live::{run_load, LiveConfig, LiveServer};
use inferbench::serving::Policy;
use inferbench::util::cli::Args;
use inferbench::util::render;

fn main() -> Result<()> {
    let args = Args::from_env(&["help"]);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "table1" => table1(),
        "submit" => submit(&args),
        "serve" => serve(&args),
        "recommend" => recommend_cmd(&args),
        "leaderboard" => leaderboard(&args),
        "status-demo" => status_demo(&args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
inferbench — automatic DL inference serving benchmark system

USAGE:
  inferbench table1
  inferbench submit <spec.yaml>... [--workers N] [--threads-per-worker N] [--policy qa_sjf|rr_fcfs|rr_sjf] [--db out.jsonl]
  inferbench serve [--model resnet_mini] [--rate 20] [--duration 10] [--max-batch 8] [--artifacts artifacts]
  inferbench recommend [--model resnet50] [--slo-ms 100] [--rate 50]
  inferbench leaderboard --db perf.jsonl [--metric p99_ms] [--task serving_sim]
  inferbench status-demo [--workers 4]
";

fn table1() -> Result<()> {
    let rows: Vec<Vec<String>> = PLATFORMS
        .iter()
        .map(|p| {
            vec![
                p.id.to_string(),
                p.name.to_string(),
                format!("{:?}", p.arch),
                format!("{} GB", p.memory_gb),
                if p.is_gpu() {
                    format!("{:.1} ({:.1})", p.peak_fp32_tflops, p.peak_fp16_tflops)
                } else {
                    "-".into()
                },
                if p.is_gpu() { format!("{:.0}", p.mem_bw_gbs) } else { "-".into() },
            ]
        })
        .collect();
    print!(
        "{}",
        render::table(
            &["ID", "Platform", "Arch", "Memory", "Peak TFLOPS (FP32/FP16)", "Mem BW (GB/s)"],
            &rows
        )
    );
    Ok(())
}

fn parse_policy(s: &str) -> Result<SchedulerPolicy> {
    match s {
        "qa_sjf" => Ok(SchedulerPolicy::qa_sjf()),
        "rr_fcfs" => Ok(SchedulerPolicy::rr_fcfs()),
        "rr_sjf" => Ok(SchedulerPolicy::rr_sjf()),
        other => Err(anyhow!("unknown policy {other:?}")),
    }
}

fn submit(args: &Args) -> Result<()> {
    let files = &args.positional[1..];
    if files.is_empty() {
        return Err(anyhow!("submit: need at least one spec file"));
    }
    let policy = parse_policy(args.get_or("policy", "qa_sjf"))?;
    let leader = Leader::start(LeaderConfig {
        workers: args.get_usize("workers", 4),
        policy,
        time_scale: args.get_f64("time-scale", 1.0),
        threads_per_worker: args.get_usize("threads-per-worker", 1),
        seed: args.get_u64("seed", 0),
    });
    let mut n = 0;
    for f in files {
        let text = std::fs::read_to_string(f)?;
        let spec = JobSpec::parse_yaml(&text)?;
        let (id, worker) = leader.submit(spec.clone())?;
        println!("submitted job {id} ({}) -> worker {worker}", spec.name);
        n += 1;
    }
    let done = leader.wait_for(n, std::time::Duration::from_secs(600))?;
    for c in &done {
        println!(
            "  job {} ({}) on worker {}: waited {} ran {} [{}]",
            c.id,
            c.name,
            c.worker,
            render::fmt_duration(c.waited_s),
            render::fmt_duration(c.ran_s),
            if c.ok { "ok" } else { "FAILED" }
        );
    }
    let db = leader.perfdb.lock().unwrap();
    println!("\nPerfDB: {} records", db.len());
    for r in db.query(&Query::default()) {
        println!("  {} {} {} {} {}", r.task, r.model, r.platform, r.software, r.metrics);
    }
    if let Some(path) = args.get("db") {
        db.save_jsonl(path)?;
        println!("saved PerfDB to {path}");
    }
    drop(db);
    leader.shutdown();
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let model = args.get_or("model", "resnet_mini");
    let rate = args.get_f64("rate", 20.0);
    let duration = args.get_f64("duration", 10.0);
    let max_batch = args.get_usize("max-batch", 8);
    println!("loading {model} artifacts (XLA compile)...");
    let server = LiveServer::start(LiveConfig {
        artifact_dir: args.get_or("artifacts", "artifacts").into(),
        model_stem: model.to_string(),
        policy: Policy::Dynamic { max_size: max_batch, max_wait_s: 0.005 },
        seed: args.get_u64("seed", 0),
    })?;
    for (b, t) in &server.info.variants {
        println!("  variant b{b}: compiled in {}", render::fmt_duration(*t));
    }
    println!("serving at {rate} rps for {duration}s...");
    let report = run_load(&server, rate, duration, 7)?;
    println!(
        "completed {} requests in {:.1}s ({:.1} rps)",
        report.completed,
        report.wall_s,
        report.throughput_rps()
    );
    println!(
        "e2e latency: p50 {} p95 {} p99 {} max {}",
        render::fmt_duration(report.e2e.percentile(50.0)),
        render::fmt_duration(report.e2e.percentile(95.0)),
        render::fmt_duration(report.e2e.percentile(99.0)),
        render::fmt_duration(report.e2e.max()),
    );
    println!(
        "infer time: p50 {}; mean batch {:.2}",
        render::fmt_duration(report.infer.percentile(50.0)),
        report.batch_sizes.mean()
    );
    server.shutdown()?;
    Ok(())
}

fn recommend_cmd(args: &Args) -> Result<()> {
    let model_name = args.get_or("model", "resnet50");
    let model =
        catalog::find(model_name).ok_or_else(|| anyhow!("model {model_name:?} not in catalog"))?;
    let slo_s = args.get_f64("slo-ms", 100.0) / 1e3;
    let rate = args.get_f64("rate", 50.0);
    let rec = recommend(model, Parallelism::cnn(28), slo_s, rate, 3);
    println!(
        "top {} of {} configs for {model_name} under SLO {} at {rate} rps:",
        rec.top.len(),
        rec.considered,
        render::fmt_duration(slo_s)
    );
    let rows: Vec<Vec<String>> = rec
        .top
        .iter()
        .map(|c| {
            vec![
                c.platform.id.to_string(),
                c.software.id.to_string(),
                c.batch.to_string(),
                render::fmt_duration(c.latency_s),
                format!("{:.0}", c.throughput_rps),
                c.cost_per_1k_usd.map(|v| format!("${v:.4}")).unwrap_or("-".into()),
            ]
        })
        .collect();
    print!(
        "{}",
        render::table(&["Platform", "Software", "Batch", "Latency", "Max RPS", "$/1k req"], &rows)
    );
    Ok(())
}

fn leaderboard(args: &Args) -> Result<()> {
    let db_path = args.get("db").ok_or_else(|| anyhow!("leaderboard: need --db"))?;
    let metric = args.get_or("metric", "p99_ms");
    let db = PerfDb::load_jsonl(db_path)?;
    let mut q = Query::default();
    if let Some(t) = args.get("task") {
        q = q.task(t);
    }
    let rows: Vec<Vec<String>> = db
        .leaderboard(&q, metric)
        .iter()
        .map(|r| {
            vec![
                r.model.clone(),
                r.platform.clone(),
                r.software.clone(),
                r.metric(metric).map(|v| format!("{v:.3}")).unwrap_or("-".into()),
            ]
        })
        .collect();
    print!("{}", render::table(&["Model", "Platform", "Software", metric], &rows));
    Ok(())
}

fn status_demo(args: &Args) -> Result<()> {
    let leader = Leader::start(LeaderConfig {
        workers: args.get_usize("workers", 4),
        policy: SchedulerPolicy::qa_sjf(),
        time_scale: 20.0,
        threads_per_worker: args.get_usize("threads-per-worker", 1),
        seed: 1,
    });
    let mut rng = inferbench::util::rng::Pcg64::seeded(3);
    for i in 0..12 {
        let secs = rng.lognormal(1.0, 0.8).clamp(0.5, 20.0);
        leader.submit(JobSpec::parse_yaml(&format!(
            "name: demo{i}\ntask: sleep\nseconds: {secs:.2}\n"
        ))?)?;
    }
    for _ in 0..6 {
        std::thread::sleep(std::time::Duration::from_millis(120));
        let status = leader.status();
        let line: Vec<String> = status
            .iter()
            .map(|s| {
                format!("w{}[q={} {}]", s.worker, s.queued, if s.busy { "busy" } else { "idle" })
            })
            .collect();
        println!("{}", line.join(" "));
    }
    let done = leader.wait_for(12, std::time::Duration::from_secs(60))?;
    println!(
        "completed {} jobs; mean JCT {:.2}s",
        done.len(),
        done.iter().map(|c| c.jct_s()).sum::<f64>() / done.len() as f64
    );
    leader.shutdown();
    Ok(())
}
