//! Metric collector + prober (paper §4.2.4 Stage 3 — Collect).
//!
//! The prober timestamps every request at each pipeline-stage boundary
//! (pre-process / transmission / batch-queue / inference / post-process);
//! the collector aggregates per-stage and end-to-end latency, throughput,
//! and a utilization timeline (Fig 13).
//!
//! Hot-path layout (see PERF.md): a [`RequestTrace`] is a flat `Copy`
//! struct — per-stage seconds live in a fixed `[f64; 5]` array (indexed by
//! `Stage as usize`) with a recorded-stage bitmask, not a `BTreeMap` — and
//! in-flight traces live in a [`TraceStore`] slab with a free list, so the
//! simulator's request lifecycle allocates nothing at steady state.

use crate::util::stats::{Summary, SummarySnapshot};
use std::collections::BTreeMap;

/// How a run's latency distributions are stored.
///
/// `Exact` keeps every sample (`Vec<f64>` per summary) — bit-exact
/// percentiles, O(completed) memory; the right choice up to ~10⁶ requests
/// and the mode every golden test pins. `Sketch` bounds memory with
/// DDSketch-style log buckets (`Summary::sketch`): percentiles within
/// relative error `alpha`, memory constant in request count — the mode
/// that makes 10⁸-request streaming runs fit in a flat RSS. Counts, sums,
/// means, min/max, and p0/p100 stay exact in both modes.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum MetricsMode {
    #[default]
    Exact,
    Sketch {
        /// Relative-error bound for quantiles, e.g. 0.01 for 1%.
        alpha: f64,
    },
}

impl MetricsMode {
    /// A fresh latency summary in this mode.
    pub fn summary(&self) -> Summary {
        match self {
            MetricsMode::Exact => Summary::new(),
            MetricsMode::Sketch { alpha } => Summary::sketch(*alpha),
        }
    }

    /// True when per-sample side tables (windowed-latency pairs, batch-size
    /// sequences) must not be materialized.
    pub fn is_bounded(&self) -> bool {
        matches!(self, MetricsMode::Sketch { .. })
    }
}

/// The five pipeline stages of Fig 4, in order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    PreProcess,
    Transmission,
    Batching,
    Inference,
    PostProcess,
}

pub const STAGES: [Stage; 5] = [
    Stage::PreProcess,
    Stage::Transmission,
    Stage::Batching,
    Stage::Inference,
    Stage::PostProcess,
];

impl Stage {
    pub fn label(&self) -> &'static str {
        match self {
            Stage::PreProcess => "pre-process",
            Stage::Transmission => "transmission",
            Stage::Batching => "batching",
            Stage::Inference => "inference",
            Stage::PostProcess => "post-process",
        }
    }

    /// Dense index into per-stage arrays (declaration order, 0..5).
    pub const fn idx(self) -> usize {
        self as usize
    }
}

/// Why a request was dropped instead of completed. The collector keeps
/// one counter per reason next to the aggregate [`Collector::dropped`]
/// count, refining the conservation ledger from
/// `issued == completed + dropped` to
/// `issued == completed + Σ dropped_by_reason` — the totals always agree
/// (both are bumped by the same `ingest` branch), so fingerprints and
/// every pre-existing check are untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DropReason {
    /// The routed replica's batch queue was at `max_queue`. The default:
    /// call sites that only flip [`RequestTrace::dropped`] keep their
    /// historical meaning.
    #[default]
    QueueFull,
    /// The admission tier shed the request before routing — token-bucket
    /// exhaustion or a class backlog threshold (`serving/ingress.rs`).
    Shed,
    /// The request was queued (or held) behind a model that was evicted
    /// out from under it — multi-model engine only.
    EvictedBacklog,
    /// No routable replica existed and none was warming/loading: the
    /// request had nowhere to go at the routing tier.
    RejectedPlacement,
    /// The request was queued or in flight on a replica that crashed,
    /// and no retry policy (or no remaining attempt) could re-issue it
    /// (`serving/faults.rs`).
    ReplicaFailed,
    /// A retry was scheduled but its deterministic backoff would have
    /// landed past the retry policy's end-to-end deadline, so the
    /// request gave up instead of re-issuing.
    TimedOut,
}

/// All drop reasons, in [`DropReason::idx`] order.
pub const DROP_REASONS: [DropReason; 6] = [
    DropReason::QueueFull,
    DropReason::Shed,
    DropReason::EvictedBacklog,
    DropReason::RejectedPlacement,
    DropReason::ReplicaFailed,
    DropReason::TimedOut,
];

impl DropReason {
    pub fn label(&self) -> &'static str {
        match self {
            DropReason::QueueFull => "queue-full",
            DropReason::Shed => "shed",
            DropReason::EvictedBacklog => "evicted-backlog",
            DropReason::RejectedPlacement => "rejected-placement",
            DropReason::ReplicaFailed => "replica-failed",
            DropReason::TimedOut => "timed-out",
        }
    }

    /// Dense index into per-reason arrays (declaration order, 0..6).
    pub const fn idx(self) -> usize {
        self as usize
    }
}

/// Per-request probe record: arrival + per-stage durations (seconds).
/// Flat and `Copy` — 72 bytes, no heap — so the trace store can hold it
/// inline and hand it around by value (the reason/class tags ride in
/// padding the pre-ledger layout already paid for).
#[derive(Debug, Clone, Copy)]
pub struct RequestTrace {
    pub id: u64,
    pub arrival_s: f64,
    /// Accumulated seconds per stage, indexed by [`Stage::idx`].
    stage_s: [f64; 5],
    /// Bitmask of stages recorded at least once: distinguishes "probed at
    /// 0 s" from "never probed", so per-stage sample counts stay exact.
    recorded: u8,
    pub completed_s: f64,
    /// Set when the request was rejected/dropped (overload).
    pub dropped: bool,
    /// Why, when `dropped` is set. Meaningless otherwise.
    pub drop_reason: DropReason,
    /// Priority class the request was admitted under (0 = highest). Stays
    /// 0 when the run has no admission tier.
    pub class: u8,
}

impl RequestTrace {
    pub fn new(id: u64, arrival_s: f64) -> Self {
        RequestTrace {
            id,
            arrival_s,
            stage_s: [0.0; 5],
            recorded: 0,
            completed_s: arrival_s,
            dropped: false,
            drop_reason: DropReason::QueueFull,
            class: 0,
        }
    }

    /// Mark the request dropped for `reason` (the tagged form of the
    /// historical `trace.dropped = true`).
    pub fn drop_with(&mut self, reason: DropReason) {
        self.dropped = true;
        self.drop_reason = reason;
    }

    pub fn record_stage(&mut self, stage: Stage, seconds: f64) {
        self.stage_s[stage.idx()] += seconds;
        self.recorded |= 1 << stage.idx();
        self.completed_s += seconds;
    }

    /// Accumulated seconds in `stage`; `None` if the stage was never probed.
    pub fn stage_s(&self, stage: Stage) -> Option<f64> {
        if self.recorded & (1 << stage.idx()) != 0 {
            Some(self.stage_s[stage.idx()])
        } else {
            None
        }
    }

    /// End-to-end latency (arrival -> completion).
    pub fn e2e_s(&self) -> f64 {
        self.completed_s - self.arrival_s
    }
}

/// Slab/free-list store for in-flight [`RequestTrace`]s: O(1) insert /
/// access / remove, with completed slots reused (LIFO), so the request
/// lifecycle is allocation-free at steady state — a closed-loop run cycles
/// the same few slots for its whole duration. Replaces the
/// `HashMap<u64, RequestTrace>` trace map (hash + probe per event, resize
/// churn mid-run; see PERF.md §Trace store).
#[derive(Debug, Default)]
pub struct TraceStore {
    slots: Vec<RequestTrace>,
    free: Vec<u32>,
}

impl TraceStore {
    pub fn with_capacity(n: usize) -> Self {
        TraceStore { slots: Vec::with_capacity(n), free: Vec::new() }
    }

    /// Live (inserted, not yet removed) trace count.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Store a trace, returning its slot. The slot stays valid until
    /// [`TraceStore::remove`], after which it may be reused.
    pub fn insert(&mut self, trace: RequestTrace) -> u32 {
        match self.free.pop() {
            Some(slot) => {
                self.slots[slot as usize] = trace;
                slot
            }
            None => {
                self.slots.push(trace);
                (self.slots.len() - 1) as u32
            }
        }
    }

    pub fn get(&self, slot: u32) -> &RequestTrace {
        &self.slots[slot as usize]
    }

    pub fn get_mut(&mut self, slot: u32) -> &mut RequestTrace {
        &mut self.slots[slot as usize]
    }

    /// Remove and return the trace in `slot`, releasing the slot for reuse.
    pub fn remove(&mut self, slot: u32) -> RequestTrace {
        self.free.push(slot);
        self.slots[slot as usize]
    }
}

/// Aggregated metrics over a benchmark run.
#[derive(Debug, Clone, Default)]
pub struct Collector {
    pub e2e: Summary,
    /// Per-stage latency summaries, indexed by [`Stage::idx`]; read via
    /// [`Collector::stage`].
    per_stage: [Summary; 5],
    /// (arrival_s, e2e_s) per completed request, in ingest order — feeds
    /// windowed tail analysis (burst-window p99, recovery curves). Empty
    /// in bounded ([`MetricsMode::Sketch`]) mode: the side table is
    /// O(completed) and would defeat the flat-RSS guarantee.
    pub arrival_e2e: Vec<(f64, f64)>,
    /// True when built with [`MetricsMode::Sketch`]: per-sample side
    /// tables are suppressed.
    bounded: bool,
    pub completed: u64,
    pub dropped: u64,
    /// Drops split by [`DropReason::idx`]. Invariant (kept by `ingest` and
    /// `absorb`): the entries sum to `dropped` exactly.
    dropped_by_reason: [u64; DROP_REASONS.len()],
    pub first_arrival_s: f64,
    pub last_completion_s: f64,
}

impl Collector {
    /// Exact collector (every sample retained).
    pub fn new() -> Self {
        Collector { first_arrival_s: f64::INFINITY, ..Default::default() }
    }

    /// Collector in the given [`MetricsMode`]. Sketch mode bounds memory:
    /// latency summaries use the quantile sketch and the per-completion
    /// `arrival_e2e` side table stays empty.
    pub fn with_mode(mode: MetricsMode) -> Self {
        Collector {
            e2e: mode.summary(),
            per_stage: std::array::from_fn(|_| mode.summary()),
            bounded: mode.is_bounded(),
            first_arrival_s: f64::INFINITY,
            ..Default::default()
        }
    }

    /// True when built with [`MetricsMode::Sketch`].
    pub fn is_bounded(&self) -> bool {
        self.bounded
    }

    pub fn ingest(&mut self, trace: &RequestTrace) {
        if trace.dropped {
            self.dropped += 1;
            self.dropped_by_reason[trace.drop_reason.idx()] += 1;
            return;
        }
        self.completed += 1;
        self.e2e.record(trace.e2e_s());
        if !self.bounded {
            self.arrival_e2e.push((trace.arrival_s, trace.e2e_s()));
        }
        for (i, summary) in self.per_stage.iter_mut().enumerate() {
            if trace.recorded & (1 << i) != 0 {
                summary.record(trace.stage_s[i]);
            }
        }
        self.first_arrival_s = self.first_arrival_s.min(trace.arrival_s);
        self.last_completion_s = self.last_completion_s.max(trace.completed_s);
    }

    /// Latency summary for one pipeline stage (empty if never probed).
    pub fn stage(&self, stage: Stage) -> &Summary {
        &self.per_stage[stage.idx()]
    }

    /// Drops attributed to one [`DropReason`].
    pub fn dropped_by(&self, reason: DropReason) -> u64 {
        self.dropped_by_reason[reason.idx()]
    }

    /// `(label, count)` per drop reason, in [`DROP_REASONS`] order — the
    /// shape the coordinator's JSON records and the fig_qos tables print.
    pub fn drop_breakdown(&self) -> [(&'static str, u64); DROP_REASONS.len()] {
        std::array::from_fn(|i| (DROP_REASONS[i].label(), self.dropped_by_reason[i]))
    }

    /// The refined ledger invariant: the per-reason counters account for
    /// every drop exactly (`dropped == Σ dropped_by_reason`). Engines
    /// assert this next to `issued == completed + dropped`.
    pub fn drops_conserved(&self) -> bool {
        self.dropped == self.dropped_by_reason.iter().sum::<u64>()
    }

    /// End-to-end latency summary restricted to requests that *arrived*
    /// within [lo_s, hi_s) — the burst-window / recovery-window view the
    /// autoscaling figures report. Requires the exact mode: in bounded
    /// mode the per-completion table is not kept, so the returned summary
    /// is empty (callers that need windowed tails run exact).
    pub fn e2e_in_window(&self, lo_s: f64, hi_s: f64) -> Summary {
        let mut s = Summary::new();
        for &(arrival, e2e) in &self.arrival_e2e {
            if arrival >= lo_s && arrival < hi_s {
                s.record(e2e);
            }
        }
        s
    }

    /// Completed requests per second over the active window.
    pub fn throughput_rps(&self) -> f64 {
        let window = self.last_completion_s - self.first_arrival_s;
        if window <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / window
    }

    /// Mean seconds spent in each stage (0 when never probed).
    pub fn stage_means(&self) -> BTreeMap<Stage, f64> {
        STAGES
            .iter()
            .map(|s| {
                let summary = &self.per_stage[s.idx()];
                (*s, if summary.is_empty() { 0.0 } else { summary.mean() })
            })
            .collect()
    }

    /// Deterministic digest of everything the benches assert about a
    /// collector: completion/drop counts, the observation window, and the
    /// p50/p95/p99/p100 order statistics, mixed bit-for-bit (FNV-1a over
    /// the raw `f64` bits). Two collectors with equal fingerprints agree
    /// on every reported number, so the parallel-sweep determinism checks
    /// (`tests/parallel_sweep.rs`, the l4 sweep bench) compare one word
    /// per cell instead of re-asserting each statistic.
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf29ce484222325;
        const FNV_PRIME: u64 = 0x100000001b3;
        let mut h = FNV_OFFSET;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(FNV_PRIME);
        };
        mix(self.completed);
        mix(self.dropped);
        mix(self.e2e.len() as u64);
        mix(self.first_arrival_s.to_bits());
        mix(self.last_completion_s.to_bits());
        for q in [50.0, 95.0, 99.0, 100.0] {
            let p = self.e2e.percentile(q);
            mix(if p.is_nan() { u64::MAX } else { p.to_bits() });
        }
        h
    }

    /// Fold another collector into this one, borrowing `other`. Thin
    /// convenience over [`Collector::absorb`]: clones `other` once and
    /// delegates, so there is exactly one buffer copy (the clone) instead
    /// of the former per-element `samples()`/`extend` path, which rebuilt
    /// every sample vector a second time — doubling peak memory exactly
    /// when merging is hottest. Prefer `absorb` when you can give up
    /// ownership: it copies nothing at all.
    ///
    /// Merge semantics by mode are `absorb`'s: exact + exact concatenates
    /// raw samples (percentiles of the union, bit-exact); sketch + sketch
    /// adds bucket counters (bounded memory, error stays ≤ α); a sketch
    /// merged into a *non-empty* exact collector panics (samples cannot be
    /// reconstructed from buckets).
    pub fn merge(&mut self, other: &Collector) {
        self.absorb(other.clone());
    }

    /// Move-based merge: consumes `other` and appends its sample buffers
    /// instead of copying them element by element (the first absorb into
    /// an empty collector takes the buffers wholesale).
    ///
    /// Mode semantics (see [`Summary::absorb`] for the full matrix):
    /// exact ← exact concatenates raw samples, so percentiles of the
    /// merged collector equal percentiles over the union of the inputs —
    /// exact, not approximate. Sketch ← sketch adds bucket counters
    /// (deterministic, commutative, error bound α preserved across
    /// chains); both sides must share the same α. An empty exact
    /// collector absorbing a sketch becomes a sketch (fan-in aggregators
    /// adopt the mode of their cells); a *non-empty* exact collector
    /// absorbing a sketch panics.
    pub fn absorb(&mut self, other: Collector) {
        self.e2e.absorb(other.e2e);
        for (dst, src) in self.per_stage.iter_mut().zip(other.per_stage) {
            dst.absorb(src);
        }
        if self.arrival_e2e.is_empty() {
            self.arrival_e2e = other.arrival_e2e;
        } else {
            self.arrival_e2e.extend(other.arrival_e2e);
        }
        self.bounded |= other.bounded;
        self.completed += other.completed;
        self.dropped += other.dropped;
        for (dst, src) in self.dropped_by_reason.iter_mut().zip(other.dropped_by_reason) {
            *dst += src;
        }
        self.first_arrival_s = self.first_arrival_s.min(other.first_arrival_s);
        self.last_completion_s = self.last_completion_s.max(other.last_completion_s);
    }

    /// Detach the serializable form that `CellResult` frames ship over the
    /// distributed-sweep wire (see `codec`). Everything the sweep layer
    /// reads off a cell collector travels — counts, the drop-reason
    /// breakdown, the observation window, and the e2e + per-stage latency
    /// payloads (raw samples in exact mode, sparse buckets in sketch
    /// mode) — so [`CollectorSnapshot::restore`] reproduces percentiles,
    /// throughput, and [`Collector::fingerprint`] bit-for-bit.
    ///
    /// Deliberately excluded: `arrival_e2e`, the per-completion windowed
    /// side table. No sweep-level record reads it, it is O(completed) on
    /// the wire, and bounded mode never materializes it; callers that need
    /// windowed tails run their figure locally in exact mode.
    pub fn snapshot(&self) -> CollectorSnapshot {
        CollectorSnapshot {
            e2e: self.e2e.snapshot(),
            per_stage: std::array::from_fn(|i| self.per_stage[i].snapshot()),
            bounded: self.bounded,
            completed: self.completed,
            dropped: self.dropped,
            dropped_by_reason: self.dropped_by_reason,
            first_arrival_s: self.first_arrival_s,
            last_completion_s: self.last_completion_s,
        }
    }
}

/// Serializable form of a [`Collector`] — the latency/ledger payload of a
/// distributed-sweep `CellResult` frame. See [`Collector::snapshot`] for
/// what travels and what is deliberately left behind.
#[derive(Debug, Clone, PartialEq)]
pub struct CollectorSnapshot {
    pub e2e: SummarySnapshot,
    /// Indexed by [`Stage::idx`], like the live collector.
    pub per_stage: [SummarySnapshot; 5],
    pub bounded: bool,
    pub completed: u64,
    pub dropped: u64,
    /// Indexed by [`DropReason::idx`]; sums to `dropped`.
    pub dropped_by_reason: [u64; DROP_REASONS.len()],
    pub first_arrival_s: f64,
    pub last_completion_s: f64,
}

impl CollectorSnapshot {
    /// Rebuild the live [`Collector`]. The restored collector absorbs,
    /// fingerprints, and reports identically to the original except for
    /// the windowed `arrival_e2e` side table, which is not shipped.
    pub fn restore(&self) -> Collector {
        Collector {
            e2e: self.e2e.restore(),
            per_stage: std::array::from_fn(|i| self.per_stage[i].restore()),
            arrival_e2e: Vec::new(),
            bounded: self.bounded,
            completed: self.completed,
            dropped: self.dropped,
            dropped_by_reason: self.dropped_by_reason,
            first_arrival_s: self.first_arrival_s,
            last_completion_s: self.last_completion_s,
        }
    }
}

/// Serializable form of a [`ClassMetrics`] ledger — rides alongside the
/// cluster-level [`CollectorSnapshot`] in a `CellResult` frame so per-class
/// QoS records survive the wire with their conservation intact.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassSnapshot {
    pub class: u8,
    pub issued: u64,
    pub collector: CollectorSnapshot,
}

impl ClassSnapshot {
    pub fn restore(&self) -> ClassMetrics {
        ClassMetrics {
            class: self.class,
            issued: self.issued,
            collector: self.collector.restore(),
        }
    }
}

/// Per-priority-class ledger of an admission-enabled run: issued count
/// plus a full [`Collector`], one per class (0 = highest priority).
/// Conservation holds independently per class:
/// `issued == collector.completed + collector.dropped`, with the drop
/// side further split by [`DropReason`]. Engines leave the class vector
/// empty when no admission tier is configured — the classless path pays
/// nothing for the ledger.
#[derive(Debug)]
pub struct ClassMetrics {
    /// Priority class (0 = highest).
    pub class: u8,
    /// Requests of this class issued by the arrival source(s).
    pub issued: u64,
    pub collector: Collector,
}

impl ClassMetrics {
    pub fn new(class: u8) -> Self {
        Self::with_mode(class, MetricsMode::Exact)
    }

    pub fn with_mode(class: u8, mode: MetricsMode) -> Self {
        ClassMetrics { class, issued: 0, collector: Collector::with_mode(mode) }
    }

    /// Whether this class's ledger balances exactly, including the
    /// per-reason refinement.
    pub fn conserved(&self) -> bool {
        self.issued == self.collector.completed + self.collector.dropped
            && self.collector.drops_conserved()
    }

    /// Fraction of issued requests that completed (goodput per offered
    /// load, the fig_qos y-axis). 0 for an idle class.
    pub fn goodput(&self) -> f64 {
        if self.issued == 0 {
            return 0.0;
        }
        self.collector.completed as f64 / self.issued as f64
    }

    /// Fraction of issued requests the admission tier shed.
    pub fn shed_fraction(&self) -> f64 {
        if self.issued == 0 {
            return 0.0;
        }
        self.collector.dropped_by(DropReason::Shed) as f64 / self.issued as f64
    }

    /// Move-based merge, mirroring [`Collector::absorb`]; both sides must
    /// describe the same class.
    pub fn absorb(&mut self, other: ClassMetrics) {
        assert_eq!(self.class, other.class, "absorbing mismatched classes");
        self.issued += other.issued;
        self.collector.absorb(other.collector);
    }

    /// Serializable form for the distributed-sweep wire.
    pub fn snapshot(&self) -> ClassSnapshot {
        ClassSnapshot { class: self.class, issued: self.issued, collector: self.collector.snapshot() }
    }
}

/// Everything the cluster serving engine measures about one replica: its
/// own collector (the cluster-level collector is fed in parallel at
/// completion time; local queue drops live in `collector.dropped`), the
/// two utilization timelines the single-server simulator reports (Fig 9 /
/// 13 metrics), and completed batch sizes.
#[derive(Debug)]
pub struct ReplicaMetrics {
    pub collector: Collector,
    /// FLOPs-efficiency-weighted utilization (achieved/peak).
    pub timeline: UtilizationTimeline,
    /// Busy-fraction utilization — what DCGM/nvidia-smi report.
    pub busy_timeline: UtilizationTimeline,
    /// Completed batch sizes on this replica; private so every append
    /// goes through [`ReplicaMetrics::record_batch`] and the running
    /// count/sum stay exact. Read via [`ReplicaMetrics::batch_sizes`].
    /// Kept empty in bounded mode (the count/sum counters still track).
    batch_sizes: Vec<usize>,
    /// Number of completed batches. Counted separately from the vector so
    /// bounded mode can drop the O(batches) sequence and keep exact means.
    batches: u64,
    batch_sum: u64,
    bounded: bool,
}

impl ReplicaMetrics {
    pub fn new(horizon_s: f64, bucket_s: f64) -> Self {
        Self::with_mode(horizon_s, bucket_s, MetricsMode::Exact)
    }

    /// Replica metrics in the given [`MetricsMode`]. Sketch mode keeps the
    /// latency sketches plus exact batch count/sum, but not the
    /// per-dispatch batch-size sequence.
    pub fn with_mode(horizon_s: f64, bucket_s: f64, mode: MetricsMode) -> Self {
        ReplicaMetrics {
            collector: Collector::with_mode(mode),
            timeline: UtilizationTimeline::new(horizon_s, bucket_s),
            busy_timeline: UtilizationTimeline::new(horizon_s, bucket_s),
            batch_sizes: Vec::new(),
            batches: 0,
            batch_sum: 0,
            bounded: mode.is_bounded(),
        }
    }

    /// Record one completed batch (keeps running count/sum for O(1) means).
    pub fn record_batch(&mut self, size: usize) {
        if !self.bounded {
            self.batch_sizes.push(size);
        }
        self.batches += 1;
        self.batch_sum += size as u64;
    }

    /// Completed batch sizes, in dispatch order. Empty in bounded mode
    /// (use [`ReplicaMetrics::batches`]/[`ReplicaMetrics::batch_sum`]).
    pub fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }

    /// Move the batch-size vector out (resets it and the running counters)
    /// — used by the single-server wrapper to hand ownership to SimResult.
    pub fn take_batch_sizes(&mut self) -> Vec<usize> {
        self.batch_sum = 0;
        self.batches = 0;
        std::mem::take(&mut self.batch_sizes)
    }

    /// Number of completed batches. O(1): maintained at record, exact in
    /// both modes.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Sum of all completed batch sizes. O(1): maintained at record.
    pub fn batch_sum(&self) -> u64 {
        self.batch_sum
    }

    /// Mean completed batch size. O(1): uses the maintained counters,
    /// exact in both modes.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batch_sum as f64 / self.batches as f64
    }
}

/// Per-model (per-stream) accounting of a multi-model serving run: the
/// model's own collector plus the number of requests its stream issued.
/// Conservation holds independently per stream:
/// `issued == collector.completed + collector.dropped`.
#[derive(Debug)]
pub struct ModelMetrics {
    pub name: String,
    /// Requests issued by this model's arrival stream.
    pub issued: u64,
    pub collector: Collector,
}

impl ModelMetrics {
    pub fn new(name: impl Into<String>) -> Self {
        Self::with_mode(name, MetricsMode::Exact)
    }

    /// Per-model metrics in the given [`MetricsMode`] — sketch mode keeps
    /// thousand-model Zipf runs at bounded memory per model.
    pub fn with_mode(name: impl Into<String>, mode: MetricsMode) -> Self {
        ModelMetrics { name: name.into(), issued: 0, collector: Collector::with_mode(mode) }
    }

    /// Whether this stream's ledger balances exactly, including the
    /// per-reason drop refinement (`Σ dropped_by_reason == dropped`).
    pub fn conserved(&self) -> bool {
        self.issued == self.collector.completed + self.collector.dropped
            && self.collector.drops_conserved()
    }
}

/// What happened to a (replica, model) placement at a [`PlacementEvent`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementEventKind {
    /// A load was admitted: the model starts paying its cold start on the
    /// replica (weight memory is charged immediately).
    LoadRequested,
    /// Cold start finished: the model is routable on the replica.
    Ready,
    /// The model left the replica: queued requests dropped, weight memory
    /// freed (in-flight work still completes).
    Evicted,
    /// A load was refused: the model did not fit even after evicting
    /// every idle co-tenant (or the op was invalid).
    Rejected,
}

impl PlacementEventKind {
    pub fn label(&self) -> &'static str {
        match self {
            PlacementEventKind::LoadRequested => "load-requested",
            PlacementEventKind::Ready => "ready",
            PlacementEventKind::Evicted => "evicted",
            PlacementEventKind::Rejected => "rejected",
        }
    }
}

/// One model-placement transition recorded by the multi-model serving
/// engine (the weight-memory analogue of [`ScaleEvent`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlacementEvent {
    pub time_s: f64,
    pub kind: PlacementEventKind,
    pub replica: usize,
    pub model: usize,
}

/// Every placement transition of a multi-model run, in event order.
/// Models hosted at t = 0 are not recorded (they never transitioned).
#[derive(Debug, Clone, Default)]
pub struct PlacementTimeline {
    pub events: Vec<PlacementEvent>,
}

impl PlacementTimeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, time_s: f64, kind: PlacementEventKind, replica: usize, model: usize) {
        self.events.push(PlacementEvent { time_s, kind, replica, model });
    }

    /// Number of events of one kind (e.g. completed loads, evictions).
    pub fn count(&self, kind: PlacementEventKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }
}

/// One replica-lifecycle transition recorded by the autoscaling cluster
/// engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaleEvent {
    pub time_s: f64,
    pub kind: ScaleEventKind,
    /// Replica index. Indices are stable for the whole run; retired
    /// replicas keep theirs (the metrics vector is append-only).
    pub replica: usize,
    /// Routable (active) replica count immediately after this event.
    pub active_after: usize,
}

/// What happened to a replica at a [`ScaleEvent`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleEventKind {
    /// Scale-up decided: the replica starts paying its cold start.
    AddRequested,
    /// Cold start finished: the replica becomes routable.
    Ready,
    /// Scale-down decided: routing stops; in-flight + queued work drains.
    DrainStarted,
    /// Drain finished: the replica retired with zero outstanding work.
    Retired,
    /// Fault injection killed the replica: routing stops instantly and
    /// queued + in-flight work dies or is retried (`serving/faults.rs`).
    Crashed,
    /// A crashed replica came back and starts paying its recovery cold
    /// start (it becomes routable again at the following `Ready`).
    Recovered,
}

impl ScaleEventKind {
    pub fn label(&self) -> &'static str {
        match self {
            ScaleEventKind::AddRequested => "add-requested",
            ScaleEventKind::Ready => "ready",
            ScaleEventKind::DrainStarted => "drain-started",
            ScaleEventKind::Retired => "retired",
            ScaleEventKind::Crashed => "crashed",
            ScaleEventKind::Recovered => "recovered",
        }
    }
}

/// Per-event replica-count timeline for autoscaling runs: every lifecycle
/// transition, with the routable-replica count after it. Empty (no events)
/// when the cluster runs without an autoscaler.
#[derive(Debug, Clone, Default)]
pub struct ScaleTimeline {
    /// Routable replicas at t = 0.
    pub initial: usize,
    pub events: Vec<ScaleEvent>,
}

impl ScaleTimeline {
    pub fn new(initial: usize) -> Self {
        ScaleTimeline { initial, events: Vec::new() }
    }

    pub fn record(&mut self, time_s: f64, kind: ScaleEventKind, replica: usize, active_after: usize) {
        self.events.push(ScaleEvent { time_s, kind, replica, active_after });
    }

    /// Step function of the routable replica count over time: starts at
    /// (0, initial); one point per event that changed the count.
    pub fn active_series(&self) -> Vec<(f64, usize)> {
        let mut series = vec![(0.0, self.initial)];
        for e in &self.events {
            if e.active_after != series.last().expect("non-empty").1 {
                series.push((e.time_s, e.active_after));
            }
        }
        series
    }

    /// Peak routable replica count over the run.
    pub fn max_active(&self) -> usize {
        self.active_series().iter().map(|&(_, n)| n).max().unwrap_or(self.initial)
    }

    /// Number of events of one kind (e.g. scale-ups, completed drains).
    pub fn count(&self, kind: ScaleEventKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }
}

/// Time-bucketed utilization timeline (Fig 13): each bucket records the
/// fraction of the bucket the device spent busy, weighted by utilization.
#[derive(Debug, Clone)]
pub struct UtilizationTimeline {
    bucket_s: f64,
    busy_weighted: Vec<f64>,
}

impl UtilizationTimeline {
    pub fn new(duration_s: f64, bucket_s: f64) -> Self {
        let n = (duration_s / bucket_s).ceil() as usize + 1;
        UtilizationTimeline { bucket_s, busy_weighted: vec![0.0; n] }
    }

    /// Record a busy interval [start, start+len) at the given utilization.
    pub fn record_busy(&mut self, start_s: f64, len_s: f64, utilization: f64) {
        let mut t = start_s;
        let end = start_s + len_s;
        while t < end {
            let idx = (t / self.bucket_s) as usize;
            if idx >= self.busy_weighted.len() {
                break;
            }
            let bucket_end = (idx as f64 + 1.0) * self.bucket_s;
            let seg = (end.min(bucket_end)) - t;
            self.busy_weighted[idx] += seg * utilization;
            t = bucket_end;
        }
    }

    /// Utilization per bucket in [0, 1].
    pub fn series(&self) -> Vec<f64> {
        self.busy_weighted.iter().map(|w| (w / self.bucket_s).min(1.0)).collect()
    }

    pub fn mean(&self) -> f64 {
        let s = self.series();
        if s.is_empty() {
            return 0.0;
        }
        s.iter().sum::<f64>() / s.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_accumulates_stages() {
        let mut t = RequestTrace::new(1, 10.0);
        t.record_stage(Stage::PreProcess, 0.001);
        t.record_stage(Stage::Inference, 0.02);
        t.record_stage(Stage::PostProcess, 0.002);
        assert!((t.e2e_s() - 0.023).abs() < 1e-12);
        assert_eq!(t.stage_s(Stage::PreProcess), Some(0.001));
        assert_eq!(t.stage_s(Stage::Transmission), None);
        assert_eq!(t.stage_s(Stage::Batching), None);
    }

    #[test]
    fn repeated_stage_adds() {
        let mut t = RequestTrace::new(1, 0.0);
        t.record_stage(Stage::Batching, 0.01);
        t.record_stage(Stage::Batching, 0.02);
        assert!((t.stage_s(Stage::Batching).unwrap() - 0.03).abs() < 1e-12);
    }

    #[test]
    fn zero_second_probe_still_counts_as_recorded() {
        // The bitmask keeps "probed at exactly 0 s" distinguishable from
        // "never probed" — the per-stage sample counts depend on it.
        let mut t = RequestTrace::new(1, 0.0);
        t.record_stage(Stage::PreProcess, 0.0);
        assert_eq!(t.stage_s(Stage::PreProcess), Some(0.0));
        let mut c = Collector::new();
        c.ingest(&t);
        assert_eq!(c.stage(Stage::PreProcess).len(), 1);
        assert_eq!(c.stage(Stage::Inference).len(), 0);
    }

    #[test]
    fn trace_store_slab_reuses_slots() {
        let mut store = TraceStore::with_capacity(4);
        let a = store.insert(RequestTrace::new(0, 0.0));
        let b = store.insert(RequestTrace::new(1, 1.0));
        assert_eq!(store.len(), 2);
        store.get_mut(a).record_stage(Stage::Inference, 0.5);
        assert_eq!(store.get(a).id, 0);
        let removed = store.remove(a);
        assert_eq!(removed.id, 0);
        assert!((removed.e2e_s() - 0.5).abs() < 1e-12);
        // Freed slot is reused for the next insert.
        let c = store.insert(RequestTrace::new(2, 2.0));
        assert_eq!(c, a);
        assert_eq!(store.get(b).id, 1);
        assert_eq!(store.get(c).id, 2);
        assert_eq!(store.len(), 2);
        store.remove(b);
        store.remove(c);
        assert!(store.is_empty());
    }

    #[test]
    fn collector_aggregates() {
        let mut c = Collector::new();
        for i in 0..10 {
            let mut t = RequestTrace::new(i, i as f64);
            t.record_stage(Stage::Inference, 0.5);
            c.ingest(&t);
        }
        assert_eq!(c.completed, 10);
        assert!((c.e2e.mean() - 0.5).abs() < 1e-12);
        // 10 requests over [0, 9.5] window.
        assert!((c.throughput_rps() - 10.0 / 9.5).abs() < 1e-9);
    }

    fn busy_collector(mode: MetricsMode, seed: u64) -> Collector {
        let mut c = Collector::with_mode(mode);
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        for i in 0..500u64 {
            let mut t = RequestTrace::new(i, i as f64 * 0.01);
            if i % 7 == 0 {
                t.dropped = true;
                t.drop_reason = DROP_REASONS[(i % 6) as usize];
            } else {
                t.record_stage(Stage::Batching, rng.lognormal(-6.0, 0.5));
                t.record_stage(Stage::Inference, rng.lognormal(-4.0, 1.0));
            }
            c.ingest(&t);
        }
        c
    }

    #[test]
    fn collector_snapshot_restore_preserves_fingerprint() {
        for mode in [MetricsMode::Exact, MetricsMode::Sketch { alpha: 0.01 }] {
            let c = busy_collector(mode, 11);
            let r = c.snapshot().restore();
            assert_eq!(r.fingerprint(), c.fingerprint(), "{mode:?}");
            assert_eq!(r.is_bounded(), c.is_bounded());
            assert_eq!(r.drop_breakdown(), c.drop_breakdown());
            assert!(r.drops_conserved());
            assert_eq!(r.throughput_rps().to_bits(), c.throughput_rps().to_bits());
            for s in STAGES {
                assert_eq!(r.stage(s).len(), c.stage(s).len(), "{mode:?} {s:?}");
                if !c.stage(s).is_empty() {
                    assert_eq!(
                        r.stage(s).percentile(99.0).to_bits(),
                        c.stage(s).percentile(99.0).to_bits()
                    );
                }
            }
        }
    }

    #[test]
    fn restored_collectors_absorb_like_originals() {
        // The leader's absorption path: restoring two cell snapshots and
        // absorbing them must fingerprint identically to absorbing the
        // originals (exact mode concatenates the same buffers in the same
        // order; sketch mode adds the same counters).
        for mode in [MetricsMode::Exact, MetricsMode::Sketch { alpha: 0.02 }] {
            let a = busy_collector(mode, 3);
            let b = busy_collector(mode, 4);
            let mut direct = Collector::new();
            direct.absorb(a.clone());
            direct.absorb(b.clone());
            let mut via_wire = Collector::new();
            via_wire.absorb(a.snapshot().restore());
            via_wire.absorb(b.snapshot().restore());
            assert_eq!(via_wire.fingerprint(), direct.fingerprint(), "{mode:?}");
        }
    }

    #[test]
    fn class_snapshot_round_trips_ledger() {
        let mut cm = ClassMetrics::with_mode(2, MetricsMode::Exact);
        cm.issued = 40;
        for i in 0..40u64 {
            let mut t = RequestTrace::new(i, i as f64);
            if i % 5 == 0 {
                t.dropped = true;
                t.drop_reason = DropReason::Shed;
            } else {
                t.record_stage(Stage::Inference, 0.003 * (i + 1) as f64);
            }
            cm.collector.ingest(&t);
        }
        assert!(cm.conserved());
        let r = cm.snapshot().restore();
        assert_eq!(r.class, 2);
        assert_eq!(r.issued, 40);
        assert!(r.conserved());
        assert_eq!(r.goodput().to_bits(), cm.goodput().to_bits());
        assert_eq!(r.shed_fraction().to_bits(), cm.shed_fraction().to_bits());
        assert_eq!(r.collector.fingerprint(), cm.collector.fingerprint());
    }

    #[test]
    fn dropped_not_counted_in_latency() {
        let mut c = Collector::new();
        let mut t = RequestTrace::new(0, 0.0);
        t.dropped = true;
        c.ingest(&t);
        assert_eq!(c.dropped, 1);
        assert_eq!(c.completed, 0);
        assert!(c.e2e.is_empty());
    }

    #[test]
    fn stage_means_cover_all_stages() {
        let c = Collector::new();
        assert_eq!(c.stage_means().len(), 5);
    }

    #[test]
    fn merge_is_exact_union() {
        let mut a = Collector::new();
        let mut b = Collector::new();
        for i in 0..4u64 {
            let mut t = RequestTrace::new(i, i as f64);
            t.record_stage(Stage::Inference, 0.010 + i as f64 * 0.010);
            if i < 2 {
                a.ingest(&t);
            } else {
                b.ingest(&t);
            }
        }
        let mut dropped = RequestTrace::new(9, 0.5);
        dropped.dropped = true;
        b.ingest(&dropped);

        let mut all = Collector::new();
        all.merge(&a);
        all.merge(&b);
        assert_eq!(all.completed, 4);
        assert_eq!(all.dropped, 1);
        assert_eq!(all.first_arrival_s, 0.0);
        assert!((all.last_completion_s - 3.040).abs() < 1e-12);
        // Percentiles over the union, not an average-of-averages.
        assert!((all.e2e.percentile(100.0) - 0.040).abs() < 1e-12);
        assert!((all.e2e.mean() - 0.025).abs() < 1e-12);
        assert_eq!(all.stage(Stage::Inference).len(), 4);
    }

    #[test]
    fn absorb_matches_merge() {
        let mut a = Collector::new();
        let mut b = Collector::new();
        for i in 0..4u64 {
            let mut t = RequestTrace::new(i, i as f64);
            t.record_stage(Stage::Inference, 0.010 + i as f64 * 0.010);
            if i < 2 {
                a.ingest(&t);
            } else {
                b.ingest(&t);
            }
        }
        let mut merged = Collector::new();
        merged.merge(&a);
        merged.merge(&b);
        let mut absorbed = Collector::new();
        absorbed.absorb(a);
        absorbed.absorb(b);
        assert_eq!(absorbed.completed, merged.completed);
        assert_eq!(absorbed.e2e.len(), merged.e2e.len());
        assert_eq!(absorbed.e2e.percentile(99.0), merged.e2e.percentile(99.0));
        assert_eq!(absorbed.e2e.percentile(50.0), merged.e2e.percentile(50.0));
        assert_eq!(absorbed.first_arrival_s, merged.first_arrival_s);
        assert_eq!(absorbed.last_completion_s, merged.last_completion_s);
        assert_eq!(absorbed.arrival_e2e, merged.arrival_e2e);
        assert_eq!(
            absorbed.stage(Stage::Inference).len(),
            merged.stage(Stage::Inference).len()
        );
    }

    #[test]
    fn fingerprint_tracks_observable_output() {
        let build = |latencies: &[f64]| {
            let mut c = Collector::new();
            for (i, &l) in latencies.iter().enumerate() {
                let mut t = RequestTrace::new(i as u64, i as f64);
                t.record_stage(Stage::Inference, l);
                c.ingest(&t);
            }
            c
        };
        let a = build(&[0.010, 0.020, 0.030]);
        let b = build(&[0.010, 0.020, 0.030]);
        assert_eq!(a.fingerprint(), b.fingerprint(), "identical runs must match");
        let c = build(&[0.010, 0.020, 0.031]);
        assert_ne!(a.fingerprint(), c.fingerprint(), "a changed tail must show");
        assert_eq!(Collector::new().fingerprint(), Collector::new().fingerprint());
    }

    #[test]
    fn merge_into_empty_preserves_window() {
        let mut src = Collector::new();
        let mut t = RequestTrace::new(0, 2.0);
        t.record_stage(Stage::Inference, 1.0);
        src.ingest(&t);
        let mut dst = Collector::new();
        dst.merge(&src);
        assert!((dst.throughput_rps() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn windowed_e2e_filters_by_arrival() {
        let mut c = Collector::new();
        for i in 0..10 {
            let mut t = RequestTrace::new(i, i as f64);
            t.record_stage(Stage::Inference, 0.1 * (i as f64 + 1.0));
            c.ingest(&t);
        }
        let w = c.e2e_in_window(3.0, 6.0); // arrivals 3, 4, 5
        assert_eq!(w.len(), 3);
        assert!((w.percentile(100.0) - 0.6).abs() < 1e-12);
        assert!((w.percentile(1.0) - 0.4).abs() < 1e-12);
        assert_eq!(c.e2e_in_window(100.0, 200.0).len(), 0);
    }

    #[test]
    fn windowed_e2e_survives_merge() {
        let mut a = Collector::new();
        let mut b = Collector::new();
        for (col, arrival) in [(&mut a, 1.0), (&mut b, 2.0)] {
            let mut t = RequestTrace::new(0, arrival);
            t.record_stage(Stage::Inference, 0.5);
            col.ingest(&t);
        }
        let mut all = Collector::new();
        all.merge(&a);
        all.merge(&b);
        assert_eq!(all.arrival_e2e.len(), 2);
        assert_eq!(all.e2e_in_window(0.0, 10.0).len(), 2);
        assert_eq!(all.e2e_in_window(1.5, 10.0).len(), 1);
    }

    #[test]
    fn scale_timeline_series_and_counts() {
        let mut s = ScaleTimeline::new(2);
        s.record(1.0, ScaleEventKind::AddRequested, 2, 2); // warming, active unchanged
        s.record(3.5, ScaleEventKind::Ready, 2, 3);
        s.record(8.0, ScaleEventKind::DrainStarted, 0, 2);
        s.record(9.0, ScaleEventKind::Retired, 0, 2);
        assert_eq!(s.active_series(), vec![(0.0, 2), (3.5, 3), (8.0, 2)]);
        assert_eq!(s.max_active(), 3);
        assert_eq!(s.count(ScaleEventKind::AddRequested), 1);
        assert_eq!(s.count(ScaleEventKind::Retired), 1);
        assert_eq!(ScaleTimeline::new(4).active_series(), vec![(0.0, 4)]);
    }

    #[test]
    fn model_metrics_conservation_check() {
        let mut m = ModelMetrics::new("resnet50");
        assert!(m.conserved(), "empty ledger balances");
        m.issued = 2;
        assert!(!m.conserved());
        let mut ok = RequestTrace::new(0, 0.0);
        ok.record_stage(Stage::Inference, 0.01);
        m.collector.ingest(&ok);
        let mut dropped = RequestTrace::new(1, 0.0);
        dropped.dropped = true;
        m.collector.ingest(&dropped);
        assert!(m.conserved());
        assert_eq!(m.name, "resnet50");
    }

    #[test]
    fn placement_timeline_counts_by_kind() {
        let mut p = PlacementTimeline::new();
        p.record(1.0, PlacementEventKind::LoadRequested, 0, 2);
        p.record(4.5, PlacementEventKind::Ready, 0, 2);
        p.record(4.5, PlacementEventKind::Evicted, 0, 1);
        p.record(9.0, PlacementEventKind::Rejected, 1, 2);
        assert_eq!(p.count(PlacementEventKind::LoadRequested), 1);
        assert_eq!(p.count(PlacementEventKind::Ready), 1);
        assert_eq!(p.count(PlacementEventKind::Evicted), 1);
        assert_eq!(p.count(PlacementEventKind::Rejected), 1);
        assert_eq!(p.events[2].model, 1);
        assert_eq!(PlacementEventKind::Evicted.label(), "evicted");
    }

    #[test]
    fn replica_metrics_mean_batch() {
        let mut m = ReplicaMetrics::new(10.0, 1.0);
        assert_eq!(m.mean_batch(), 0.0);
        m.record_batch(2);
        m.record_batch(4);
        assert!((m.mean_batch() - 3.0).abs() < 1e-12);
        assert_eq!(m.batch_sum(), 6);
        assert_eq!(m.batches(), 2);
        assert_eq!(m.batch_sizes(), &[2, 4]);
    }

    #[test]
    fn bounded_collector_skips_side_tables() {
        let mode = MetricsMode::Sketch { alpha: 0.01 };
        let mut c = Collector::with_mode(mode);
        for i in 0..100 {
            let mut t = RequestTrace::new(i, i as f64);
            t.record_stage(Stage::Inference, 0.01 + 1e-4 * i as f64);
            c.ingest(&t);
        }
        assert!(c.is_bounded());
        assert_eq!(c.completed, 100);
        assert!(c.arrival_e2e.is_empty(), "bounded mode must not grow the side table");
        assert_eq!(c.e2e_in_window(0.0, 100.0).len(), 0);
        assert_eq!(c.e2e.len(), 100);
        assert!(c.e2e.is_sketch());
        // Extremes + counts are still exact.
        assert!((c.e2e.percentile(100.0) - (0.01 + 1e-4 * 99.0)).abs() < 1e-12);
    }

    #[test]
    fn bounded_replica_metrics_keep_exact_batch_counters() {
        let mut m = ReplicaMetrics::with_mode(10.0, 1.0, MetricsMode::Sketch { alpha: 0.01 });
        m.record_batch(3);
        m.record_batch(5);
        assert!(m.batch_sizes().is_empty(), "bounded mode drops the sequence");
        assert_eq!(m.batches(), 2);
        assert_eq!(m.batch_sum(), 8);
        assert!((m.mean_batch() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn sketch_collectors_absorb_deterministically() {
        let mode = MetricsMode::Sketch { alpha: 0.01 };
        let build = |ids: std::ops::Range<u64>| {
            let mut c = Collector::with_mode(mode);
            for i in ids {
                let mut t = RequestTrace::new(i, i as f64);
                t.record_stage(Stage::Inference, 0.005 + 1e-4 * (i % 37) as f64);
                c.ingest(&t);
            }
            c
        };
        let mut ab = Collector::new();
        ab.absorb(build(0..500));
        ab.absorb(build(500..900));
        let mut ba = Collector::new();
        ba.absorb(build(500..900));
        ba.absorb(build(0..500));
        assert!(ab.is_bounded() && ba.is_bounded());
        assert_eq!(ab.completed, 900);
        // Bucket merges commute: same fingerprint either way.
        assert_eq!(ab.fingerprint(), ba.fingerprint());
    }

    #[test]
    fn model_metrics_with_mode_is_bounded() {
        let m = ModelMetrics::with_mode("m0", MetricsMode::Sketch { alpha: 0.02 });
        assert!(m.collector.is_bounded());
        assert!(m.conserved());
    }

    #[test]
    fn utilization_timeline_buckets() {
        let mut u = UtilizationTimeline::new(10.0, 1.0);
        u.record_busy(0.5, 1.0, 1.0); // spans buckets 0 and 1
        let s = u.series();
        assert!((s[0] - 0.5).abs() < 1e-9);
        assert!((s[1] - 0.5).abs() < 1e-9);
        assert_eq!(s[2], 0.0);
    }

    #[test]
    fn utilization_clamped_to_one() {
        let mut u = UtilizationTimeline::new(2.0, 1.0);
        u.record_busy(0.0, 1.0, 1.0);
        u.record_busy(0.0, 1.0, 1.0); // double-booked
        assert_eq!(u.series()[0], 1.0);
    }

    #[test]
    fn drop_reasons_split_the_single_counter() {
        let mut c = Collector::new();
        let mut a = RequestTrace::new(0, 0.0);
        a.dropped = true; // bare flag: historical queue-full meaning
        c.ingest(&a);
        let mut b = RequestTrace::new(1, 0.0);
        b.drop_with(DropReason::Shed);
        c.ingest(&b);
        let mut e = RequestTrace::new(2, 0.0);
        e.drop_with(DropReason::EvictedBacklog);
        c.ingest(&e);
        c.ingest(&e); // same reason twice
        let mut f = RequestTrace::new(3, 0.0);
        f.drop_with(DropReason::ReplicaFailed);
        c.ingest(&f);
        let mut t = RequestTrace::new(4, 0.0);
        t.drop_with(DropReason::TimedOut);
        c.ingest(&t);
        assert_eq!(c.dropped, 6);
        assert_eq!(c.dropped_by(DropReason::QueueFull), 1);
        assert_eq!(c.dropped_by(DropReason::Shed), 1);
        assert_eq!(c.dropped_by(DropReason::EvictedBacklog), 2);
        assert_eq!(c.dropped_by(DropReason::RejectedPlacement), 0);
        assert_eq!(c.dropped_by(DropReason::ReplicaFailed), 1);
        assert_eq!(c.dropped_by(DropReason::TimedOut), 1);
        assert!(c.drops_conserved());
        let breakdown = c.drop_breakdown();
        assert_eq!(breakdown[0], ("queue-full", 1));
        assert_eq!(breakdown[1], ("shed", 1));
        assert_eq!(breakdown[2], ("evicted-backlog", 2));
        assert_eq!(breakdown[3], ("rejected-placement", 0));
        assert_eq!(breakdown[4], ("replica-failed", 1));
        assert_eq!(breakdown[5], ("timed-out", 1));
    }

    #[test]
    fn drop_reasons_survive_absorb_and_do_not_move_fingerprints() {
        let run = |reason: Option<DropReason>| {
            let mut c = Collector::new();
            let mut ok = RequestTrace::new(0, 0.0);
            ok.record_stage(Stage::Inference, 0.01);
            c.ingest(&ok);
            let mut bad = RequestTrace::new(1, 0.5);
            match reason {
                Some(r) => bad.drop_with(r),
                None => bad.dropped = true,
            }
            c.ingest(&bad);
            c
        };
        // The reason tag refines the ledger without entering the digest:
        // a shed drop and a legacy queue-full drop fingerprint alike.
        assert_eq!(run(None).fingerprint(), run(Some(DropReason::Shed)).fingerprint());
        // The fault-tier reasons follow the same convention exactly.
        assert_eq!(run(None).fingerprint(), run(Some(DropReason::ReplicaFailed)).fingerprint());
        assert_eq!(run(None).fingerprint(), run(Some(DropReason::TimedOut)).fingerprint());
        let mut all = Collector::new();
        all.absorb(run(Some(DropReason::Shed)));
        all.absorb(run(Some(DropReason::RejectedPlacement)));
        all.absorb(run(Some(DropReason::ReplicaFailed)));
        all.absorb(run(Some(DropReason::TimedOut)));
        all.absorb(run(None));
        assert_eq!(all.dropped, 5);
        assert_eq!(all.dropped_by(DropReason::Shed), 1);
        assert_eq!(all.dropped_by(DropReason::RejectedPlacement), 1);
        assert_eq!(all.dropped_by(DropReason::ReplicaFailed), 1);
        assert_eq!(all.dropped_by(DropReason::TimedOut), 1);
        assert_eq!(all.dropped_by(DropReason::QueueFull), 1);
        assert!(all.drops_conserved());
    }

    #[test]
    fn class_metrics_ledger_balances() {
        let mut g = ClassMetrics::new(0);
        assert!(g.conserved(), "empty class ledger balances");
        assert_eq!(g.goodput(), 0.0);
        g.issued = 3;
        let mut ok = RequestTrace::new(0, 0.0);
        ok.class = 0;
        ok.record_stage(Stage::Inference, 0.02);
        g.collector.ingest(&ok);
        g.collector.ingest(&ok);
        let mut shed = RequestTrace::new(1, 0.0);
        shed.drop_with(DropReason::Shed);
        g.collector.ingest(&shed);
        assert!(g.conserved());
        assert!((g.goodput() - 2.0 / 3.0).abs() < 1e-12);
        assert!((g.shed_fraction() - 1.0 / 3.0).abs() < 1e-12);

        let mut h = ClassMetrics::new(0);
        h.issued = 1;
        h.collector.ingest(&ok);
        g.absorb(h);
        assert_eq!(g.issued, 4);
        assert_eq!(g.collector.completed, 3);
        assert!(g.conserved());
    }

    #[test]
    #[should_panic(expected = "mismatched classes")]
    fn class_metrics_absorb_rejects_mismatched_class() {
        ClassMetrics::new(0).absorb(ClassMetrics::new(1));
    }

    #[test]
    fn utilization_weighted_by_level() {
        let mut u = UtilizationTimeline::new(1.0, 1.0);
        u.record_busy(0.0, 1.0, 0.3);
        assert!((u.series()[0] - 0.3).abs() < 1e-9);
        assert!((u.mean() - 0.15).abs() < 0.16); // 2 buckets incl. tail
    }
}
