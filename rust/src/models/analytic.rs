//! Analytic FLOPs / parameter / memory-traffic model — the exact rust
//! mirror of `python/compile/analytic.py`.
//!
//! Both sides compute the same formulas from the same hyper-parameters;
//! `rust/tests/manifest_consistency.rs` asserts this module reproduces the
//! values aot.py wrote into `artifacts/manifest.json`, so the GPU roofline
//! models and the Python-lowered artifacts can never drift apart.

/// Per-sample compute profile of a model configuration (f32).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Profile {
    /// Forward FLOPs per sample.
    pub flops: u64,
    /// Parameter count.
    pub params: u64,
    /// Bytes of weights read once per batch.
    pub weight_bytes: u64,
    /// Activation read+write bytes per sample.
    pub act_bytes: u64,
}

impl Profile {
    /// FLOPs per HBM byte at batch `b` — x-axis of the Roofline (Fig 10).
    pub fn arithmetic_intensity(&self, batch: usize) -> f64 {
        let b = batch as f64;
        (self.flops as f64 * b) / (self.weight_bytes as f64 + self.act_bytes as f64 * b)
    }

    /// Total FLOPs for a batch.
    pub fn batch_flops(&self, batch: usize) -> f64 {
        self.flops as f64 * batch as f64
    }

    /// Total HBM bytes for a batch.
    pub fn batch_bytes(&self, batch: usize) -> f64 {
        self.weight_bytes as f64 + self.act_bytes as f64 * batch as f64
    }
}

/// MLP family: `depth` FC blocks of `width`, mirroring `mlp_profile`.
pub fn mlp(depth: u64, width: u64, in_dim: u64, classes: u64) -> Profile {
    let flops = 2 * in_dim * width + depth * 2 * width * width + 2 * width * classes;
    let params =
        in_dim * width + width + depth * (width * width + width) + width * classes + classes;
    let act_elems = in_dim + (depth + 1) * width + classes;
    Profile { flops, params, weight_bytes: params * 4, act_bytes: 2 * act_elems * 4 }
}

/// CNN family: residual blocks at `hw` x `hw`, mirroring `cnn_profile`.
pub fn cnn(depth: u64, channels: u64, hw: u64, cin: u64, classes: u64) -> Profile {
    let px = hw * hw;
    let flops = 2 * 9 * cin * channels * px
        + depth * 2 * 9 * channels * channels * px
        + 2 * channels * classes;
    let params = 9 * cin * channels
        + channels
        + depth * (9 * channels * channels + channels)
        + channels * classes
        + classes;
    let act_elems = px * cin + (depth + 1) * px * channels + channels + classes;
    Profile { flops, params, weight_bytes: params * 4, act_bytes: 2 * act_elems * 4 }
}

/// RNN family: stacked LSTM layers, mirroring `rnn_profile`.
pub fn rnn(depth: u64, hidden: u64, seq: u64, in_dim: u64, classes: u64) -> Profile {
    let gates = 2 * (hidden * 4 * hidden) * 2;
    let flops = 2 * in_dim * hidden * seq
        + depth * seq * gates
        + depth * seq * 10 * hidden
        + 2 * hidden * classes;
    let params = in_dim * hidden
        + hidden
        + depth * (hidden * 4 * hidden * 2 + 4 * hidden)
        + hidden * classes
        + classes;
    let act_elems = seq * in_dim + (depth + 1) * seq * hidden + classes;
    Profile { flops, params, weight_bytes: params * 4, act_bytes: 2 * act_elems * 4 }
}

/// Transformer family: attention blocks, mirroring `transformer_profile`.
pub fn transformer(depth: u64, d_model: u64, heads: u64, seq: u64, classes: u64) -> Profile {
    let d = d_model;
    let per_layer = 8 * seq * d * d + 4 * seq * seq * d + 5 * seq * seq + 16 * seq * d * d;
    let flops = depth * per_layer + 2 * d * classes;
    let params =
        depth * (4 * d * d + d * 4 * d + 4 * d + 4 * d * d + d + 4 * d) + d * classes + classes;
    let act_elems = seq * d * (4 * depth + 1) + depth * heads * seq * seq + classes;
    Profile { flops, params, weight_bytes: params * 4, act_bytes: 2 * act_elems * 4 }
}

/// Hyper-parameters for any family (unused fields ignored per family).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HyperParams {
    pub depth: u64,
    pub width: u64,
    pub channels: u64,
    pub hidden: u64,
    pub d_model: u64,
    pub heads: u64,
    pub seq: u64,
    pub hw: u64,
    pub in_dim: u64,
    pub cin: u64,
    pub classes: u64,
}

impl Default for HyperParams {
    fn default() -> Self {
        // Defaults mirror python/compile/analytic.py signature defaults.
        HyperParams {
            depth: 2,
            width: 256,
            channels: 32,
            hidden: 128,
            d_model: 128,
            heads: 4,
            seq: 0, // per-family default applied in profile_for
            hw: 32,
            in_dim: 0, // per-family default applied in profile_for
            cin: 3,
            classes: 16,
        }
    }
}

/// Dispatch matching `analytic.profile_for`.
pub fn profile_for(family: &str, hp: &HyperParams) -> Profile {
    match family {
        "mlp" => mlp(hp.depth, hp.width, default(hp.in_dim, 256), hp.classes),
        "cnn" => cnn(hp.depth, hp.channels, hp.hw, hp.cin, hp.classes),
        "rnn" => rnn(hp.depth, hp.hidden, default(hp.seq, 16), default(hp.in_dim, 64), hp.classes),
        "transformer" => {
            transformer(hp.depth, hp.d_model, hp.heads, default(hp.seq, 64), hp.classes)
        }
        other => panic!("unknown family {other:?}"),
    }
}

fn default(v: u64, d: u64) -> u64 {
    if v == 0 {
        d
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_formula_matches_python() {
        // Same case as python test_analytic: depth=4, width=128.
        let p = mlp(4, 128, 256, 16);
        assert_eq!(p.flops, 2 * 256 * 128 + 4 * 2 * 128 * 128 + 2 * 128 * 16);
    }

    #[test]
    fn deeper_costs_more_all_families() {
        let base = HyperParams::default();
        for fam in ["mlp", "cnn", "rnn", "transformer"] {
            let shallow = profile_for(fam, &HyperParams { depth: 2, ..base });
            let deep = profile_for(fam, &HyperParams { depth: 8, ..base });
            assert!(deep.flops > shallow.flops, "{fam}");
            assert!(deep.params > shallow.params, "{fam}");
        }
    }

    #[test]
    fn intensity_monotone_in_batch() {
        let p = mlp(8, 512, 256, 16);
        assert!(p.arithmetic_intensity(32) > p.arithmetic_intensity(8));
        assert!(p.arithmetic_intensity(8) > p.arithmetic_intensity(1));
    }

    #[test]
    fn width_does_not_raise_intensity() {
        // Paper Fig 10b: more neurons/layers leave a model memory-bound at
        // small batch — FLOPs and weight bytes both scale ~W^2, so
        // arithmetic intensity stays ~flat in width; only batch (weight
        // reuse) moves a model towards the compute-bound region.
        let narrow = mlp(8, 128, 256, 16);
        let wide = mlp(8, 2048, 256, 16);
        let ratio = wide.arithmetic_intensity(1) / narrow.arithmetic_intensity(1);
        assert!(ratio < 1.15, "intensity should be ~flat in width, got {ratio}");
        // While batch raises it several-fold.
        assert!(wide.arithmetic_intensity(16) > 5.0 * wide.arithmetic_intensity(1));
    }

    #[test]
    #[should_panic(expected = "unknown family")]
    fn unknown_family_panics() {
        profile_for("gan", &HyperParams::default());
    }
}
