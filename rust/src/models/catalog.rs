//! Real-world model catalog (paper §5.1 "registered many real-world models").
//!
//! Each entry carries the published compute profile of the full-scale model
//! (used by the calibrated GPU roofline models to regenerate the paper's
//! hardware-tier curves) plus, where available, the `artifact_stem` of the
//! small AOT-compiled stand-in that the CPU platform executes for real
//! (resnet_mini, bert_mini, ...). DESIGN.md §2 documents this substitution.

use super::analytic::Profile;

/// Inference task class — the paper's Fig 7c categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Image classification.
    IC,
    /// Text classification.
    TC,
    /// Object detection.
    OD,
    /// Image generation (CycleGAN).
    GAN,
    /// Language model (BERT).
    NLP,
}

impl Task {
    pub fn label(&self) -> &'static str {
        match self {
            Task::IC => "IC",
            Task::TC => "TC",
            Task::OD => "OD",
            Task::GAN => "GAN",
            Task::NLP => "NLP",
        }
    }
}

/// A catalog model: full-scale published profile + optional mini artifact.
#[derive(Debug, Clone)]
pub struct CatalogModel {
    pub name: &'static str,
    pub task: Task,
    /// Full-scale per-sample profile (published FLOPs/params, estimated
    /// activation traffic) used by the GPU roofline models.
    pub profile: Profile,
    /// Request payload in bytes (e.g. a JPEG or token ids) — drives the
    /// transmission stage of the pipeline tier.
    pub request_bytes: u64,
    /// Artifact name stem of the runnable mini stand-in, if one exists.
    pub artifact_stem: Option<&'static str>,
}

/// The registered real-world models. FLOPs are forward-pass per sample
/// (2 x MACs), params from the original papers; activation bytes are
/// order-of-magnitude estimates consistent with framework memory profiles.
pub const CATALOG: &[CatalogModel] = &[
    CatalogModel {
        name: "resnet50",
        task: Task::IC,
        profile: Profile {
            flops: 8_200_000_000, // 4.1 GMACs @ 224x224
            params: 25_600_000,
            weight_bytes: 25_600_000 * 4,
            act_bytes: 128_000_000, // ~16M activation elems, read+write
        },
        request_bytes: 150_000, // typical JPEG
        artifact_stem: Some("resnet_mini"),
    },
    CatalogModel {
        name: "mobilenet_v1",
        task: Task::IC,
        profile: Profile {
            flops: 1_140_000_000, // 0.57 GMACs
            params: 4_200_000,
            weight_bytes: 4_200_000 * 4,
            act_bytes: 80_000_000, // activation-heavy: depthwise stages
        },
        request_bytes: 150_000,
        artifact_stem: Some("mobilenet_mini"),
    },
    CatalogModel {
        name: "bert_large",
        task: Task::NLP,
        profile: Profile {
            flops: 87_000_000_000, // ~2 * 340M params * 128 tokens
            params: 340_000_000,
            weight_bytes: 340_000_000 * 4,
            act_bytes: 50_000_000,
        },
        request_bytes: 4_000, // 128 token ids + metadata
        artifact_stem: Some("bert_mini"),
    },
    CatalogModel {
        name: "ssd_mobilenet",
        task: Task::OD,
        profile: Profile {
            flops: 30_000_000_000,
            params: 35_000_000,
            weight_bytes: 35_000_000 * 4,
            act_bytes: 200_000_000,
        },
        request_bytes: 250_000,
        artifact_stem: None,
    },
    CatalogModel {
        name: "cyclegan",
        task: Task::GAN,
        profile: Profile {
            flops: 54_000_000_000, // 256x256 generator
            params: 11_400_000,
            weight_bytes: 11_400_000 * 4,
            act_bytes: 450_000_000,
        },
        request_bytes: 200_000,
        artifact_stem: None,
    },
    CatalogModel {
        name: "textlstm",
        task: Task::TC,
        profile: Profile {
            // Bi-LSTM text classifier, seq 64 x hidden 512.
            flops: 2_400_000_000,
            params: 10_000_000,
            weight_bytes: 10_000_000 * 4,
            act_bytes: 30_000_000,
        },
        request_bytes: 2_000,
        artifact_stem: Some("lstm_mini"),
    },
];

/// Look up a catalog model by name.
pub fn find(name: &str) -> Option<&'static CatalogModel> {
    CATALOG.iter().find(|m| m.name == name)
}

/// Models for the Fig 7c speedup study (OD, GAN, TC, IC).
pub fn speedup_study_models() -> Vec<&'static CatalogModel> {
    ["ssd_mobilenet", "cyclegan", "textlstm", "resnet50"]
        .iter()
        .map(|n| find(n).unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_lookup() {
        assert!(find("resnet50").is_some());
        assert!(find("nonexistent").is_none());
        assert_eq!(find("bert_large").unwrap().task, Task::NLP);
    }

    #[test]
    fn resnet_is_compute_heavier_than_mobilenet() {
        let rn = find("resnet50").unwrap();
        let mb = find("mobilenet_v1").unwrap();
        assert!(rn.profile.flops > 4 * mb.profile.flops);
        // Paper Fig 10a: MobileNet has lower arithmetic intensity (more
        // memory-bound) than ResNet50.
        assert!(mb.profile.arithmetic_intensity(1) < rn.profile.arithmetic_intensity(1));
    }

    #[test]
    fn speedup_study_has_all_four_tasks() {
        let models = speedup_study_models();
        assert_eq!(models.len(), 4);
        let tasks: Vec<Task> = models.iter().map(|m| m.task).collect();
        assert!(tasks.contains(&Task::OD));
        assert!(tasks.contains(&Task::GAN));
        assert!(tasks.contains(&Task::TC));
        assert!(tasks.contains(&Task::IC));
    }

    #[test]
    fn all_entries_have_positive_profiles() {
        for m in CATALOG {
            assert!(m.profile.flops > 0, "{}", m.name);
            assert!(m.profile.params > 0, "{}", m.name);
            assert!(m.request_bytes > 0, "{}", m.name);
        }
    }
}
