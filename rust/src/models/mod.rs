//! Model descriptors: canonical-family analytics + the real-world catalog.
//!
//! `analytic` mirrors python/compile/analytic.py (cross-checked against the
//! manifest); `catalog` lists the registered real-world models the paper's
//! evaluation uses (§5.1), with published full-scale compute profiles and
//! pointers to the runnable mini stand-ins.

pub mod analytic;
pub mod catalog;

pub use analytic::{profile_for, HyperParams, Profile};
pub use catalog::{CatalogModel, Task, CATALOG};
