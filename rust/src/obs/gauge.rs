//! Gauge timelines: engine internals sampled on a fixed sim-time grid
//! into bounded ring buffers.
//!
//! The recorder follows the DES invariant that state only changes at
//! events: when the engine pops an event at `now`, every grid point in
//! `(last_sampled, now]` saw the *current* (pre-event) state, so the
//! engine calls [`GaugeRecorder::begin`] at the top of its loop and, if
//! it returns `n > 0`, records each gauge value `n` times. Rings keep
//! the most recent `cap` samples per series (the sketch-mode
//! bounded-memory discipline from PR 6): memory is
//! `O(series x cap)` regardless of run length, and the overwritten
//! prefix is accounted in [`GaugeSeries::dropped`] rather than
//! silently lost.
//!
//! Determinism: sampling reads engine state, never mutates it, and
//! draws no randomness — grid times are a pure function of the
//! configured interval, so two runs of the same seed produce identical
//! series byte-for-byte.

use std::collections::BTreeMap;

/// One exported gauge timeline on the fixed grid.
#[derive(Debug, Clone, PartialEq)]
pub struct GaugeSeries {
    /// Series name, e.g. `queue_depth/2` or `heap_depth`.
    pub name: String,
    /// Sim time of `samples[0]` (grid-aligned).
    pub t0: f64,
    /// Grid interval in sim seconds.
    pub dt: f64,
    /// Most recent samples in time order (ring-bounded).
    pub samples: Vec<f64>,
    /// Samples overwritten because the ring wrapped.
    pub dropped: u64,
}

/// Ring of the last `cap` samples plus the count of everything older.
#[derive(Debug, Clone)]
struct Ring {
    /// Global grid tick at which this series first recorded.
    start_tick: u64,
    /// Total samples ever pushed.
    total: u64,
    buf: Vec<f64>,
}

impl Ring {
    fn push(&mut self, v: f64, cap: usize) {
        if self.buf.len() < cap {
            self.buf.push(v);
        } else {
            let idx = (self.total % cap as u64) as usize;
            self.buf[idx] = v;
        }
        self.total += 1;
    }

    /// Samples in time order (oldest first).
    fn ordered(&self, cap: usize) -> Vec<f64> {
        if self.total <= cap as u64 {
            return self.buf.clone();
        }
        let head = (self.total % cap as u64) as usize;
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[head..]);
        out.extend_from_slice(&self.buf[..head]);
        out
    }
}

/// Samples named gauges on a fixed sim-time grid into bounded rings.
#[derive(Debug, Clone)]
pub struct GaugeRecorder {
    dt: f64,
    cap: usize,
    /// Next grid tick to emit (tick `k` is sim time `k * dt`).
    next_tick: u64,
    series: BTreeMap<String, Ring>,
    enabled: bool,
}

impl GaugeRecorder {
    /// Recorder sampling every `interval_s` sim seconds, keeping the
    /// last `cap` samples per series.
    pub fn new(interval_s: f64, cap: usize) -> Self {
        assert!(interval_s > 0.0, "gauge interval must be positive");
        assert!(cap > 0, "gauge ring capacity must be positive");
        GaugeRecorder {
            dt: interval_s,
            cap,
            next_tick: 0,
            series: BTreeMap::new(),
            enabled: true,
        }
    }

    /// Disabled recorder: `begin` always returns 0, `record` is a no-op.
    pub fn off() -> Self {
        GaugeRecorder { dt: 1.0, cap: 1, next_tick: 0, series: BTreeMap::new(), enabled: false }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Cheap guard for the engine hot loop.
    #[inline]
    pub fn due(&self, now: f64) -> bool {
        self.enabled && now >= self.next_tick as f64 * self.dt
    }

    /// Advance the grid past `now`, returning how many grid points were
    /// crossed (each pending `record` call should push that many
    /// copies — the state was constant between events).
    pub fn begin(&mut self, now: f64) -> u64 {
        if !self.enabled {
            return 0;
        }
        let mut n = 0u64;
        while self.next_tick as f64 * self.dt <= now {
            self.next_tick += 1;
            n += 1;
        }
        n
    }

    /// Record `value` for `n` grid points on series `name` (created on
    /// first use, aligned to the tick of its first sample).
    pub fn record(&mut self, name: &str, value: f64, n: u64) {
        if !self.enabled || n == 0 {
            return;
        }
        let cap = self.cap;
        let first_tick = self.next_tick - n;
        let ring = self.series.entry(name.to_string()).or_insert_with(|| Ring {
            start_tick: first_tick,
            total: 0,
            buf: Vec::new(),
        });
        // Pushing more than `cap` copies of one value is pure overwrite
        // churn: account the excess as dropped and push at most `cap`.
        let pushes = n.min(cap as u64);
        ring.total += n - pushes;
        for _ in 0..pushes {
            ring.push(value, cap);
        }
    }

    /// Indexed series helper (`name/idx`), e.g. per-replica gauges.
    pub fn record_indexed(&mut self, name: &str, idx: usize, value: f64, n: u64) {
        if !self.enabled || n == 0 {
            return;
        }
        self.record(&format!("{name}/{idx}"), value, n);
    }

    /// Export all series in name order (BTreeMap iteration is sorted,
    /// so the output is deterministic).
    pub fn into_series(self) -> Vec<GaugeSeries> {
        let (dt, cap) = (self.dt, self.cap);
        self.series
            .into_iter()
            .map(|(name, ring)| {
                let samples = ring.ordered(cap);
                let dropped = ring.total - samples.len() as u64;
                GaugeSeries {
                    name,
                    t0: (ring.start_tick + dropped) as f64 * dt,
                    dt,
                    samples,
                    dropped,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_recorder_records_nothing() {
        let mut g = GaugeRecorder::off();
        assert!(!g.due(100.0));
        assert_eq!(g.begin(100.0), 0);
        g.record("x", 1.0, 5);
        assert!(g.into_series().is_empty());
    }

    #[test]
    fn grid_fills_every_point_between_events() {
        let mut g = GaugeRecorder::new(0.5, 64);
        // First event at t=0: one grid point (t=0.0).
        let n = g.begin(0.0);
        assert_eq!(n, 1);
        g.record("q", 3.0, n);
        // Next event at t=2.2: grid points 0.5, 1.0, 1.5, 2.0.
        let n = g.begin(2.2);
        assert_eq!(n, 4);
        g.record("q", 7.0, n);
        assert!(!g.due(2.3), "next grid point is 2.5");
        let s = g.into_series();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].samples, vec![3.0, 7.0, 7.0, 7.0, 7.0]);
        assert_eq!(s[0].t0, 0.0);
        assert_eq!(s[0].dropped, 0);
    }

    #[test]
    fn ring_stays_bounded_and_accounts_drops() {
        let mut g = GaugeRecorder::new(1.0, 4);
        for t in 0..100 {
            let n = g.begin(t as f64);
            g.record("depth", t as f64, n);
        }
        let s = g.into_series();
        assert_eq!(s[0].samples.len(), 4, "ring capped");
        assert_eq!(s[0].samples, vec![96.0, 97.0, 98.0, 99.0]);
        assert_eq!(s[0].dropped, 96);
        assert_eq!(s[0].t0, 96.0);
    }

    #[test]
    fn giant_gap_is_accounted_not_materialized() {
        let mut g = GaugeRecorder::new(0.001, 8);
        let n = g.begin(10_000.0);
        assert!(n > 1_000_000);
        g.record("q", 1.0, n);
        let s = g.into_series();
        assert_eq!(s[0].samples.len(), 8);
        assert_eq!(s[0].dropped, n - 8);
    }

    #[test]
    fn late_series_keeps_its_own_origin() {
        let mut g = GaugeRecorder::new(1.0, 16);
        let n = g.begin(0.0);
        g.record("a", 1.0, n);
        let n = g.begin(5.0);
        g.record("a", 2.0, n);
        g.record("b", 9.0, n); // first seen at the same batch
        let s = g.into_series();
        assert_eq!(s[0].name, "a");
        assert_eq!(s[0].t0, 0.0);
        assert_eq!(s[1].name, "b");
        assert_eq!(s[1].t0, 1.0, "b's first sample covers ticks 1..=5");
        assert_eq!(s[1].samples.len(), 5);
    }

    #[test]
    fn indexed_series_sort_deterministically() {
        let mut g = GaugeRecorder::new(1.0, 8);
        let n = g.begin(0.0);
        g.record_indexed("q", 2, 1.0, n);
        g.record_indexed("q", 0, 2.0, n);
        let names: Vec<String> = g.into_series().into_iter().map(|s| s.name).collect();
        assert_eq!(names, vec!["q/0".to_string(), "q/2".to_string()]);
    }
}
