//! Deterministic tracing & telemetry for the serving engines and the
//! coordinator.
//!
//! Three pillars, one export path:
//!
//! 1. **Request spans** — [`TraceRecorder`] records, for head-sampled
//!    requests, a span tree of lifecycle stages: arrival, the
//!    admission/token-bucket verdict, held-at-routing, the route
//!    decision and chosen replica, batch membership and size, service,
//!    and completion or drop with its `DropReason`; retry and hedge
//!    attempts are linked as child spans. Sampling is a pure function
//!    of the request id ([`SampleSpec::sampled`] — a splitmix64 hash,
//!    no RNG stream is consulted), so the sampled set is identical
//!    across runs and thread counts.
//! 2. **Gauge timelines** — [`GaugeRecorder`] samples engine internals
//!    (per-replica queue depth and outstanding, batcher occupancy,
//!    token-bucket levels, routable-set size, DES heap depth,
//!    warming/draining counts) on a fixed sim-time grid into bounded
//!    rings (see [`gauge`]).
//! 3. **Job spans** — the coordinator leader exports submit → queue →
//!    run → complete/fail spans per job, and distributed sweeps export
//!    shard → cell spans with `DistStats` attached as attributes
//!    (wall-clock for the leader, sim-time for cells; only the
//!    sim-time spans are covered by the byte-stability guarantee).
//!
//! Everything exports through [`TraceSink`]: Chrome-trace/Perfetto
//! JSON (loadable in `ui.perfetto.dev`, built on [`crate::util::json`])
//! or line-delimited [`crate::codec`] `Span` frames (follower spans
//! ride the distributed-sweep wire alongside `CellResult`s).
//!
//! # The determinism contract
//!
//! Recording is strictly passive: hooks read engine state at existing
//! decision points, never push events, never draw randomness, and
//! never reorder the heap. `TraceConfig::off()` and a fully-enabled
//! run therefore produce bit-identical `Collector::fingerprint()`s,
//! event counts, and percentile bits — gated by `tests/obs.rs` at
//! 1/2/8 sweep threads, the same bar as the PR 3/6/8 refactors. For a
//! fixed seed the exported trace itself is byte-stable: spans are
//! emitted in deterministic event order and gauge series iterate a
//! `BTreeMap`.
//!
//! # Memory bounds
//!
//! Span count is capped by [`TraceConfig::max_spans`] (applied to
//! request roots in deterministic arrival order; overflow is counted
//! in [`TraceOutput::truncated`], never silently lost). Gauge memory
//! is `O(series x ring capacity)` regardless of run length.

pub mod gauge;
pub mod perfetto;

pub use gauge::{GaugeRecorder, GaugeSeries};

use std::io::Write as _;

/// Which requests get span trees. Sampling is a pure function of the
/// request id — deciding it consumes no randomness from any PCG
/// stream, so enabling tracing cannot perturb the simulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SampleSpec {
    /// No request spans.
    Off,
    /// Every request.
    All,
    /// Requests whose id is divisible by `n`.
    EveryNth(u64),
    /// Pseudo-random fraction `p` of requests, chosen by hashing the
    /// request id (splitmix64) — deterministic head-sampling.
    Rate(f64),
}

/// splitmix64 finalizer: a well-mixed pure hash of the request id.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl SampleSpec {
    /// Is request `id` sampled? Pure — no state, no RNG.
    pub fn sampled(&self, id: u64) -> bool {
        match *self {
            SampleSpec::Off => false,
            SampleSpec::All => true,
            SampleSpec::EveryNth(n) => n > 0 && id % n == 0,
            SampleSpec::Rate(p) => {
                // Top 53 bits as a uniform fraction in [0, 1).
                let frac = (splitmix64(id) >> 11) as f64 / (1u64 << 53) as f64;
                frac < p
            }
        }
    }
}

/// How much detail sampled spans carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Detail {
    /// Lifecycle stage spans only.
    Stages,
    /// Stages plus batch-membership attributes and retry/hedge links.
    Full,
}

/// Tracing knobs for one engine run. Constructed `off()` by default;
/// engines take it by reference so the config is engine-agnostic.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    pub sample: SampleSpec,
    pub detail: Detail,
    /// Gauge grid interval in sim seconds (`None` = no gauges).
    pub gauge_interval_s: Option<f64>,
    /// Ring capacity per gauge series.
    pub gauge_cap: usize,
    /// Maximum sampled request roots kept (arrival order).
    pub max_spans: usize,
}

impl TraceConfig {
    /// Tracing fully disabled — the zero-cost path.
    pub fn off() -> Self {
        TraceConfig {
            sample: SampleSpec::Off,
            detail: Detail::Stages,
            gauge_interval_s: None,
            gauge_cap: 4096,
            max_spans: 0,
        }
    }

    /// Everything on: all requests sampled at full detail, gauges on a
    /// 100 ms grid. The configuration the bit-identity tests run.
    pub fn full() -> Self {
        TraceConfig {
            sample: SampleSpec::All,
            detail: Detail::Full,
            gauge_interval_s: Some(0.1),
            gauge_cap: 4096,
            max_spans: 65_536,
        }
    }

    pub fn enabled(&self) -> bool {
        !matches!(self.sample, SampleSpec::Off) || self.gauge_interval_s.is_some()
    }

    /// Gauge recorder matching this config.
    pub fn gauge_recorder(&self) -> GaugeRecorder {
        match self.gauge_interval_s {
            Some(dt) => GaugeRecorder::new(dt, self.gauge_cap),
            None => GaugeRecorder::off(),
        }
    }
}

/// A span attribute value.
#[derive(Debug, Clone, PartialEq)]
pub enum Attr {
    U(u64),
    F(f64),
    S(String),
}

impl Attr {
    /// Stringify for wire frames / Perfetto args (deterministic).
    pub fn render(&self) -> String {
        match self {
            Attr::U(v) => v.to_string(),
            Attr::F(v) => format!("{v:?}"),
            Attr::S(v) => v.clone(),
        }
    }
}

/// One span: a named interval on a track, optionally parented to form
/// a tree. Request spans use the request id as the track; job spans
/// use a worker/shard index.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Index into the owning `spans` vec.
    pub id: u32,
    /// Parent span id (tree edge), if any.
    pub parent: Option<u32>,
    pub name: String,
    /// Grouping key for display: request id, worker index, shard index.
    pub track: u64,
    pub start_s: f64,
    pub end_s: f64,
    pub attrs: Vec<(String, Attr)>,
}

/// Everything one traced run produced.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TraceOutput {
    pub spans: Vec<Span>,
    pub gauges: Vec<GaugeSeries>,
    /// Sampled roots refused because `max_spans` was reached.
    pub truncated: u64,
}

/// Per-slab-slot recorder state. The metrics `TraceStore` slab reuses
/// slots via a free list, so the mapping is installed at arrival and
/// torn down at the terminal event.
#[derive(Debug, Clone, Copy)]
struct SlotState {
    root: u32,
    /// Currently open lifecycle-phase child span.
    phase: Option<u32>,
}

/// Records request span trees for one engine run. Every method is an
/// early-return no-op when the request (or the whole recorder) is not
/// sampled, so the disabled path costs one branch per hook.
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    sample: SampleSpec,
    detail: Detail,
    max_spans: usize,
    spans: Vec<Span>,
    slots: Vec<Option<SlotState>>,
    truncated: u64,
    on: bool,
}

impl TraceRecorder {
    pub fn new(cfg: &TraceConfig) -> Self {
        TraceRecorder {
            sample: cfg.sample,
            detail: cfg.detail,
            max_spans: cfg.max_spans,
            spans: Vec::new(),
            slots: Vec::new(),
            truncated: 0,
            on: !matches!(cfg.sample, SampleSpec::Off),
        }
    }

    /// Whether any request could be sampled (hot-loop guard).
    #[inline]
    pub fn enabled(&self) -> bool {
        self.on
    }

    /// Whether batch/retry detail is requested.
    #[inline]
    pub fn full_detail(&self) -> bool {
        self.on && self.detail == Detail::Full
    }

    /// Is this slot currently mapped to a sampled request?
    #[inline]
    pub fn is_traced(&self, slot: usize) -> bool {
        self.on && self.slots.get(slot).map_or(false, |s| s.is_some())
    }

    fn push_span(
        &mut self,
        parent: Option<u32>,
        name: &str,
        track: u64,
        start_s: f64,
    ) -> u32 {
        let id = self.spans.len() as u32;
        self.spans.push(Span {
            id,
            parent,
            name: name.to_string(),
            track,
            start_s,
            end_s: start_s,
            attrs: Vec::new(),
        });
        id
    }

    /// A request arrived: open its root span if sampled and under the
    /// root cap (checked in arrival order, so truncation is
    /// deterministic too).
    pub fn arrival(&mut self, slot: usize, req_id: u64, now: f64) {
        if !self.on || !self.sample.sampled(req_id) {
            return;
        }
        if self.spans.len() >= self.max_spans {
            self.truncated += 1;
            return;
        }
        let root = self.push_span(None, "request", req_id, now);
        self.spans[root as usize].attrs.push(("id".to_string(), Attr::U(req_id)));
        if self.slots.len() <= slot {
            self.slots.resize(slot + 1, None);
        }
        self.slots[slot] = Some(SlotState { root, phase: None });
    }

    /// Enter a lifecycle phase: closes the open phase (if any) at
    /// `now` and opens a child span of the request root.
    pub fn phase(&mut self, slot: usize, name: &str, now: f64) {
        let Some(st) = self.slot(slot) else { return };
        if let Some(p) = st.phase {
            self.spans[p as usize].end_s = now;
        }
        let id = self.push_span(Some(st.root), name, self.spans[st.root as usize].track, now);
        if let Some(Some(st)) = self.slots.get_mut(slot) {
            st.phase = Some(id);
        }
    }

    /// Instantaneous child event (admission verdict, route decision).
    pub fn event(&mut self, slot: usize, name: &str, now: f64, attrs: Vec<(&str, Attr)>) {
        let Some(st) = self.slot(slot) else { return };
        let id = self.push_span(Some(st.root), name, self.spans[st.root as usize].track, now);
        let span = &mut self.spans[id as usize];
        span.attrs.extend(attrs.into_iter().map(|(k, v)| (k.to_string(), v)));
    }

    /// Attach an attribute to the request's root span.
    pub fn attr(&mut self, slot: usize, key: &str, val: Attr) {
        let Some(st) = self.slot(slot) else { return };
        self.spans[st.root as usize].attrs.push((key.to_string(), val));
    }

    /// Attach an attribute to the currently open phase span.
    pub fn phase_attr(&mut self, slot: usize, key: &str, val: Attr) {
        let Some(st) = self.slot(slot) else { return };
        if let Some(p) = st.phase {
            self.spans[p as usize].attrs.push((key.to_string(), val));
        }
    }

    /// Link a retry/hedge attempt (`child_slot`) under the span tree of
    /// the attempt that spawned it (`parent_slot`).
    pub fn link(&mut self, parent_slot: usize, child_slot: usize) {
        let (Some(parent), Some(child)) = (self.slot(parent_slot), self.slot(child_slot)) else {
            return;
        };
        self.spans[child.root as usize].parent = Some(parent.root);
    }

    /// Terminal event: closes the open phase and the root, stamps the
    /// outcome, and unmaps the slab slot (it will be reused).
    pub fn terminal(&mut self, slot: usize, now: f64, outcome: &str) {
        let Some(st) = self.slot(slot) else { return };
        if let Some(p) = st.phase {
            self.spans[p as usize].end_s = now;
        }
        let root = &mut self.spans[st.root as usize];
        root.end_s = now;
        root.attrs.push(("outcome".to_string(), Attr::S(outcome.to_string())));
        self.slots[slot] = None;
    }

    #[inline]
    fn slot(&self, slot: usize) -> Option<SlotState> {
        if !self.on {
            return None;
        }
        self.slots.get(slot).copied().flatten()
    }

    /// Close out the run, absorbing the gauge recorder. Returns `None`
    /// when nothing was enabled, so results stay `trace: None` on the
    /// untraced path.
    pub fn finish(self, gauges: GaugeRecorder) -> Option<TraceOutput> {
        if !self.on && !gauges.enabled() {
            return None;
        }
        Some(TraceOutput {
            spans: self.spans,
            gauges: gauges.into_series(),
            truncated: self.truncated,
        })
    }
}

/// Builder for coordinator job spans (leader submit/queue/run and
/// distributed shard/cell spans). Same `Span` vocabulary as request
/// traces so everything shares one export path.
#[derive(Debug, Clone, Default)]
pub struct JobSpans {
    pub spans: Vec<Span>,
}

impl JobSpans {
    pub fn new() -> Self {
        JobSpans::default()
    }

    /// Add a span; returns its id for parenting children.
    pub fn add(
        &mut self,
        parent: Option<u32>,
        name: &str,
        track: u64,
        start_s: f64,
        end_s: f64,
        attrs: Vec<(String, Attr)>,
    ) -> u32 {
        let id = self.spans.len() as u32;
        self.spans.push(Span {
            id,
            parent,
            name: name.to_string(),
            track,
            start_s,
            end_s,
            attrs,
        });
        id
    }

    pub fn into_output(self) -> TraceOutput {
        TraceOutput { spans: self.spans, gauges: Vec::new(), truncated: 0 }
    }
}

/// One export path for all three pillars: Perfetto JSON or
/// line-delimited codec frames.
pub struct TraceSink;

impl TraceSink {
    /// Chrome-trace/Perfetto JSON document (see [`perfetto`]).
    pub fn to_perfetto(out: &TraceOutput) -> crate::util::json::Json {
        perfetto::trace_json(out)
    }

    /// Serialize the Perfetto document compactly (byte-stable for a
    /// fixed seed: span order and gauge order are deterministic).
    pub fn perfetto_string(out: &TraceOutput) -> String {
        Self::to_perfetto(out).to_string_compact()
    }

    /// Write the Perfetto JSON to `path`.
    pub fn write_perfetto(path: &str, out: &TraceOutput) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(Self::perfetto_string(out).as_bytes())
    }

    /// Map spans onto wire frames (`Frame::Span`), one per span —
    /// the same frames follower shards stream to the leader.
    pub fn to_frames(track_name: &str, out: &TraceOutput) -> Vec<crate::codec::Frame> {
        out.spans
            .iter()
            .map(|s| {
                crate::codec::Frame::Span(crate::codec::SpanFrame {
                    track: track_name.to_string(),
                    id: s.id as u64,
                    parent: s.parent.map_or(-1, |p| p as i64),
                    name: s.name.clone(),
                    start_s: s.start_s,
                    end_s: s.end_s,
                    attrs: s
                        .attrs
                        .iter()
                        .map(|(k, v)| (k.clone(), v.render()))
                        .collect(),
                })
            })
            .collect()
    }

    /// Write spans as line-delimited codec frames to `path`.
    pub fn write_frames(path: &str, track_name: &str, out: &TraceOutput) -> std::io::Result<()> {
        use crate::codec::Codec as _;
        let codec = crate::codec::JsonLinesCodec;
        let mut buf = Vec::new();
        for frame in Self::to_frames(track_name, out) {
            codec.encode(&frame, &mut buf);
        }
        std::fs::write(path, buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_pure_and_stable() {
        let every = SampleSpec::EveryNth(10);
        for id in 0..100 {
            assert_eq!(every.sampled(id), id % 10 == 0);
        }
        let rate = SampleSpec::Rate(0.25);
        let first: Vec<bool> = (0..1000).map(|id| rate.sampled(id)).collect();
        let second: Vec<bool> = (0..1000).map(|id| rate.sampled(id)).collect();
        assert_eq!(first, second, "hash sampling must be pure");
        let hits = first.iter().filter(|&&b| b).count();
        assert!((150..350).contains(&hits), "rate 0.25 over 1000 ids hit {hits}");
        assert!((0..1000).all(|id| SampleSpec::All.sampled(id)));
        assert!(!(0..1000).any(|id| SampleSpec::Off.sampled(id)));
        assert!((0.0..1.0).contains(&0.5)); // guard against typo'd ranges above
    }

    #[test]
    fn off_config_disables_everything() {
        let cfg = TraceConfig::off();
        assert!(!cfg.enabled());
        let mut rec = TraceRecorder::new(&cfg);
        assert!(!rec.enabled());
        rec.arrival(0, 7, 1.0);
        rec.phase(0, "held", 2.0);
        rec.terminal(0, 3.0, "completed");
        assert!(rec.finish(cfg.gauge_recorder()).is_none());
    }

    #[test]
    fn span_tree_records_phases_and_outcome() {
        let cfg = TraceConfig::full();
        let mut rec = TraceRecorder::new(&cfg);
        rec.arrival(3, 42, 1.0);
        assert!(rec.is_traced(3));
        rec.event(3, "admission", 1.0, vec![("verdict", Attr::S("admitted".into()))]);
        rec.phase(3, "held", 1.0);
        rec.phase(3, "batch_wait", 1.5);
        rec.phase_attr(3, "replica", Attr::U(2));
        rec.phase(3, "service", 2.0);
        rec.terminal(3, 2.5, "completed");
        assert!(!rec.is_traced(3), "slot unmapped at terminal");
        let out = rec.finish(GaugeRecorder::off()).unwrap();
        assert_eq!(out.spans.len(), 5, "root + admission + 3 phases");
        let root = &out.spans[0];
        assert_eq!(root.name, "request");
        assert_eq!(root.track, 42);
        assert_eq!(root.end_s, 2.5);
        assert!(root.attrs.iter().any(|(k, v)| k == "outcome" && *v == Attr::S("completed".into())));
        let held = out.spans.iter().find(|s| s.name == "held").unwrap();
        assert_eq!(held.parent, Some(root.id));
        assert_eq!((held.start_s, held.end_s), (1.0, 1.5));
        let service = out.spans.iter().find(|s| s.name == "service").unwrap();
        assert_eq!((service.start_s, service.end_s), (2.0, 2.5));
    }

    #[test]
    fn slot_reuse_does_not_cross_wires() {
        let cfg = TraceConfig { sample: SampleSpec::EveryNth(2), ..TraceConfig::full() };
        let mut rec = TraceRecorder::new(&cfg);
        rec.arrival(0, 4, 1.0); // sampled
        rec.terminal(0, 2.0, "completed");
        rec.arrival(0, 5, 3.0); // slot reused, NOT sampled
        assert!(!rec.is_traced(0));
        rec.phase(0, "held", 3.0); // must be a no-op
        let out = rec.finish(GaugeRecorder::off()).unwrap();
        assert_eq!(out.spans.len(), 1);
        assert_eq!(out.spans[0].track, 4);
    }

    #[test]
    fn root_cap_truncates_deterministically() {
        let cfg = TraceConfig { max_spans: 2, ..TraceConfig::full() };
        let mut rec = TraceRecorder::new(&cfg);
        for id in 0..10u64 {
            rec.arrival(id as usize, id, id as f64);
        }
        let out = rec.finish(GaugeRecorder::off()).unwrap();
        assert_eq!(out.spans.len(), 2, "first two arrivals kept");
        assert_eq!(out.truncated, 8);
        assert_eq!(out.spans[0].track, 0);
        assert_eq!(out.spans[1].track, 1);
    }

    #[test]
    fn retry_links_nest_attempts() {
        let cfg = TraceConfig::full();
        let mut rec = TraceRecorder::new(&cfg);
        rec.arrival(0, 1, 0.0);
        rec.arrival(1, 2, 5.0); // the retry attempt, separate slot
        rec.link(0, 1);
        let out = rec.finish(GaugeRecorder::off()).unwrap();
        let child = out.spans.iter().find(|s| s.track == 2).unwrap();
        let parent = out.spans.iter().find(|s| s.track == 1).unwrap();
        assert_eq!(child.parent, Some(parent.id));
    }

    #[test]
    fn job_spans_share_the_export_path() {
        let mut js = JobSpans::new();
        let root = js.add(None, "job:sweep", 0, 0.0, 2.0, vec![("attempts".into(), Attr::U(1))]);
        js.add(Some(root), "queued", 0, 0.0, 0.5, Vec::new());
        js.add(Some(root), "run", 0, 0.5, 2.0, Vec::new());
        let out = js.into_output();
        assert_eq!(out.spans.len(), 3);
        let doc = TraceSink::perfetto_string(&out);
        assert!(doc.contains("traceEvents"));
        assert!(doc.contains("job:sweep"));
    }
}
