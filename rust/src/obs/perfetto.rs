//! Chrome-trace / Perfetto JSON export.
//!
//! Emits the classic Chrome trace-event document (`{"traceEvents":
//! [...], "displayTimeUnit": "ms"}`) that `ui.perfetto.dev` and
//! `chrome://tracing` both load:
//!
//! - spans become complete events (`"ph": "X"`) with microsecond
//!   `ts`/`dur` (sim seconds x 1e6), grouped `pid`/`tid` by pillar and
//!   track so each sampled request, worker, or shard renders as its
//!   own lane;
//! - gauge series become counter events (`"ph": "C"`), one per grid
//!   sample, so queue depths and token-bucket levels draw as
//!   staircase timelines under the spans.
//!
//! Determinism: events are emitted in span order then gauge order
//! (both deterministic), objects serialize through
//! [`crate::util::json::Json`] whose `BTreeMap` keys are sorted, and
//! floats render through the crate's canonical writer — so the byte
//! stream for a fixed seed never varies.

use super::{Span, TraceOutput};
use crate::util::json::Json;

/// `pid` for request/job span lanes.
const PID_SPANS: i64 = 1;
/// `pid` for gauge counter lanes.
const PID_GAUGES: i64 = 2;

fn micros(s: f64) -> Json {
    Json::Num(s * 1e6)
}

fn span_event(span: &Span) -> Json {
    let mut args = Json::obj();
    for (k, v) in &span.attrs {
        args.set(k, Json::Str(v.render()));
    }
    if let Some(p) = span.parent {
        args.set("parent", Json::Int(p as i64));
    }
    let mut ev = Json::obj();
    ev.set("name", Json::Str(span.name.clone()))
        .set("ph", Json::Str("X".to_string()))
        .set("ts", micros(span.start_s))
        .set("dur", micros((span.end_s - span.start_s).max(0.0)))
        .set("pid", Json::Int(PID_SPANS))
        .set("tid", Json::Int(span.track as i64))
        .set("args", args);
    ev
}

fn counter_event(name: &str, t: f64, value: f64) -> Json {
    let mut args = Json::obj();
    args.set("value", Json::Num(value));
    let mut ev = Json::obj();
    ev.set("name", Json::Str(name.to_string()))
        .set("ph", Json::Str("C".to_string()))
        .set("ts", micros(t))
        .set("pid", Json::Int(PID_GAUGES))
        .set("args", args);
    ev
}

/// Build the full trace-event document for one run.
pub fn trace_json(out: &TraceOutput) -> Json {
    let mut events = Vec::new();
    for span in &out.spans {
        events.push(span_event(span));
    }
    for series in &out.gauges {
        for (i, v) in series.samples.iter().enumerate() {
            events.push(counter_event(&series.name, series.t0 + i as f64 * series.dt, *v));
        }
    }
    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(events))
        .set("displayTimeUnit", Json::Str("ms".to_string()));
    if out.truncated > 0 {
        doc.set("truncatedSpans", Json::Int(out.truncated as i64));
    }
    doc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Attr, GaugeSeries};
    use crate::util::json::parse;

    fn sample_output() -> TraceOutput {
        TraceOutput {
            spans: vec![
                Span {
                    id: 0,
                    parent: None,
                    name: "request".to_string(),
                    track: 42,
                    start_s: 1.0,
                    end_s: 2.5,
                    attrs: vec![("outcome".to_string(), Attr::S("completed".to_string()))],
                },
                Span {
                    id: 1,
                    parent: Some(0),
                    name: "service".to_string(),
                    track: 42,
                    start_s: 2.0,
                    end_s: 2.5,
                    attrs: vec![("replica".to_string(), Attr::U(1))],
                },
            ],
            gauges: vec![GaugeSeries {
                name: "heap_depth".to_string(),
                t0: 0.0,
                dt: 0.5,
                samples: vec![3.0, 5.0],
                dropped: 0,
            }],
            truncated: 0,
        }
    }

    #[test]
    fn document_shape_is_chrome_trace() {
        let doc = trace_json(&sample_output());
        let text = doc.to_string_compact();
        let back = parse(&text).unwrap();
        let events = match back.get("traceEvents") {
            Some(Json::Arr(evs)) => evs.clone(),
            other => panic!("traceEvents missing: {other:?}"),
        };
        assert_eq!(events.len(), 4, "2 spans + 2 counter samples");
        assert_eq!(events[0].get("ph").unwrap(), &Json::Str("X".to_string()));
        assert_eq!(events[0].get("ts").and_then(Json::as_f64), Some(1.0e6));
        assert_eq!(events[0].get("dur").and_then(Json::as_f64), Some(1.5e6));
        assert_eq!(events[2].get("ph").unwrap(), &Json::Str("C".to_string()));
        let args = events[2].get("args").unwrap();
        assert_eq!(args.get("value").and_then(Json::as_f64), Some(3.0));
        assert_eq!(back.get("displayTimeUnit").unwrap(), &Json::Str("ms".to_string()));
    }

    #[test]
    fn export_bytes_are_stable() {
        let a = trace_json(&sample_output()).to_string_compact();
        let b = trace_json(&sample_output()).to_string_compact();
        assert_eq!(a, b);
    }

    #[test]
    fn truncation_is_visible_not_silent() {
        let mut out = sample_output();
        out.truncated = 9;
        let doc = trace_json(&out);
        assert_eq!(doc.get("truncatedSpans").and_then(Json::as_i64), Some(9));
    }
}
