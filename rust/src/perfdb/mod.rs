//! PerfDB: the performance database (paper §4.2.5).
//!
//! The paper backs this with MongoDB; here it is an in-memory store with
//! JSON-Lines persistence (one record per line, append-only — the same
//! write pattern the leader's daemon uses). Records are schemaless JSON
//! objects with a few indexed envelope fields (task, model, platform,
//! software), supporting the query/aggregate operations the analysis
//! stage needs, plus the leaderboard sort.

use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::io::Write;
use std::path::Path;

/// One benchmark result record.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    /// Envelope: what was benchmarked.
    pub task: String,
    pub model: String,
    pub platform: String,
    pub software: String,
    /// Free-form metrics payload (latency percentiles, throughput, ...).
    pub metrics: Json,
}

impl Record {
    pub fn new(task: &str, model: &str, platform: &str, software: &str) -> Record {
        Record {
            task: task.into(),
            model: model.into(),
            platform: platform.into(),
            software: software.into(),
            metrics: Json::obj(),
        }
    }

    pub fn with_metric(mut self, key: &str, value: f64) -> Record {
        self.metrics.set(key, Json::Num(value));
        self
    }

    /// Attach a string tag (e.g. the router policy of a sweep cell) —
    /// dimensions that identify a grid cell but aren't numeric metrics.
    pub fn with_label(mut self, key: &str, value: &str) -> Record {
        self.metrics.set(key, Json::Str(value.to_string()));
        self
    }

    pub fn metric(&self, key: &str) -> Option<f64> {
        self.metrics.get(key).and_then(|v| v.as_f64())
    }

    /// String tag accessor (`None` when absent or not a string).
    pub fn label(&self, key: &str) -> Option<&str> {
        self.metrics.get(key).and_then(|v| v.as_str())
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("task", Json::Str(self.task.clone()))
            .set("model", Json::Str(self.model.clone()))
            .set("platform", Json::Str(self.platform.clone()))
            .set("software", Json::Str(self.software.clone()))
            .set("metrics", self.metrics.clone());
        o
    }

    fn from_json(v: &Json) -> Result<Record> {
        let s = |k: &str| -> Result<String> {
            Ok(v.get(k)
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow!("record missing {k}"))?
                .to_string())
        };
        Ok(Record {
            task: s("task")?,
            model: s("model")?,
            platform: s("platform")?,
            software: s("software")?,
            metrics: v.get("metrics").cloned().unwrap_or_else(Json::obj),
        })
    }
}

/// Query filter: None = match-all per envelope field, plus any number of
/// string-label equality constraints (tags set via [`Record::with_label`],
/// e.g. a sweep cell's router policy). All constraints AND together.
#[derive(Debug, Default, Clone)]
pub struct Query {
    pub task: Option<String>,
    pub model: Option<String>,
    pub platform: Option<String>,
    pub software: Option<String>,
    /// Label equality constraints; a record matches only if it carries
    /// every listed key as a string tag with the exact value.
    pub labels: Vec<(String, String)>,
}

impl Query {
    pub fn task(mut self, t: &str) -> Self {
        self.task = Some(t.into());
        self
    }

    pub fn model(mut self, m: &str) -> Self {
        self.model = Some(m.into());
        self
    }

    pub fn platform(mut self, p: &str) -> Self {
        self.platform = Some(p.into());
        self
    }

    pub fn software(mut self, s: &str) -> Self {
        self.software = Some(s.into());
        self
    }

    /// Require a string tag: `Query::default().label("router", "p2c")`
    /// composes with the envelope filters and with further `label` calls.
    pub fn label(mut self, key: &str, value: &str) -> Self {
        self.labels.push((key.into(), value.into()));
        self
    }

    fn matches(&self, r: &Record) -> bool {
        fn ok(f: &Option<String>, v: &str) -> bool {
            f.as_deref().map_or(true, |x| x == v)
        }
        ok(&self.task, &r.task)
            && ok(&self.model, &r.model)
            && ok(&self.platform, &r.platform)
            && ok(&self.software, &r.software)
            && self.labels.iter().all(|(k, v)| r.label(k) == Some(v.as_str()))
    }
}

/// The database.
#[derive(Debug, Default)]
pub struct PerfDb {
    records: Vec<Record>,
}

impl PerfDb {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, r: Record) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn query(&self, q: &Query) -> Vec<&Record> {
        self.records.iter().filter(|r| q.matches(r)).collect()
    }

    /// Query records additionally filtered by a string tag — sugar for
    /// `query(&q.clone().label(key, value))`, kept for callers holding a
    /// `&Query`. Label filtering proper lives on the [`Query`] builder,
    /// so it composes with `aggregate_mean` and `leaderboard` too.
    pub fn query_by_label(&self, q: &Query, key: &str, value: &str) -> Vec<&Record> {
        self.query(&q.clone().label(key, value))
    }

    /// Mean of a metric over matching records.
    pub fn aggregate_mean(&self, q: &Query, metric: &str) -> Option<f64> {
        let vals: Vec<f64> = self.query(q).iter().filter_map(|r| r.metric(metric)).collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }

    /// Leaderboard: matching records sorted ascending by a metric
    /// (missing metric sorts last). Paper §4.2.5.
    pub fn leaderboard(&self, q: &Query, metric: &str) -> Vec<&Record> {
        let mut rows = self.query(q);
        rows.sort_by(|a, b| {
            let av = a.metric(metric).unwrap_or(f64::INFINITY);
            let bv = b.metric(metric).unwrap_or(f64::INFINITY);
            av.partial_cmp(&bv).unwrap()
        });
        rows
    }

    /// Append all records to a JSONL file (creates parents).
    pub fn save_jsonl(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        for r in &self.records {
            writeln!(f, "{}", r.to_json().to_string_compact())?;
        }
        Ok(())
    }

    /// Load a JSONL file written by `save_jsonl`.
    pub fn load_jsonl(path: impl AsRef<Path>) -> Result<PerfDb> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let mut db = PerfDb::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = json::parse(line).map_err(|e| anyhow!("line {}: {e}", i + 1))?;
            db.insert(Record::from_json(&v)?);
        }
        Ok(db)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_db() -> PerfDb {
        let mut db = PerfDb::new();
        db.insert(Record::new("serve", "resnet50", "G1", "tfs").with_metric("p99_ms", 25.0));
        db.insert(Record::new("serve", "resnet50", "G1", "tris").with_metric("p99_ms", 12.0));
        db.insert(Record::new("serve", "resnet50", "G3", "tfs").with_metric("p99_ms", 40.0));
        db.insert(Record::new("serve", "bert_large", "G1", "tfs").with_metric("p99_ms", 80.0));
        db
    }

    #[test]
    fn query_filters_compose() {
        let db = sample_db();
        assert_eq!(db.query(&Query::default()).len(), 4);
        assert_eq!(db.query(&Query::default().model("resnet50")).len(), 3);
        assert_eq!(db.query(&Query::default().model("resnet50").platform("G1")).len(), 2);
        assert_eq!(db.query(&Query::default().software("tris")).len(), 1);
    }

    #[test]
    fn query_by_label_filters_tagged_records() {
        let mut db = sample_db();
        db.insert(
            Record::new("sweep", "resnet50", "G1", "tris")
                .with_label("router", "round-robin")
                .with_metric("p99_ms", 20.0),
        );
        db.insert(
            Record::new("sweep", "resnet50", "G1", "tris")
                .with_label("router", "least-outstanding")
                .with_metric("p99_ms", 15.0),
        );
        let rr = db.query_by_label(&Query::default().task("sweep"), "router", "round-robin");
        assert_eq!(rr.len(), 1);
        assert_eq!(rr[0].metric("p99_ms"), Some(20.0));
        // Envelope filters still compose with the label filter.
        assert!(db
            .query_by_label(&Query::default().task("serve"), "router", "round-robin")
            .is_empty());
        // Records without the label never match; a numeric metric under
        // the same key is not a string label.
        assert!(db.query_by_label(&Query::default(), "p99_ms", "20").is_empty());
        assert!(db.query_by_label(&Query::default(), "router", "teleport").is_empty());
    }

    #[test]
    fn label_filter_composes_on_the_query_builder() {
        let mut db = sample_db();
        for (router, cell, p99) in [
            ("round-robin", "1x", 30.0),
            ("round-robin", "2x", 22.0),
            ("least-outstanding", "1x", 18.0),
        ] {
            db.insert(
                Record::new("sweep", "resnet50", "G1", "tris")
                    .with_label("router", router)
                    .with_label("cell", cell)
                    .with_metric("p99_ms", p99),
            );
        }
        let q = Query::default().task("sweep").label("router", "round-robin");
        assert_eq!(db.query(&q).len(), 2);
        // Multiple label constraints AND together.
        assert_eq!(db.query(&q.clone().label("cell", "2x")).len(), 1);
        assert!(db.query(&q.clone().label("cell", "4x")).is_empty());
        // And the label-aware query flows through the aggregations.
        let mean = db.aggregate_mean(&q, "p99_ms").unwrap();
        assert!((mean - 26.0).abs() < 1e-12);
        let best = db.leaderboard(&Query::default().task("sweep"), "p99_ms");
        assert_eq!(best[0].label("router"), Some("least-outstanding"));
        // query_by_label is now sugar over the builder: same rows.
        assert_eq!(
            db.query_by_label(&Query::default().task("sweep"), "router", "round-robin"),
            db.query(&Query::default().task("sweep").label("router", "round-robin"))
        );
    }

    #[test]
    fn aggregate_mean() {
        let db = sample_db();
        let m = db.aggregate_mean(&Query::default().model("resnet50").software("tfs"), "p99_ms");
        assert!((m.unwrap() - 32.5).abs() < 1e-12);
        assert!(db.aggregate_mean(&Query::default().model("nope"), "p99_ms").is_none());
    }

    #[test]
    fn leaderboard_sorted_ascending() {
        let db = sample_db();
        let rows = db.leaderboard(&Query::default().model("resnet50"), "p99_ms");
        let vals: Vec<f64> = rows.iter().map(|r| r.metric("p99_ms").unwrap()).collect();
        assert_eq!(vals, vec![12.0, 25.0, 40.0]);
        assert_eq!(rows[0].software, "tris");
    }

    #[test]
    fn jsonl_roundtrip() {
        let db = sample_db();
        let dir = std::env::temp_dir().join("inferbench_test_perfdb");
        let path = dir.join("perf.jsonl");
        db.save_jsonl(&path).unwrap();
        let loaded = PerfDb::load_jsonl(&path).unwrap();
        assert_eq!(loaded.len(), 4);
        assert_eq!(loaded.records[1], db.records[1]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_corrupt_lines() {
        let dir = std::env::temp_dir().join("inferbench_test_perfdb_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"task\":\"t\",\"model\":\"m\",\"platform\":\"p\",\"software\":\"s\",\"metrics\":{}}\nnot json\n").unwrap();
        assert!(PerfDb::load_jsonl(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_metric_sorts_last() {
        let mut db = sample_db();
        db.insert(Record::new("serve", "resnet50", "G4", "torchscript"));
        let rows = db.leaderboard(&Query::default().model("resnet50"), "p99_ms");
        assert_eq!(rows.last().unwrap().software, "torchscript");
    }
}
