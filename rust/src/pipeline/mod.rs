//! Tier-3 pipeline models (paper §2.3, Fig 4, §5.4): the stages a request
//! passes through around the inference itself — client pre-processing,
//! network transmission, and post-processing — plus the three network
//! technologies the paper tests (LAN, 4G LTE, campus WiFi).

use crate::util::rng::Pcg64;

/// A network technology: latency floor + bandwidth + jitter (paper §5.1
/// "three network scenarios").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Network {
    pub name: &'static str,
    /// One-way base latency, seconds.
    pub base_latency_s: f64,
    /// Effective application-layer bandwidth, BYTES per second.
    pub bandwidth_bps: f64,
    /// Lognormal jitter sigma (multiplies the base latency).
    pub jitter_sigma: f64,
}

/// Datacenter 1GbE including gRPC/TCP framing overhead.
pub const LAN: Network = Network {
    name: "LAN",
    base_latency_s: 1.2e-3,
    bandwidth_bps: 110.0e6, // ~1 Gbps effective
    jitter_sigma: 0.10,
};

/// Campus 802.11ac (contended).
pub const WIFI: Network = Network {
    name: "Campus WiFi",
    base_latency_s: 4.0e-3,
    bandwidth_bps: 6.0e6, // ~48 Mbps effective
    jitter_sigma: 0.35,
};

/// Cellular uplink: high RTT, modest bandwidth, heavy jitter.
pub const LTE_4G: Network = Network {
    name: "4G LTE",
    base_latency_s: 45.0e-3,
    bandwidth_bps: 1.5e6, // ~12 Mbps uplink
    jitter_sigma: 0.5,
};

pub const NETWORKS: &[Network] = &[LAN, WIFI, LTE_4G];

impl Network {
    /// Sample one request's transmission time for a payload.
    pub fn sample_s(&self, payload_bytes: u64, rng: &mut Pcg64) -> f64 {
        let jitter = rng.lognormal(0.0, self.jitter_sigma);
        self.base_latency_s * jitter + payload_bytes as f64 / self.bandwidth_bps
    }

    /// Deterministic mean transmission time (for tables).
    pub fn mean_s(&self, payload_bytes: u64) -> f64 {
        // E[lognormal(0, s)] = exp(s^2/2).
        let mean_jitter = (self.jitter_sigma * self.jitter_sigma / 2.0).exp();
        self.base_latency_s * mean_jitter + payload_bytes as f64 / self.bandwidth_bps
    }
}

/// Pre-/post-processing cost model (paper §4.2.3): per-request CPU work
/// like image resize + tensor conversion, and class-id -> label lookup.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Processors {
    /// Pre-processing seconds per request (e.g. decode + resize ~ 2-4 ms
    /// for images, ~0.2 ms for tokenized text).
    pub pre_s: f64,
    /// Post-processing seconds per request.
    pub post_s: f64,
}

impl Processors {
    /// Typical image-classification processors (decode + resize + argmax).
    pub fn image() -> Processors {
        Processors { pre_s: 2.5e-3, post_s: 0.3e-3 }
    }

    /// Text pipelines (tokenize + label lookup).
    pub fn text() -> Processors {
        Processors { pre_s: 0.4e-3, post_s: 0.1e-3 }
    }

    pub fn none() -> Processors {
        Processors { pre_s: 0.0, post_s: 0.0 }
    }
}

/// Full request-path model around the server: processors + network +
/// payload size. Used by the serving simulator to draw per-request stage
/// durations.
#[derive(Debug, Clone, Copy)]
pub struct RequestPath {
    pub processors: Processors,
    pub network: Network,
    pub payload_bytes: u64,
}

impl RequestPath {
    /// PRNG steps one [`RequestPath::sample`] call consumes, always:
    /// processors are deterministic and the network jitter is one
    /// `lognormal` draw (two steps). The streaming serving engines use
    /// this to fast-forward their loop-phase RNG past the issue-phase
    /// draws with `Pcg64::advance` instead of materializing the workload
    /// (pinned by a test below).
    pub const RNG_STEPS_PER_SAMPLE: u64 = 2;

    pub fn local(processors: Processors) -> RequestPath {
        RequestPath { processors, network: LAN, payload_bytes: 1_000 }
    }

    /// Sample (pre, transmission, post) durations for one request.
    pub fn sample(&self, rng: &mut Pcg64) -> (f64, f64, f64) {
        (
            self.processors.pre_s,
            self.network.sample_s(self.payload_bytes, rng),
            self.processors.post_s,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lte_slowest_lan_fastest() {
        // Paper Fig 14b: 4G LTE has the longest end-to-end latency.
        let payload = 150_000;
        assert!(LAN.mean_s(payload) < WIFI.mean_s(payload));
        assert!(WIFI.mean_s(payload) < LTE_4G.mean_s(payload));
    }

    #[test]
    fn sample_mean_close_to_analytic() {
        let mut rng = Pcg64::seeded(3);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| WIFI.sample_s(150_000, &mut rng)).sum::<f64>() / n as f64;
        let expect = WIFI.mean_s(150_000);
        assert!((mean / expect - 1.0).abs() < 0.05, "mean {mean} expect {expect}");
    }

    #[test]
    fn transmission_grows_with_payload() {
        assert!(LTE_4G.mean_s(1_000_000) > LTE_4G.mean_s(10_000) + 0.05);
    }

    #[test]
    fn samples_positive_and_jittered() {
        let mut rng = Pcg64::seeded(5);
        let a: Vec<f64> = (0..100).map(|_| LTE_4G.sample_s(1000, &mut rng)).collect();
        assert!(a.iter().all(|&x| x > 0.0));
        let distinct = a.windows(2).filter(|w| w[0] != w[1]).count();
        assert!(distinct > 90);
    }

    #[test]
    fn request_path_sample_components() {
        let mut rng = Pcg64::seeded(1);
        let p = RequestPath { processors: Processors::image(), network: LAN, payload_bytes: 150_000 };
        let (pre, tx, post) = p.sample(&mut rng);
        assert_eq!(pre, 2.5e-3);
        assert_eq!(post, 0.3e-3);
        assert!(tx > 0.0);
    }

    #[test]
    fn sample_consumes_exactly_the_advertised_rng_steps() {
        // Every network (jitter sigma 0.1 .. 0.5) and payload must cost the
        // same fixed step count, or the engines' loop-RNG fast-forward
        // desynchronizes from the materialized draw order.
        for network in NETWORKS {
            for payload in [0u64, 1_000, 5_000_000] {
                let p = RequestPath { processors: Processors::image(), network: *network, payload_bytes: payload };
                let mut sampled = Pcg64::seeded(99);
                p.sample(&mut sampled);
                let mut jumped = Pcg64::seeded(99);
                jumped.advance(RequestPath::RNG_STEPS_PER_SAMPLE as u128);
                assert_eq!(
                    sampled.next_u64(),
                    jumped.next_u64(),
                    "{} payload {payload}",
                    network.name
                );
            }
        }
    }

    #[test]
    fn processors_presets() {
        assert!(Processors::image().pre_s > Processors::text().pre_s);
        assert_eq!(Processors::none().pre_s, 0.0);
    }
}
