//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.
//!
//! `artifacts/manifest.json` lists, per lowered model variant, the HLO file,
//! the ordered input tensor specs (params first, then `x`), the output
//! shape, and the analytic compute profile (FLOPs / params / weight &
//! activation bytes) that drives the hardware roofline models.

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One named tensor the executable expects (or produces).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_size(&self) -> usize {
        // Only f32 is emitted today; keep the map explicit for extension.
        let elem = match self.dtype.as_str() {
            "f32" => 4,
            "bf16" | "f16" => 2,
            other => panic!("unsupported dtype {other}"),
        };
        self.element_count() * elem
    }
}

/// Manifest entry for one AOT-compiled model variant.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub family: String,
    pub hyperparams: BTreeMap<String, f64>,
    /// Ordered inputs: model params first, then the data tensor `x` (last).
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
    pub flops_per_sample: u64,
    pub params: u64,
    pub weight_bytes: u64,
    pub act_bytes_per_sample: u64,
    pub hlo_file: String,
}

impl ArtifactEntry {
    /// The data input (by convention the last entry).
    pub fn x_spec(&self) -> &TensorSpec {
        self.inputs.last().expect("manifest entry has no inputs")
    }

    /// Batch size = leading dim of the data input.
    pub fn batch(&self) -> usize {
        self.x_spec().shape.first().copied().unwrap_or(1)
    }

    /// Arithmetic intensity (FLOPs per HBM byte) at this artifact's batch —
    /// the x-axis of the Roofline analysis (paper Fig 10).
    pub fn arithmetic_intensity(&self) -> f64 {
        let b = self.batch() as f64;
        (self.flops_per_sample as f64 * b)
            / (self.weight_bytes as f64 + self.act_bytes_per_sample as f64 * b)
    }
}

/// The parsed manifest plus its base directory (for resolving HLO paths).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts` first", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text (dir used for HLO path resolution).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let obj = root.as_obj().ok_or_else(|| anyhow!("manifest root must be an object"))?;
        let mut entries = BTreeMap::new();
        for (name, v) in obj {
            entries.insert(name.clone(), parse_entry(name, v)?);
        }
        Ok(Manifest { dir, entries })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries.get(name).ok_or_else(|| {
            anyhow!(
                "artifact {name:?} not in manifest (available: {:?})",
                self.entries.keys().take(8).collect::<Vec<_>>()
            )
        })
    }

    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.hlo_file)
    }

    /// Artifact names for a (model stem, any batch) — e.g. "resnet_mini".
    pub fn variants_of(&self, stem: &str) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> = self
            .entries
            .iter()
            .filter(|(k, _)| k.starts_with(stem))
            .map(|(_, e)| e)
            .collect();
        v.sort_by_key(|e| e.batch());
        v
    }
}

fn parse_entry(name: &str, v: &Json) -> Result<ArtifactEntry> {
    let get = |k: &str| -> Result<&Json> {
        v.get(k).ok_or_else(|| anyhow!("artifact {name}: missing field {k:?}"))
    };
    let str_field = |k: &str| -> Result<String> {
        Ok(get(k)?.as_str().ok_or_else(|| anyhow!("artifact {name}: {k} not a string"))?.to_string())
    };
    let u64_field = |k: &str| -> Result<u64> {
        get(k)?.as_i64().map(|i| i as u64).ok_or_else(|| anyhow!("artifact {name}: {k} not an int"))
    };

    let mut hyperparams = BTreeMap::new();
    if let Some(hp) = get("hyperparams")?.as_obj() {
        for (k, val) in hp {
            if let Some(f) = val.as_f64() {
                hyperparams.insert(k.clone(), f);
            }
        }
    }

    let inputs = get("inputs")?
        .as_arr()
        .ok_or_else(|| anyhow!("artifact {name}: inputs not an array"))?
        .iter()
        .map(|t| parse_tensor(name, t))
        .collect::<Result<Vec<_>>>()?;
    if inputs.is_empty() {
        bail!("artifact {name}: empty inputs");
    }
    let output = parse_tensor(name, get("output")?)?;

    Ok(ArtifactEntry {
        name: name.to_string(),
        family: str_field("family")?,
        hyperparams,
        inputs,
        output,
        flops_per_sample: u64_field("flops_per_sample")?,
        params: u64_field("params")?,
        weight_bytes: u64_field("weight_bytes")?,
        act_bytes_per_sample: u64_field("act_bytes_per_sample")?,
        hlo_file: str_field("hlo_file")?,
    })
}

fn parse_tensor(artifact: &str, v: &Json) -> Result<TensorSpec> {
    let shape = v
        .get("shape")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow!("artifact {artifact}: tensor missing shape"))?
        .iter()
        .map(|d| d.as_i64().map(|i| i as usize).ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSpec {
        name: v.get("name").and_then(|n| n.as_str()).unwrap_or("out").to_string(),
        shape,
        dtype: v.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32").to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "mlp_d2_w64_b4": {
        "family": "mlp",
        "hyperparams": {"depth": 2, "width": 64, "batch": 4},
        "inputs": [
          {"name": "w_in", "shape": [256, 64], "dtype": "f32"},
          {"name": "x", "shape": [4, 256], "dtype": "f32"}
        ],
        "output": {"shape": [4, 16], "dtype": "f32"},
        "flops_per_sample": 49152,
        "params": 16448,
        "weight_bytes": 65792,
        "act_bytes_per_sample": 2688,
        "hlo_file": "mlp_d2_w64_b4.hlo.txt"
      }
    }"#;

    #[test]
    fn parses_entry() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let e = m.get("mlp_d2_w64_b4").unwrap();
        assert_eq!(e.family, "mlp");
        assert_eq!(e.batch(), 4);
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.x_spec().name, "x");
        assert_eq!(e.hyperparams["width"], 64.0);
        assert_eq!(m.hlo_path(e), PathBuf::from("/tmp/mlp_d2_w64_b4.hlo.txt"));
    }

    #[test]
    fn tensor_spec_sizes() {
        let t = TensorSpec { name: "x".into(), shape: vec![4, 256], dtype: "f32".into() };
        assert_eq!(t.element_count(), 1024);
        assert_eq!(t.byte_size(), 4096);
    }

    #[test]
    fn arithmetic_intensity_monotone_in_batch() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let e = m.get("mlp_d2_w64_b4").unwrap();
        let mut e1 = e.clone();
        e1.inputs.last_mut().unwrap().shape[0] = 1;
        let mut e32 = e.clone();
        e32.inputs.last_mut().unwrap().shape[0] = 32;
        assert!(e32.arithmetic_intensity() > e.arithmetic_intensity());
        assert!(e.arithmetic_intensity() > e1.arithmetic_intensity());
    }

    #[test]
    fn missing_field_is_error() {
        let bad = r#"{"m": {"family": "mlp"}}"#;
        assert!(Manifest::parse(bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn unknown_artifact_lists_available() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("mlp_d2_w64_b4"));
    }

    #[test]
    fn variants_sorted_by_batch() {
        let mut doc = String::from("{");
        for (i, b) in [8, 1, 4].iter().enumerate() {
            if i > 0 {
                doc.push(',');
            }
            doc.push_str(&format!(
                r#""m_b{b}": {{"family":"mlp","hyperparams":{{}},
                "inputs":[{{"name":"x","shape":[{b},8],"dtype":"f32"}}],
                "output":{{"shape":[{b},16],"dtype":"f32"}},
                "flops_per_sample":1,"params":1,"weight_bytes":4,
                "act_bytes_per_sample":4,"hlo_file":"m_b{b}.hlo.txt"}}"#
            ));
        }
        doc.push('}');
        let m = Manifest::parse(&doc, PathBuf::from("/tmp")).unwrap();
        let batches: Vec<usize> = m.variants_of("m_b").iter().map(|e| e.batch()).collect();
        assert_eq!(batches, vec![1, 4, 8]);
    }
}
