//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` -> `HloModuleProto::
//! from_text_file` -> `client.compile` -> `execute_b`. Model parameters are
//! generated once (seeded, shapes from the manifest) and uploaded to device
//! buffers at load; the request hot path only uploads the data tensor `x`.
//!
//! PJRT handles are not `Send`: the serving engine owns an [`Engine`] on a
//! dedicated executor thread and feeds it through channels (see
//! `serving::live`).

pub mod manifest;

pub use manifest::{ArtifactEntry, Manifest, TensorSpec};

use crate::util::rng::Pcg64;
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// A PJRT client plus the manifest it loads artifacts from.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl Engine {
    /// CPU PJRT client over the given artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact and upload seeded parameters; returns the
    /// ready-to-serve model. `seed` makes param contents reproducible
    /// (they affect numerics, not benchmark timing).
    pub fn load(&self, name: &str, seed: u64) -> Result<LoadedModel> {
        let entry = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&entry);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let compile_time = t0.elapsed();

        // Upload every param tensor once; x (last input) is uploaded per call.
        let t1 = Instant::now();
        let mut rng = Pcg64::seeded(seed);
        let mut param_buffers = Vec::with_capacity(entry.inputs.len() - 1);
        for spec in &entry.inputs[..entry.inputs.len() - 1] {
            if spec.dtype != "f32" {
                bail!("artifact {name}: unsupported param dtype {}", spec.dtype);
            }
            let fan_in = if spec.shape.len() >= 2 {
                spec.shape[spec.shape.len() - 2]
            } else {
                spec.shape.first().copied().unwrap_or(1)
            };
            let scale = 1.0 / (fan_in.max(1) as f32).sqrt();
            let data = rng.f32_vec(spec.element_count(), scale);
            let buf = self
                .client
                .buffer_from_host_buffer(&data, &spec.shape, None)
                .with_context(|| format!("uploading param {}", spec.name))?;
            param_buffers.push(buf);
        }
        let upload_time = t1.elapsed();

        Ok(LoadedModel { entry, exe, param_buffers, compile_time, upload_time })
    }
}

/// A compiled executable with its parameters resident on device.
pub struct LoadedModel {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
    param_buffers: Vec<xla::PjRtBuffer>,
    /// HLO-parse + XLA-compile time (the dominant part of cold start).
    pub compile_time: std::time::Duration,
    /// Param generation + host->device transfer time.
    pub upload_time: std::time::Duration,
}

impl LoadedModel {
    pub fn name(&self) -> &str {
        &self.entry.name
    }

    pub fn batch(&self) -> usize {
        self.entry.batch()
    }

    /// Element count of one request's data tensor.
    pub fn x_elements(&self) -> usize {
        self.entry.x_spec().element_count()
    }

    /// Run one inference. `x` must have exactly `x_elements()` values.
    /// Returns the flattened logits.
    pub fn infer(&self, x: &[f32]) -> Result<Vec<f32>> {
        let spec = self.entry.x_spec();
        if x.len() != spec.element_count() {
            bail!(
                "model {}: x has {} elements, expected {} {:?}",
                self.entry.name,
                x.len(),
                spec.element_count(),
                spec.shape
            );
        }
        let xbuf = self
            .exe
            .client()
            .buffer_from_host_buffer(x, &spec.shape, None)
            .context("uploading x")?;
        let mut args: Vec<&xla::PjRtBuffer> = self.param_buffers.iter().collect();
        args.push(&xbuf);
        let result = self.exe.execute_b(&args).context("execute")?;
        let literal = result[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(literal.to_vec::<f32>()?)
    }

    /// Timed inference: returns (logits, wall time). The measurement the
    /// CPU-platform (C1) latency numbers in every bench come from.
    pub fn infer_timed(&self, x: &[f32]) -> Result<(Vec<f32>, std::time::Duration)> {
        let t0 = Instant::now();
        let out = self.infer(x)?;
        Ok((out, t0.elapsed()))
    }

    /// Deterministic input tensor for benchmarking.
    pub fn make_input(&self, seed: u64) -> Vec<f32> {
        Pcg64::seeded(seed).f32_vec(self.x_elements(), 1.0)
    }

    /// Run a few inferences to absorb first-call overhead; returns the
    /// steady-state mean latency over `iters` timed runs.
    pub fn warmup_and_measure(&self, warmup: usize, iters: usize) -> Result<f64> {
        let x = self.make_input(7);
        for _ in 0..warmup {
            self.infer(&x)?;
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            self.infer(&x)?;
        }
        Ok(t0.elapsed().as_secs_f64() / iters.max(1) as f64)
    }
}

// Engine tests that need real artifacts live in
// rust/tests/runtime_integration.rs (they require `make artifacts`).
// Manifest parsing is unit-tested in manifest.rs.
