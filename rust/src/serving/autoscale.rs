//! Autoscaling policies for the cluster serving tier (paper §5 spike
//! loads, Fig 11c; "Scalable AI Inference" replica scale-up lag).
//!
//! Pure decision logic, like [`super::batcher`] and [`super::router`]: the
//! cluster engine evaluates the policy on a fixed interval with a
//! [`ScaleSignal`] (active/warming counts, outstanding work, utilization)
//! and gets back a [`ScaleDecision`]. The *mechanics* live in the engine:
//!
//!  * **Scale-up** appends a replica from the template which pays
//!    [`Software::coldstart_s`] for the configured weight footprint before
//!    it becomes routable — the paper's ">10 s even for a small IC model"
//!    cold start is exactly what makes spike response hard.
//!  * **Scale-down** is drain-on-remove: the chosen replica stops
//!    receiving traffic, finishes its queued + in-flight requests, then
//!    retires — so `issued == completed + dropped` holds exactly across
//!    every scale event (no request is lost at retirement).
//!
//! Submissions reach this through the coordinator's `cluster_sim` job kind
//! (see [`crate::coordinator::job`] for a YAML example).

use super::cluster::ReplicaConfig;

/// When to add or remove replicas.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScalePolicy {
    /// Threshold on outstanding requests (queued + in service) per
    /// provisioned replica: scale up above `up_per_replica`, down below
    /// `down_per_replica`. Warming replicas count as provisioned so a
    /// burst does not trigger one add per evaluation while the first
    /// cold start is still in progress beyond what the queue justifies.
    QueueDepth { up_per_replica: f64, down_per_replica: f64, cooldown_s: f64 },
    /// Threshold on the busy fraction of active replicas since the last
    /// evaluation: scale up above `up`, down below `down` (both in [0,1]).
    Utilization { up: f64, down: f64, cooldown_s: f64 },
}

impl ScalePolicy {
    pub fn label(&self) -> &'static str {
        match self {
            ScalePolicy::QueueDepth { .. } => "queue-depth",
            ScalePolicy::Utilization { .. } => "utilization",
        }
    }

    pub fn cooldown_s(&self) -> f64 {
        match *self {
            ScalePolicy::QueueDepth { cooldown_s, .. } => cooldown_s,
            ScalePolicy::Utilization { cooldown_s, .. } => cooldown_s,
        }
    }
}

/// Full autoscaler configuration for a cluster run.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    pub policy: ScalePolicy,
    /// Never drain below this many active replicas (>= 1).
    pub min_replicas: usize,
    /// Never provision (active + warming) beyond this.
    pub max_replicas: usize,
    /// Configuration for replicas added by scale-up.
    pub template: ReplicaConfig,
    /// Model weight footprint: sets the cold start via
    /// [`Software::coldstart_s`](super::backends::Software::coldstart_s).
    pub weight_bytes: u64,
    /// How often the policy is evaluated.
    pub eval_interval_s: f64,
}

/// What the cluster looked like at an evaluation instant.
#[derive(Debug, Clone, Copy)]
pub struct ScaleSignal {
    /// Routable replicas.
    pub active: usize,
    /// Replicas still paying their cold start.
    pub warming: usize,
    /// Replicas draining toward retirement.
    pub draining: usize,
    /// Replicas currently down after a crash (fault injection). They are
    /// not provisioned capacity: the same outstanding work spread over
    /// fewer provisioned replicas reads as scale-up pressure, so the
    /// policy reacts to crash-induced capacity loss without a special
    /// case.
    pub failed: usize,
    /// Outstanding requests (queued + in service) across active replicas.
    pub outstanding: usize,
    /// Busy fraction of active replicas since the last evaluation, [0,1].
    pub utilization: f64,
}

/// The policy's verdict for one evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// Add one replica (it will warm up before taking traffic).
    Add,
    /// Drain-on-remove one active replica.
    Remove,
}

/// Policy state machine: thresholds + cooldown bookkeeping.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    config: AutoscaleConfig,
    last_scale_s: f64,
}

impl Autoscaler {
    pub fn new(config: AutoscaleConfig) -> Autoscaler {
        assert!(config.min_replicas >= 1, "autoscaler needs min_replicas >= 1");
        assert!(
            config.max_replicas >= config.min_replicas,
            "max_replicas must be >= min_replicas"
        );
        assert!(config.eval_interval_s > 0.0, "eval interval must be positive");
        Autoscaler { config, last_scale_s: f64::NEG_INFINITY }
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.config
    }

    /// Evaluate the policy at `now`. At most one replica is added or
    /// removed per call, and never within the cooldown of the previous
    /// scale action (evaluations during cooldown hold).
    pub fn decide(&mut self, now: f64, s: ScaleSignal) -> ScaleDecision {
        if now - self.last_scale_s < self.config.policy.cooldown_s() {
            return ScaleDecision::Hold;
        }
        let provisioned = s.active + s.warming;
        let (want_up, want_down) = match self.config.policy {
            ScalePolicy::QueueDepth { up_per_replica, down_per_replica, .. } => {
                let per = s.outstanding as f64 / provisioned.max(1) as f64;
                (per > up_per_replica, per < down_per_replica)
            }
            ScalePolicy::Utilization { up, down, .. } => (s.utilization > up, s.utilization < down),
        };
        if want_up && provisioned < self.config.max_replicas {
            self.last_scale_s = now;
            ScaleDecision::Add
        } else if want_down && s.active > self.config.min_replicas && s.warming == 0 {
            // Never drain while capacity is still warming: the add that is
            // in flight was justified by recent load.
            self.last_scale_s = now;
            ScaleDecision::Remove
        } else {
            ScaleDecision::Hold
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::backends;
    use crate::serving::batcher::Policy;
    use crate::serving::service::ServiceModel;

    fn template() -> ReplicaConfig {
        ReplicaConfig {
            software: &backends::TFS,
            service: ServiceModel::Measured { per_batch: vec![(1, 0.005)], utilization: 0.5 },
            policy: Policy::Single,
            max_queue: 1024,
        }
    }

    fn scaler(policy: ScalePolicy, min: usize, max: usize) -> Autoscaler {
        Autoscaler::new(AutoscaleConfig {
            policy,
            min_replicas: min,
            max_replicas: max,
            template: template(),
            weight_bytes: 100_000_000,
            eval_interval_s: 0.5,
        })
    }

    fn signal(active: usize, warming: usize, outstanding: usize, util: f64) -> ScaleSignal {
        ScaleSignal { active, warming, draining: 0, failed: 0, outstanding, utilization: util }
    }

    #[test]
    fn crash_induced_capacity_loss_reads_as_scale_up_pressure() {
        let mut a = scaler(
            ScalePolicy::QueueDepth { up_per_replica: 4.0, down_per_replica: 0.5, cooldown_s: 0.0 },
            1,
            8,
        );
        // 12 outstanding over 4 healthy replicas: 3 per replica, hold.
        assert_eq!(a.decide(0.0, signal(4, 0, 12, 0.9)), ScaleDecision::Hold);
        // Two of them crash: the same backlog over 2 provisioned replicas
        // is 6 per replica — the policy adds without a fault special case.
        let crashed = ScaleSignal {
            active: 2,
            warming: 0,
            draining: 0,
            failed: 2,
            outstanding: 12,
            utilization: 0.9,
        };
        assert_eq!(a.decide(1.0, crashed), ScaleDecision::Add);
    }

    #[test]
    fn queue_depth_scales_up_above_threshold() {
        let mut a = scaler(
            ScalePolicy::QueueDepth { up_per_replica: 4.0, down_per_replica: 0.5, cooldown_s: 1.0 },
            1,
            8,
        );
        assert_eq!(a.decide(0.0, signal(2, 0, 20, 0.9)), ScaleDecision::Add);
        // Cooldown: immediate re-evaluation holds even though still hot.
        assert_eq!(a.decide(0.5, signal(2, 1, 30, 0.9)), ScaleDecision::Hold);
        assert_eq!(a.decide(1.5, signal(2, 1, 30, 0.9)), ScaleDecision::Add);
    }

    #[test]
    fn queue_depth_counts_warming_toward_provisioned() {
        let mut a = scaler(
            ScalePolicy::QueueDepth { up_per_replica: 4.0, down_per_replica: 0.5, cooldown_s: 0.0 },
            1,
            8,
        );
        // 12 outstanding over 2 active + 2 warming = 3 per replica < 4.
        assert_eq!(a.decide(0.0, signal(2, 2, 12, 1.0)), ScaleDecision::Hold);
        // Same queue with no warming capacity: 6 per replica -> add.
        assert_eq!(a.decide(1.0, signal(2, 0, 12, 1.0)), ScaleDecision::Add);
    }

    #[test]
    fn queue_depth_scales_down_when_idle() {
        let mut a = scaler(
            ScalePolicy::QueueDepth { up_per_replica: 4.0, down_per_replica: 0.5, cooldown_s: 0.0 },
            2,
            8,
        );
        assert_eq!(a.decide(0.0, signal(4, 0, 0, 0.02)), ScaleDecision::Remove);
        // But never below min_replicas.
        assert_eq!(a.decide(1.0, signal(2, 0, 0, 0.0)), ScaleDecision::Hold);
        // And never while a replica is warming.
        assert_eq!(a.decide(2.0, signal(4, 1, 0, 0.0)), ScaleDecision::Hold);
    }

    #[test]
    fn respects_max_replicas() {
        let mut a = scaler(
            ScalePolicy::QueueDepth { up_per_replica: 1.0, down_per_replica: 0.1, cooldown_s: 0.0 },
            1,
            3,
        );
        assert_eq!(a.decide(0.0, signal(3, 0, 100, 1.0)), ScaleDecision::Hold);
        assert_eq!(a.decide(1.0, signal(2, 1, 100, 1.0)), ScaleDecision::Hold);
        assert_eq!(a.decide(2.0, signal(2, 0, 100, 1.0)), ScaleDecision::Add);
    }

    #[test]
    fn utilization_policy_thresholds() {
        let mut a = scaler(ScalePolicy::Utilization { up: 0.8, down: 0.3, cooldown_s: 0.0 }, 1, 4);
        assert_eq!(a.decide(0.0, signal(2, 0, 5, 0.95)), ScaleDecision::Add);
        assert_eq!(a.decide(1.0, signal(3, 0, 2, 0.5)), ScaleDecision::Hold);
        assert_eq!(a.decide(2.0, signal(3, 0, 0, 0.1)), ScaleDecision::Remove);
    }

    #[test]
    fn cooldown_applies_across_directions() {
        let mut a = scaler(ScalePolicy::Utilization { up: 0.8, down: 0.3, cooldown_s: 5.0 }, 1, 4);
        assert_eq!(a.decide(0.0, signal(2, 0, 5, 0.95)), ScaleDecision::Add);
        // A crash in load right after the add does not whipsaw into a
        // remove until the cooldown passes.
        assert_eq!(a.decide(2.0, signal(3, 0, 0, 0.05)), ScaleDecision::Hold);
        assert_eq!(a.decide(6.0, signal(3, 0, 0, 0.05)), ScaleDecision::Remove);
    }

    #[test]
    #[should_panic(expected = "min_replicas")]
    fn rejects_zero_min() {
        let _ = scaler(ScalePolicy::Utilization { up: 0.8, down: 0.3, cooldown_s: 0.0 }, 0, 4);
    }
}
