//! Serving-software profiles (paper §3.2 Tier 2, Fig 6).
//!
//! The paper benchmarks four serving infrastructures: TensorFlow-Serving
//! (TFS), Triton Inference Server (TrIS), ONNX Runtime behind FastAPI, and
//! TorchScript behind FastAPI. This testbed cannot run the real binaries
//! (DESIGN.md §2); what differs between them — and what Fig 11d/12/14c
//! measure — is queueing + overhead behaviour, which these profiles model:
//! per-request RPC overhead, per-batch dispatch overhead, runtime
//! optimization quality, dynamic-batching implementation quality, and the
//! cold-start profile. Values are calibrated to reproduce the paper's
//! qualitative ordering (TrIS < ONNX-RT < TFS < TorchScript on tail
//! latency; TrIS >> TFS on dynamic batching; TrIS slowest to cold-start).

/// How well a platform's dynamic batching works (Fig 12).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DynamicBatching {
    /// No server-side batching (plain web-framework wrappers).
    None,
    /// Forms batches but adds `penalty_s` scheduling delay per formed
    /// batch and caps effective batch at `effective_cap` under light
    /// concurrency — TFS's observed "worse than no batching at small
    /// concurrency" behaviour.
    Naive { penalty_s: f64, effective_cap: usize },
    /// Well-implemented (TrIS): negligible added delay, full batch use.
    Optimized,
}

/// One serving-software profile.
#[derive(Debug, Clone)]
pub struct Software {
    pub id: &'static str,
    pub name: &'static str,
    /// Per-request fixed overhead: RPC deserialize, tensor conversion,
    /// framework glue (python web frameworks pay more).
    pub request_overhead_s: f64,
    /// Per-batch dispatch overhead into the runtime.
    pub batch_overhead_s: f64,
    /// Multiplier on device inference time (<1 = optimized runtime, e.g.
    /// TensorRT kernels under TrIS; >1 = interpreter overhead).
    pub runtime_factor: f64,
    pub dynamic_batching: DynamicBatching,
    /// Cold start: fixed initialization plus per-GB-of-weights load time
    /// (Fig 14c).
    pub coldstart_base_s: f64,
    pub coldstart_per_gb_s: f64,
}

pub const TFS: Software = Software {
    id: "tfs",
    name: "TensorFlow-Serving",
    request_overhead_s: 1.2e-3,
    batch_overhead_s: 0.5e-3,
    runtime_factor: 1.0,
    dynamic_batching: DynamicBatching::Naive { penalty_s: 4.0e-3, effective_cap: 8 },
    coldstart_base_s: 2.0,
    coldstart_per_gb_s: 2.0,
};

pub const TRIS: Software = Software {
    id: "tris",
    name: "Triton Inference Server",
    request_overhead_s: 0.4e-3,
    batch_overhead_s: 0.2e-3,
    runtime_factor: 0.8, // TensorRT-optimized kernels
    dynamic_batching: DynamicBatching::Optimized,
    coldstart_base_s: 9.0, // paper: >10s even for a small IC model
    coldstart_per_gb_s: 4.0,
};

pub const ONNX_FASTAPI: Software = Software {
    id: "onnx",
    name: "ONNX Runtime + FastAPI",
    request_overhead_s: 0.8e-3,
    batch_overhead_s: 0.4e-3,
    runtime_factor: 0.92, // graph-level optimizations
    dynamic_batching: DynamicBatching::None,
    coldstart_base_s: 1.2,
    coldstart_per_gb_s: 1.5,
};

pub const TORCHSCRIPT_FASTAPI: Software = Software {
    id: "torchscript",
    name: "TorchScript + FastAPI",
    request_overhead_s: 1.5e-3,
    batch_overhead_s: 0.6e-3,
    runtime_factor: 1.1, // jit interpreter overhead
    dynamic_batching: DynamicBatching::None,
    coldstart_base_s: 1.8,
    coldstart_per_gb_s: 2.5,
};

pub const ALL: &[&Software] = &[&TFS, &TRIS, &ONNX_FASTAPI, &TORCHSCRIPT_FASTAPI];

pub fn find(id: &str) -> Option<&'static Software> {
    ALL.iter().copied().find(|s| s.id == id)
}

impl Software {
    /// Cold-start time for a model with the given weight footprint
    /// (Fig 14c). On the real CPU path the XLA compile time measured by
    /// the runtime is added by the caller.
    pub fn coldstart_s(&self, weight_bytes: u64) -> f64 {
        self.coldstart_base_s + (weight_bytes as f64 / 1e9) * self.coldstart_per_gb_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_platforms_registered() {
        assert_eq!(ALL.len(), 4);
        for id in ["tfs", "tris", "onnx", "torchscript"] {
            assert!(find(id).is_some(), "{id}");
        }
        assert!(find("clipper").is_none());
    }

    #[test]
    fn paper_overhead_ordering() {
        // Fig 11d: TrIS < ONNX-RT < TFS < TorchScript on per-request cost.
        let total = |s: &Software| s.request_overhead_s + s.batch_overhead_s;
        assert!(total(&TRIS) < total(&ONNX_FASTAPI));
        assert!(total(&ONNX_FASTAPI) < total(&TFS));
        assert!(total(&TFS) < total(&TORCHSCRIPT_FASTAPI));
    }

    #[test]
    fn tris_runtime_fastest() {
        assert!(TRIS.runtime_factor < ONNX_FASTAPI.runtime_factor);
        assert!(ONNX_FASTAPI.runtime_factor < TFS.runtime_factor);
        assert!(TFS.runtime_factor < TORCHSCRIPT_FASTAPI.runtime_factor);
    }

    #[test]
    fn tris_coldstart_longest() {
        // Fig 14c: TrIS takes >10s to start even a small model.
        let small_model = 100_000_000; // 100 MB of weights
        let tris = TRIS.coldstart_s(small_model);
        assert!(tris > 9.0);
        for s in [&TFS, &ONNX_FASTAPI, &TORCHSCRIPT_FASTAPI] {
            assert!(s.coldstart_s(small_model) < tris, "{}", s.id);
        }
    }

    #[test]
    fn coldstart_scales_with_weights() {
        let small = TFS.coldstart_s(10_000_000);
        let large = TFS.coldstart_s(1_400_000_000); // BERT-Large f32
        assert!(large > small + 2.0);
    }

    #[test]
    fn web_frameworks_have_no_dynamic_batching() {
        assert_eq!(ONNX_FASTAPI.dynamic_batching, DynamicBatching::None);
        assert_eq!(TORCHSCRIPT_FASTAPI.dynamic_batching, DynamicBatching::None);
        assert_ne!(TFS.dynamic_batching, DynamicBatching::None);
        assert_eq!(TRIS.dynamic_batching, DynamicBatching::Optimized);
    }
}
