//! Batching policies (paper §2.3 batch manager, §5.3 dynamic batching).
//!
//! Pure decision logic, independent of the clock that drives it (the DES
//! and the live engine both use it): requests enter a queue; the policy
//! decides when a batch leaves and how large it is.
//!
//! Hot-path shape (see PERF.md): a dispatch moves requests into an
//! internal buffer that is reused across batches — [`Decision::Dispatch`]
//! carries only the count and the caller reads the formed batch via
//! [`Batcher::ready`] — so the decide/dispatch cycle allocates nothing at
//! steady state. The oldest-queued deadline is tracked incrementally
//! instead of re-scanned per decision.

/// Batch formation policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Every request served alone (batch size 1).
    Single,
    /// Fixed batch: wait until exactly `size` requests are queued
    /// (with a safety timeout so the tail of a run still drains).
    Fixed { size: usize, timeout_s: f64 },
    /// Dynamic batching: dispatch when `max_size` queued, or when the
    /// oldest queued request has waited `max_wait_s`.
    Dynamic { max_size: usize, max_wait_s: f64 },
}

/// A queued request the batcher tracks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Queued {
    pub id: u64,
    pub enqueue_s: f64,
}

/// What the batcher wants done next.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Nothing to do until another arrival.
    Wait,
    /// Wake the batcher at this time (timeout-based dispatch).
    WakeAt(f64),
    /// This many requests formed a batch and left the queue; read them
    /// with [`Batcher::ready`] (valid until the next dispatch).
    Dispatch(usize),
}

/// Queue + policy state machine.
#[derive(Debug, Clone)]
pub struct Batcher {
    policy: Policy,
    queue: Vec<Queued>,
    /// The most recently dispatched batch (FIFO order). Reused across
    /// dispatches: the hot loop never allocates per batch.
    ready: Vec<Queued>,
    /// Earliest enqueue time currently queued (`INFINITY` when empty);
    /// maintained incrementally so decisions don't re-scan the queue.
    oldest_s: f64,
}

impl Batcher {
    pub fn new(policy: Policy) -> Self {
        Batcher { policy, queue: Vec::new(), ready: Vec::new(), oldest_s: f64::INFINITY }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Max requests a formed batch may contain under this policy.
    pub fn max_batch(&self) -> usize {
        match self.policy {
            Policy::Single => 1,
            Policy::Fixed { size, .. } => size,
            Policy::Dynamic { max_size, .. } => max_size,
        }
    }

    /// The batch formed by the most recent [`Decision::Dispatch`], oldest
    /// request first. Overwritten by the next dispatch.
    pub fn ready(&self) -> &[Queued] {
        &self.ready
    }

    /// A request arrives at `now`; returns the action to take.
    pub fn on_arrival(&mut self, id: u64, now: f64) -> Decision {
        self.enqueue(id, now);
        self.decide(now)
    }

    /// Queue a request without deciding (used by the simulator while the
    /// server is busy; it polls when the server frees).
    pub fn enqueue(&mut self, id: u64, now: f64) {
        self.queue.push(Queued { id, enqueue_s: now });
        self.oldest_s = self.oldest_s.min(now);
    }

    /// Re-evaluate the queue at `now` without a new arrival.
    pub fn poll(&mut self, now: f64) -> Decision {
        self.decide(now)
    }

    /// A previously requested wake-up fired at `now`.
    ///
    /// The wake may be stale: it was scheduled for a batch that has since
    /// dispatched (it filled up, or a server-free poll flushed it), and the
    /// queue now holds younger requests whose deadline has not expired.
    /// Flushing unconditionally here dispatched those partial batches early
    /// (the stale-wake bug), so the decision is re-derived from the current
    /// queue: dispatch only if the oldest queued request's deadline has
    /// actually passed, otherwise hand back the corrected wake time.
    pub fn on_wake(&mut self, now: f64) -> Decision {
        self.decide(now)
    }

    /// The server became free at `now` — opportunity to dispatch more.
    pub fn on_server_free(&mut self, now: f64) -> Decision {
        self.decide(now)
    }

    /// Remove and return every queued request (model-eviction / teardown
    /// path: the queue's owner is disappearing, and the caller must
    /// account for each drained request). Resets the tracked oldest
    /// deadline; the ready buffer (an already-dispatched batch) is
    /// untouched.
    pub fn take_queue(&mut self) -> Vec<Queued> {
        self.oldest_s = f64::INFINITY;
        std::mem::take(&mut self.queue)
    }

    fn decide(&mut self, now: f64) -> Decision {
        if self.queue.is_empty() {
            return Decision::Wait;
        }
        match self.policy {
            Policy::Single => self.dispatch_up_to(1),
            Policy::Fixed { size, timeout_s } => {
                if self.queue.len() >= size {
                    self.dispatch_up_to(size)
                } else {
                    self.deadline_or_dispatch(self.oldest_s + timeout_s, now, size)
                }
            }
            Policy::Dynamic { max_size, max_wait_s } => {
                if self.queue.len() >= max_size {
                    self.dispatch_up_to(max_size)
                } else {
                    self.deadline_or_dispatch(self.oldest_s + max_wait_s, now, max_size)
                }
            }
        }
    }

    /// If the oldest request's deadline has already passed (e.g. a late
    /// arrival while the server was busy), dispatch immediately — a
    /// WakeAt in the past would make a time-ordered driver go backwards.
    fn deadline_or_dispatch(&mut self, deadline: f64, now: f64, max: usize) -> Decision {
        if deadline <= now {
            self.dispatch_up_to(max)
        } else {
            Decision::WakeAt(deadline)
        }
    }

    fn dispatch_up_to(&mut self, n: usize) -> Decision {
        let n = n.min(self.queue.len());
        // FIFO: oldest requests leave first. The sort is stable and the
        // queue is already in enqueue order for a time-ordered driver, so
        // this is a single presorted pass in the common case.
        self.queue.sort_by(|a, b| a.enqueue_s.partial_cmp(&b.enqueue_s).expect("NaN enqueue time"));
        self.ready.clear();
        self.ready.extend(self.queue.drain(..n));
        // The remainder is sorted, so its head is the new oldest.
        self.oldest_s = self.queue.first().map_or(f64::INFINITY, |q| q.enqueue_s);
        Decision::Dispatch(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Dispatch helper: assert the decision dispatched and return the batch.
    fn dispatched(b: &Batcher, d: Decision) -> Vec<Queued> {
        match d {
            Decision::Dispatch(n) => {
                assert_eq!(n, b.ready().len());
                b.ready().to_vec()
            }
            d => panic!("expected dispatch, got {d:?}"),
        }
    }

    #[test]
    fn single_dispatches_immediately() {
        let mut b = Batcher::new(Policy::Single);
        let d = b.on_arrival(1, 0.0);
        assert_eq!(dispatched(&b, d).len(), 1);
        assert_eq!(b.queue_len(), 0);
    }

    #[test]
    fn fixed_waits_for_full_batch() {
        let mut b = Batcher::new(Policy::Fixed { size: 3, timeout_s: 1.0 });
        assert!(matches!(b.on_arrival(1, 0.0), Decision::WakeAt(t) if (t - 1.0).abs() < 1e-12));
        assert!(matches!(b.on_arrival(2, 0.1), Decision::WakeAt(_)));
        let d = b.on_arrival(3, 0.2);
        let batch = dispatched(&b, d);
        assert_eq!(batch.iter().map(|q| q.id).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn fixed_timeout_flushes_partial() {
        let mut b = Batcher::new(Policy::Fixed { size: 4, timeout_s: 0.5 });
        b.on_arrival(1, 0.0);
        b.on_arrival(2, 0.1);
        let d = b.on_wake(0.5);
        assert_eq!(dispatched(&b, d).len(), 2);
    }

    #[test]
    fn dynamic_dispatches_at_max_size() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 2, max_wait_s: 0.01 });
        b.on_arrival(1, 0.0);
        let d = b.on_arrival(2, 0.001);
        assert_eq!(dispatched(&b, d).len(), 2);
    }

    #[test]
    fn dynamic_wake_time_tracks_oldest() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 8, max_wait_s: 0.02 });
        match b.on_arrival(1, 1.0) {
            Decision::WakeAt(t) => assert!((t - 1.02).abs() < 1e-12),
            d => panic!("{d:?}"),
        }
        // Second arrival doesn't push the deadline later.
        match b.on_arrival(2, 1.01) {
            Decision::WakeAt(t) => assert!((t - 1.02).abs() < 1e-12),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 3, max_wait_s: 1.0 });
        b.on_arrival(10, 0.3);
        b.on_arrival(11, 0.1); // arrives out of order (racing clients)
        let d = b.on_arrival(12, 0.2);
        let batch = dispatched(&b, d);
        assert_eq!(batch.iter().map(|q| q.id).collect::<Vec<_>>(), vec![11, 12, 10]);
    }

    #[test]
    fn oldest_deadline_tracks_out_of_order_arrivals() {
        // The incrementally tracked oldest enqueue time must follow an
        // out-of-order (older) arrival, not just the first one.
        let mut b = Batcher::new(Policy::Dynamic { max_size: 8, max_wait_s: 0.02 });
        assert!(matches!(b.on_arrival(1, 1.0), Decision::WakeAt(t) if (t - 1.02).abs() < 1e-12));
        // An out-of-order older arrival pulls the deadline earlier:
        // oldest becomes 0.5, so the wake moves to 0.52, not 1.02.
        assert!(matches!(b.on_arrival(2, 0.5), Decision::WakeAt(t) if (t - 0.52).abs() < 1e-12));
        let d = b.on_wake(0.52);
        assert_eq!(dispatched(&b, d).len(), 2);
        // After the dispatch the tracked deadline resets with the queue.
        assert!(matches!(b.on_arrival(3, 2.0), Decision::WakeAt(t) if (t - 2.02).abs() < 1e-12));
    }

    #[test]
    fn stale_wake_reschedules_instead_of_flushing_young_queue() {
        // Regression: requests 1+2 form a full batch, leaving their wake
        // (scheduled for t=0.01) stale in the driver's event queue. When it
        // fires, only the younger request 3 (deadline 0.018) is queued — the
        // batcher must push the wake forward, not flush 3 early.
        let mut b = Batcher::new(Policy::Dynamic { max_size: 2, max_wait_s: 0.01 });
        assert!(matches!(b.on_arrival(1, 0.0), Decision::WakeAt(_)));
        assert!(matches!(b.on_arrival(2, 0.001), Decision::Dispatch(_)));
        b.enqueue(3, 0.008);
        match b.on_wake(0.01) {
            Decision::WakeAt(t) => assert!((t - 0.018).abs() < 1e-12, "{t}"),
            d => panic!("stale wake must not flush a young partial batch: {d:?}"),
        }
        let d = b.on_wake(0.018);
        assert_eq!(dispatched(&b, d).len(), 1);
    }

    #[test]
    fn wake_at_true_deadline_flushes_partial() {
        let mut b = Batcher::new(Policy::Fixed { size: 4, timeout_s: 0.5 });
        b.on_arrival(1, 0.0);
        b.on_arrival(2, 0.1);
        // Before the oldest deadline: reschedule; at it: flush both.
        assert!(matches!(b.on_wake(0.3), Decision::WakeAt(t) if (t - 0.5).abs() < 1e-12));
        let d = b.on_wake(0.5);
        assert_eq!(dispatched(&b, d).len(), 2);
    }

    #[test]
    fn wake_with_empty_queue_is_noop() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 4, max_wait_s: 0.1 });
        assert_eq!(b.on_wake(5.0), Decision::Wait);
    }

    #[test]
    fn server_free_drains_backlog() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 2, max_wait_s: 10.0 });
        for i in 0..5 {
            b.on_arrival(i, i as f64 * 0.001);
        }
        // 5 arrivals with max 2: two dispatches happened inline; 1 remains.
        assert_eq!(b.queue_len(), 1);
        match b.on_server_free(1.0) {
            Decision::WakeAt(_) => {}
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn never_exceeds_max_batch() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 4, max_wait_s: 100.0 });
        for i in 0..100 {
            if let Decision::Dispatch(n) = b.on_arrival(i, 0.0) {
                assert!(n <= 4);
                assert!(b.ready().len() <= 4);
            }
        }
    }

    #[test]
    fn take_queue_drains_everything_and_resets_deadline() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 8, max_wait_s: 0.02 });
        b.on_arrival(1, 1.0);
        b.on_arrival(2, 1.005);
        let drained = b.take_queue();
        assert_eq!(drained.iter().map(|q| q.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(b.queue_len(), 0);
        // The tracked oldest deadline reset with the queue: the next
        // arrival's wake derives from itself, not the drained requests.
        assert!(matches!(b.on_arrival(3, 5.0), Decision::WakeAt(t) if (t - 5.02).abs() < 1e-12));
    }

    #[test]
    fn ready_buffer_reused_across_dispatches() {
        let mut b = Batcher::new(Policy::Single);
        b.on_arrival(1, 0.0);
        assert_eq!(b.ready()[0].id, 1);
        b.on_arrival(2, 1.0);
        // Previous batch is overwritten, not appended to.
        assert_eq!(b.ready().len(), 1);
        assert_eq!(b.ready()[0].id, 2);
    }
}
