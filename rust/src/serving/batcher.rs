//! Batching policies (paper §2.3 batch manager, §5.3 dynamic batching).
//!
//! Pure decision logic, independent of the clock that drives it (the DES
//! and the live engine both use it): requests enter a queue; the policy
//! decides when a batch leaves and how large it is.

/// Batch formation policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Every request served alone (batch size 1).
    Single,
    /// Fixed batch: wait until exactly `size` requests are queued
    /// (with a safety timeout so the tail of a run still drains).
    Fixed { size: usize, timeout_s: f64 },
    /// Dynamic batching: dispatch when `max_size` queued, or when the
    /// oldest queued request has waited `max_wait_s`.
    Dynamic { max_size: usize, max_wait_s: f64 },
}

/// A queued request the batcher tracks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Queued {
    pub id: u64,
    pub enqueue_s: f64,
}

/// What the batcher wants done next.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    /// Nothing to do until another arrival.
    Wait,
    /// Wake the batcher at this time (timeout-based dispatch).
    WakeAt(f64),
    /// Dispatch these requests as one batch now.
    Dispatch(Vec<Queued>),
}

/// Queue + policy state machine.
#[derive(Debug, Clone)]
pub struct Batcher {
    policy: Policy,
    queue: Vec<Queued>,
}

impl Batcher {
    pub fn new(policy: Policy) -> Self {
        Batcher { policy, queue: Vec::new() }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Max requests a formed batch may contain under this policy.
    pub fn max_batch(&self) -> usize {
        match self.policy {
            Policy::Single => 1,
            Policy::Fixed { size, .. } => size,
            Policy::Dynamic { max_size, .. } => max_size,
        }
    }

    /// A request arrives at `now`; returns the action to take.
    pub fn on_arrival(&mut self, id: u64, now: f64) -> Decision {
        self.enqueue(id, now);
        self.decide(now)
    }

    /// Queue a request without deciding (used by the simulator while the
    /// server is busy; it polls when the server frees).
    pub fn enqueue(&mut self, id: u64, now: f64) {
        self.queue.push(Queued { id, enqueue_s: now });
    }

    /// Re-evaluate the queue at `now` without a new arrival.
    pub fn poll(&mut self, now: f64) -> Decision {
        self.decide(now)
    }

    /// A previously requested wake-up fired at `now`.
    ///
    /// The wake may be stale: it was scheduled for a batch that has since
    /// dispatched (it filled up, or a server-free poll flushed it), and the
    /// queue now holds younger requests whose deadline has not expired.
    /// Flushing unconditionally here dispatched those partial batches early
    /// (the stale-wake bug), so the decision is re-derived from the current
    /// queue: dispatch only if the oldest queued request's deadline has
    /// actually passed, otherwise hand back the corrected wake time.
    pub fn on_wake(&mut self, now: f64) -> Decision {
        self.decide(now)
    }

    /// The server became free at `now` — opportunity to dispatch more.
    pub fn on_server_free(&mut self, now: f64) -> Decision {
        self.decide(now)
    }

    fn decide(&mut self, now: f64) -> Decision {
        if self.queue.is_empty() {
            return Decision::Wait;
        }
        match self.policy {
            Policy::Single => self.dispatch_up_to(1),
            Policy::Fixed { size, timeout_s } => {
                if self.queue.len() >= size {
                    self.dispatch_up_to(size)
                } else {
                    self.deadline_or_dispatch(self.oldest() + timeout_s, now, size)
                }
            }
            Policy::Dynamic { max_size, max_wait_s } => {
                if self.queue.len() >= max_size {
                    self.dispatch_up_to(max_size)
                } else {
                    self.deadline_or_dispatch(self.oldest() + max_wait_s, now, max_size)
                }
            }
        }
    }

    /// If the oldest request's deadline has already passed (e.g. a late
    /// arrival while the server was busy), dispatch immediately — a
    /// WakeAt in the past would make a time-ordered driver go backwards.
    fn deadline_or_dispatch(&mut self, deadline: f64, now: f64, max: usize) -> Decision {
        if deadline <= now {
            self.dispatch_up_to(max)
        } else {
            Decision::WakeAt(deadline)
        }
    }

    fn oldest(&self) -> f64 {
        self.queue.iter().map(|q| q.enqueue_s).fold(f64::INFINITY, f64::min)
    }

    fn dispatch_up_to(&mut self, n: usize) -> Decision {
        let n = n.min(self.queue.len());
        // FIFO: oldest requests leave first. (A skip-sort-if-already-
        // sorted fast path was tried and measured slower — §Perf.)
        self.queue.sort_by(|a, b| a.enqueue_s.partial_cmp(&b.enqueue_s).unwrap());
        let batch: Vec<Queued> = self.queue.drain(..n).collect();
        Decision::Dispatch(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_dispatches_immediately() {
        let mut b = Batcher::new(Policy::Single);
        match b.on_arrival(1, 0.0) {
            Decision::Dispatch(batch) => assert_eq!(batch.len(), 1),
            d => panic!("{d:?}"),
        }
        assert_eq!(b.queue_len(), 0);
    }

    #[test]
    fn fixed_waits_for_full_batch() {
        let mut b = Batcher::new(Policy::Fixed { size: 3, timeout_s: 1.0 });
        assert!(matches!(b.on_arrival(1, 0.0), Decision::WakeAt(t) if (t - 1.0).abs() < 1e-12));
        assert!(matches!(b.on_arrival(2, 0.1), Decision::WakeAt(_)));
        match b.on_arrival(3, 0.2) {
            Decision::Dispatch(batch) => {
                assert_eq!(batch.iter().map(|q| q.id).collect::<Vec<_>>(), vec![1, 2, 3]);
            }
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn fixed_timeout_flushes_partial() {
        let mut b = Batcher::new(Policy::Fixed { size: 4, timeout_s: 0.5 });
        b.on_arrival(1, 0.0);
        b.on_arrival(2, 0.1);
        match b.on_wake(0.5) {
            Decision::Dispatch(batch) => assert_eq!(batch.len(), 2),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn dynamic_dispatches_at_max_size() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 2, max_wait_s: 0.01 });
        b.on_arrival(1, 0.0);
        match b.on_arrival(2, 0.001) {
            Decision::Dispatch(batch) => assert_eq!(batch.len(), 2),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn dynamic_wake_time_tracks_oldest() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 8, max_wait_s: 0.02 });
        match b.on_arrival(1, 1.0) {
            Decision::WakeAt(t) => assert!((t - 1.02).abs() < 1e-12),
            d => panic!("{d:?}"),
        }
        // Second arrival doesn't push the deadline later.
        match b.on_arrival(2, 1.01) {
            Decision::WakeAt(t) => assert!((t - 1.02).abs() < 1e-12),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 3, max_wait_s: 1.0 });
        b.on_arrival(10, 0.3);
        b.on_arrival(11, 0.1); // arrives out of order (racing clients)
        match b.on_arrival(12, 0.2) {
            Decision::Dispatch(batch) => {
                assert_eq!(batch.iter().map(|q| q.id).collect::<Vec<_>>(), vec![11, 12, 10]);
            }
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn stale_wake_reschedules_instead_of_flushing_young_queue() {
        // Regression: requests 1+2 form a full batch, leaving their wake
        // (scheduled for t=0.01) stale in the driver's event queue. When it
        // fires, only the younger request 3 (deadline 0.018) is queued — the
        // batcher must push the wake forward, not flush 3 early.
        let mut b = Batcher::new(Policy::Dynamic { max_size: 2, max_wait_s: 0.01 });
        assert!(matches!(b.on_arrival(1, 0.0), Decision::WakeAt(_)));
        assert!(matches!(b.on_arrival(2, 0.001), Decision::Dispatch(_)));
        b.enqueue(3, 0.008);
        match b.on_wake(0.01) {
            Decision::WakeAt(t) => assert!((t - 0.018).abs() < 1e-12, "{t}"),
            d => panic!("stale wake must not flush a young partial batch: {d:?}"),
        }
        match b.on_wake(0.018) {
            Decision::Dispatch(batch) => assert_eq!(batch.len(), 1),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn wake_at_true_deadline_flushes_partial() {
        let mut b = Batcher::new(Policy::Fixed { size: 4, timeout_s: 0.5 });
        b.on_arrival(1, 0.0);
        b.on_arrival(2, 0.1);
        // Before the oldest deadline: reschedule; at it: flush both.
        assert!(matches!(b.on_wake(0.3), Decision::WakeAt(t) if (t - 0.5).abs() < 1e-12));
        match b.on_wake(0.5) {
            Decision::Dispatch(batch) => assert_eq!(batch.len(), 2),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn wake_with_empty_queue_is_noop() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 4, max_wait_s: 0.1 });
        assert_eq!(b.on_wake(5.0), Decision::Wait);
    }

    #[test]
    fn server_free_drains_backlog() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 2, max_wait_s: 10.0 });
        for i in 0..5 {
            b.on_arrival(i, i as f64 * 0.001);
        }
        // 5 arrivals with max 2: two dispatches happened inline; 1 remains.
        assert_eq!(b.queue_len(), 1);
        match b.on_server_free(1.0) {
            Decision::WakeAt(_) => {}
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn never_exceeds_max_batch() {
        let mut b = Batcher::new(Policy::Dynamic { max_size: 4, max_wait_s: 100.0 });
        for i in 0..100 {
            if let Decision::Dispatch(batch) = b.on_arrival(i, 0.0) {
                assert!(batch.len() <= 4);
            }
        }
    }
}
