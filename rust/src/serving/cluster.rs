//! Discrete-event simulation of an N-replica serving cluster: a routing
//! tier in front of N independent accelerator+software replicas, each
//! owning its own [`Batcher`] and [`ServiceModel`] (heterogeneous replicas
//! allowed — mixed hardware generations are the common production case).
//!
//! Request flow per Fig 4, generalized: arrivals -> pre-process ->
//! transmission -> **router** -> per-replica batch queue -> inference ->
//! post-process. The single-server engine (`sim::run`) is the N=1 special
//! case and delegates here, so every policy/overhead behaviour the
//! software-tier figures measure carries over replica-for-replica.
//!
//! With an [`AutoscaleConfig`] the fleet is elastic: a [`ScalePolicy`] is
//! evaluated on a fixed interval; scale-up appends a replica that pays
//! [`Software::coldstart_s`] before it becomes routable (the paper's
//! ">10 s even for a small IC model" spike-response problem), and
//! scale-down drains-on-remove — the chosen replica stops receiving
//! traffic, finishes queued + in-flight work, then retires — so
//! `issued == completed + dropped` holds exactly across scale events.
//! With [`ClusterConfig::cold_start`] the *initial* fleet starts cold too;
//! requests that arrive before any replica is routable are **held at the
//! routing tier** (FIFO) and flushed to the router the instant the first
//! replica becomes ready — never handed to the router as an empty
//! candidate set.
//!
//! Hot-path structure (see PERF.md): the request lifecycle is
//! allocation-free at steady state — traces live in a [`TraceStore`] slab,
//! batches are read out of the batcher's reusable buffer, completions
//! drain `in_flight` in place, and the router's inputs (per-replica
//! outstanding counts + the sorted routable-candidate list) are maintained
//! incrementally on state transitions instead of being rebuilt per
//! request.
//!
//! Metrics: each replica records its own [`ReplicaMetrics`]; the
//! cluster-level [`Collector`] is fed the same traces at completion time
//! (plus routing-tier rejections, which belong to no replica), so it is
//! the exact union of everything the run observed. The [`ScaleTimeline`]
//! records every replica-lifecycle transition.
//!
//! Ingress tier: the pre-batching front door — held-request parking,
//! flush-on-ready, drop accounting, and (when [`ClusterConfig::admission`]
//! is set) per-tenant token buckets, weighted-fair queueing, and
//! priority-class shedding — lives in `serving::ingress`, shared with the
//! multi-model engine. With `admission: None` the FIFO path performs
//! exactly the pre-ingress operations (golden bit-identity); with an
//! [`AdmissionConfig`] the workload must be [`Workload::Streams`] so each
//! arrival carries its tenant, and every request stages admit → hold
//! (WFQ) → route → batch, with per-class ledgers in
//! [`ClusterResult::classes`].
//!
//! Streaming workloads: the engine pulls arrivals lazily from
//! [`Workload::source`] — an arrival is injected into the event heap only
//! once simulated time reaches it — so a run over 10⁸ requests holds
//! O(in-flight) traces, not O(horizon). Bit-identity with the old
//! materialize-then-simulate engine is preserved by (a) splitting the
//! seeded RNG into an issue-phase generator (arrival pipeline draws, in
//! arrival order) and a loop-phase clone fast-forwarded past the
//! `RequestPath::RNG_STEPS_PER_SAMPLE × N` issue draws via
//! [`Pcg64::advance`], and (b) partitioning event-sequence tie-breakers by
//! scheduling phase (see `serving::des`). With
//! [`MetricsMode::Sketch`], latency summaries drop to bounded-memory
//! quantile sketches and the whole run is flat-RSS in the request count.

use super::autoscale::{Autoscaler, ScaleDecision, ScaleSignal};
use super::backends::{DynamicBatching, Software};
use super::batcher::{Batcher, Decision, Policy};
use super::des::{self, push, EventBox, Key};
use super::faults::{FaultKind, FaultPlan, ScheduledFault};
use super::ingress::{self, class_ingest, Admission, HeldQueue, RetryPolicy};
use super::router::{Router, RouterPolicy};
use super::service::ServiceModel;
use crate::metrics::{
    ClassMetrics, Collector, DropReason, MetricsMode, ReplicaMetrics, RequestTrace,
    ScaleEventKind, ScaleTimeline, Stage, TraceStore,
};
use crate::obs::{Attr, TraceConfig, TraceOutput, TraceRecorder};
use crate::pipeline::RequestPath;
use crate::util::rng::Pcg64;
use crate::workload::{MergedSource, Pattern, SourceIter, Workload};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

pub use super::autoscale::AutoscaleConfig;
pub use super::ingress::{AdmissionConfig, TenantSpec};

// The parallel sweep engine (`crate::sweep`) moves cell configs into
// scoped worker threads and their results back out. Keep both types
// transferable: a field that is not `Send`/`Sync` (an `Rc`, a raw
// pointer, a non-atomic shared cache) would silently serialize every
// sweep, so the requirement is pinned at compile time here, next to the
// type definitions.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    const fn assert_send<T: Send>() {}
    assert_send_sync::<ClusterConfig>();
    assert_send::<ClusterResult>();
};

/// Closed-loop client retry delay after a queue rejection: the client
/// observes the rejection and re-issues. A strictly positive backoff also
/// guarantees event-time progress for degenerate zero-latency request
/// paths (otherwise reissue + re-reject could loop at one instant).
pub const REJECT_RETRY_BACKOFF_S: f64 = 1e-4;

/// One replica's static configuration. Replicas may differ in software,
/// service model, batching policy, and queue capacity.
#[derive(Debug, Clone)]
pub struct ReplicaConfig {
    pub software: &'static Software,
    pub service: ServiceModel,
    pub policy: Policy,
    /// Replica-local queue capacity; arrivals routed here beyond it are
    /// rejected (overload).
    pub max_queue: usize,
}

/// Cluster simulation configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// What drives the run: a pre-materialized arrival list, a streaming
    /// pattern (never materialized — O(1) generator memory), or a closed
    /// loop of clients, each issuing its next request when the previous
    /// completes — or is rejected (see [`REJECT_RETRY_BACKOFF_S`]).
    pub workload: Workload,
    /// Simulated duration; no new requests issued past this.
    pub duration_s: f64,
    /// The initial fleet (routable at t = 0 unless `cold_start` is set).
    pub replicas: Vec<ReplicaConfig>,
    pub router: RouterPolicy,
    /// Elastic-fleet policy; `None` keeps the fleet fixed.
    pub autoscale: Option<AutoscaleConfig>,
    /// Start the initial fleet cold: each replica pays its software's
    /// cold start for this weight footprint (bytes) before it becomes
    /// routable. Requests arriving before the first replica is ready are
    /// held at the routing tier. `None` starts the fleet warm.
    pub cold_start: Option<u64>,
    pub path: RequestPath,
    /// Latency-metric backend: [`MetricsMode::Exact`] keeps every sample
    /// (bit-identical to the historical collector); [`MetricsMode::Sketch`]
    /// bounds metric memory for horizon-scale runs. Simulation behaviour
    /// (routing, batching, drops, event count) is identical in both modes.
    pub metrics: MetricsMode,
    /// Per-tenant admission tier (token buckets + WFQ + priority-class
    /// shedding; see `serving::ingress`). Requires a
    /// [`Workload::Streams`] workload so each arrival carries its tenant;
    /// the spec is validated loudly against the stream count. `None`
    /// disables the tier entirely — the request path is then bit-identical
    /// to the pre-ingress engine.
    pub admission: Option<AdmissionConfig>,
    /// Deterministic fault injection: scripted and/or seeded-random
    /// replica crashes, recoveries-through-cold-start, and straggler
    /// slowdowns (see `serving::faults`). Only the initial fleet is a
    /// fault target. `None` — or a plan with nothing to inject — keeps
    /// the run bit-identical to the pre-fault engine (the schedule draws
    /// from its own PCG streams, so it cannot move workload or routing
    /// draws either way).
    pub faults: Option<FaultPlan>,
    /// Retry policy for requests stranded on a crashed replica: they
    /// re-enter the ingress tier after a deterministic exponential
    /// backoff instead of dying. `None` means fail-and-drop
    /// ([`DropReason::ReplicaFailed`]).
    pub retry: Option<RetryPolicy>,
    pub seed: u64,
}

/// Cluster simulation output.
#[derive(Debug)]
pub struct ClusterResult {
    /// Cluster-level collector: the exact union of every request the run
    /// observed — per-replica completions and rejections, plus requests
    /// rejected at the routing tier (which belong to no replica).
    pub collector: Collector,
    /// Per-replica metrics. The first `ClusterConfig::replicas.len()`
    /// entries are the initial fleet; replicas added by the autoscaler
    /// append after them in add order (indices are stable for the run).
    pub replicas: Vec<ReplicaMetrics>,
    /// Every replica-lifecycle transition (empty without an autoscaler or
    /// cold start).
    pub scale: ScaleTimeline,
    /// Requests rejected across all replica queues and the routing tier.
    /// `collector.drop_breakdown()` splits this by [`DropReason`].
    pub dropped: u64,
    /// Per-class ledgers (issued / completed / dropped-by-reason +
    /// latency), indexed by priority class. Empty when
    /// [`ClusterConfig::admission`] is `None`; otherwise one entry per
    /// configured class, each individually conserved.
    pub classes: Vec<ClassMetrics>,
    /// Requests issued in total (completed + dropped == issued).
    pub issued: u64,
    /// Total replica-seconds spent in the `Failed` state within
    /// `[0, duration_s]`, summed over the fleet (recovery cold starts
    /// count as warming, like scale-up, not as downtime). Availability
    /// over the run is `1 - downtime_s / (replicas × duration_s)`.
    /// Zero without fault injection.
    pub downtime_s: f64,
    /// Discrete events processed by the simulation loop (the events/sec
    /// numerator for the `l4_des_throughput` bench).
    pub events: u64,
    /// Span trees and gauge timelines when the run was traced
    /// ([`run_traced`] with an enabled [`TraceConfig`]); `None` on the
    /// untraced path. Purely observational: present or absent, every
    /// other field of the result is bit-identical (`tests/obs.rs`).
    pub trace: Option<TraceOutput>,
}

impl ClusterResult {
    /// Completed requests per simulated second, cluster-wide.
    pub fn throughput_rps(&self) -> f64 {
        self.collector.throughput_rps()
    }

    /// Mean completed batch size across all replicas. O(replicas): uses
    /// the counters maintained at record time (exact in both metric
    /// modes), not a rescan of every batch.
    pub fn mean_batch(&self) -> f64 {
        let n: u64 = self.replicas.iter().map(|r| r.batches()).sum();
        if n == 0 {
            return 0.0;
        }
        let total: u64 = self.replicas.iter().map(|r| r.batch_sum()).sum();
        total as f64 / n as f64
    }
}

/// Effective policy/overhead after applying the software's dynamic-batching
/// quality (paper §5.3: TFS's naive scheduler hurts at low concurrency;
/// web frameworks cannot batch server-side at all).
pub(super) fn effective(policy: Policy, software: &Software) -> (Policy, f64) {
    match (policy, software.dynamic_batching) {
        (Policy::Dynamic { .. }, DynamicBatching::None) => (Policy::Single, 0.0),
        (
            Policy::Dynamic { max_size, max_wait_s },
            DynamicBatching::Naive { penalty_s, effective_cap },
        ) => (Policy::Dynamic { max_size: max_size.min(effective_cap), max_wait_s }, penalty_s),
        (p, _) => (p, 0.0),
    }
}

/// Replica lifecycle under autoscaling. A fixed fleet is always `Active`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReplicaState {
    /// Paying its cold start; not routable yet.
    Warming,
    /// Routable.
    Active,
    /// Drain-on-remove in progress: not routable, finishing its backlog.
    Draining,
    /// Drained and gone; receives no further events.
    Retired,
    /// Crashed by fault injection: not routable, backlog killed. Leaves
    /// this state only through a scheduled `Recover` (→ `Warming`).
    Failed,
}

/// One replica's live state during the run.
struct Replica {
    batcher: Batcher,
    penalty_s: f64,
    software: &'static Software,
    service: ServiceModel,
    max_queue: usize,
    state: ReplicaState,
    busy: bool,
    queued: usize,
    in_flight: Vec<(u32, f64, f64)>, // (trace slot, service start, enqueue time)
    /// Busy seconds accrued since the last autoscaler evaluation (batches
    /// are charged at dispatch; one spanning an evaluation boundary counts
    /// toward the interval it started in).
    busy_s_since_eval: f64,
    /// Incarnation counter, bumped at every crash: in-heap `ServerFree`/
    /// `ReplicaReady` events carry the epoch they were scheduled under
    /// and are ignored if the replica crashed in between (the batch they
    /// announce died with the process).
    epoch: u32,
    /// Straggler service-time multiplier (1.0 = healthy). Applied at
    /// batch start; a fault-free run never reads a value other than 1.0.
    slowdown: f64,
    /// When the current `Failed` interval began (downtime accounting).
    failed_at: f64,
    metrics: ReplicaMetrics,
}

impl Replica {
    fn new(rc: &ReplicaConfig, state: ReplicaState, horizon_s: f64, mode: MetricsMode) -> Replica {
        let (policy, penalty_s) = effective(rc.policy, rc.software);
        Replica {
            batcher: Batcher::new(policy),
            penalty_s,
            software: rc.software,
            service: rc.service.clone(),
            max_queue: rc.max_queue,
            state,
            busy: false,
            queued: 0,
            in_flight: Vec::new(),
            busy_s_since_eval: 0.0,
            epoch: 0,
            slowdown: 1.0,
            failed_at: 0.0,
            metrics: ReplicaMetrics::with_mode(horizon_s, 0.5, mode),
        }
    }

    /// Requests this replica is responsible for right now (the router's
    /// load signal): queued + in service.
    fn outstanding(&self) -> usize {
        self.queued + self.in_flight.len()
    }
}

#[derive(Debug, PartialEq)]
enum Event {
    /// Request reaches the routing tier (pre-processing + transmission
    /// done). Carries the trace's slot in the [`TraceStore`].
    Enqueue { slot: u32 },
    /// Batcher timeout on one replica.
    Wake { replica: usize, scheduled_for: f64 },
    /// One replica finishes its in-flight batch. `epoch` is the
    /// replica's incarnation at scheduling time; a crash in between
    /// makes the event stale (the batch died with the process).
    ServerFree { replica: usize, epoch: u32 },
    /// A warming replica finished its cold start and becomes routable.
    /// Stale (crashed-mid-warm-up) readiness is filtered by `epoch`.
    ReplicaReady { replica: usize, epoch: u32 },
    /// Periodic autoscaler evaluation.
    ScaleEval,
    /// Entry `fault` of the materialized fault schedule fires.
    Fault { fault: usize },
    /// A crash-stranded request re-enters the ingress tier after its
    /// retry backoff.
    Retry { slot: u32 },
}

/// Time-then-sequence event heap, shared with the multi-model engine
/// (see `serving::des` for the determinism contract of the ordering).
type Heap = des::Heap<Event>;

/// Insert `ri` into the ascending candidate list (no-op if present).
/// Shared with the multi-model engine, which keeps one such list per
/// model.
pub(super) fn insert_routable(routable: &mut Vec<usize>, ri: usize) {
    if let Err(pos) = routable.binary_search(&ri) {
        routable.insert(pos, ri);
    }
}

/// Remove `ri` from the ascending candidate list (no-op if absent).
pub(super) fn remove_routable(routable: &mut Vec<usize>, ri: usize) {
    if let Ok(pos) = routable.binary_search(&ri) {
        routable.remove(pos);
    }
}

/// Start the batch just formed by `r.batcher` (read via
/// [`Batcher::ready`]): record waits, occupy the replica.
fn start_batch(
    ri: usize,
    r: &mut Replica,
    now: f64,
    heap: &mut Heap,
    seq: &mut u64,
    tr: &mut TraceRecorder,
    traces: &mut TraceStore,
) {
    let batch = r.batcher.ready();
    let b = batch.len();
    r.queued -= b;
    let mut service = r.service.service_s(b, r.software) + r.penalty_s;
    if r.slowdown != 1.0 {
        // Straggler window (fault injection): the arithmetic is gated so
        // the fault-free path performs the exact historical operations.
        service *= r.slowdown;
    }
    let util = r.service.utilization(b);
    r.metrics.timeline.record_busy(now, service, util);
    r.metrics.busy_timeline.record_busy(now, service, 1.0);
    r.metrics.record_batch(b);
    r.busy_s_since_eval += service;
    for q in batch {
        let trace = traces.get_mut(q.id as u32);
        // Batching stage: enqueue -> service start.
        trace.record_stage(Stage::Batching, now - q.enqueue_s);
        tr.phase(q.id as usize, "service", now);
        if tr.full_detail() && tr.is_traced(q.id as usize) {
            tr.phase_attr(q.id as usize, "replica", Attr::U(ri as u64));
            tr.phase_attr(q.id as usize, "batch_size", Attr::U(b as u64));
        }
        r.in_flight.push((q.id as u32, now, q.enqueue_s));
    }
    r.busy = true;
    push(heap, now + service, Event::ServerFree { replica: ri, epoch: r.epoch }, seq);
}

fn count_state(replicas: &[Replica], state: ReplicaState) -> usize {
    replicas.iter().filter(|r| r.state == state).count()
}

/// True when capacity is on the way: a replica is warming, or a crashed
/// replica has a recovery left in the fault schedule. Requests held at
/// the routing tier wait for it; otherwise the backlog can never drain
/// and is rejected. (`upcoming_recovers` covers the initial fleet only —
/// autoscaled replicas are never fault targets.)
fn capacity_pending(replicas: &[Replica], upcoming_recovers: &[u32]) -> bool {
    replicas.iter().enumerate().any(|(i, r)| {
        r.state == ReplicaState::Warming
            || (r.state == ReplicaState::Failed
                && upcoming_recovers.get(i).copied().unwrap_or(0) > 0)
    })
}

/// Hedge/retry roles, kept in a slot-indexed side table ([`RetrySide`]).
const PRIMARY: u8 = 0;
/// A hedged shadow copy: pure extra load, invisible to every ledger
/// until it wins the race (then it completes *as* the request).
const GHOST: u8 = 1;
/// The losing copy of a decided race: drained silently on completion.
const ORPHAN: u8 = 2;
const NO_LINK: u32 = u32::MAX;

/// Retry/hedge side tables, indexed by trace slot. Slots are reused, so
/// every entry is rewritten when its slot is re-issued. All-empty (and
/// never grown) when the engine runs without a retry policy.
struct RetrySide {
    on: bool,
    /// Attempts started for the request in this slot (1 = original issue).
    attempts: Vec<u32>,
    roles: Vec<u8>,
    /// Partner slot of a live hedge pair, [`NO_LINK`] otherwise.
    links: Vec<u32>,
}

impl RetrySide {
    fn new(on: bool) -> Self {
        RetrySide { on, attempts: Vec::new(), roles: Vec::new(), links: Vec::new() }
    }

    fn grow(&mut self, slot: u32) {
        let idx = slot as usize;
        if idx >= self.attempts.len() {
            self.attempts.resize(idx + 1, 0);
            self.roles.resize(idx + 1, PRIMARY);
            self.links.resize(idx + 1, NO_LINK);
        }
    }

    /// A slot was (re)issued: fresh attempt-1 primary, no partner.
    fn reset(&mut self, slot: u32) {
        if !self.on {
            return;
        }
        self.grow(slot);
        self.attempts[slot as usize] = 1;
        self.roles[slot as usize] = PRIMARY;
        self.links[slot as usize] = NO_LINK;
    }

    fn role(&self, slot: u32) -> u8 {
        if !self.on {
            return PRIMARY;
        }
        self.roles[slot as usize]
    }

    /// A copy completed or died: if its partner is still live, detach it
    /// (and orphan it when `orphan` — the race is decided).
    fn detach_partner(&mut self, slot: u32, orphan: bool) {
        if !self.on {
            return;
        }
        let p = self.links[slot as usize];
        if p != NO_LINK {
            if orphan {
                self.roles[p as usize] = ORPHAN;
            }
            self.links[p as usize] = NO_LINK;
            self.links[slot as usize] = NO_LINK;
        }
    }

    /// Stage `gslot` as the hedged shadow of `primary`.
    fn make_ghost(&mut self, gslot: u32, primary: u32) {
        self.grow(gslot);
        self.roles[gslot as usize] = GHOST;
        self.attempts[gslot as usize] = 0;
        self.links[gslot as usize] = primary;
        self.links[primary as usize] = gslot;
    }

    /// The primary died on a crashed replica but its shadow is alive:
    /// the shadow becomes the request (keeping the attempt count).
    fn promote(&mut self, gslot: u32, attempts: u32) {
        self.roles[gslot as usize] = PRIMARY;
        self.attempts[gslot as usize] = attempts;
        self.links[gslot as usize] = NO_LINK;
    }
}

/// Lazy arrival feed: the tenant-blind [`SourceIter`] for untagged
/// workloads, or the tagged [`MergedSource`] when the admission tier
/// needs each arrival's tenant. Both yield identical `(time, id)`
/// sequences for the same `Workload::Streams` (the `SourceIter::Merged`
/// arm is the same merge with the tag projected away), so enabling
/// admission never moves an arrival.
enum Feed<'a> {
    Plain(SourceIter<'a>),
    Tagged(MergedSource),
}

impl Feed<'_> {
    fn next(&mut self) -> Option<(f64, u32)> {
        match self {
            Feed::Plain(s) => s.next().map(|a| (a.time_s, 0)),
            Feed::Tagged(m) => m.next().map(|a| (a.time_s, a.stream as u32)),
        }
    }
}

/// Release WFQ-held requests while capacity exists (admission-enabled
/// path only). Called at the end of every event that can change the
/// routable set or free queue space. Stops on backpressure — the routed
/// replica's queue is full — rather than dropping: with an admission
/// tier, overload is shed at admission (by class), not at replica
/// queues. If nothing is routable and nothing is warming, the backlog
/// can never drain and is rejected as [`DropReason::RejectedPlacement`].
#[allow(clippy::too_many_arguments)]
fn drain_held(
    now: f64,
    held: &mut HeldQueue,
    admission: &mut Admission,
    router: &mut Router,
    routable: &[usize],
    outstanding: &mut [usize],
    replicas: &mut [Replica],
    upcoming_recovers: &[u32],
    traces: &mut TraceStore,
    collector: &mut Collector,
    classes: &mut [ClassMetrics],
    heap: &mut Heap,
    seq: &mut u64,
    tr: &mut TraceRecorder,
) {
    while !held.is_empty() {
        if routable.is_empty() {
            if capacity_pending(replicas, upcoming_recovers) {
                return; // capacity is on the way; keep holding
            }
            while let Some((slot, _tenant)) = held.pop_wfq(admission) {
                tr.terminal(slot as usize, now, DropReason::RejectedPlacement.label());
                let mut trace = traces.remove(slot);
                ingress::drop_trace(&mut trace, DropReason::RejectedPlacement, [&mut *collector]);
                class_ingest(classes, &trace);
            }
            return;
        }
        let ri = router.route_among(now, routable, outstanding);
        if replicas[ri].queued >= replicas[ri].max_queue {
            return; // backpressure: hold until the queue frees up
        }
        let Some((slot, _tenant)) = held.pop_wfq(admission) else { return };
        if tr.is_traced(slot as usize) {
            tr.event(slot as usize, "route", now, vec![("replica", Attr::U(ri as u64))]);
        }
        tr.phase(slot as usize, "batch_wait", now);
        let r = &mut replicas[ri];
        let d = ingress::stage_into_batcher(traces.get_mut(slot), &mut r.batcher, slot, now, r.busy);
        r.queued += 1;
        outstanding[ri] += 1;
        match d {
            Decision::Dispatch(_) => start_batch(ri, &mut replicas[ri], now, heap, seq, tr, traces),
            Decision::WakeAt(t) => push(heap, t, Event::Wake { replica: ri, scheduled_for: t }, seq),
            Decision::Wait => {}
        }
    }
}

/// Run the cluster simulation (untraced — the historical entry point).
pub fn run(config: &ClusterConfig) -> ClusterResult {
    run_traced(config, &TraceConfig::off())
}

/// Run the cluster simulation with tracing/telemetry. With
/// [`TraceConfig::off()`] this IS [`run`] — every hook early-returns on
/// a boolean — and with tracing enabled the hooks are purely passive
/// (they read state at existing decision points, never push events and
/// never draw randomness), so the simulation outcome is bit-identical
/// either way.
pub fn run_traced(config: &ClusterConfig, tcfg: &TraceConfig) -> ClusterResult {
    assert!(!config.replicas.is_empty(), "cluster needs at least one replica");
    let mut tr = TraceRecorder::new(tcfg);
    let mut gauges = tcfg.gauge_recorder();
    let closed_loop = config.workload.closed_loop_clients();
    if let Some(streams) = config.workload.stream_specs() {
        for s in streams {
            assert!(
                !matches!(s.pattern, Pattern::ClosedLoop { .. }),
                "Workload::Streams cannot contain closed-loop patterns (stream {:?})",
                s.name
            );
        }
    }
    // Admission tier setup: validated loudly up front, like every other
    // config assert. Tenant i is stream i, so the workload must carry
    // tenant tags.
    if let Some(adm) = &config.admission {
        let streams = config
            .workload
            .stream_specs()
            .expect("admission control requires a tenant-tagged workload (Workload::Streams)");
        adm.validate(streams.len());
    }
    let mut admission = config.admission.as_ref().map(Admission::new);
    // Tenant -> priority class (authoritative: the AdmissionConfig);
    // empty when the tier is off.
    let class_tags: Vec<u8> =
        config.admission.as_ref().map_or(Vec::new(), |a| {
            a.tenants.iter().map(|t| t.class).collect()
        });
    let mut classes: Vec<ClassMetrics> = config.admission.as_ref().map_or(Vec::new(), |a| {
        (0..a.n_classes()).map(|c| ClassMetrics::with_mode(c as u8, config.metrics)).collect()
    });
    // Slot -> tenant side table (slots are reused; entries are rewritten
    // at issue). Only maintained when the admission tier is on.
    let mut tenant_of: Vec<u32> = Vec::new();
    // O(1)-memory counting pre-pass over the source: how many requests the
    // issue phase will draw. The loop-phase RNG is the seeded generator
    // fast-forwarded past those draws, so lazily interleaving issue-phase
    // draws with loop-phase draws reproduces the materialized engine's
    // single-sequence draw order bit for bit.
    let n_issue = config.workload.count_in(config.duration_s);
    let mut rng_issue = Pcg64::seeded(config.seed);
    let mut rng_loop = rng_issue.clone();
    rng_loop.advance(RequestPath::RNG_STEPS_PER_SAMPLE as u128 * n_issue as u128);
    let mut router = Router::new(config.router);
    let horizon_s = config.duration_s.max(1.0) * 1.5;
    let cold = config.cold_start.is_some();
    let initial_state = if cold { ReplicaState::Warming } else { ReplicaState::Active };
    let mut replicas: Vec<Replica> = config
        .replicas
        .iter()
        .map(|rc| Replica::new(rc, initial_state, horizon_s, config.metrics))
        .collect();
    let mut scaler = config.autoscale.clone().map(Autoscaler::new);
    if let Some(s) = &scaler {
        assert!(
            config.replicas.len() >= s.config().min_replicas,
            "initial fleet below min_replicas"
        );
    }
    let mut scale = ScaleTimeline::new(if cold { 0 } else { replicas.len() });

    let mut heap: Heap = BinaryHeap::new();
    // Sequence numbers partition by scheduling phase (see `serving::des`):
    // setup events from 0, arrivals from ARRIVAL_SEQ_BASE in arrival
    // order, loop-scheduled events from LOOP_SEQ_BASE — the same
    // tie-break order the materialized engine produced with one counter.
    let mut setup_seq = 0u64;
    let mut arrival_seq = des::ARRIVAL_SEQ_BASE;
    let mut seq = des::LOOP_SEQ_BASE;
    // Slab trace store: slot indices are dense and reused after
    // completion, so the lifecycle is allocation-free at steady state.
    // Live traces scale with in-flight concurrency (queued + in service +
    // inside the pre/tx pipeline window), not with the horizon, so
    // streaming runs need only a small slab regardless of request count.
    let expected = match &config.workload {
        Workload::Arrivals(v) => v.len(),
        Workload::ClosedLoop { clients } => *clients,
        Workload::Stream { .. } | Workload::Streams { .. } => 0,
    };
    let mut traces = TraceStore::with_capacity(expected.clamp(64, 1 << 16));
    let mut next_id = 0u64;
    // Cluster-level collector, fed directly at completion/rejection time —
    // the end-of-run merge that copied every raw sample is gone (§Perf,
    // PERF.md).
    let mut collector = Collector::with_mode(config.metrics);

    // Cold initial fleet: every replica schedules its readiness.
    if let Some(weight_bytes) = config.cold_start {
        for (i, rc) in config.replicas.iter().enumerate() {
            let coldstart = rc.software.coldstart_s(weight_bytes);
            push(&mut heap, coldstart, Event::ReplicaReady { replica: i, epoch: 0 }, &mut setup_seq);
        }
    }

    // Fault injection: materialize the whole plan up front (its PCG
    // streams are disjoint from every other draw in the run) and pin the
    // events' tie-break slots just past the arrival range, after the
    // initial ScaleEval slot. An empty plan pushes nothing and consumes
    // nothing — `faults: None` and `FaultPlan::none()` are byte-for-byte
    // the same run as the pre-fault engine.
    let fault_sched: Vec<ScheduledFault> = match &config.faults {
        Some(plan) if !plan.is_none() => {
            plan.schedule(config.replicas.len(), config.duration_s)
        }
        _ => Vec::new(),
    };
    for (i, f) in fault_sched.iter().enumerate() {
        des::push_at(
            &mut heap,
            f.at_s,
            Event::Fault { fault: i },
            des::ARRIVAL_SEQ_BASE + n_issue + 1 + i as u64,
        );
    }
    // Recoveries left in the schedule, per initial replica: a crashed
    // replica with one pending still counts as capacity-on-the-way for
    // requests held at the routing tier.
    let mut upcoming_recovers = vec![0u32; config.replicas.len()];
    for f in &fault_sched {
        if f.kind == FaultKind::Recover {
            upcoming_recovers[f.replica] += 1;
        }
    }
    let recovery_bytes = config.faults.as_ref().map_or(0, |p| p.recovery_bytes);
    if let Some(pol) = &config.retry {
        pol.validate();
    }
    let mut side = RetrySide::new(config.retry.is_some());
    let mut downtime_s = 0.0f64;

    // Issue one request: samples its pipeline stages and schedules Enqueue.
    // Issue-phase callers (lazy arrival injection) pass `rng_issue` +
    // `arrival_seq`; loop-phase callers (closed-loop reissues) pass
    // `rng_loop` + the loop counter. `tenant` tags the request for the
    // admission tier (always 0 when the tier is off — closed-loop
    // reissues are tenant 0 by construction, since admission and closed
    // loops cannot coexist).
    let mut issue = |arrival_s: f64,
                     tenant: u32,
                     heap: &mut Heap,
                     traces: &mut TraceStore,
                     tenant_of: &mut Vec<u32>,
                     classes: &mut [ClassMetrics],
                     side: &mut RetrySide,
                     tr: &mut TraceRecorder,
                     rng: &mut Pcg64,
                     seq: &mut u64| {
        let id = next_id;
        next_id += 1;
        let (pre, tx, _post) = config.path.sample(rng);
        let mut trace = RequestTrace::new(id, arrival_s);
        trace.record_stage(Stage::PreProcess, pre);
        trace.record_stage(Stage::Transmission, tx);
        if !classes.is_empty() {
            trace.class = class_tags[tenant as usize];
            classes[trace.class as usize].issued += 1;
        }
        let enqueue_at = trace.completed_s;
        let slot = traces.insert(trace);
        side.reset(slot);
        tr.arrival(slot as usize, id, arrival_s);
        tr.phase(slot as usize, "pre_tx", arrival_s);
        if !classes.is_empty() {
            if slot as usize >= tenant_of.len() {
                tenant_of.resize(slot as usize + 1, 0);
            }
            tenant_of[slot as usize] = tenant;
        }
        push(heap, enqueue_at, Event::Enqueue { slot }, seq);
    };

    // Lazy arrival stream: `pending` is the next arrival not yet
    // injected. With the admission tier on, the tagged merge is consumed
    // directly so each arrival keeps its tenant (same times and ids as
    // the projected `SourceIter::Merged`).
    let mut source = match (&config.workload, &admission) {
        (Workload::Streams { streams, seed }, Some(_)) => {
            Feed::Tagged(MergedSource::new(streams, config.duration_s, *seed))
        }
        _ => Feed::Plain(config.workload.source(config.duration_s)),
    };
    let mut pending = source.next();

    // First autoscaler evaluation one interval in. The materialized engine
    // scheduled this right after seeding all N arrivals, so its tie-break
    // slot is pinned just past the arrival range.
    if let Some(s) = &scaler {
        let interval = s.config().eval_interval_s;
        if interval < config.duration_s {
            des::push_at(&mut heap, interval, Event::ScaleEval, des::ARRIVAL_SEQ_BASE + n_issue);
        }
    }

    // Incremental router inputs (§Perf, PERF.md: the per-Enqueue rebuild
    // of both vectors was the top cluster hot spot): per-replica
    // outstanding counts, updated O(1) on accept/complete, and the
    // ascending routable-candidate list, updated on state transitions.
    let mut outstanding: Vec<usize> = vec![0; replicas.len()];
    let mut routable: Vec<usize> = if cold { Vec::new() } else { (0..replicas.len()).collect() };
    // Requests held at the routing tier: FIFO (flushed the instant a
    // replica becomes ready — the historical behaviour, bit-identical)
    // without admission, weighted-fair-queued with it.
    let mut held = if admission.is_some() { HeldQueue::wfq() } else { HeldQueue::fifo() };
    let mut events = 0u64;

    loop {
        // Inject every arrival due at or before the next event (all of
        // them if the heap is idle). An arrival's Enqueue fires at
        // `arrival + pre + tx >= arrival`, so injecting once simulated
        // time reaches the arrival instant is always early enough — and
        // injection order is arrival order, which keeps both the
        // issue-phase RNG draw order and the arrival-range sequence
        // numbers identical to the materialized engine's upfront loop.
        while let Some((time_s, tenant)) = pending {
            let due = match heap.peek() {
                Some(Reverse((Key(t, _), _))) => time_s <= *t,
                None => true,
            };
            if !due {
                break;
            }
            issue(
                time_s,
                tenant,
                &mut heap,
                &mut traces,
                &mut tenant_of,
                &mut classes,
                &mut side,
                &mut tr,
                &mut rng_issue,
                &mut arrival_seq,
            );
            pending = source.next();
        }
        let Some(Reverse((Key(now, _), EventBox(event)))) = heap.pop() else { break };
        events += 1;
        // Gauge sampling: engine state only changes at events, so the
        // pre-event state holds at every grid point crossed since the
        // last event. One cheap branch when gauges are off.
        if gauges.due(now) {
            let n = gauges.begin(now);
            gauges.record("heap_depth", heap.len() as f64, n);
            gauges.record("held", held.len() as f64, n);
            gauges.record("routable", routable.len() as f64, n);
            gauges.record("warming", count_state(&replicas, ReplicaState::Warming) as f64, n);
            gauges.record("draining", count_state(&replicas, ReplicaState::Draining) as f64, n);
            for (i, r) in replicas.iter().enumerate() {
                gauges.record_indexed("queued", i, r.queued as f64, n);
                gauges.record_indexed("outstanding", i, r.outstanding() as f64, n);
            }
            if let Some(adm) = &admission {
                for t in 0..adm.n_tenants() {
                    let level = adm.bucket_level(t, now);
                    if level.is_finite() {
                        gauges.record_indexed("bucket_level", t, level, n);
                    }
                }
            }
        }
        match event {
            Event::Enqueue { slot } => {
                if let Some(adm) = admission.as_mut() {
                    // Admission tier: admit (token bucket + class shed)
                    // against the live in-system count excluding this
                    // arrival, then park in the WFQ and drain what
                    // capacity allows. Closed loops cannot coexist with
                    // admission (asserted above), so no reissue here.
                    let tenant = tenant_of[slot as usize] as usize;
                    if let Some(reason) = adm.admit(now, tenant, traces.len() - 1) {
                        tr.terminal(slot as usize, now, reason.label());
                        let mut trace = traces.remove(slot);
                        ingress::drop_trace(&mut trace, reason, [&mut collector]);
                        class_ingest(&mut classes, &trace);
                    } else {
                        if tr.is_traced(slot as usize) {
                            tr.event(
                                slot as usize,
                                "admission",
                                now,
                                vec![
                                    ("verdict", Attr::S("admitted".to_string())),
                                    ("tenant", Attr::U(tenant as u64)),
                                ],
                            );
                        }
                        tr.phase(slot as usize, "held", now);
                        held.push_wfq(adm, tenant, slot);
                        drain_held(
                            now, &mut held, adm, &mut router, &routable, &mut outstanding,
                            &mut replicas, &upcoming_recovers, &mut traces, &mut collector, &mut classes,
                            &mut heap, &mut seq, &mut tr,
                        );
                    }
                    continue;
                }
                if routable.is_empty() {
                    // Empty candidate set (cold start, or every replica
                    // warming/draining at a scale boundary): never handed
                    // to the router. Hold while capacity is on the way;
                    // reject if nothing will ever become routable.
                    if capacity_pending(&replicas, &upcoming_recovers) {
                        tr.phase(slot as usize, "held", now);
                        held.push_fifo(slot);
                    } else {
                        tr.terminal(slot as usize, now, DropReason::RejectedPlacement.label());
                        let mut trace = traces.remove(slot);
                        ingress::drop_trace(
                            &mut trace,
                            DropReason::RejectedPlacement,
                            [&mut collector],
                        );
                        if closed_loop.is_some() && now < config.duration_s {
                            issue(
                                now + REJECT_RETRY_BACKOFF_S,
                                0,
                                &mut heap,
                                &mut traces,
                                &mut tenant_of,
                                &mut classes,
                                &mut side,
                                &mut tr,
                                &mut rng_loop,
                                &mut seq,
                            );
                        }
                    }
                    continue;
                }
                let ri = router.route_among(now, &routable, &outstanding);
                if replicas[ri].queued >= replicas[ri].max_queue {
                    // Overloaded replica: reject. The trace leaves the slab
                    // (no leak) and a closed-loop client re-issues after a
                    // short retry backoff instead of silently dying.
                    tr.terminal(slot as usize, now, DropReason::QueueFull.label());
                    let mut trace = traces.remove(slot);
                    ingress::drop_trace(
                        &mut trace,
                        DropReason::QueueFull,
                        [&mut replicas[ri].metrics.collector, &mut collector],
                    );
                    if closed_loop.is_some() && now < config.duration_s {
                        issue(
                            now + REJECT_RETRY_BACKOFF_S,
                            0,
                            &mut heap,
                            &mut traces,
                            &mut tenant_of,
                            &mut classes,
                            &mut side,
                            &mut tr,
                            &mut rng_loop,
                            &mut seq,
                        );
                    }
                    continue;
                }
                // Shared ingress tail: routing-tier hold time (cold-start
                // window) charged to queueing, batcher enqueue, idle poll.
                if tr.is_traced(slot as usize) {
                    tr.event(slot as usize, "route", now, vec![("replica", Attr::U(ri as u64))]);
                }
                tr.phase(slot as usize, "batch_wait", now);
                let r = &mut replicas[ri];
                let d = ingress::stage_into_batcher(
                    traces.get_mut(slot),
                    &mut r.batcher,
                    slot,
                    now,
                    r.busy,
                );
                r.queued += 1;
                outstanding[ri] += 1;
                match d {
                    Decision::Dispatch(_) => {
                        start_batch(ri, &mut replicas[ri], now, &mut heap, &mut seq, &mut tr, &mut traces)
                    }
                    Decision::WakeAt(t) => {
                        push(&mut heap, t, Event::Wake { replica: ri, scheduled_for: t }, &mut seq)
                    }
                    Decision::Wait => {}
                }
            }
            Event::Wake { replica: ri, scheduled_for } => {
                if matches!(replicas[ri].state, ReplicaState::Retired | ReplicaState::Failed)
                    || replicas[ri].busy
                    || scheduled_for < now - 1e-12
                {
                    continue; // busy replica polls again at ServerFree
                }
                match replicas[ri].batcher.on_wake(now) {
                    Decision::Dispatch(_) => {
                        start_batch(ri, &mut replicas[ri], now, &mut heap, &mut seq, &mut tr, &mut traces)
                    }
                    // Stale wake (its batch already dispatched): re-arm for
                    // the oldest queued request's true deadline.
                    Decision::WakeAt(t) => {
                        push(&mut heap, t, Event::Wake { replica: ri, scheduled_for: t }, &mut seq)
                    }
                    Decision::Wait => {}
                }
                // A dispatch freed queue slots: release backpressured holds.
                if let Some(adm) = admission.as_mut() {
                    drain_held(
                        now, &mut held, adm, &mut router, &routable, &mut outstanding,
                        &mut replicas, &upcoming_recovers, &mut traces, &mut collector, &mut classes,
                        &mut heap, &mut seq, &mut tr,
                    );
                }
            }
            Event::ServerFree { replica: ri, epoch } => {
                if epoch != replicas[ri].epoch {
                    // The batch this event announced died in a crash; the
                    // replica (if recovered) is a new incarnation.
                    continue;
                }
                replicas[ri].busy = false;
                // Complete in-flight requests in place (no drain-collect):
                // inference + request overhead + post-processing, then
                // collect on this replica and the cluster.
                let overhead = replicas[ri].software.request_overhead_s;
                let n_done = replicas[ri].in_flight.len();
                // Indexed loop (not an iterator): each body iteration needs
                // `replicas`, `traces`, and the issue closure mutably, so no
                // borrow of `in_flight` may live across it.
                #[allow(clippy::needless_range_loop)]
                for k in 0..n_done {
                    let (slot, started, enqueued) = replicas[ri].in_flight[k];
                    if side.on {
                        match side.role(slot) {
                            // The losing copy of a decided hedge race:
                            // drained silently — it was never issued, so
                            // no ledger may see it.
                            ORPHAN => {
                                tr.terminal(slot as usize, now, "hedge-lost");
                                traces.remove(slot);
                                continue;
                            }
                            // Winner of a live race (either copy): the
                            // survivor below completes as the request;
                            // its partner becomes the orphan.
                            _ => side.detach_partner(slot, true),
                        }
                        if side.roles[slot as usize] == GHOST {
                            side.roles[slot as usize] = PRIMARY;
                        }
                    }
                    let mut trace = traces.remove(slot);
                    trace.record_stage(Stage::Inference, now - started + overhead);
                    let (_, _, post) = config.path.sample(&mut rng_loop);
                    trace.record_stage(Stage::PostProcess, post);
                    tr.terminal(slot as usize, trace.completed_s, "completed");
                    // Latency-aware routing signal: replica residence time
                    // (queue wait + service + overhead), what a
                    // response-time probe at the routing tier would see.
                    router.observe(ri, now - enqueued + overhead);
                    replicas[ri].metrics.collector.ingest(&trace);
                    collector.ingest(&trace);
                    class_ingest(&mut classes, &trace);
                    // Closed loop: this client's next request enters now
                    // (and is routed fresh at its enqueue time).
                    if closed_loop.is_some() && trace.completed_s < config.duration_s {
                        issue(
                            trace.completed_s,
                            0,
                            &mut heap,
                            &mut traces,
                            &mut tenant_of,
                            &mut classes,
                            &mut side,
                            &mut tr,
                            &mut rng_loop,
                            &mut seq,
                        );
                    }
                }
                replicas[ri].in_flight.clear();
                outstanding[ri] -= n_done;
                // Drain this replica's backlog.
                match replicas[ri].batcher.poll(now) {
                    Decision::Dispatch(_) => {
                        start_batch(ri, &mut replicas[ri], now, &mut heap, &mut seq, &mut tr, &mut traces)
                    }
                    Decision::WakeAt(t) => {
                        push(&mut heap, t, Event::Wake { replica: ri, scheduled_for: t }, &mut seq)
                    }
                    Decision::Wait => {}
                }
                // Drain-on-remove completes: a draining replica with no
                // queued or in-flight work retires here, after every
                // accepted request finished (conservation holds exactly).
                if replicas[ri].state == ReplicaState::Draining
                    && !replicas[ri].busy
                    && replicas[ri].outstanding() == 0
                {
                    replicas[ri].state = ReplicaState::Retired;
                    let active = count_state(&replicas, ReplicaState::Active);
                    scale.record(now, ScaleEventKind::Retired, ri, active);
                }
                // Completions freed queue + in-flight capacity: release
                // backpressured holds.
                if let Some(adm) = admission.as_mut() {
                    drain_held(
                        now, &mut held, adm, &mut router, &routable, &mut outstanding,
                        &mut replicas, &upcoming_recovers, &mut traces, &mut collector, &mut classes,
                        &mut heap, &mut seq, &mut tr,
                    );
                }
            }
            Event::ReplicaReady { replica: ri, epoch } => {
                if epoch != replicas[ri].epoch {
                    continue; // crashed while warming; readiness is stale
                }
                debug_assert_eq!(replicas[ri].state, ReplicaState::Warming);
                replicas[ri].state = ReplicaState::Active;
                insert_routable(&mut routable, ri);
                let active = count_state(&replicas, ReplicaState::Active);
                scale.record(now, ScaleEventKind::Ready, ri, active);
                match admission.as_mut() {
                    // Flush requests held at the routing tier, in arrival
                    // order (the sequence counter keeps the FIFO exact).
                    None => {
                        for slot in held.drain_fifo() {
                            push(&mut heap, now, Event::Enqueue { slot }, &mut seq);
                        }
                    }
                    // WFQ holds release by weighted-fair order, routed
                    // directly (no event round-trip needed for fairness —
                    // the virtual clock, not the event heap, orders them).
                    Some(adm) => drain_held(
                        now, &mut held, adm, &mut router, &routable, &mut outstanding,
                        &mut replicas, &upcoming_recovers, &mut traces, &mut collector, &mut classes,
                        &mut heap, &mut seq, &mut tr,
                    ),
                }
            }
            Event::ScaleEval => {
                let Some(scaler) = scaler.as_mut() else { continue };
                let interval = scaler.config().eval_interval_s;
                let active = count_state(&replicas, ReplicaState::Active);
                let warming = count_state(&replicas, ReplicaState::Warming);
                let draining = count_state(&replicas, ReplicaState::Draining);
                // Requests held at the routing tier are demand no replica
                // has absorbed yet: they count toward outstanding work.
                let mut queued_total = held.len();
                let mut busy_total = 0.0f64;
                for r in replicas.iter_mut() {
                    if r.state == ReplicaState::Active {
                        queued_total += r.outstanding();
                        busy_total += r.busy_s_since_eval.min(interval);
                    }
                    // Busy seconds beyond this interval carry over: a batch
                    // longer than the eval interval keeps its replica
                    // reported busy across the evaluations it spans,
                    // instead of one saturated reading followed by phantom
                    // idleness (which would drain a busy replica mid-burst
                    // under the utilization policy).
                    r.busy_s_since_eval = (r.busy_s_since_eval - interval).max(0.0);
                }
                let utilization = if active == 0 {
                    0.0
                } else {
                    (busy_total / (interval * active as f64)).min(1.0)
                };
                let signal = ScaleSignal {
                    active,
                    warming,
                    draining,
                    failed: count_state(&replicas, ReplicaState::Failed),
                    outstanding: queued_total,
                    utilization,
                };
                match scaler.decide(now, signal) {
                    ScaleDecision::Add => {
                        let cfg = scaler.config();
                        let coldstart = cfg.template.software.coldstart_s(cfg.weight_bytes);
                        let ri = replicas.len();
                        replicas.push(Replica::new(
                            &cfg.template,
                            ReplicaState::Warming,
                            horizon_s,
                            config.metrics,
                        ));
                        outstanding.push(0);
                        scale.record(now, ScaleEventKind::AddRequested, ri, active);
                        push(
                            &mut heap,
                            now + coldstart,
                            Event::ReplicaReady { replica: ri, epoch: 0 },
                            &mut seq,
                        );
                    }
                    ScaleDecision::Remove => {
                        // Drain the least-loaded active replica (cheapest
                        // drain); prefer the highest index so the initial
                        // fleet survives symmetric-load scale-downs.
                        let victim = replicas
                            .iter()
                            .enumerate()
                            .filter(|(_, r)| r.state == ReplicaState::Active)
                            .min_by_key(|(i, r)| (r.outstanding(), Reverse(*i)))
                            .map(|(i, _)| i)
                            .expect("decide() returned Remove with no active replica");
                        replicas[victim].state = ReplicaState::Draining;
                        remove_routable(&mut routable, victim);
                        scale.record(now, ScaleEventKind::DrainStarted, victim, active - 1);
                        // Already idle and empty: retire on the spot.
                        if !replicas[victim].busy && replicas[victim].outstanding() == 0 {
                            replicas[victim].state = ReplicaState::Retired;
                            scale.record(now, ScaleEventKind::Retired, victim, active - 1);
                        }
                    }
                    ScaleDecision::Hold => {}
                }
                let next = now + interval;
                if next < config.duration_s {
                    push(&mut heap, next, Event::ScaleEval, &mut seq);
                }
                // A scale-down shrank the routable set: if nothing is
                // routable or warming any more, held requests must be
                // rejected now, not leaked (the slab empty-at-end assert
                // pins this).
                if let Some(adm) = admission.as_mut() {
                    drain_held(
                        now, &mut held, adm, &mut router, &routable, &mut outstanding,
                        &mut replicas, &upcoming_recovers, &mut traces, &mut collector, &mut classes,
                        &mut heap, &mut seq, &mut tr,
                    );
                }
            }
            Event::Fault { fault } => {
                let ScheduledFault { replica: ri, kind, .. } = fault_sched[fault];
                match kind {
                    FaultKind::DegradeStart { factor } => {
                        if replicas[ri].state != ReplicaState::Retired {
                            replicas[ri].slowdown = factor;
                        }
                    }
                    FaultKind::DegradeEnd => {
                        replicas[ri].slowdown = 1.0;
                    }
                    FaultKind::Recover => {
                        upcoming_recovers[ri] -= 1;
                        if replicas[ri].state == ReplicaState::Failed {
                            downtime_s += now - replicas[ri].failed_at;
                            replicas[ri].state = ReplicaState::Warming;
                            let active = count_state(&replicas, ReplicaState::Active);
                            scale.record(now, ScaleEventKind::Recovered, ri, active);
                            // Recovery pays a cold start: the plan's own
                            // footprint, or the fleet's configured one.
                            let bytes = if recovery_bytes > 0 {
                                recovery_bytes
                            } else {
                                config.cold_start.unwrap_or(0)
                            };
                            let coldstart = replicas[ri].software.coldstart_s(bytes);
                            push(
                                &mut heap,
                                now + coldstart,
                                Event::ReplicaReady { replica: ri, epoch: replicas[ri].epoch },
                                &mut seq,
                            );
                        }
                    }
                    FaultKind::Crash => {
                        if matches!(
                            replicas[ri].state,
                            ReplicaState::Retired | ReplicaState::Failed
                        ) {
                            continue; // already dead
                        }
                        // A draining replica was leaving anyway: its crash
                        // retires it for good (it never recovers).
                        let draining = replicas[ri].state == ReplicaState::Draining;
                        replicas[ri].state =
                            if draining { ReplicaState::Retired } else { ReplicaState::Failed };
                        replicas[ri].failed_at = now;
                        replicas[ri].epoch += 1; // in-heap events go stale
                        replicas[ri].busy = false;
                        replicas[ri].slowdown = 1.0; // the process restarts healthy
                        remove_routable(&mut routable, ri);
                        // Kill the backlog: queued requests in queue order,
                        // then the in-flight batch in dispatch order.
                        let mut killed: Vec<u32> = replicas[ri]
                            .batcher
                            .take_queue()
                            .iter()
                            .map(|q| q.id as u32)
                            .collect();
                        killed.extend(
                            std::mem::take(&mut replicas[ri].in_flight)
                                .iter()
                                .map(|&(slot, _, _)| slot),
                        );
                        replicas[ri].queued = 0;
                        outstanding[ri] = 0;
                        let active = count_state(&replicas, ReplicaState::Active);
                        scale.record(now, ScaleEventKind::Crashed, ri, active);
                        for slot in killed {
                            // Hedge bookkeeping first: shadow copies and
                            // decided losers vanish silently — the request
                            // itself lives or dies elsewhere.
                            match side.role(slot) {
                                ORPHAN => {
                                    tr.terminal(slot as usize, now, "hedge-lost");
                                    traces.remove(slot);
                                    continue;
                                }
                                GHOST => {
                                    tr.terminal(slot as usize, now, "hedge-lost");
                                    side.detach_partner(slot, false);
                                    traces.remove(slot);
                                    continue;
                                }
                                _ => {}
                            }
                            if side.on {
                                let g = side.links[slot as usize];
                                if g != NO_LINK {
                                    // The primary died but its hedged shadow
                                    // is alive on another replica: the shadow
                                    // becomes the request.
                                    tr.terminal(slot as usize, now, "failed-over");
                                    side.promote(g, side.attempts[slot as usize]);
                                    side.links[slot as usize] = NO_LINK;
                                    traces.remove(slot);
                                    continue;
                                }
                            }
                            // Retry or die.
                            let mut terminal = Some(DropReason::ReplicaFailed);
                            if let Some(pol) = &config.retry {
                                let made = side.attempts[slot as usize];
                                if made < pol.max_attempts {
                                    let delay = pol.delay_for(made);
                                    let deadline =
                                        traces.get_mut(slot).arrival_s + pol.deadline_s;
                                    if now + delay <= deadline {
                                        side.attempts[slot as usize] = made + 1;
                                        if tr.is_traced(slot as usize) {
                                            tr.event(
                                                slot as usize,
                                                "retry_scheduled",
                                                now,
                                                vec![
                                                    ("attempt", Attr::U(made as u64 + 1)),
                                                    ("delay_s", Attr::F(delay)),
                                                ],
                                            );
                                        }
                                        tr.phase(slot as usize, "retry_wait", now);
                                        push(&mut heap, now + delay, Event::Retry { slot }, &mut seq);
                                        terminal = None;
                                    } else {
                                        terminal = Some(DropReason::TimedOut);
                                    }
                                }
                            }
                            if let Some(reason) = terminal {
                                tr.terminal(slot as usize, now, reason.label());
                                let mut trace = traces.remove(slot);
                                ingress::drop_trace(
                                    &mut trace,
                                    reason,
                                    [&mut replicas[ri].metrics.collector, &mut collector],
                                );
                                class_ingest(&mut classes, &trace);
                                if closed_loop.is_some() && now < config.duration_s {
                                    issue(
                                        now + REJECT_RETRY_BACKOFF_S,
                                        0,
                                        &mut heap,
                                        &mut traces,
                                        &mut tenant_of,
                                        &mut classes,
                                        &mut side,
                                        &mut tr,
                                        &mut rng_loop,
                                        &mut seq,
                                    );
                                }
                            }
                        }
                        // The crash may have stranded the held backlog (no
                        // routable replica left and none on the way): reject
                        // it now, not at the end of the run.
                        match admission.as_mut() {
                            Some(adm) => drain_held(
                                now, &mut held, adm, &mut router, &routable, &mut outstanding,
                                &mut replicas, &upcoming_recovers, &mut traces, &mut collector, &mut classes,
                                &mut heap, &mut seq, &mut tr,
                            ),
                            None => {
                                if routable.is_empty()
                                    && !capacity_pending(&replicas, &upcoming_recovers)
                                    && !held.is_empty()
                                {
                                    let stranded: Vec<u32> = held.drain_fifo().collect();
                                    for slot in stranded {
                                        tr.terminal(
                                            slot as usize,
                                            now,
                                            DropReason::RejectedPlacement.label(),
                                        );
                                        let mut trace = traces.remove(slot);
                                        ingress::drop_trace(
                                            &mut trace,
                                            DropReason::RejectedPlacement,
                                            [&mut collector],
                                        );
                                        if closed_loop.is_some() && now < config.duration_s {
                                            issue(
                                                now + REJECT_RETRY_BACKOFF_S,
                                                0,
                                                &mut heap,
                                                &mut traces,
                                                &mut tenant_of,
                                                &mut classes,
                                                &mut side,
                                                &mut tr,
                                                &mut rng_loop,
                                                &mut seq,
                                            );
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
            Event::Retry { slot } => {
                // A retried attempt re-enters the routing tier below
                // admission (it was admitted at first issue). Its backoff
                // gap lands in Stage::Batching via the staging charge, so
                // retried e2e latency keeps the original arrival.
                if routable.is_empty() {
                    if capacity_pending(&replicas, &upcoming_recovers) {
                        tr.phase(slot as usize, "held", now);
                        match admission.as_mut() {
                            None => held.push_fifo(slot),
                            Some(adm) => {
                                let tenant =
                                    tenant_of.get(slot as usize).copied().unwrap_or(0) as usize;
                                held.push_wfq(adm, tenant, slot);
                            }
                        }
                    } else {
                        tr.terminal(slot as usize, now, DropReason::RejectedPlacement.label());
                        let mut trace = traces.remove(slot);
                        ingress::drop_trace(
                            &mut trace,
                            DropReason::RejectedPlacement,
                            [&mut collector],
                        );
                        class_ingest(&mut classes, &trace);
                        if closed_loop.is_some() && now < config.duration_s {
                            issue(
                                now + REJECT_RETRY_BACKOFF_S,
                                0,
                                &mut heap,
                                &mut traces,
                                &mut tenant_of,
                                &mut classes,
                                &mut side,
                                &mut tr,
                                &mut rng_loop,
                                &mut seq,
                            );
                        }
                    }
                    continue;
                }
                let ri = router.route_among(now, &routable, &outstanding);
                if replicas[ri].queued >= replicas[ri].max_queue {
                    tr.terminal(slot as usize, now, DropReason::QueueFull.label());
                    let mut trace = traces.remove(slot);
                    ingress::drop_trace(
                        &mut trace,
                        DropReason::QueueFull,
                        [&mut replicas[ri].metrics.collector, &mut collector],
                    );
                    class_ingest(&mut classes, &trace);
                    if closed_loop.is_some() && now < config.duration_s {
                        issue(
                            now + REJECT_RETRY_BACKOFF_S,
                            0,
                            &mut heap,
                            &mut traces,
                            &mut tenant_of,
                            &mut classes,
                            &mut side,
                            &mut tr,
                            &mut rng_loop,
                            &mut seq,
                        );
                    }
                    continue;
                }
                let pol = config.retry.expect("Retry events exist only with a retry policy");
                if tr.is_traced(slot as usize) {
                    tr.event(slot as usize, "route", now, vec![("replica", Attr::U(ri as u64))]);
                }
                tr.phase(slot as usize, "batch_wait", now);
                // Hedge: snapshot the trace before staging so both copies
                // charge their own arrival→now gap.
                let ghost =
                    if pol.hedge && routable.len() >= 2 { Some(*traces.get_mut(slot)) } else { None };
                let r = &mut replicas[ri];
                let d = ingress::stage_into_batcher(
                    traces.get_mut(slot),
                    &mut r.batcher,
                    slot,
                    now,
                    r.busy,
                );
                r.queued += 1;
                outstanding[ri] += 1;
                match d {
                    Decision::Dispatch(_) => {
                        start_batch(ri, &mut replicas[ri], now, &mut heap, &mut seq, &mut tr, &mut traces)
                    }
                    Decision::WakeAt(t) => {
                        push(&mut heap, t, Event::Wake { replica: ri, scheduled_for: t }, &mut seq)
                    }
                    Decision::Wait => {}
                }
                if let Some(g) = ghost {
                    // Shadow copy on the least-loaded other healthy replica
                    // with queue room (ascending scan: index breaks ties).
                    let mut second: Option<usize> = None;
                    for &cand in &routable {
                        if cand == ri || replicas[cand].queued >= replicas[cand].max_queue {
                            continue;
                        }
                        match second {
                            None => second = Some(cand),
                            Some(b) if outstanding[cand] < outstanding[b] => second = Some(cand),
                            _ => {}
                        }
                    }
                    if let Some(gi) = second {
                        let gslot = traces.insert(g);
                        side.make_ghost(gslot, slot);
                        if tr.full_detail() && tr.is_traced(slot as usize) {
                            // The hedged shadow gets its own span tree,
                            // linked under the primary attempt's root.
                            let rid = traces.get_mut(gslot).id;
                            tr.arrival(gslot as usize, rid, now);
                            tr.link(slot as usize, gslot as usize);
                            tr.attr(gslot as usize, "hedge", Attr::U(1));
                            if tr.is_traced(gslot as usize) {
                                tr.event(
                                    gslot as usize,
                                    "route",
                                    now,
                                    vec![("replica", Attr::U(gi as u64))],
                                );
                            }
                            tr.phase(gslot as usize, "batch_wait", now);
                        }
                        let r = &mut replicas[gi];
                        let d = ingress::stage_into_batcher(
                            traces.get_mut(gslot),
                            &mut r.batcher,
                            gslot,
                            now,
                            r.busy,
                        );
                        r.queued += 1;
                        outstanding[gi] += 1;
                        match d {
                            Decision::Dispatch(_) => start_batch(
                                gi, &mut replicas[gi], now, &mut heap, &mut seq, &mut tr, &mut traces,
                            ),
                            Decision::WakeAt(t) => push(
                                &mut heap,
                                t,
                                Event::Wake { replica: gi, scheduled_for: t },
                                &mut seq,
                            ),
                            Decision::Wait => {}
                        }
                    }
                }
            }
        }
    }

    // Every issued trace was completed or rejected; the slab must be
    // empty or the conservation invariant is broken upstream.
    debug_assert!(traces.is_empty(), "trace leak: {} live traces at end of run", traces.len());
    // The loop drains the source before exiting, and the counting
    // pre-pass must agree with what the source actually yielded (the
    // loop-phase RNG offset depends on it).
    debug_assert!(pending.is_none(), "arrivals left uninjected at end of run");
    debug_assert_eq!(
        arrival_seq - des::ARRIVAL_SEQ_BASE,
        n_issue,
        "count_in pre-pass disagrees with the arrivals the source yielded"
    );

    // Single source of truth for drops: the cluster collector ingested
    // every rejected trace exactly once (replica queue or routing tier),
    // with its reason — the breakdown must sum back to the total.
    let dropped = collector.dropped;
    debug_assert!(collector.drops_conserved(), "drop-reason ledger out of balance");
    // Per-class conservation: each class ledger balances on its own
    // (issued == completed + Σ dropped-by-reason), and the classes sum to
    // the cluster totals.
    if !classes.is_empty() {
        debug_assert_eq!(classes.iter().map(|c| c.issued).sum::<u64>(), next_id);
        for cm in &classes {
            debug_assert!(cm.conserved(), "class {} ledger out of balance", cm.class);
        }
    }
    // Replicas still down when the clock runs out owe the rest of the
    // horizon to the downtime ledger.
    for r in &replicas {
        if r.state == ReplicaState::Failed {
            downtime_s += config.duration_s - r.failed_at;
        }
    }
    ClusterResult {
        collector,
        replicas: replicas.into_iter().map(|r| r.metrics).collect(),
        scale,
        dropped,
        classes,
        issued: next_id,
        downtime_s,
        events,
        trace: tr.finish(gauges),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{Processors, RequestPath};
    use crate::serving::autoscale::ScalePolicy;
    use crate::serving::backends;
    use crate::workload::{generate, Pattern};

    fn replica(per_req_ms: f64) -> ReplicaConfig {
        ReplicaConfig {
            software: &backends::TRIS,
            service: ServiceModel::Measured {
                per_batch: vec![(1, per_req_ms / 1e3), (8, per_req_ms * 2.2 / 1e3)],
                utilization: 0.6,
            },
            policy: Policy::Single,
            max_queue: 100_000,
        }
    }

    fn base(n: usize, rate: f64, duration: f64, router: RouterPolicy) -> ClusterConfig {
        ClusterConfig {
            workload: Workload::Arrivals(generate(&Pattern::Poisson { rate }, duration, 11)),
            duration_s: duration,
            replicas: (0..n).map(|_| replica(5.0)).collect(),
            router,
            autoscale: None,
            cold_start: None,
            path: RequestPath::local(Processors::none()),
            metrics: MetricsMode::Exact,
            admission: None,
            faults: None,
            retry: None,
            seed: 5,
        }
    }

    /// Three tagged tenants (gold/silver/bronze) at `rate` rps each.
    fn three_class_streams(rate: f64) -> Workload {
        use crate::workload::StreamSpec;
        Workload::Streams {
            streams: vec![
                StreamSpec::new("gold", Pattern::Poisson { rate }).with_qos(0, 4.0),
                StreamSpec::new("silver", Pattern::Poisson { rate }).with_qos(1, 2.0),
                StreamSpec::new("bronze", Pattern::Poisson { rate }).with_qos(2, 1.0),
            ],
            seed: 42,
        }
    }

    fn three_class_admission() -> AdmissionConfig {
        AdmissionConfig {
            tenants: vec![
                TenantSpec::new("gold").with_class(0).with_weight(4.0),
                TenantSpec::new("silver").with_class(1).with_weight(2.0),
                TenantSpec::new("bronze").with_class(2).with_weight(1.0),
            ],
            shed_depth: vec![300, 100, 30],
        }
    }

    #[test]
    fn conservation_across_replicas() {
        let cfg = base(4, 200.0, 20.0, RouterPolicy::RoundRobin);
        let n = cfg.workload.count_in(20.0);
        let r = run(&cfg);
        assert_eq!(r.collector.completed + r.dropped, n);
        assert_eq!(r.issued, n);
        // The cluster collector agrees with the per-replica sums.
        let completed: u64 = r.replicas.iter().map(|m| m.collector.completed).sum();
        assert_eq!(completed, r.collector.completed);
        let dropped: u64 = r.replicas.iter().map(|m| m.collector.dropped).sum();
        assert_eq!(dropped, r.dropped);
        // The event count covers at least one enqueue + one completion
        // per request.
        assert!(r.events >= 2 * n);
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let r = run(&base(4, 200.0, 20.0, RouterPolicy::RoundRobin));
        let per: Vec<u64> = r.replicas.iter().map(|m| m.collector.completed).collect();
        let max = *per.iter().max().unwrap() as f64;
        let min = *per.iter().min().unwrap() as f64;
        assert!(min > 0.0, "{per:?}");
        assert!(max / min < 1.05, "round-robin should balance: {per:?}");
    }

    #[test]
    fn all_routers_deterministic_per_seed() {
        for router in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastOutstanding,
            RouterPolicy::PowerOfTwoChoices { seed: 17 },
            RouterPolicy::LatencyEwma { alpha: 0.3, stale_s: 0.25 },
        ] {
            let (a, b) = (run(&base(3, 150.0, 10.0, router)), run(&base(3, 150.0, 10.0, router)));
            assert_eq!(a.collector.completed, b.collector.completed, "{}", router.label());
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.events, b.events);
            for (ra, rb) in a.replicas.iter().zip(&b.replicas) {
                assert_eq!(ra.batch_sizes(), rb.batch_sizes(), "{}", router.label());
            }
            assert_eq!(a.collector.e2e.percentile(99.0), b.collector.e2e.percentile(99.0));
        }
    }

    #[test]
    fn scale_out_absorbs_overload() {
        // 600 rps against 5 ms replicas (200 rps capacity each): one
        // replica drowns, four absorb it.
        let mut one = base(1, 600.0, 15.0, RouterPolicy::LeastOutstanding);
        let mut four = base(4, 600.0, 15.0, RouterPolicy::LeastOutstanding);
        for cfg in [&mut one, &mut four] {
            for rc in &mut cfg.replicas {
                rc.max_queue = 64;
            }
        }
        let (r1, r4) = (run(&one), run(&four));
        assert!(r1.dropped > 0, "single replica must overflow");
        assert!(
            r4.collector.completed > 2 * r1.collector.completed,
            "4 replicas: {} vs 1: {}",
            r4.collector.completed,
            r1.collector.completed
        );
        assert!(r4.collector.e2e.percentile(99.0) < r1.collector.e2e.percentile(99.0));
    }

    #[test]
    fn heterogeneous_replicas_keep_own_service_models() {
        // Fast replica finishes far more work than the slow one under
        // least-outstanding routing.
        let mut cfg = base(2, 150.0, 20.0, RouterPolicy::LeastOutstanding);
        cfg.replicas = vec![replica(2.0), replica(20.0)];
        let r = run(&cfg);
        let fast = r.replicas[0].collector.completed;
        let slow = r.replicas[1].collector.completed;
        assert!(fast > slow * 2, "fast {fast} vs slow {slow}");
        assert_eq!(fast + slow, r.collector.completed);
    }

    #[test]
    fn ewma_router_shifts_load_off_slow_replica() {
        // Same heterogeneous pair: the latency-aware router should finish
        // clearly more work on the fast replica than oblivious cycling.
        let mut rr = base(2, 150.0, 20.0, RouterPolicy::RoundRobin);
        let mut ewma = base(2, 150.0, 20.0, RouterPolicy::LatencyEwma { alpha: 0.3, stale_s: 0.1 });
        for cfg in [&mut rr, &mut ewma] {
            cfg.replicas = vec![replica(2.0), replica(20.0)];
        }
        let (r_rr, r_ew) = (run(&rr), run(&ewma));
        let fast_share = |r: &ClusterResult| {
            r.replicas[0].collector.completed as f64 / r.collector.completed.max(1) as f64
        };
        assert!(
            fast_share(&r_ew) > fast_share(&r_rr) + 0.1,
            "ewma fast share {} vs rr {}",
            fast_share(&r_ew),
            fast_share(&r_rr)
        );
    }

    #[test]
    fn closed_loop_cluster_sustains_concurrency() {
        let mut cfg = base(2, 1.0, 10.0, RouterPolicy::LeastOutstanding);
        cfg.workload = Workload::ClosedLoop { clients: 8 };
        let r = run(&cfg);
        // 8 clients over 2 replicas at ~4.2 ms effective service: thousands
        // of completions; every client's chain stays alive to the horizon.
        assert!(r.collector.completed > 2000, "completed {}", r.collector.completed);
        assert_eq!(r.collector.completed + r.dropped, r.issued);
    }

    #[test]
    fn per_replica_timelines_active() {
        let r = run(&base(2, 100.0, 20.0, RouterPolicy::RoundRobin));
        for (i, m) in r.replicas.iter().enumerate() {
            assert!(m.busy_timeline.mean() > 0.01, "replica {i} idle timeline");
            assert!(m.mean_batch() >= 1.0, "replica {i}");
        }
    }

    #[test]
    fn fixed_fleet_records_no_scale_events() {
        let r = run(&base(3, 100.0, 10.0, RouterPolicy::RoundRobin));
        assert_eq!(r.scale.initial, 3);
        assert!(r.scale.events.is_empty());
        assert_eq!(r.scale.max_active(), 3);
    }

    #[test]
    fn cold_start_holds_requests_at_routing_tier() {
        // Regression (empty candidate set): a cold fleet has zero routable
        // replicas while every early request arrives — these used to reach
        // `route_among` with an empty slice. They must be held and served
        // once the first replica warms, with exact conservation.
        let mut cfg = base(2, 100.0, 10.0, RouterPolicy::LeastOutstanding);
        cfg.cold_start = Some(50_000_000);
        let coldstart = backends::TRIS.coldstart_s(50_000_000);
        assert!(coldstart > 0.5, "scenario needs a visible cold start, got {coldstart}");
        let n = cfg.workload.count_in(10.0);
        let r = run(&cfg);
        assert_eq!(r.collector.completed + r.dropped, n, "conservation across the hold");
        assert_eq!(r.dropped, 0, "held requests must not be dropped");
        // The fleet came up through Ready events from an initial 0.
        assert_eq!(r.scale.initial, 0);
        assert_eq!(r.scale.count(ScaleEventKind::Ready), 2);
        assert_eq!(r.scale.max_active(), 2);
        // A request that arrived at ~t=0 could not complete before the
        // cold start elapsed, and its wait shows up as queueing time.
        let first_e2e = r.collector.e2e.max();
        assert!(
            first_e2e >= coldstart * 0.9,
            "earliest requests must pay the cold start: max e2e {first_e2e} vs {coldstart}"
        );
        assert!(r.collector.stage(Stage::Batching).max() >= coldstart * 0.9);
    }

    #[test]
    fn cold_start_closed_loop_clients_survive_the_hold() {
        // Closed-loop clients issue at t=0 into a fully cold fleet: every
        // first request is held, the chains resume after warm-up, and
        // accounting stays exact.
        let mut cfg = base(2, 1.0, 15.0, RouterPolicy::LeastOutstanding);
        cfg.workload = Workload::ClosedLoop { clients: 4 };
        cfg.cold_start = Some(10_000_000);
        let r = run(&cfg);
        assert_eq!(r.collector.completed + r.dropped, r.issued);
        assert!(r.collector.completed > 100, "chains must resume: {}", r.collector.completed);
        assert_eq!(r.scale.count(ScaleEventKind::Ready), 2);
        // Determinism across runs, including the held-flush ordering.
        let r2 = run(&cfg);
        assert_eq!(r.collector.completed, r2.collector.completed);
        assert_eq!(r.events, r2.events);
        assert_eq!(r.collector.e2e.percentile(99.0), r2.collector.e2e.percentile(99.0));
    }

    #[test]
    fn autoscale_adds_capacity_under_spike_and_drains_after() {
        // 1 replica at ~200 rps capacity; a 600 rps burst forces scale-up,
        // and the post-burst lull forces drain-on-remove back toward min.
        let mut cfg = base(1, 60.0, 60.0, RouterPolicy::LeastOutstanding);
        // Streamed, not materialized: the autoscaler path (ScaleEval seq
        // pinning, warm-up ReplicaReady events) must hold under lazy
        // injection too.
        cfg.workload = Workload::Stream {
            pattern: Pattern::Spike {
                base_rate: 60.0,
                burst_rate: 600.0,
                start_s: 10.0,
                duration_s: 10.0,
            },
            seed: 21,
        };
        cfg.autoscale = Some(AutoscaleConfig {
            policy: ScalePolicy::QueueDepth {
                up_per_replica: 6.0,
                down_per_replica: 0.5,
                cooldown_s: 1.0,
            },
            min_replicas: 1,
            max_replicas: 6,
            template: replica(5.0),
            weight_bytes: 50_000_000,
            eval_interval_s: 0.5,
        });
        let r = run(&cfg);
        // Conservation holds exactly across every scale event.
        assert_eq!(r.collector.completed + r.dropped, r.issued);
        assert!(r.scale.count(ScaleEventKind::AddRequested) >= 1, "no scale-up under burst");
        assert!(r.scale.count(ScaleEventKind::Ready) >= 1);
        assert!(
            r.scale.count(ScaleEventKind::Retired) >= 1,
            "no drain-on-remove after the burst: {:?}",
            r.scale.events
        );
        assert!(r.scale.max_active() > 1);
        // Retired replicas completed work and kept it (metrics preserved).
        let completed: u64 = r.replicas.iter().map(|m| m.collector.completed).sum();
        assert_eq!(completed, r.collector.completed);
    }

    #[test]
    fn streaming_workload_bit_identical_to_materialized() {
        // The tentpole guarantee: feeding the engine a lazy pattern stream
        // produces the same run — to the last bit — as materializing the
        // same pattern first. Covers plain serving, overload (drops), and
        // a router that draws its own RNG.
        let pattern = Pattern::Spike {
            base_rate: 150.0,
            burst_rate: 500.0,
            start_s: 5.0,
            duration_s: 5.0,
        };
        for router in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastOutstanding,
            RouterPolicy::PowerOfTwoChoices { seed: 17 },
        ] {
            let mut materialized = base(3, 100.0, 20.0, router);
            materialized.workload = Workload::Arrivals(generate(&pattern, 20.0, 77));
            for rc in &mut materialized.replicas {
                rc.max_queue = 48; // force some drops into the comparison
            }
            let mut streamed = materialized.clone();
            streamed.workload = Workload::Stream { pattern: pattern.clone(), seed: 77 };
            let (a, b) = (run(&materialized), run(&streamed));
            assert_eq!(a.issued, b.issued, "{}", router.label());
            assert_eq!(a.dropped, b.dropped, "{}", router.label());
            assert_eq!(a.events, b.events, "{}", router.label());
            assert_eq!(a.collector.completed, b.collector.completed);
            assert_eq!(a.collector.fingerprint(), b.collector.fingerprint(), "{}", router.label());
            for (ra, rb) in a.replicas.iter().zip(&b.replicas) {
                assert_eq!(ra.batch_sizes(), rb.batch_sizes(), "{}", router.label());
            }
            for q in [50.0, 95.0, 99.0, 100.0] {
                assert_eq!(
                    a.collector.e2e.percentile(q).to_bits(),
                    b.collector.e2e.percentile(q).to_bits(),
                    "p{q} {}",
                    router.label()
                );
            }
        }
    }

    #[test]
    fn streaming_autoscaled_run_bit_identical_to_materialized() {
        // Same equivalence across scale events: warming replicas, the
        // pinned initial ScaleEval slot, and drain-on-remove all happen
        // with lazy injection active.
        let pattern = Pattern::Spike {
            base_rate: 60.0,
            burst_rate: 600.0,
            start_s: 10.0,
            duration_s: 10.0,
        };
        let mut materialized = base(1, 60.0, 60.0, RouterPolicy::LeastOutstanding);
        materialized.workload = Workload::Arrivals(generate(&pattern, 60.0, 21));
        materialized.autoscale = Some(AutoscaleConfig {
            policy: ScalePolicy::QueueDepth {
                up_per_replica: 6.0,
                down_per_replica: 0.5,
                cooldown_s: 1.0,
            },
            min_replicas: 1,
            max_replicas: 6,
            template: replica(5.0),
            weight_bytes: 50_000_000,
            eval_interval_s: 0.5,
        });
        let mut streamed = materialized.clone();
        streamed.workload = Workload::Stream { pattern, seed: 21 };
        let (a, b) = (run(&materialized), run(&streamed));
        assert_eq!(a.issued, b.issued);
        assert_eq!(a.events, b.events);
        assert_eq!(a.collector.fingerprint(), b.collector.fingerprint());
        assert_eq!(a.scale.events.len(), b.scale.events.len());
        assert_eq!(a.replicas.len(), b.replicas.len());
        assert_eq!(a.collector.e2e.percentile(99.0).to_bits(), b.collector.e2e.percentile(99.0).to_bits());
    }

    #[test]
    fn closed_loop_source_is_single_truth_for_issued_counts() {
        // Regression (satellite): the initial closed-loop wave comes from
        // the workload source, not an engine-private loop — the streaming
        // count pre-pass, the engine's issued ledger, and both closed-loop
        // spellings must agree.
        let mut cfg = base(2, 1.0, 10.0, RouterPolicy::LeastOutstanding);
        cfg.workload = Workload::ClosedLoop { clients: 8 };
        assert_eq!(cfg.workload.count_in(10.0), 8, "source must emit exactly the initial wave");
        let r = run(&cfg);
        assert!(r.issued > 8, "clients must reissue");
        assert_eq!(r.collector.completed + r.dropped, r.issued);

        let mut via_pattern = cfg.clone();
        via_pattern.workload =
            Workload::Stream { pattern: Pattern::ClosedLoop { concurrency: 8 }, seed: 123 };
        let r2 = run(&via_pattern);
        assert_eq!(r.issued, r2.issued, "both closed-loop spellings drive the same run");
        assert_eq!(r.events, r2.events);
        assert_eq!(r.collector.fingerprint(), r2.collector.fingerprint());
    }

    #[test]
    fn tagged_streams_without_admission_match_projected_merge() {
        // Workload::Streams with the admission tier off takes the plain
        // FIFO path: tags are inert, and the run is bit-identical to any
        // other spelling of the same merged arrival sequence.
        let mut cfg = base(2, 100.0, 10.0, RouterPolicy::LeastOutstanding);
        cfg.workload = three_class_streams(50.0);
        let n = cfg.workload.count_in(10.0);
        let r = run(&cfg);
        assert_eq!(r.issued, n);
        assert_eq!(r.collector.completed + r.dropped, n);
        assert!(r.classes.is_empty(), "no admission tier, no class ledgers");
        let r2 = run(&cfg);
        assert_eq!(r.collector.fingerprint(), r2.collector.fingerprint());
    }

    #[test]
    fn admission_keeps_exact_per_class_conservation() {
        // Overloaded: 3 tenants at 150 rps each against one ~200 rps
        // replica. Every class ledger balances individually; shed order
        // is strictly lowest-class-first.
        let mut cfg = base(1, 10.0, 15.0, RouterPolicy::LeastOutstanding);
        cfg.workload = three_class_streams(150.0);
        cfg.admission = Some(three_class_admission());
        let r = run(&cfg);
        assert_eq!(r.classes.len(), 3);
        let issued: u64 = r.classes.iter().map(|c| c.issued).sum();
        assert_eq!(issued, r.issued);
        for cm in &r.classes {
            assert!(cm.conserved(), "class {} out of balance", cm.class);
        }
        assert_eq!(r.collector.completed + r.dropped, r.issued);
        // Lowest class sheds hardest, highest least.
        let shed: Vec<f64> = r.classes.iter().map(|c| c.shed_fraction()).collect();
        assert!(shed[2] > shed[1] && shed[1] > shed[0], "shed fractions {shed:?}");
        assert!(shed[2] > 0.1, "bronze must shed under 2.25x overload: {shed:?}");
        // Reason ledger: admission drops are Shed, nothing else fires in
        // this scenario (queues are deep, fleet is fixed and warm).
        assert_eq!(r.collector.dropped_by(crate::metrics::DropReason::Shed), r.dropped);
        assert!(r.collector.drops_conserved());
        // Deterministic replay, WFQ and buckets included.
        let r2 = run(&cfg);
        assert_eq!(r.events, r2.events);
        assert_eq!(r.collector.fingerprint(), r2.collector.fingerprint());
        for (a, b) in r.classes.iter().zip(&r2.classes) {
            assert_eq!(a.collector.fingerprint(), b.collector.fingerprint());
        }
    }

    #[test]
    fn admission_protects_gold_latency_under_overload() {
        let mut cfg = base(1, 10.0, 15.0, RouterPolicy::LeastOutstanding);
        cfg.workload = three_class_streams(150.0);
        cfg.admission = Some(three_class_admission());
        let r = run(&cfg);
        let gold = &r.classes[0];
        // Gold keeps high goodput; its backlog is capped by shed_depth so
        // its p99 stays bounded even at 2.25x aggregate overload.
        assert!(gold.goodput() > 0.9, "gold goodput {}", gold.goodput());
        let p99 = gold.collector.e2e.percentile(99.0);
        assert!(p99 < 5.0, "gold p99 {p99} unbounded under overload");
    }

    #[test]
    #[should_panic(expected = "admission control requires a tenant-tagged workload")]
    fn admission_rejects_untagged_workloads() {
        let mut cfg = base(1, 100.0, 5.0, RouterPolicy::RoundRobin);
        cfg.admission = Some(three_class_admission());
        run(&cfg);
    }

    #[test]
    #[should_panic(expected = "admission defines 3 tenants but the workload has 2 streams")]
    fn admission_rejects_tenant_stream_mismatch() {
        use crate::workload::StreamSpec;
        let mut cfg = base(1, 100.0, 5.0, RouterPolicy::RoundRobin);
        cfg.workload = Workload::Streams {
            streams: vec![
                StreamSpec::new("a", Pattern::Poisson { rate: 10.0 }),
                StreamSpec::new("b", Pattern::Poisson { rate: 10.0 }),
            ],
            seed: 1,
        };
        cfg.admission = Some(three_class_admission());
        run(&cfg);
    }

    #[test]
    #[should_panic(expected = "cannot contain closed-loop patterns")]
    fn streams_reject_closed_loop_patterns() {
        use crate::workload::StreamSpec;
        let mut cfg = base(1, 100.0, 5.0, RouterPolicy::RoundRobin);
        cfg.workload = Workload::Streams {
            streams: vec![StreamSpec::new("cl", Pattern::ClosedLoop { concurrency: 4 })],
            seed: 1,
        };
        run(&cfg);
    }

    #[test]
    fn token_bucket_caps_a_tenant_end_to_end() {
        // Tenant "bronze" rate-limited to 20 rps while offering ~150:
        // most of its traffic sheds at the bucket, the others are
        // untouched (fleet has headroom for the admitted load).
        let mut cfg = base(4, 10.0, 15.0, RouterPolicy::LeastOutstanding);
        cfg.workload = three_class_streams(150.0);
        let mut adm = three_class_admission();
        adm.tenants[2] = adm.tenants[2].clone().with_rate(20.0, 5.0);
        cfg.admission = Some(adm);
        let r = run(&cfg);
        let bronze = &r.classes[2];
        assert!(
            bronze.shed_fraction() > 0.7,
            "bucket must cap bronze: shed {}",
            bronze.shed_fraction()
        );
        // Admitted bronze ~ 20 rps * 15 s (plus the initial burst).
        let admitted = bronze.issued - bronze.collector.dropped;
        assert!((250..=400).contains(&admitted), "admitted bronze {admitted}");
        for cm in &r.classes[..2] {
            assert!(cm.goodput() > 0.95, "class {} goodput {}", cm.class, cm.goodput());
        }
    }

    #[test]
    fn sketch_metrics_do_not_perturb_the_simulation() {
        // MetricsMode changes how latency is summarized, never what the
        // simulation does: counts, events, and batch ledgers stay exact,
        // and sketch percentiles track the exact ones within alpha.
        let mut exact = base(3, 300.0, 20.0, RouterPolicy::LeastOutstanding);
        exact.workload =
            Workload::Stream { pattern: Pattern::Poisson { rate: 300.0 }, seed: 31 };
        let mut sketch = exact.clone();
        let alpha = 0.01;
        sketch.metrics = MetricsMode::Sketch { alpha };
        let (e, s) = (run(&exact), run(&sketch));
        assert_eq!(e.issued, s.issued);
        assert_eq!(e.dropped, s.dropped);
        assert_eq!(e.events, s.events);
        assert_eq!(e.collector.completed, s.collector.completed);
        assert_eq!(e.mean_batch(), s.mean_batch());
        for (re, rs) in e.replicas.iter().zip(&s.replicas) {
            assert_eq!(re.batches(), rs.batches());
            assert_eq!(re.batch_sum(), rs.batch_sum());
            assert!(rs.collector.is_bounded());
            assert!(rs.batch_sizes().is_empty(), "bounded mode keeps no batch vector");
        }
        assert!(s.collector.is_bounded());
        for q in [50.0, 95.0, 99.0] {
            let (pe, ps) = (e.collector.e2e.percentile(q), s.collector.e2e.percentile(q));
            assert!(
                (ps - pe).abs() <= 2.0 * alpha * pe.abs(),
                "p{q}: sketch {ps} vs exact {pe}"
            );
        }
        // min/max are tracked exactly even in sketch mode.
        assert_eq!(e.collector.e2e.min(), s.collector.e2e.min());
        assert_eq!(e.collector.e2e.max(), s.collector.e2e.max());
    }
}
