//! Shared discrete-event-heap machinery for the serving engines
//! (`cluster` and `multimodel`), generic over the engine's event type.
//!
//! Determinism rests on the key: events order by time, with a
//! monotonically increasing sequence number breaking ties — FIFO among
//! simultaneous events, so the processing order of a time-collision is
//! the order the events were scheduled, never heap-internal layout. Both
//! engines advertise bit-identical replays per seed; keeping one
//! definition of this ordering (instead of a copy per engine) keeps that
//! guarantee from silently diverging.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Sequence-number ranges partitioning the tie-break space. The streaming
/// engines no longer schedule every arrival before the event loop starts,
/// so a single shared counter would hand arrivals *loop-phase* sequence
/// numbers and change tie-break outcomes versus the materialized engine.
/// Instead each scheduling phase draws from its own range, chosen so the
/// relative order between phases — setup (cold-start readiness) before
/// arrivals before the initial autoscaler evaluation before loop-scheduled
/// events — matches the order the old engine scheduled them in:
///
/// - setup events count from 0,
/// - arrival enqueues count from [`ARRIVAL_SEQ_BASE`] in arrival order
///   (the initial `ScaleEval`, which the old engine pushed right after
///   seeding all N arrivals, sits at `ARRIVAL_SEQ_BASE + N`),
/// - loop-scheduled events count from [`LOOP_SEQ_BASE`].
///
/// Bit-identical replays per seed across the engine rewrite rest on this
/// partition; see the golden tests.
pub(super) const ARRIVAL_SEQ_BASE: u64 = 1 << 32;
pub(super) const LOOP_SEQ_BASE: u64 = 1 << 62;

/// f64-ordered heap key; the sequence number breaks ties
/// deterministically (FIFO among simultaneous events).
#[derive(Debug, PartialEq, PartialOrd)]
pub(super) struct Key(pub f64, pub u64);

impl Eq for Key {}

#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).expect("NaN event time")
    }
}

/// Newtype so an engine's event type participates in the heap tuple
/// without needing its own `Ord` (ordering lives entirely in [`Key`]).
#[derive(Debug, PartialEq)]
pub(super) struct EventBox<E: PartialEq>(pub E);

impl<E: PartialEq> Eq for EventBox<E> {}

impl<E: PartialEq> PartialOrd for EventBox<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<E: PartialEq> Ord for EventBox<E> {
    fn cmp(&self, _other: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal // ordering handled entirely by Key
    }
}

/// Min-heap of (time, sequence)-keyed events.
pub(super) type Heap<E> = BinaryHeap<Reverse<(Key, EventBox<E>)>>;

/// Schedule `e` at time `t`, consuming one sequence number.
pub(super) fn push<E: PartialEq>(heap: &mut Heap<E>, t: f64, e: E, seq: &mut u64) {
    heap.push(Reverse((Key(t, *seq), EventBox(e))));
    *seq += 1;
}

/// Schedule `e` at time `t` with an explicit sequence number (no counter
/// consumed) — for one-off events whose tie-break position is pinned by
/// the range partition above rather than by a running counter.
pub(super) fn push_at<E: PartialEq>(heap: &mut Heap<E>, t: f64, e: E, seq: u64) {
    heap.push(Reverse((Key(t, seq), EventBox(e))));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_schedule_order() {
        let mut heap: Heap<&'static str> = BinaryHeap::new();
        let mut seq = 0u64;
        push(&mut heap, 2.0, "late", &mut seq);
        push(&mut heap, 1.0, "first-at-1", &mut seq);
        push(&mut heap, 1.0, "second-at-1", &mut seq);
        let mut order = Vec::new();
        while let Some(Reverse((Key(t, _), EventBox(e)))) = heap.pop() {
            order.push((t, e));
        }
        assert_eq!(
            order,
            vec![(1.0, "first-at-1"), (1.0, "second-at-1"), (2.0, "late")],
            "time ascending; FIFO among simultaneous events"
        );
    }

    #[test]
    fn seq_ranges_order_phases_at_equal_times() {
        // At one instant: setup < arrival < initial-scale-eval < loop,
        // regardless of push order — the partition the streaming engines
        // rely on for bit-identity with the materialized engine.
        let mut heap: Heap<&'static str> = BinaryHeap::new();
        let mut loop_seq = LOOP_SEQ_BASE;
        push(&mut heap, 1.0, "loop", &mut loop_seq);
        push_at(&mut heap, 1.0, "scale-eval", ARRIVAL_SEQ_BASE + 2);
        push_at(&mut heap, 1.0, "arrival-1", ARRIVAL_SEQ_BASE + 1);
        push_at(&mut heap, 1.0, "arrival-0", ARRIVAL_SEQ_BASE);
        let mut setup_seq = 0u64;
        push(&mut heap, 1.0, "setup", &mut setup_seq);
        let mut order = Vec::new();
        while let Some(Reverse((_, EventBox(e)))) = heap.pop() {
            order.push(e);
        }
        assert_eq!(order, vec!["setup", "arrival-0", "arrival-1", "scale-eval", "loop"]);
    }
}
