//! Shared discrete-event-heap machinery for the serving engines
//! (`cluster` and `multimodel`), generic over the engine's event type.
//!
//! Determinism rests on the key: events order by time, with a
//! monotonically increasing sequence number breaking ties — FIFO among
//! simultaneous events, so the processing order of a time-collision is
//! the order the events were scheduled, never heap-internal layout. Both
//! engines advertise bit-identical replays per seed; keeping one
//! definition of this ordering (instead of a copy per engine) keeps that
//! guarantee from silently diverging.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// f64-ordered heap key; the sequence number breaks ties
/// deterministically (FIFO among simultaneous events).
#[derive(Debug, PartialEq, PartialOrd)]
pub(super) struct Key(pub f64, pub u64);

impl Eq for Key {}

#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).expect("NaN event time")
    }
}

/// Newtype so an engine's event type participates in the heap tuple
/// without needing its own `Ord` (ordering lives entirely in [`Key`]).
#[derive(Debug, PartialEq)]
pub(super) struct EventBox<E: PartialEq>(pub E);

impl<E: PartialEq> Eq for EventBox<E> {}

impl<E: PartialEq> PartialOrd for EventBox<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<E: PartialEq> Ord for EventBox<E> {
    fn cmp(&self, _other: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal // ordering handled entirely by Key
    }
}

/// Min-heap of (time, sequence)-keyed events.
pub(super) type Heap<E> = BinaryHeap<Reverse<(Key, EventBox<E>)>>;

/// Schedule `e` at time `t`, consuming one sequence number.
pub(super) fn push<E: PartialEq>(heap: &mut Heap<E>, t: f64, e: E, seq: &mut u64) {
    heap.push(Reverse((Key(t, *seq), EventBox(e))));
    *seq += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_schedule_order() {
        let mut heap: Heap<&'static str> = BinaryHeap::new();
        let mut seq = 0u64;
        push(&mut heap, 2.0, "late", &mut seq);
        push(&mut heap, 1.0, "first-at-1", &mut seq);
        push(&mut heap, 1.0, "second-at-1", &mut seq);
        let mut order = Vec::new();
        while let Some(Reverse((Key(t, _), EventBox(e)))) = heap.pop() {
            order.push((t, e));
        }
        assert_eq!(
            order,
            vec![(1.0, "first-at-1"), (1.0, "second-at-1"), (2.0, "late")],
            "time ascending; FIFO among simultaneous events"
        );
    }
}
