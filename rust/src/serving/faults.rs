//! Deterministic fault injection for the serving engines.
//!
//! A [`FaultPlan`] describes replica crashes, recoveries and straggler
//! slowdowns for one DES run. Plans come in two flavors that compose:
//!
//! - **Scripted**: an explicit list of [`FaultOp`]s at fixed times —
//!   exactly reproducible by construction, the right tool for goldens
//!   and targeted what-if studies ("kill replica 1 at t=3s").
//! - **Random profile**: a [`FaultProfile`] with exponential MTTF/MTTR
//!   (and optionally a degrade distribution) sampled from seeded PCG
//!   streams. Each replica draws from its own stream, derived as
//!   `Pcg64::new(seed, FAULT_STREAM + replica)` — streams the workload
//!   generator (`Pcg64::seeded`, stream `0xda3e39cb94b95bdb`), the
//!   engines' loop RNGs (clones of the same stream) and the routers'
//!   p2c stream (`0x9e3779b97f4a7c15`) never touch. Adding, removing
//!   or re-seeding faults therefore cannot shift a single workload or
//!   routing draw: the only way a fault changes a run is through the
//!   injected events themselves.
//!
//! The whole plan is materialized into a sorted [`ScheduledFault`] list
//! at engine setup, before the first simulated event. [`FaultPlan::none`]
//! materializes to an empty list, pushes zero DES events and consumes
//! zero RNG draws or sequence numbers — which is why a `faults: none`
//! run is bit-identical to an engine that predates this module (gated by
//! `tests/faults.rs`).

use crate::util::rng::Pcg64;

/// PCG stream base for per-replica crash/recover draws: the high bits of
/// sqrt(2), disjoint from the workload stream (`0xda3e39cb94b95bdb`, used
/// by `Pcg64::seeded`) and the router p2c stream (`0x9e3779b97f4a7c15`).
pub const FAULT_STREAM: u64 = 0x6a09e667f3bcc908;

/// PCG stream base for per-replica degrade draws: the high bits of
/// sqrt(3). Separate from [`FAULT_STREAM`] so toggling the degrade
/// profile does not move the crash schedule.
pub const DEGRADE_STREAM: u64 = 0xbb67ae8584caa73b;

/// One scripted fault event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultOp {
    /// Replica dies at `at_s`: it leaves the routable set instantly and
    /// its queued + in-flight requests die or are retried.
    Crash { replica: usize, at_s: f64 },
    /// A crashed replica begins recovery at `at_s`; it becomes routable
    /// again after paying its cold start.
    Recover { replica: usize, at_s: f64 },
    /// Straggler window: service times on `replica` are multiplied by
    /// `factor` (≥ 1.0) from `at_s` until `until_s`.
    Degrade { replica: usize, at_s: f64, until_s: f64, factor: f64 },
}

/// Random degrade (straggler) distribution: exponential gaps between
/// windows of fixed duration and slowdown.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradeProfile {
    /// Mean time between degrade-window starts, seconds (exponential).
    pub mtbd_s: f64,
    /// Length of each degrade window, seconds.
    pub duration_s: f64,
    /// Service-time multiplier during the window (≥ 1.0).
    pub factor: f64,
}

/// Random crash/recover distribution: classic exponential MTTF/MTTR.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultProfile {
    /// Mean time to failure, seconds (exponential up-time).
    pub mttf_s: f64,
    /// Mean time to recovery, seconds (exponential down-time).
    pub mttr_s: f64,
    /// Optional straggler distribution layered on the same replicas.
    pub degrade: Option<DegradeProfile>,
}

/// A full fault-injection plan for one run: scripted ops, an optional
/// random profile, and the seed the profile draws from.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Explicit events, applied verbatim (after validation).
    pub script: Vec<FaultOp>,
    /// Random MTTF/MTTR (+ degrade) sampling, per replica.
    pub profile: Option<FaultProfile>,
    /// Seed for the profile's per-replica PCG streams. Ignored for
    /// purely scripted plans.
    pub seed: u64,
    /// Weight bytes re-loaded on recovery; `0` means "reuse the
    /// engine's configured cold-start size". Lets a study price
    /// recovery differently from scale-up cold starts.
    pub recovery_bytes: u64,
}

/// What a materialized fault does, in tie-break order (crashes before
/// recoveries at the same instant would re-kill a replica that just came
/// back; processing the crash first keeps same-instant scripts sane).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    Crash,
    Recover,
    DegradeStart { factor: f64 },
    DegradeEnd,
}

impl FaultKind {
    fn rank(&self) -> u8 {
        match self {
            FaultKind::Crash => 0,
            FaultKind::Recover => 1,
            FaultKind::DegradeStart { .. } => 2,
            FaultKind::DegradeEnd => 3,
        }
    }
}

/// One materialized fault event, ready for the DES heap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduledFault {
    pub at_s: f64,
    pub replica: usize,
    pub kind: FaultKind,
}

impl FaultPlan {
    /// The empty plan: no script, no profile. Materializes to zero
    /// events; engines treat it exactly like `faults: None`.
    pub fn none() -> Self {
        FaultPlan { script: Vec::new(), profile: None, seed: 0, recovery_bytes: 0 }
    }

    /// A purely scripted plan.
    pub fn scripted(ops: Vec<FaultOp>) -> Self {
        FaultPlan { script: ops, profile: None, seed: 0, recovery_bytes: 0 }
    }

    /// A purely random plan drawing from `seed`'s fault streams.
    pub fn random(profile: FaultProfile, seed: u64) -> Self {
        FaultPlan { script: Vec::new(), profile: Some(profile), seed, recovery_bytes: 0 }
    }

    /// True when the plan can inject nothing.
    pub fn is_none(&self) -> bool {
        self.script.is_empty() && self.profile.is_none()
    }

    /// Panics (loudly, like `AdmissionConfig::validate`) on nonsense:
    /// negative times, inverted degrade windows, slowdown factors below
    /// 1.0, non-positive profile means.
    pub fn validate(&self) {
        for op in &self.script {
            match *op {
                FaultOp::Crash { at_s, .. } | FaultOp::Recover { at_s, .. } => {
                    assert!(at_s >= 0.0, "fault op time must be >= 0, got {at_s}");
                }
                FaultOp::Degrade { at_s, until_s, factor, .. } => {
                    assert!(at_s >= 0.0, "degrade start must be >= 0, got {at_s}");
                    assert!(
                        until_s > at_s,
                        "degrade window must end after it starts ({at_s}..{until_s})"
                    );
                    assert!(factor >= 1.0, "degrade factor must be >= 1.0, got {factor}");
                }
            }
        }
        if let Some(p) = &self.profile {
            assert!(p.mttf_s > 0.0, "mttf_s must be > 0, got {}", p.mttf_s);
            assert!(p.mttr_s > 0.0, "mttr_s must be > 0, got {}", p.mttr_s);
            if let Some(d) = &p.degrade {
                assert!(d.mtbd_s > 0.0, "mtbd_s must be > 0, got {}", d.mtbd_s);
                assert!(d.duration_s > 0.0, "degrade duration_s must be > 0, got {}", d.duration_s);
                assert!(d.factor >= 1.0, "degrade factor must be >= 1.0, got {}", d.factor);
            }
        }
    }

    /// Materialize the plan against a fleet of `n_replicas` initial
    /// replicas over `[0, duration_s)`. Scripted ops naming a replica
    /// outside the initial fleet are dropped (autoscaled replicas added
    /// mid-run are not fault targets — only the configured fleet is).
    /// The result is sorted by `(time, replica, kind)`, a deterministic
    /// total order: the same plan always materializes to the same list.
    pub fn schedule(&self, n_replicas: usize, duration_s: f64) -> Vec<ScheduledFault> {
        self.validate();
        let mut out = Vec::new();
        for op in &self.script {
            match *op {
                FaultOp::Crash { replica, at_s } => {
                    if replica < n_replicas && at_s < duration_s {
                        out.push(ScheduledFault { at_s, replica, kind: FaultKind::Crash });
                    }
                }
                FaultOp::Recover { replica, at_s } => {
                    if replica < n_replicas && at_s < duration_s {
                        out.push(ScheduledFault { at_s, replica, kind: FaultKind::Recover });
                    }
                }
                FaultOp::Degrade { replica, at_s, until_s, factor } => {
                    if replica < n_replicas && at_s < duration_s {
                        out.push(ScheduledFault {
                            at_s,
                            replica,
                            kind: FaultKind::DegradeStart { factor },
                        });
                        if until_s < duration_s {
                            out.push(ScheduledFault {
                                at_s: until_s,
                                replica,
                                kind: FaultKind::DegradeEnd,
                            });
                        }
                    }
                }
            }
        }
        if let Some(p) = &self.profile {
            for replica in 0..n_replicas {
                let mut rng = Pcg64::new(self.seed, FAULT_STREAM.wrapping_add(replica as u64));
                let mut t = rng.exponential(1.0 / p.mttf_s);
                while t < duration_s {
                    out.push(ScheduledFault { at_s: t, replica, kind: FaultKind::Crash });
                    t += rng.exponential(1.0 / p.mttr_s);
                    if t >= duration_s {
                        break; // down for the rest of the run
                    }
                    out.push(ScheduledFault { at_s: t, replica, kind: FaultKind::Recover });
                    t += rng.exponential(1.0 / p.mttf_s);
                }
                if let Some(d) = &p.degrade {
                    let mut rng =
                        Pcg64::new(self.seed, DEGRADE_STREAM.wrapping_add(replica as u64));
                    let mut t = rng.exponential(1.0 / d.mtbd_s);
                    while t < duration_s {
                        out.push(ScheduledFault {
                            at_s: t,
                            replica,
                            kind: FaultKind::DegradeStart { factor: d.factor },
                        });
                        let end = t + d.duration_s;
                        if end < duration_s {
                            out.push(ScheduledFault {
                                at_s: end,
                                replica,
                                kind: FaultKind::DegradeEnd,
                            });
                        }
                        t = end + rng.exponential(1.0 / d.mtbd_s);
                    }
                }
            }
        }
        out.sort_by(|a, b| {
            a.at_s
                .total_cmp(&b.at_s)
                .then(a.replica.cmp(&b.replica))
                .then(a.kind.rank().cmp(&b.kind.rank()))
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_materializes_to_nothing() {
        let plan = FaultPlan::none();
        assert!(plan.is_none());
        assert!(plan.schedule(8, 100.0).is_empty());
    }

    #[test]
    fn schedule_is_deterministic() {
        let profile = FaultProfile {
            mttf_s: 5.0,
            mttr_s: 1.0,
            degrade: Some(DegradeProfile { mtbd_s: 7.0, duration_s: 2.0, factor: 3.0 }),
        };
        let a = FaultPlan::random(profile, 42).schedule(4, 60.0);
        let b = FaultPlan::random(profile, 42).schedule(4, 60.0);
        assert!(!a.is_empty(), "a 60s run at mttf 5s should produce crashes");
        assert_eq!(a, b);
        let c = FaultPlan::random(profile, 43).schedule(4, 60.0);
        assert_ne!(a, c, "different seeds should move the schedule");
    }

    #[test]
    fn profile_alternates_crash_recover_per_replica() {
        let plan = FaultPlan::random(
            FaultProfile { mttf_s: 3.0, mttr_s: 0.5, degrade: None },
            7,
        );
        let sched = plan.schedule(3, 200.0);
        for r in 0..3 {
            let mine: Vec<&ScheduledFault> =
                sched.iter().filter(|f| f.replica == r).collect();
            assert!(mine.len() >= 2, "replica {r} should fail at least once in 200s");
            for (i, f) in mine.iter().enumerate() {
                let want = if i % 2 == 0 { FaultKind::Crash } else { FaultKind::Recover };
                assert_eq!(f.kind, want, "replica {r} event {i}");
            }
            for w in mine.windows(2) {
                assert!(w[0].at_s < w[1].at_s, "strictly increasing per replica");
            }
        }
    }

    #[test]
    fn scripted_ops_sorted_and_clipped() {
        let plan = FaultPlan::scripted(vec![
            FaultOp::Recover { replica: 1, at_s: 5.0 },
            FaultOp::Crash { replica: 1, at_s: 2.0 },
            FaultOp::Crash { replica: 9, at_s: 1.0 },  // outside fleet: dropped
            FaultOp::Crash { replica: 0, at_s: 50.0 }, // past duration: dropped
            FaultOp::Degrade { replica: 0, at_s: 3.0, until_s: 40.0, factor: 2.0 },
        ]);
        let sched = plan.schedule(2, 10.0);
        assert_eq!(sched.len(), 3);
        assert_eq!(sched[0], ScheduledFault { at_s: 2.0, replica: 1, kind: FaultKind::Crash });
        assert_eq!(
            sched[1],
            ScheduledFault { at_s: 3.0, replica: 0, kind: FaultKind::DegradeStart { factor: 2.0 } }
        );
        // Degrade end past duration is clipped; only the start survives.
        assert_eq!(sched[2], ScheduledFault { at_s: 5.0, replica: 1, kind: FaultKind::Recover });
    }

    #[test]
    fn same_instant_crash_sorts_before_recover() {
        let plan = FaultPlan::scripted(vec![
            FaultOp::Recover { replica: 0, at_s: 4.0 },
            FaultOp::Crash { replica: 0, at_s: 4.0 },
        ]);
        let sched = plan.schedule(1, 10.0);
        assert_eq!(sched[0].kind, FaultKind::Crash);
        assert_eq!(sched[1].kind, FaultKind::Recover);
    }

    #[test]
    fn degrade_stream_disjoint_from_crash_stream() {
        // Toggling the degrade profile must not move the crash schedule.
        let bare = FaultPlan::random(
            FaultProfile { mttf_s: 4.0, mttr_s: 1.0, degrade: None },
            99,
        )
        .schedule(2, 100.0);
        let with_degrade = FaultPlan::random(
            FaultProfile {
                mttf_s: 4.0,
                mttr_s: 1.0,
                degrade: Some(DegradeProfile { mtbd_s: 9.0, duration_s: 1.0, factor: 2.0 }),
            },
            99,
        )
        .schedule(2, 100.0);
        let crashes = |s: &[ScheduledFault]| -> Vec<ScheduledFault> {
            s.iter()
                .filter(|f| matches!(f.kind, FaultKind::Crash | FaultKind::Recover))
                .copied()
                .collect()
        };
        assert_eq!(crashes(&bare), crashes(&with_degrade));
        assert!(with_degrade.len() > bare.len(), "degrade windows present");
    }

    #[test]
    #[should_panic(expected = "degrade factor must be >= 1.0")]
    fn speedup_factors_rejected() {
        FaultPlan::scripted(vec![FaultOp::Degrade {
            replica: 0,
            at_s: 0.0,
            until_s: 1.0,
            factor: 0.5,
        }])
        .schedule(1, 10.0);
    }

    #[test]
    #[should_panic(expected = "mttf_s must be > 0")]
    fn non_positive_mttf_rejected() {
        FaultPlan::random(FaultProfile { mttf_s: 0.0, mttr_s: 1.0, degrade: None }, 1)
            .schedule(1, 10.0);
    }
}
