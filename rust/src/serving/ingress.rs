//! Shared routing-tier front end — the ingress tier (PERF.md §The
//! ingress tier).
//!
//! Both serving engines stage every request through the same path:
//!
//! ```text
//!   arrival → admit (token bucket + class shed) → hold/flush → route → batch
//! ```
//!
//! Before this module existed the hold/flush and drop-accounting halves
//! of that path were written twice — once in `serving/cluster.rs`, once
//! in `serving/multimodel.rs`. The pieces live here now, parameterized
//! over the two cases:
//!
//! * [`HeldQueue`] — requests parked at the routing tier. One per
//!   routing domain (the cluster engine has one; the multi-model engine
//!   has one per model). In FIFO mode it is byte-identical to the
//!   historical held vector: insertion-order flush, same event pushes.
//!   In WFQ mode it orders releases by weighted-fair virtual finish
//!   time.
//! * [`Admission`] — per-tenant token buckets, per-class backlog
//!   thresholds, and the WFQ virtual clock. Pure state machine over
//!   simulated time: given the same event sequence it makes the same
//!   decisions, so the PCG seeding discipline is untouched (it draws no
//!   randomness at all).
//! * [`stage_into_batcher`] / [`drop_trace`] — the two exits of the
//!   staged path: into a replica's batch queue (hold-time accounting +
//!   enqueue + idle poll) or into the drop ledger with a
//!   [`DropReason`], ingested by each sink collector in the engine's
//!   canonical order.
//!
//! # Determinism
//!
//! The admission tier never touches an RNG. Token buckets are a pure
//! function of simulated time (`tokens = min(burst, tokens + Δt·rate)`);
//! class shedding compares the live in-system count against a fixed
//! threshold; WFQ tags are computed from per-tenant weights with a
//! monotone sequence number breaking ties. A run with
//! `admission: None` takes the FIFO code path, which performs exactly
//! the operations the pre-refactor engines performed — the golden
//! suites (`tests/golden_determinism.rs`, `tests/qos.rs`) pin this
//! bit-for-bit at 1/2/8 sweep threads.
//!
//! # Shed policy
//!
//! Classes are priorities: **0 is the highest**. `shed_depth[c]` is the
//! in-system backlog at which class `c` arrivals are shed, so giving
//! lower classes (higher indices) smaller depths makes overload shed
//! strictly lowest-class-first: as backlog rises it crosses the bronze
//! threshold before the silver one before the gold one. `fig_qos`
//! asserts exactly this shape at 2–5× offered overload.
//!
//! ```
//! use inferbench::serving::ingress::{AdmissionConfig, TenantSpec};
//!
//! // Three tenants, three classes: gold is rate-unlimited with the
//! // deepest backlog allowance; bronze is rate-limited and shed first.
//! let admission = AdmissionConfig {
//!     tenants: vec![
//!         TenantSpec::new("gold").with_class(0).with_weight(4.0),
//!         TenantSpec::new("silver").with_class(1).with_weight(2.0),
//!         TenantSpec::new("bronze").with_class(2).with_rate(50.0, 10.0),
//!     ],
//!     shed_depth: vec![600, 200, 60],
//! };
//! admission.validate(3);
//! assert_eq!(admission.n_classes(), 3);
//! ```

use crate::metrics::{ClassMetrics, Collector, DropReason, RequestTrace, Stage};
use crate::workload::StreamSpec;
use crate::serving::batcher::{Batcher, Decision};
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// QoS contract for one tenant (one tagged stream): priority class, WFQ
/// weight, and an optional token-bucket rate limit.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Priority class, 0 = highest. Indexes `AdmissionConfig::shed_depth`.
    pub class: u8,
    /// Weighted-fair-queueing weight (> 0): a tenant with weight 2 drains
    /// twice as often as a weight-1 tenant when both are backlogged.
    pub weight: f64,
    /// Token-bucket refill rate in requests/second; `None` = unlimited.
    pub rate: Option<f64>,
    /// Token-bucket capacity (burst allowance), in requests. Ignored when
    /// `rate` is `None`.
    pub burst: f64,
}

impl TenantSpec {
    /// An unconstrained tenant: class 0, weight 1, no rate limit — admission
    /// passes it through untouched.
    pub fn new(name: impl Into<String>) -> Self {
        TenantSpec { name: name.into(), class: 0, weight: 1.0, rate: None, burst: 1.0 }
    }

    pub fn with_class(mut self, class: u8) -> Self {
        self.class = class;
        self
    }

    pub fn with_weight(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }

    /// Cap the tenant at `rate` requests/second with a bucket of `burst`
    /// tokens (the bucket starts full).
    pub fn with_rate(mut self, rate: f64, burst: f64) -> Self {
        self.rate = Some(rate);
        self.burst = burst;
        self
    }
}

/// Configuration of the admission tier. `None` at the engine level means
/// no tier at all: the request path is bit-identical to the
/// pre-ingress engines.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionConfig {
    /// One spec per tenant. Tenant `i` is stream `i` of the workload
    /// (`Workload::Streams`) or model `i` (multi-model engine).
    pub tenants: Vec<TenantSpec>,
    /// Per-class in-system backlog thresholds, indexed by class: a class-c
    /// arrival is shed when the live request count (held + queued +
    /// in-flight) is already at `shed_depth[c]`. Length fixes the class
    /// count; every tenant's class must index into it.
    pub shed_depth: Vec<usize>,
}

impl AdmissionConfig {
    /// Derive the tenant set from a tagged stream list: one rate-unlimited
    /// tenant per stream, carrying the stream's class and WFQ weight. The
    /// stream tags stay generation-neutral (they never perturb arrival
    /// times), so this is the one-liner for "my workload already says who
    /// is gold and who is bronze".
    pub fn from_streams(streams: &[StreamSpec], shed_depth: Vec<usize>) -> Self {
        AdmissionConfig {
            tenants: streams
                .iter()
                .map(|s| {
                    TenantSpec::new(s.name.clone()).with_class(s.class).with_weight(s.weight)
                })
                .collect(),
            shed_depth,
        }
    }

    /// Number of priority classes.
    pub fn n_classes(&self) -> usize {
        self.shed_depth.len()
    }

    /// Panic loudly on an inconsistent config (the engines call this once
    /// up front, mirroring their other config asserts): tenant count must
    /// match the workload's stream count, weights must be positive, rates
    /// positive with at least one token of burst, and every class must
    /// have a shed depth.
    pub fn validate(&self, n_tenants: usize) {
        assert!(!self.shed_depth.is_empty(), "admission needs at least one class");
        assert_eq!(
            self.tenants.len(),
            n_tenants,
            "admission defines {} tenants but the workload has {} streams",
            self.tenants.len(),
            n_tenants
        );
        for t in &self.tenants {
            assert!(
                (t.class as usize) < self.shed_depth.len(),
                "tenant {:?} has class {} but only {} shed depths are configured",
                t.name,
                t.class,
                self.shed_depth.len()
            );
            assert!(t.weight > 0.0, "tenant {:?}: WFQ weight must be positive", t.name);
            if let Some(rate) = t.rate {
                assert!(rate > 0.0, "tenant {:?}: token rate must be positive", t.name);
                assert!(t.burst >= 1.0, "tenant {:?}: burst must hold at least one token", t.name);
            }
        }
    }
}

/// Ingress-tier retry policy for requests stranded by a replica crash
/// (`serving/faults.rs`). `None` at the engine level means fail-and-drop:
/// a crash kills its queued + in-flight requests with
/// `DropReason::ReplicaFailed`, and the request path is bit-identical to
/// the pre-retry engines.
///
/// Retries are deterministic: attempt `k` (1-based; the original issue is
/// attempt 1) re-enters the ingress tier after
/// `min(backoff_s · 2^(k-1), backoff_cap_s)` — no jitter, no RNG. A retry
/// whose backoff would land past `arrival + deadline_s` gives up
/// immediately with `DropReason::TimedOut`. The end-to-end latency of a
/// retried request keeps its original arrival time, so backoff gaps show
/// up in `Stage::Batching` exactly like held-at-routing time does.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts including the original issue (≥ 1; 1 = no retries).
    pub max_attempts: u32,
    /// End-to-end deadline from the request's arrival, seconds. A retry
    /// scheduled past it is dropped as timed out.
    pub deadline_s: f64,
    /// First backoff gap, seconds; doubles each further attempt.
    pub backoff_s: f64,
    /// Cap on the exponential backoff, seconds.
    pub backoff_cap_s: f64,
    /// Hedge: when a retried request is staged and a second healthy
    /// replica exists, stage a shadow copy there too; first completion
    /// wins, the loser is discarded without touching the ledgers.
    pub hedge: bool,
}

impl RetryPolicy {
    /// A plain exponential-backoff policy: no hedging, backoff capped at
    /// 16× the base gap.
    pub fn new(max_attempts: u32, deadline_s: f64, backoff_s: f64) -> Self {
        RetryPolicy {
            max_attempts,
            deadline_s,
            backoff_s,
            backoff_cap_s: backoff_s * 16.0,
            hedge: false,
        }
    }

    pub fn with_hedge(mut self) -> Self {
        self.hedge = true;
        self
    }

    /// Backoff before attempt `attempt + 1`, given `attempt` attempts
    /// already made (≥ 1): `min(backoff_s · 2^(attempt-1), cap)`.
    pub fn delay_for(&self, attempt: u32) -> f64 {
        debug_assert!(attempt >= 1, "the original issue is attempt 1");
        let exp = (attempt - 1).min(52); // past 2^52 the cap decides anyway
        (self.backoff_s * (1u64 << exp) as f64).min(self.backoff_cap_s)
    }

    /// Panic loudly on nonsense, mirroring `AdmissionConfig::validate`.
    pub fn validate(&self) {
        assert!(self.max_attempts >= 1, "retry needs at least one attempt (the original)");
        assert!(self.deadline_s > 0.0, "retry deadline_s must be positive");
        assert!(self.backoff_s >= 0.0, "retry backoff_s must be non-negative");
        assert!(
            self.backoff_cap_s >= self.backoff_s,
            "retry backoff_cap_s must be >= backoff_s"
        );
    }
}

/// Token bucket: refills continuously at `rate`, capped at `burst`. A
/// pure function of simulated time — no RNG, no wall clock.
#[derive(Debug, Clone)]
struct TokenBucket {
    tokens: f64,
    last_s: f64,
    rate: f64,
    burst: f64,
}

impl TokenBucket {
    fn unlimited() -> Self {
        TokenBucket { tokens: 0.0, last_s: 0.0, rate: f64::INFINITY, burst: 0.0 }
    }

    fn limited(rate: f64, burst: f64) -> Self {
        // Starts full: a tenant's first burst is free.
        TokenBucket { tokens: burst, last_s: 0.0, rate, burst }
    }

    /// Current token level at `now` — a pure read for the gauge
    /// timelines (`obs`): same refill arithmetic as `admit`, but the
    /// bucket state is untouched, so observing a level can never
    /// change a later admission verdict. Unlimited buckets read as
    /// infinite (the gauge layer skips them).
    fn level(&self, now: f64) -> f64 {
        if self.rate.is_infinite() {
            return f64::INFINITY;
        }
        (self.tokens + (now - self.last_s) * self.rate).min(self.burst)
    }

    /// Spend one token at `now` if available.
    fn admit(&mut self, now: f64) -> bool {
        if self.rate.is_infinite() {
            return true;
        }
        self.tokens = (self.tokens + (now - self.last_s) * self.rate).min(self.burst);
        self.last_s = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Live admission state: buckets, thresholds, and the WFQ virtual clock.
/// Built once per run from an [`AdmissionConfig`].
#[derive(Debug)]
pub(super) struct Admission {
    classes: Vec<u8>,
    weights: Vec<f64>,
    buckets: Vec<TokenBucket>,
    shed_depth: Vec<usize>,
    /// Per-tenant virtual finish tag of the last admitted request.
    last_finish: Vec<f64>,
    /// Global virtual time: advanced to each released request's finish tag
    /// (start-time fair queueing), so an idle tenant re-enters at the
    /// current clock instead of burning accumulated lag.
    virtual_t: f64,
    /// Admission-order tie-break for identical finish tags.
    seq: u64,
}

impl Admission {
    pub(super) fn new(config: &AdmissionConfig) -> Self {
        let buckets = config
            .tenants
            .iter()
            .map(|t| match t.rate {
                Some(rate) => TokenBucket::limited(rate, t.burst),
                None => TokenBucket::unlimited(),
            })
            .collect();
        Admission {
            classes: config.tenants.iter().map(|t| t.class).collect(),
            weights: config.tenants.iter().map(|t| t.weight).collect(),
            buckets,
            shed_depth: config.shed_depth.clone(),
            last_finish: vec![0.0; config.tenants.len()],
            virtual_t: 0.0,
            seq: 0,
        }
    }

    pub(super) fn n_classes(&self) -> usize {
        self.shed_depth.len()
    }

    pub(super) fn class_of(&self, tenant: usize) -> u8 {
        self.classes[tenant]
    }

    /// Tenant count — the gauge timeline's iteration bound.
    pub(super) fn n_tenants(&self) -> usize {
        self.buckets.len()
    }

    /// Pure read of tenant `tenant`'s token-bucket level at `now` (see
    /// [`TokenBucket::level`]). Infinite for unlimited tenants.
    pub(super) fn bucket_level(&self, tenant: usize, now: f64) -> f64 {
        self.buckets[tenant].level(now)
    }

    /// Admit or shed a class-tagged arrival. `in_system` is the live
    /// request count *excluding* the arrival itself. Returns the drop
    /// reason on shed, `None` on admit.
    pub(super) fn admit(&mut self, now: f64, tenant: usize, in_system: usize) -> Option<DropReason> {
        if !self.buckets[tenant].admit(now) {
            return Some(DropReason::Shed);
        }
        if in_system >= self.shed_depth[self.classes[tenant] as usize] {
            return Some(DropReason::Shed);
        }
        None
    }

    /// WFQ tag for an admitted request: start at `max(virtual_t,
    /// last_finish[tenant])`, finish one weighted quantum later.
    fn tag(&mut self, tenant: usize) -> (f64, u64) {
        let start = self.virtual_t.max(self.last_finish[tenant]);
        let finish = start + 1.0 / self.weights[tenant];
        self.last_finish[tenant] = finish;
        let seq = self.seq;
        self.seq += 1;
        (finish, seq)
    }

    /// Advance the virtual clock past a released request's tag.
    fn release(&mut self, finish: f64) {
        self.virtual_t = self.virtual_t.max(finish);
    }
}

/// One request parked at the routing tier, tagged for weighted-fair
/// release. Min-ordered by `(finish, seq)`.
#[derive(Debug, Clone, Copy)]
struct HeldEntry {
    finish: f64,
    seq: u64,
    slot: u32,
    tenant: u32,
}

impl PartialEq for HeldEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeldEntry {}
impl PartialOrd for HeldEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeldEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.finish
            .partial_cmp(&other.finish)
            .expect("NaN WFQ tag")
            .then(self.seq.cmp(&other.seq))
    }
}

/// Requests held at the routing tier of one routing domain (the cluster
/// engine's single front door, or one model of the multi-model engine).
///
/// FIFO mode is the historical held vector: `push_fifo`/`drain_fifo`
/// preserve insertion order exactly, which the golden suites pin. WFQ
/// mode releases in weighted-fair order via the shared [`Admission`]
/// virtual clock.
#[derive(Debug)]
pub(super) enum HeldQueue {
    Fifo(Vec<u32>),
    Wfq(BinaryHeap<Reverse<HeldEntry>>),
}

impl HeldQueue {
    pub(super) fn fifo() -> Self {
        HeldQueue::Fifo(Vec::new())
    }

    pub(super) fn wfq() -> Self {
        HeldQueue::Wfq(BinaryHeap::new())
    }

    pub(super) fn len(&self) -> usize {
        match self {
            HeldQueue::Fifo(v) => v.len(),
            HeldQueue::Wfq(h) => h.len(),
        }
    }

    pub(super) fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Park a request in insertion order (admission-disabled path).
    pub(super) fn push_fifo(&mut self, slot: u32) {
        match self {
            HeldQueue::Fifo(v) => v.push(slot),
            HeldQueue::Wfq(_) => panic!("push_fifo on a WFQ queue"),
        }
    }

    /// Flush every FIFO-held slot, in insertion order (admission-disabled
    /// path — the caller re-pushes them as enqueue events, exactly like
    /// the pre-ingress engines did).
    pub(super) fn drain_fifo(&mut self) -> std::vec::Drain<'_, u32> {
        match self {
            HeldQueue::Fifo(v) => v.drain(..),
            HeldQueue::Wfq(_) => panic!("drain_fifo on a WFQ queue"),
        }
    }

    /// Park a request with a weighted-fair tag from the admission tier.
    pub(super) fn push_wfq(&mut self, admission: &mut Admission, tenant: usize, slot: u32) {
        match self {
            HeldQueue::Wfq(h) => {
                let (finish, seq) = admission.tag(tenant);
                h.push(Reverse(HeldEntry { finish, seq, slot, tenant: tenant as u32 }));
            }
            HeldQueue::Fifo(_) => panic!("push_wfq on a FIFO queue"),
        }
    }

    /// Release the weighted-fair head, advancing the shared virtual clock.
    pub(super) fn pop_wfq(&mut self, admission: &mut Admission) -> Option<(u32, u32)> {
        match self {
            HeldQueue::Wfq(h) => h.pop().map(|Reverse(e)| {
                admission.release(e.finish);
                (e.slot, e.tenant)
            }),
            HeldQueue::Fifo(_) => panic!("pop_wfq on a FIFO queue"),
        }
    }

    /// Remove every held request, in queue order, as `(slot, tenant)`
    /// pairs — the eviction/teardown path (the multi-model engine drops
    /// stranded holds when their model loses its last placement).
    pub(super) fn drain_all(&mut self) -> Vec<(u32, u32)> {
        match self {
            HeldQueue::Fifo(v) => v.drain(..).map(|slot| (slot, 0)).collect(),
            HeldQueue::Wfq(h) => {
                let mut entries: Vec<HeldEntry> = h.drain().map(|Reverse(e)| e).collect();
                entries.sort();
                entries.into_iter().map(|e| (e.slot, e.tenant)).collect()
            }
        }
    }
}

/// Stage a request into a replica's batch queue — the shared tail of the
/// ingress path. Time the request spent parked (anything past its last
/// probe) is charged to [`Stage::Batching`], then the batcher takes it;
/// the batcher is polled only when the server is idle (a busy server
/// polls itself at the next `ServerFree`). Both engines call this for
/// every admitted request; the caller owns the queue counters and acts
/// on the returned [`Decision`].
pub(super) fn stage_into_batcher(
    trace: &mut RequestTrace,
    batcher: &mut Batcher,
    slot: u32,
    now: f64,
    busy: bool,
) -> Decision {
    if now > trace.completed_s {
        trace.record_stage(Stage::Batching, now - trace.completed_s);
    }
    batcher.enqueue(slot as u64, now);
    if busy {
        Decision::Wait
    } else {
        batcher.poll(now)
    }
}

/// Drop a request with a [`DropReason`], ingesting it into each sink in
/// order. The order is the engine's canonical ledger order (e.g. replica
/// → model → cluster) and must stay stable: the golden suites compare
/// collector state after every drop.
pub(super) fn drop_trace<'a>(
    trace: &mut RequestTrace,
    reason: DropReason,
    sinks: impl IntoIterator<Item = &'a mut Collector>,
) {
    trace.drop_with(reason);
    for sink in sinks {
        sink.ingest(trace);
    }
}

/// Ingest a finished/dropped trace into its class ledger. No-op when the
/// admission tier is off (`classes` is empty and every trace carries the
/// default class 0), so both engines call it unconditionally.
pub(super) fn class_ingest(classes: &mut [ClassMetrics], trace: &RequestTrace) {
    if let Some(cm) = classes.get_mut(trace.class as usize) {
        cm.collector.ingest(trace);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn three_tier() -> AdmissionConfig {
        AdmissionConfig {
            tenants: vec![
                TenantSpec::new("gold").with_class(0).with_weight(4.0),
                TenantSpec::new("silver").with_class(1).with_weight(2.0),
                TenantSpec::new("bronze").with_class(2).with_rate(10.0, 2.0),
            ],
            shed_depth: vec![300, 100, 30],
        }
    }

    #[test]
    fn config_validates_matching_shape() {
        let cfg = three_tier();
        cfg.validate(3);
        assert_eq!(cfg.n_classes(), 3);
    }

    #[test]
    #[should_panic(expected = "admission defines 3 tenants but the workload has 2 streams")]
    fn config_rejects_tenant_count_mismatch() {
        three_tier().validate(2);
    }

    #[test]
    #[should_panic(expected = "only 1 shed depths are configured")]
    fn config_rejects_class_without_depth() {
        let cfg = AdmissionConfig {
            tenants: vec![TenantSpec::new("t").with_class(1)],
            shed_depth: vec![10],
        };
        cfg.validate(1);
    }

    #[test]
    #[should_panic(expected = "burst must hold at least one token")]
    fn config_rejects_fractional_burst() {
        let cfg = AdmissionConfig {
            tenants: vec![TenantSpec::new("t").with_rate(5.0, 0.5)],
            shed_depth: vec![10],
        };
        cfg.validate(1);
    }

    #[test]
    fn from_streams_carries_the_workload_tags() {
        let streams = vec![
            StreamSpec::new("gold", crate::workload::Pattern::Poisson { rate: 10.0 })
                .with_qos(0, 4.0),
            StreamSpec::new("bronze", crate::workload::Pattern::Poisson { rate: 10.0 })
                .with_qos(2, 1.0),
        ];
        let cfg = AdmissionConfig::from_streams(&streams, vec![300, 100, 30]);
        cfg.validate(2);
        assert_eq!(cfg.tenants[0].name, "gold");
        assert_eq!(cfg.tenants[0].class, 0);
        assert_eq!(cfg.tenants[0].weight, 4.0);
        assert_eq!(cfg.tenants[1].class, 2);
        assert!(cfg.tenants.iter().all(|t| t.rate.is_none()), "derived tenants are unlimited");
    }

    #[test]
    fn token_bucket_refills_with_simulated_time() {
        let cfg = AdmissionConfig {
            tenants: vec![TenantSpec::new("t").with_rate(10.0, 2.0)],
            shed_depth: vec![1000],
        };
        let mut adm = Admission::new(&cfg);
        // Bucket starts full (2 tokens), then refills at 10/s.
        assert_eq!(adm.admit(0.0, 0, 0), None);
        assert_eq!(adm.admit(0.0, 0, 0), None);
        assert_eq!(adm.admit(0.0, 0, 0), Some(DropReason::Shed), "bucket exhausted");
        // 0.1 s later one token has refilled.
        assert_eq!(adm.admit(0.1, 0, 0), None);
        assert_eq!(adm.admit(0.1, 0, 0), Some(DropReason::Shed));
        // A long idle stretch caps at burst, not unbounded credit.
        assert_eq!(adm.admit(100.0, 0, 0), None);
        assert_eq!(adm.admit(100.0, 0, 0), None);
        assert_eq!(adm.admit(100.0, 0, 0), Some(DropReason::Shed));
    }

    #[test]
    fn bucket_level_is_a_pure_read() {
        let cfg = AdmissionConfig {
            tenants: vec![
                TenantSpec::new("limited").with_rate(10.0, 2.0),
                TenantSpec::new("unlimited"),
            ],
            shed_depth: vec![1000],
        };
        let mut adm = Admission::new(&cfg);
        assert_eq!(adm.n_tenants(), 2);
        assert_eq!(adm.bucket_level(0, 0.0), 2.0, "bucket starts full");
        assert!(adm.bucket_level(1, 0.0).is_infinite());
        // Observing the level must never change a later verdict.
        for _ in 0..10 {
            let _ = adm.bucket_level(0, 0.0);
        }
        assert_eq!(adm.admit(0.0, 0, 0), None);
        assert_eq!(adm.admit(0.0, 0, 0), None);
        assert_eq!(adm.admit(0.0, 0, 0), Some(DropReason::Shed));
        // And the level tracks refill between observations.
        assert_eq!(adm.bucket_level(0, 0.05), 0.5);
    }

    #[test]
    fn class_shed_is_lowest_class_first() {
        let mut adm = Admission::new(&three_tier());
        // Backlog 30: bronze (class 2, depth 30) sheds, silver and gold
        // do not.
        assert_eq!(adm.admit(1.0, 2, 30), Some(DropReason::Shed));
        assert_eq!(adm.admit(1.0, 1, 30), None);
        assert_eq!(adm.admit(1.0, 0, 30), None);
        // Backlog 100: silver sheds too; gold survives until 300.
        assert_eq!(adm.admit(1.0, 1, 100), Some(DropReason::Shed));
        assert_eq!(adm.admit(1.0, 0, 100), None);
        assert_eq!(adm.admit(1.0, 0, 299), None);
        assert_eq!(adm.admit(1.0, 0, 300), Some(DropReason::Shed));
    }

    #[test]
    fn wfq_interleaves_by_weight() {
        // Two backlogged tenants with weights 2 and 1: releases should
        // interleave 2:1, not starve either.
        let cfg = AdmissionConfig {
            tenants: vec![
                TenantSpec::new("heavy").with_weight(2.0),
                TenantSpec::new("light").with_weight(1.0),
            ],
            shed_depth: vec![1000],
        };
        let mut adm = Admission::new(&cfg);
        let mut q = HeldQueue::wfq();
        // Six from the heavy tenant (slots 0..6), three from the light
        // (slots 10..13), all parked before anything releases.
        for slot in 0..6 {
            q.push_wfq(&mut adm, 0, slot);
        }
        for slot in 10..13 {
            q.push_wfq(&mut adm, 1, slot);
        }
        let order: Vec<(u32, u32)> = std::iter::from_fn(|| q.pop_wfq(&mut adm)).collect();
        assert_eq!(order.len(), 9);
        // Finish tags: heavy at 0.5, 1.0, ... 3.0; light at 1.0, 2.0, 3.0
        // — ties break by admission order (heavy was parked first).
        let tenants: Vec<u32> = order.iter().map(|&(_, t)| t).collect();
        assert_eq!(tenants, vec![0, 0, 1, 0, 0, 1, 0, 0, 1]);
        // Within a tenant, releases keep arrival order.
        let heavy: Vec<u32> =
            order.iter().filter(|&&(_, t)| t == 0).map(|&(s, _)| s).collect();
        assert_eq!(heavy, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn wfq_idle_tenant_rejoins_at_current_clock() {
        // A tenant that was idle while others drained must not have
        // banked credit: its next request tags at the live virtual time.
        let cfg = AdmissionConfig {
            tenants: vec![
                TenantSpec::new("busy").with_weight(1.0),
                TenantSpec::new("idle").with_weight(1.0),
            ],
            shed_depth: vec![1000],
        };
        let mut adm = Admission::new(&cfg);
        let mut q = HeldQueue::wfq();
        for slot in 0..4 {
            q.push_wfq(&mut adm, 0, slot);
            let released = q.pop_wfq(&mut adm);
            assert_eq!(released, Some((slot, 0)));
        }
        // Virtual clock sits at 4.0; the idle tenant joins at 5.0, the
        // busy tenant's next would also be 5.0 — fair interleave resumes
        // instead of the idle tenant draining 4 in a row.
        q.push_wfq(&mut adm, 1, 100);
        q.push_wfq(&mut adm, 0, 101);
        q.push_wfq(&mut adm, 1, 102);
        let next: Vec<(u32, u32)> = std::iter::from_fn(|| q.pop_wfq(&mut adm)).collect();
        assert_eq!(next, vec![(100, 1), (101, 0), (102, 1)]);
    }

    #[test]
    fn fifo_queue_preserves_insertion_order() {
        let mut q = HeldQueue::fifo();
        assert!(q.is_empty());
        for slot in [5u32, 3, 9] {
            q.push_fifo(slot);
        }
        assert_eq!(q.len(), 3);
        let flushed: Vec<u32> = q.drain_fifo().collect();
        assert_eq!(flushed, vec![5, 3, 9]);
        assert!(q.is_empty());
    }

    #[test]
    fn drain_all_orders_by_queue_discipline() {
        let mut fifo = HeldQueue::fifo();
        fifo.push_fifo(7);
        fifo.push_fifo(2);
        assert_eq!(fifo.drain_all(), vec![(7, 0), (2, 0)]);

        let cfg = AdmissionConfig {
            tenants: vec![
                TenantSpec::new("a").with_weight(1.0),
                TenantSpec::new("b").with_weight(10.0),
            ],
            shed_depth: vec![100],
        };
        let mut adm = Admission::new(&cfg);
        let mut wfq = HeldQueue::wfq();
        wfq.push_wfq(&mut adm, 0, 1); // finish 1.0
        wfq.push_wfq(&mut adm, 1, 2); // finish 0.1 — drains first
        assert_eq!(wfq.drain_all(), vec![(2, 1), (1, 0)]);
        assert!(wfq.is_empty());
    }

    #[test]
    fn retry_backoff_doubles_and_caps() {
        let pol = RetryPolicy::new(6, 10.0, 0.05);
        pol.validate();
        assert_eq!(pol.delay_for(1), 0.05);
        assert_eq!(pol.delay_for(2), 0.10);
        assert_eq!(pol.delay_for(3), 0.20);
        assert_eq!(pol.delay_for(5), 0.80, "exact doubling: powers of two are exact in f64");
        // Cap: 16× base = 0.8, so attempt 6+ stays put.
        assert_eq!(pol.delay_for(6), 0.80);
        assert_eq!(pol.delay_for(60), 0.80, "huge attempt counts saturate, no overflow");
        // Deterministic: same inputs, same bits.
        assert_eq!(pol.delay_for(4).to_bits(), pol.delay_for(4).to_bits());
        assert!(!pol.hedge);
        assert!(RetryPolicy::new(3, 1.0, 0.01).with_hedge().hedge);
    }

    #[test]
    #[should_panic(expected = "at least one attempt")]
    fn retry_rejects_zero_attempts() {
        RetryPolicy { max_attempts: 0, deadline_s: 1.0, backoff_s: 0.0, backoff_cap_s: 0.0, hedge: false }
            .validate();
    }

    #[test]
    fn drop_trace_ingests_every_sink_in_order() {
        let mut a = Collector::new();
        let mut b = Collector::new();
        let mut t = RequestTrace::new(0, 1.0);
        drop_trace(&mut t, DropReason::EvictedBacklog, [&mut a, &mut b]);
        assert!(t.dropped);
        for c in [&a, &b] {
            assert_eq!(c.dropped, 1);
            assert_eq!(c.dropped_by(DropReason::EvictedBacklog), 1);
            assert!(c.drops_conserved());
        }
    }

    #[test]
    fn stage_into_batcher_charges_hold_time() {
        use crate::serving::batcher::Policy;
        let mut batcher = Batcher::new(Policy::Single);
        let mut t = RequestTrace::new(0, 1.0);
        t.record_stage(Stage::PreProcess, 0.5); // completed_s = 1.5
        // Held until t = 2.0: the 0.5 s gap lands in Stage::Batching.
        let d = stage_into_batcher(&mut t, &mut batcher, 0, 2.0, false);
        assert_eq!(t.stage_s(Stage::Batching), Some(0.5));
        assert!(matches!(d, Decision::Dispatch(1)));
        // A busy server defers the poll.
        let mut t2 = RequestTrace::new(1, 2.0);
        let mut b2 = Batcher::new(Policy::Single);
        let d2 = stage_into_batcher(&mut t2, &mut b2, 1, 2.0, true);
        assert!(matches!(d2, Decision::Wait));
        assert_eq!(t2.stage_s(Stage::Batching), None, "no hold, no charge");
    }
}
