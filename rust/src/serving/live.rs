//! Live serving engine: the real thing, on the CPU platform (C1).
//!
//! Three threads — clients -> batcher -> executor — wired with channels.
//! The batcher runs the same [`Batcher`] policy logic the simulator uses,
//! but against the wall clock; the executor owns the PJRT engine (PJRT
//! handles are not Send, so all XLA objects live on that one thread) and
//! executes real AOT-compiled artifacts. Used by the e2e example and by
//! the benches that anchor the CPU columns with measured latencies.
//!
//! Batch-size handling: artifacts are compiled at fixed batch shapes
//! (b1/b4/b8); a formed batch of size n runs on the smallest variant with
//! batch >= n, zero-padded — exactly what TFS does with its
//! `allowed_batch_sizes`.

use super::batcher::{Batcher, Decision, Policy};
use crate::runtime::{Engine, LoadedModel};
use crate::util::stats::Summary;
use anyhow::{anyhow, bail, Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Configuration for a live server.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub artifact_dir: PathBuf,
    /// Model stem, e.g. "resnet_mini" — all `<stem>_b*` variants load.
    pub model_stem: String,
    pub policy: Policy,
    /// Seed for the generated model parameters.
    pub seed: u64,
}

/// One in-flight request.
struct LiveRequest {
    id: u64,
    x: Vec<f32>,
    submitted: Instant,
    reply: mpsc::Sender<LiveResponse>,
}

/// Completed-request report.
#[derive(Debug, Clone)]
pub struct LiveResponse {
    pub id: u64,
    /// argmax of the logits (the "prediction").
    pub predicted_class: usize,
    /// Requests in the executed batch.
    pub batch_size: usize,
    /// Time from submit to batch formation.
    pub queue_s: f64,
    /// XLA execution time of the batch.
    pub infer_s: f64,
    /// Submit -> reply.
    pub e2e_s: f64,
}

/// Info reported once the executor has loaded all variants.
#[derive(Debug, Clone)]
pub struct ServerInfo {
    /// (batch size, XLA compile seconds) per loaded variant — the measured
    /// cold-start component (Fig 14c).
    pub variants: Vec<(usize, f64)>,
    /// Elements per request input.
    pub x_elements: usize,
}

enum BatcherMsg {
    Request(LiveRequest),
    Shutdown,
}

struct BatchJob {
    requests: Vec<(LiveRequest, f64)>, // (request, queue seconds)
}

/// A running live server.
pub struct LiveServer {
    tx: mpsc::Sender<BatcherMsg>,
    batcher_handle: Option<std::thread::JoinHandle<()>>,
    exec_handle: Option<std::thread::JoinHandle<Result<()>>>,
    pub info: ServerInfo,
    next_id: std::sync::atomic::AtomicU64,
}

impl LiveServer {
    /// Start the server: loads every `<stem>_b*` artifact on the executor
    /// thread and blocks until ready.
    pub fn start(config: LiveConfig) -> Result<LiveServer> {
        let (req_tx, req_rx) = mpsc::channel::<BatcherMsg>();
        let (batch_tx, batch_rx) = mpsc::sync_channel::<Option<BatchJob>>(64);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<ServerInfo>>();

        let cfg = config.clone();
        let exec_handle = std::thread::Builder::new()
            .name("inferbench-executor".into())
            .spawn(move || executor_thread(cfg, batch_rx, ready_tx))
            .context("spawning executor")?;

        let info = ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))??;

        let policy = config.policy;
        let batcher_handle = std::thread::Builder::new()
            .name("inferbench-batcher".into())
            .spawn(move || batcher_thread(policy, req_rx, batch_tx))
            .context("spawning batcher")?;

        Ok(LiveServer {
            tx: req_tx,
            batcher_handle: Some(batcher_handle),
            exec_handle: Some(exec_handle),
            info,
            next_id: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Submit a request; the response arrives on `reply`.
    pub fn submit(&self, x: Vec<f32>, reply: mpsc::Sender<LiveResponse>) -> Result<u64> {
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.tx
            .send(BatcherMsg::Request(LiveRequest { id, x, submitted: Instant::now(), reply }))
            .map_err(|_| anyhow!("server stopped"))?;
        Ok(id)
    }

    /// Graceful shutdown: drains queues, joins threads.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(BatcherMsg::Shutdown);
        if let Some(h) = self.batcher_handle.take() {
            h.join().map_err(|_| anyhow!("batcher panicked"))?;
        }
        if let Some(h) = self.exec_handle.take() {
            h.join().map_err(|_| anyhow!("executor panicked"))??;
        }
        Ok(())
    }
}

impl Drop for LiveServer {
    fn drop(&mut self) {
        let _ = self.tx.send(BatcherMsg::Shutdown);
    }
}

fn batcher_thread(
    policy: Policy,
    rx: mpsc::Receiver<BatcherMsg>,
    batch_tx: mpsc::SyncSender<Option<BatchJob>>,
) {
    let start = Instant::now();
    let now_s = || start.elapsed().as_secs_f64();
    let mut batcher = Batcher::new(policy);
    let mut pending: std::collections::HashMap<u64, LiveRequest> = Default::default();
    let mut wake_at: Option<f64> = None;

    // The batch slice borrows the batcher's reusable buffer (the
    // decide/dispatch cycle allocates nothing per batch — §Perf, PERF.md).
    let dispatch = |batch: &[super::batcher::Queued],
                    pending: &mut std::collections::HashMap<u64, LiveRequest>,
                    t: f64| {
        let requests: Vec<(LiveRequest, f64)> = batch
            .iter()
            .filter_map(|q| pending.remove(&q.id).map(|r| (r, t - q.enqueue_s)))
            .collect();
        if !requests.is_empty() {
            let _ = batch_tx.send(Some(BatchJob { requests }));
        }
    };

    loop {
        let timeout = match wake_at {
            Some(t) => Duration::from_secs_f64((t - now_s()).max(0.0)),
            None => Duration::from_millis(200),
        };
        match rx.recv_timeout(timeout) {
            Ok(BatcherMsg::Request(req)) => {
                let t = now_s();
                let id = req.id;
                pending.insert(id, req);
                match batcher.on_arrival(id, t) {
                    Decision::Dispatch(_) => {
                        wake_at = None;
                        dispatch(batcher.ready(), &mut pending, now_s());
                    }
                    Decision::WakeAt(t) => wake_at = Some(t),
                    Decision::Wait => {}
                }
            }
            Ok(BatcherMsg::Shutdown) => break,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if wake_at.map_or(false, |t| now_s() >= t) {
                    wake_at = None;
                    match batcher.on_wake(now_s()) {
                        Decision::Dispatch(_) => dispatch(batcher.ready(), &mut pending, now_s()),
                        // Stale wake: the batch it was armed for already
                        // dispatched; re-arm for the corrected deadline.
                        Decision::WakeAt(t) => wake_at = Some(t),
                        Decision::Wait => {}
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    // Drain what's left as one final flush.
    if let Decision::Dispatch(_) = batcher.on_wake(now_s() + 1e9) {
        dispatch(batcher.ready(), &mut pending, now_s());
    }
    let _ = batch_tx.send(None); // executor shutdown signal
}

fn executor_thread(
    config: LiveConfig,
    batch_rx: mpsc::Receiver<Option<BatchJob>>,
    ready_tx: mpsc::Sender<Result<ServerInfo>>,
) -> Result<()> {
    // Load everything; report readiness (or the error) to the caller.
    let setup = (|| -> Result<(Vec<LoadedModel>, ServerInfo)> {
        let engine = Engine::cpu(&config.artifact_dir)?;
        let names: Vec<String> = engine
            .manifest
            .variants_of(&format!("{}_b", config.model_stem))
            .iter()
            .map(|e| e.name.clone())
            .collect();
        if names.is_empty() {
            bail!("no artifacts match stem {:?}", config.model_stem);
        }
        let mut variants = Vec::new();
        for n in &names {
            variants.push(engine.load(n, config.seed)?);
        }
        variants.sort_by_key(|m| m.batch());
        let info = ServerInfo {
            variants: variants
                .iter()
                .map(|m| (m.batch(), m.compile_time.as_secs_f64()))
                .collect(),
            x_elements: variants[0].x_elements() / variants[0].batch(),
        };
        Ok((variants, info))
    })();

    let (variants, info) = match setup {
        Ok(ok) => ok,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return Ok(());
        }
    };
    let per_sample = info.x_elements;

    // Warm every variant (first execution pays allocator/pool setup that
    // would otherwise land in a request's tail) and measure its steady
    // cost; then precompute, for every batch size n, the cost-minimal
    // decomposition into variant runs (a batch of 2 on a 4x-cost b4
    // artifact is often worse than two b1 runs). Warmup happens BEFORE
    // the ready signal so no request ever queues behind it. §Perf.
    let mut costs = Vec::with_capacity(variants.len());
    for m in &variants {
        let x = vec![0f32; m.batch() * per_sample];
        let _ = m.infer(&x);
        let t0 = Instant::now();
        let _ = m.infer(&x);
        costs.push(t0.elapsed().as_secs_f64());
    }
    let _ = ready_tx.send(Ok(info.clone()));
    let max_n = variants.last().map(|m| m.batch()).unwrap_or(1).max(
        variants.iter().map(|m| m.batch()).max().unwrap_or(1),
    );
    // plan[n] = sequence of variant indices covering n requests at min cost.
    let mut best_cost = vec![0.0f64; max_n + 1];
    let mut best_choice = vec![usize::MAX; max_n + 1];
    for n in 1..=max_n {
        best_cost[n] = f64::INFINITY;
        for (vi, m) in variants.iter().enumerate() {
            let covered = m.batch().min(n);
            let c = costs[vi] + best_cost[n - covered];
            if c < best_cost[n] {
                best_cost[n] = c;
                best_choice[n] = vi;
            }
        }
    }
    let plan_for = |mut n: usize| -> Vec<usize> {
        let mut plan = Vec::new();
        while n > 0 {
            let vi = best_choice[n.min(max_n)];
            plan.push(vi);
            n -= variants[vi].batch().min(n);
        }
        plan
    };

    while let Ok(Some(job)) = batch_rx.recv() {
        let n = job.requests.len();
        let plan = plan_for(n);
        let mut offset = 0usize;
        for vi in plan {
            let model = &variants[vi];
            let cap = model.batch();
            let chunk = &job.requests[offset..(offset + cap).min(n)];
            offset += chunk.len();
            let mut x = vec![0f32; cap * per_sample];
            for (i, (req, _)) in chunk.iter().enumerate() {
                let len = req.x.len().min(per_sample);
                x[i * per_sample..i * per_sample + len].copy_from_slice(&req.x[..len]);
            }
            let t0 = Instant::now();
            let out = model.infer(&x);
            let infer_s = t0.elapsed().as_secs_f64();
            match out {
                Ok(logits) => {
                    let classes = logits.len() / cap;
                    for (i, (req, queue_s)) in chunk.iter().enumerate() {
                        let row = &logits[i * classes..(i + 1) * classes];
                        let predicted_class = row
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .map(|(i, _)| i)
                            .unwrap_or(0);
                        let _ = req.reply.send(LiveResponse {
                            id: req.id,
                            predicted_class,
                            batch_size: chunk.len(),
                            queue_s: *queue_s,
                            infer_s,
                            e2e_s: req.submitted.elapsed().as_secs_f64(),
                        });
                    }
                }
                Err(e) => {
                    // Report failure by dropping reply senders (clients see
                    // a disconnect); log to stderr for diagnosis.
                    eprintln!("executor: inference failed: {e:#}");
                }
            }
        }
    }
    Ok(())
}

/// Load-test summary from [`run_load`].
#[derive(Debug)]
pub struct LoadReport {
    pub e2e: Summary,
    pub queue: Summary,
    pub infer: Summary,
    pub batch_sizes: Summary,
    pub completed: u64,
    pub wall_s: f64,
}

impl LoadReport {
    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.wall_s
    }
}

/// Drive a live server with Poisson-ish open-loop load from this thread,
/// collecting every response. Inter-arrival gaps are exponential; sleeps
/// are wall-clock so measured latencies are real.
pub fn run_load(server: &LiveServer, rate_rps: f64, duration_s: f64, seed: u64) -> Result<LoadReport> {
    use crate::util::rng::Pcg64;
    let mut rng = Pcg64::seeded(seed);
    let (reply_tx, reply_rx) = mpsc::channel();
    let start = Instant::now();
    let mut sent = 0u64;
    let mut t_next = rng.exponential(rate_rps);
    while start.elapsed().as_secs_f64() < duration_s {
        let now = start.elapsed().as_secs_f64();
        if now < t_next {
            std::thread::sleep(Duration::from_secs_f64((t_next - now).min(0.05)));
            continue;
        }
        let x = rng.f32_vec(server.info.x_elements, 1.0);
        server.submit(x, reply_tx.clone())?;
        sent += 1;
        t_next += rng.exponential(rate_rps);
    }
    drop(reply_tx);

    let mut report = LoadReport {
        e2e: Summary::new(),
        queue: Summary::new(),
        infer: Summary::new(),
        batch_sizes: Summary::new(),
        completed: 0,
        wall_s: 0.0,
    };
    // Collect replies (executor may still be draining).
    let deadline = Instant::now() + Duration::from_secs(30);
    while report.completed < sent && Instant::now() < deadline {
        match reply_rx.recv_timeout(Duration::from_millis(500)) {
            Ok(r) => {
                report.completed += 1;
                report.e2e.record(r.e2e_s);
                report.queue.record(r.queue_s);
                report.infer.record(r.infer_s);
                report.batch_sizes.record(r.batch_size as f64);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    report.wall_s = start.elapsed().as_secs_f64();
    Ok(report)
}

// Integration tests for the live engine live in rust/tests/ (they need
// real artifacts from `make artifacts`).
