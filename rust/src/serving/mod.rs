//! Tier-2 serving layer: software profiles, batching policies, service-time
//! models, the discrete-event pipeline simulator, and the live CPU engine.
//!
//! The *control flow* (batcher decisions, queueing) is shared between the
//! simulator (`sim`, used for the GPU platforms and long workloads) and
//! the live engine (`live`, real XLA execution on the CPU platform), so
//! simulated results exercise the same code the real server runs.

pub mod backends;
pub mod batcher;
pub mod service;
pub mod live;
pub mod sim;

pub use backends::{DynamicBatching, Software};
pub use batcher::{Batcher, Decision, Policy};
pub use service::ServiceModel;
pub use sim::{run, SimConfig, SimResult};
