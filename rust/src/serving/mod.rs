//! Tier-2 serving layer: software profiles, batching policies, service-time
//! models, the discrete-event pipeline simulator, the N-replica cluster
//! engine with pluggable request routing, and the live CPU engine.
//!
//! The *control flow* (batcher decisions, queueing) is shared between the
//! simulator (`sim`, used for the GPU platforms and long workloads) and
//! the live engine (`live`, real XLA execution on the CPU platform), so
//! simulated results exercise the same code the real server runs.
//!
//! Scale-out structure: `cluster` simulates N replicas — each with its own
//! [`Batcher`] + [`ServiceModel`] + [`Software`], heterogeneous mixes
//! allowed — behind a `router` (round-robin, least-outstanding, seeded
//! power-of-two-choices, or latency-aware EWMA over sampled signals).
//! `sim::run` is the N=1 special case and delegates to it. The fleet is
//! elastic when an `autoscale` policy is attached: scale-up pays the
//! software's cold start before taking traffic; scale-down drains the
//! replica before retiring it (no request lost at a scale event).
//!
//! Multi-model structure: `multimodel` hosts several models per replica —
//! per-model batchers and queues behind a model-aware `ModelRouter`,
//! under a per-replica weight-memory budget (loads pay cold starts,
//! overflowing placements evict idle co-tenants or are rejected) and an
//! MPS-style contention multiplier derived from `hardware::sharing` (the
//! paper's Sharing-versus-Dedicate study, event-driven).
//!
//! Ingress structure: both engines stage every request through the shared
//! `ingress` tier — `admit (token bucket + class shed) → route →
//! hold/flush → batch`. With an [`AdmissionConfig`] attached, tenants
//! (tagged workload streams in `cluster`, models in `multimodel`) get
//! token-bucket rate limits, priority classes that shed
//! lowest-class-first under overload, and — where tenants share one
//! routing domain — weighted-fair release of held requests. The tier is
//! RNG-free, so determinism is untouched; `admission: None` keeps the
//! request path bit-identical to the pre-ingress engines (pinned by the
//! golden suites at 1/2/8 sweep threads). Per-class ledgers land in
//! `metrics::ClassMetrics` with exact conservation and a
//! per-[`DropReason`](crate::metrics::DropReason) breakdown; see
//! `benches/fig_qos.rs` for the overload study.
//!
//! Fault structure: a [`FaultPlan`] (`faults`) injects deterministic
//! replica crashes, recoveries-through-cold-start and straggler
//! slowdowns into both engines. Crashed replicas leave the routable set
//! instantly and their queued + in-flight requests either die (new
//! `ReplicaFailed`/`TimedOut` drop reasons, same exact conservation) or
//! re-enter the ingress tier under a [`RetryPolicy`] with deterministic
//! exponential backoff and optional hedged shadow attempts; the
//! autoscaler sees crash-induced capacity loss as scale-up pressure.
//! Fault schedules draw from PCG streams disjoint from the workload and
//! routing streams, so `faults: None` (or `FaultPlan::none()`) is
//! bit-identical to the pre-fault engines — pinned by `tests/faults.rs`
//! at 1/2/8 sweep threads; see `benches/fig_faults.rs` for the
//! availability study.
//!
//! The DES request lifecycle is allocation-free at steady state and its
//! throughput (simulated requests/sec) is tracked per PR — see PERF.md
//! and `benches/l4_des_throughput.rs`.

pub mod autoscale;
pub mod backends;
pub mod batcher;
pub mod cluster;
mod des;
pub mod faults;
pub mod ingress;
pub mod live;
pub mod multimodel;
pub mod router;
pub mod service;
pub mod sim;

pub use autoscale::{AutoscaleConfig, Autoscaler, ScaleDecision, ScalePolicy, ScaleSignal};
pub use backends::{DynamicBatching, Software};
pub use batcher::{Batcher, Decision, Policy};
pub use cluster::{ClusterConfig, ClusterResult, ReplicaConfig};
pub use faults::{DegradeProfile, FaultOp, FaultPlan, FaultProfile};
pub use ingress::{AdmissionConfig, RetryPolicy, TenantSpec};
pub use multimodel::{
    ContentionModel, ModelSpec, MultiModelConfig, MultiModelResult, MultiReplicaConfig,
    PlacementOp,
};
pub use router::{ModelRouter, Router, RouterPolicy};
pub use service::ServiceModel;
pub use sim::{run, SimConfig, SimResult};
